"""Spotlight parallel loading (§III-D) + latency-preference sweep (§III-A).

Shows the two knobs a deployment actually turns:
  1. z parallel partitioner instances with a restricted spread,
  2. the latency preference L trading partitioning time for quality.

    PYTHONPATH=src python examples/parallel_loading.py
"""
import numpy as np

from repro.core import AdwiseConfig, partition_stream, spotlight_partition
from repro.graph import make_graph, replica_sets_from_assignment, replication_degree


def rd_of(edges, n, k, assign):
    return replication_degree(replica_sets_from_assignment(edges, assign, n, k))


def main():
    edges, n = make_graph("web_like", seed=0, scale=0.03)
    k, z = 32, 8
    print(f"graph: |V|={n} |E|={len(edges)}; k={k}, z={z} parallel loaders\n")

    print("spotlight spread sweep (hdrf under the hood):")
    for spread in (32, 16, 8, 4):
        res = spotlight_partition(edges, n, k, z=z, spread=spread, strategy="hdrf")
        print(f"  spread={spread:2d}  RD={rd_of(edges, n, k, res.assign):.3f}")

    print("\nADWISE latency-preference sweep (single instance):")
    base = partition_stream(edges, n, AdwiseConfig(k=k, window_max=1,
                                                   window_init=1, adapt=False))
    t1 = base.stats["wall_time_s"]
    print(f"  single-edge baseline: RD={rd_of(edges, n, k, base.assign):.3f} "
          f"({t1:.2f}s)")
    for mult in (2, 4, 8):
        cfg = AdwiseConfig(k=k, window_max=256, latency_budget=t1 * mult)
        res = partition_stream(edges, n, cfg)
        print(f"  L={mult}x single-edge: RD={rd_of(edges, n, k, res.assign):.3f} "
              f"({res.stats['wall_time_s']:.2f}s, final w={res.stats['final_w']})")


if __name__ == "__main__":
    main()
