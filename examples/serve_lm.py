"""Serving example: batched prefill + greedy decode on three families.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main


def main():
    for arch in ("qwen1.5-0.5b", "rwkv6-7b", "zamba2-7b"):
        print(f"\n--- {arch} (reduced config) ---")
        serve_main(["--arch", arch, "--reduced", "--batch", "2",
                    "--prompt-len", "16", "--gen", "8"])


if __name__ == "__main__":
    main()
