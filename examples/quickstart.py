"""Quickstart: the paper in one script.

Partition a clustered graph with ADWISE (windowed, adaptive) and with the
single-edge baselines, run PageRank on the vertex-cut engine, and compare
total latency = partitioning + modeled cluster processing.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import AdwiseConfig, dbh_partition, hdrf_partition, partition_stream
from repro.engine import PAPER_CLUSTER, build_partitioned_graph, pagerank, process_latency
from repro.graph import make_graph, replica_sets_from_assignment, replication_degree


def main():
    edges, n = make_graph("brain_like", seed=0, scale=0.02)
    k = 32
    print(f"graph: |V|={n} |E|={len(edges)}, k={k} partitions\n")

    runs = {
        "dbh": lambda: dbh_partition(edges, n, k),
        "hdrf": lambda: hdrf_partition(edges, n, k),
        "adwise(w<=256)": lambda: partition_stream(
            edges, n, AdwiseConfig(k=k, window_max=256)),
    }
    print(f"{'strategy':16s} {'RD':>6s} {'partition_s':>11s} "
          f"{'process_s':>10s} {'total_s':>8s}")
    for name, fn in runs.items():
        res = fn()
        rd = replication_degree(replica_sets_from_assignment(edges, res.assign, n, k))
        g = build_partitioned_graph(edges, res.assign, n, k)
        pr, info = pagerank(g, iters=5)  # correctness-checked engine run
        model = process_latency(g, 300, 1, PAPER_CLUSTER)  # 300 iterations
        total = res.stats["wall_time_s"] + model["t_total_s"]
        print(f"{name:16s} {rd:6.3f} {res.stats['wall_time_s']:11.2f} "
              f"{model['t_total_s']:10.2f} {total:8.2f}")
    print("\nADWISE invests partitioning latency to cut replication degree — "
          "the paper's total-latency trade (Fig. 7).")


if __name__ == "__main__":
    main()
