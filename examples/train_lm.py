"""End-to-end training driver.

Default: the reduced qwen1.5-0.5b family config for a quick CPU run with
checkpoint/restart + failure injection exercised. `--full-small` trains the
real qwen1.5-0.5b (~460M params) — sized for a real accelerator.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --steps 300      # longer run
"""
import argparse
import tempfile

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full-small", action="store_true",
                    help="real qwen1.5-0.5b config (accelerator-sized)")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        argv = [
            "--arch", "qwen1.5-0.5b",
            "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--lr", "1e-2",
            "--ckpt-dir", d, "--ckpt-every", "25",
            "--inject-failure-at", "10",  # prove fault tolerance mid-run
        ]
        if not args.full_small:
            argv.append("--reduced")
        losses = train_main(argv)
        print(f"\nfirst-5 mean loss {sum(losses[:5])/5:.4f} -> "
              f"last-5 mean loss {sum(losses[-5:])/5:.4f} "
              f"(injected failure at step 10 was absorbed)")


if __name__ == "__main__":
    main()
