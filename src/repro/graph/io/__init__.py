"""Out-of-core graph I/O: binary edge-stream files, text ingest, external shuffle.

The disk-backed substrate for graphs that do not fit in memory (ROADMAP:
real, huge workloads). Three pieces:

* :mod:`repro.graph.io.format` — versioned binary edge-stream format
  (64-byte header: magic / version / dtype / m / n; mmap-able int32 payload)
  with bounded-chunk writer/reader classes and row-range sub-readers (the
  spotlight per-instance byte ranges).
* :mod:`repro.graph.io.ingest` — one-pass SNAP-style text → binary ingester
  (comments, blank lines, whitespace variants, optional dense relabeling,
  inferred n) with O(chunk) edge memory. Three parse tiers behind one
  semantics: a C-tokenizer fast path for strict numeric blocks, a vectorized
  ``np.frombuffer`` block parser, and the per-line reference loop (the
  parity oracle, ``parser="python"``).
* :mod:`repro.graph.io.shuffle` — two-pass external shuffle, O(chunk) memory
  as a *hard* bound (oversized buckets recursively re-scatter; the realized
  profile comes back as a :class:`ShuffleReport`), for stream-order
  sensitivity experiments on file-resident graphs.

``repro.core.oocore.partition_file`` drives any registry partitioner over an
:class:`EdgeFileReader` with bounded resident edge memory.
"""
from repro.graph.io.format import (
    HEADER_BYTES,
    MAGIC,
    VERSION,
    EdgeFileReader,
    EdgeFileWriter,
    read_edge_file,
    write_edge_file,
)
from repro.graph.io.ingest import IngestReport, ingest_text
from repro.graph.io.shuffle import ShuffleReport, shuffle_file

__all__ = [
    "MAGIC",
    "VERSION",
    "HEADER_BYTES",
    "EdgeFileReader",
    "EdgeFileWriter",
    "read_edge_file",
    "write_edge_file",
    "IngestReport",
    "ingest_text",
    "ShuffleReport",
    "shuffle_file",
]
