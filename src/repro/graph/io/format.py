"""Versioned binary edge-stream file format (the out-of-core substrate).

Layout (little-endian, 64-byte fixed header + flat payload):

    offset  size  field
    0       8     magic   b"ADWSTRM\\0"
    8       4     version uint32 (currently 1)
    12      4     dtype   uint32 code (1 = int32 (u, v) pairs)
    16      8     m       uint64 — number of edges
    24      8     n       uint64 — number of vertices
    32      8     flags   uint64 (reserved, 0)
    40      24    zero padding (reserved)
    64      m*8   payload: int32[m, 2] edge rows in stream order

The payload is a flat, aligned int32 array, so the file can be ``np.memmap``-ed
directly (``EdgeFileReader(path, mmap=True)``) or read in bounded chunks with
plain seek+read (the default — every ``read()`` returns a fresh owned array,
which is what the bounded-memory driver in ``repro.core.oocore`` wants and
what the memory-accounting tests count).

Writers stream: ``append()`` takes (c, 2) chunks, the header's ``m`` (and,
when not pinned up front, ``n``) is back-patched on ``close()``, so a text
ingest or an external shuffle never holds more than one chunk of edges.
"""
from __future__ import annotations

import io
import os
import struct
import time
from typing import Iterator, Optional, Sequence

import numpy as np

__all__ = [
    "MAGIC",
    "VERSION",
    "HEADER_BYTES",
    "EdgeFileWriter",
    "EdgeFileReader",
    "write_edge_file",
    "read_edge_file",
]

MAGIC = b"ADWSTRM\x00"
VERSION = 1
HEADER_BYTES = 64
DTYPE_INT32_PAIR = 1
_ROW_BYTES = 8  # 2 * int32
_HEADER_FMT = "<8sIIQQQ"  # magic, version, dtype, m, n, flags


def _pack_header(m: int, n: int, flags: int = 0) -> bytes:
    head = struct.pack(_HEADER_FMT, MAGIC, VERSION, DTYPE_INT32_PAIR, m, n, flags)
    return head.ljust(HEADER_BYTES, b"\x00")


def _unpack_header(head: bytes, path: str) -> tuple[int, int, int]:
    if len(head) < HEADER_BYTES:
        raise ValueError(f"{path}: truncated header ({len(head)} < {HEADER_BYTES} bytes)")
    magic, version, dtype, m, n, flags = struct.unpack_from(_HEADER_FMT, head)
    if magic != MAGIC:
        raise ValueError(f"{path}: not an ADWISE edge-stream file (magic {magic!r})")
    if version != VERSION:
        raise ValueError(
            f"{path}: unsupported edge-stream format version {version} "
            f"(this build reads version {VERSION})"
        )
    if dtype != DTYPE_INT32_PAIR:
        raise ValueError(f"{path}: unknown payload dtype code {dtype}")
    return int(m), int(n), int(flags)


class EdgeFileWriter:
    """Streaming writer: append (c, 2) int32 chunks, header patched on close.

    ``num_vertices=None`` infers n = max vertex id + 1 over everything
    appended (0 for an empty file). Usable as a context manager.
    """

    def __init__(self, path: str, num_vertices: Optional[int] = None):
        self.path = path
        self._n = num_vertices
        self._max_id = -1
        self._m = 0
        self._f: Optional[io.BufferedWriter] = open(path, "wb")
        self._f.write(_pack_header(0, 0))

    def append(self, edges: np.ndarray) -> None:
        edges = np.ascontiguousarray(edges, dtype=np.int32)
        assert edges.ndim == 2 and edges.shape[1] == 2, edges.shape
        if self._f is None:
            raise ValueError("writer is closed")
        if len(edges) == 0:
            return
        if self._n is None:
            self._max_id = max(self._max_id, int(edges.max()))
        self._f.write(edges.tobytes())
        self._m += len(edges)

    @property
    def num_edges(self) -> int:
        return self._m

    def close(self) -> None:
        if self._f is None:
            return
        n = self._n if self._n is not None else self._max_id + 1
        self._f.seek(0)
        self._f.write(_pack_header(self._m, n))
        self._f.close()
        self._f = None

    def abort(self) -> None:
        """Discard a partial file (the header is never finalized)."""
        if self._f is not None:
            self._f.close()
            self._f = None
        try:
            os.remove(self.path)
        except OSError:
            pass

    def __enter__(self) -> "EdgeFileWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # A raised body must not leave a valid-looking truncated file behind
        # (a later run would silently partition the partial stream).
        if exc_type is not None:
            self.abort()
        else:
            self.close()


class EdgeFileReader:
    """Bounded-chunk reader over a binary edge-stream file (or a row range).

    ``read(start, count)`` returns an owned (count, 2) int32 array — O(count)
    memory per call; ``chunks(c)`` iterates the whole range in c-row chunks.
    ``sub(start, stop)`` / ``split(z)`` present row sub-ranges as readers with
    local 0-based addressing (the spotlight per-instance byte ranges; ``z``
    uses the same ceil(m/z) boundaries as ``EdgeStream.split_bounds``).

    IO accounting for the latency model: ``rows_read`` / ``read_seconds``
    accumulate across every ``read`` (shared by all ``sub`` views, so a
    driver's total measured ingest wall is the root reader's counter).

    ``mmap=True`` exposes the payload as a read-only ``np.memmap`` instead
    (zero-copy; resident set then belongs to the page cache, not the process
    heap — reads still return views, so the counting tests use the default).
    """

    def __init__(self, path: str, *, mmap: bool = False):
        self.path = path
        with open(path, "rb") as f:
            head = f.read(HEADER_BYTES)
        m, n, flags = _unpack_header(head, path)
        payload = os.path.getsize(path) - HEADER_BYTES
        if payload < m * _ROW_BYTES:
            raise ValueError(
                f"{path}: payload truncated ({payload} bytes < {m} rows)"
            )
        self.num_edges = m
        self.num_vertices = n
        self.flags = flags
        self._mmap: Optional[np.memmap] = None
        self._f: Optional[io.BufferedReader] = None
        if mmap:
            self._mmap = np.memmap(
                path, dtype=np.int32, mode="r", offset=HEADER_BYTES, shape=(m, 2)
            )
        else:
            self._f = open(path, "rb")
        # IO accounting (shared with sub-readers).
        self.rows_read = 0
        self.read_seconds = 0.0

    # -- core access -------------------------------------------------------
    def read(self, start: int, count: int) -> np.ndarray:
        """(count', 2) int32 rows [start, start+count) clipped to the file."""
        start = max(0, int(start))
        stop = min(self.num_edges, start + max(0, int(count)))
        c = stop - start
        if c <= 0:
            return np.zeros((0, 2), np.int32)
        t0 = time.perf_counter()
        if self._mmap is not None:
            out = np.asarray(self._mmap[start:stop])
        else:
            self._f.seek(HEADER_BYTES + start * _ROW_BYTES)
            out = np.fromfile(self._f, dtype=np.int32, count=c * 2).reshape(c, 2)
        self.read_seconds += time.perf_counter() - t0
        self.rows_read += c
        return out

    def chunks(self, chunk_edges: int) -> Iterator[np.ndarray]:
        assert chunk_edges >= 1
        for start in range(0, self.num_edges, chunk_edges):
            yield self.read(start, chunk_edges)

    def read_all(self) -> np.ndarray:
        return self.read(0, self.num_edges)

    # -- range views -------------------------------------------------------
    def sub(self, start: int, stop: int) -> "EdgeFileSubReader":
        """Reader over rows [start, stop) with local 0-based addressing."""
        assert 0 <= start <= stop <= self.num_edges, (start, stop, self.num_edges)
        return EdgeFileSubReader(self, start, stop)

    def split(self, z: int) -> Sequence["EdgeFileSubReader"]:
        """z contiguous sub-readers over the ceil(m/z) instance boundaries
        shared with ``EdgeStream.split_bounds`` / ``split_padded``."""
        from repro.graph.stream import EdgeStream

        bounds = EdgeStream.split_bounds(self.num_edges, z)
        return [self.sub(int(bounds[i]), int(bounds[i + 1])) for i in range(z)]

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None
        self._mmap = None

    def __enter__(self) -> "EdgeFileReader":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class EdgeFileSubReader:
    """View over a row range of a parent reader (local 0-based rows).

    Duck-types the full reader surface the out-of-core driver uses:
    ``num_edges``, ``num_vertices``, ``read``, ``chunks``, ``read_all``,
    ``sub``, ``split``, and the ``rows_read`` / ``read_seconds`` accounting
    (which flows to — and reads from — the root reader).
    """

    def __init__(self, parent, start: int, stop: int):
        self._parent = parent
        self._start = start
        self.num_edges = stop - start
        self.num_vertices = parent.num_vertices
        self.path = getattr(parent, "path", None)

    @property
    def rows_read(self) -> int:
        return self._parent.rows_read

    @property
    def read_seconds(self) -> float:
        return self._parent.read_seconds

    def read(self, start: int, count: int) -> np.ndarray:
        start = max(0, int(start))
        count = min(max(0, int(count)), max(self.num_edges - start, 0))
        return self._parent.read(self._start + start, count)

    def chunks(self, chunk_edges: int) -> Iterator[np.ndarray]:
        assert chunk_edges >= 1
        for start in range(0, self.num_edges, chunk_edges):
            yield self.read(start, chunk_edges)

    def read_all(self) -> np.ndarray:
        return self.read(0, self.num_edges)

    def sub(self, start: int, stop: int) -> "EdgeFileSubReader":
        assert 0 <= start <= stop <= self.num_edges
        return EdgeFileSubReader(self._parent, self._start + start, self._start + stop)

    def split(self, z: int) -> Sequence["EdgeFileSubReader"]:
        from repro.graph.stream import EdgeStream

        bounds = EdgeStream.split_bounds(self.num_edges, z)
        return [self.sub(int(bounds[i]), int(bounds[i + 1])) for i in range(z)]


def write_edge_file(path: str, edges: np.ndarray, num_vertices: int) -> None:
    """One-shot convenience: write a resident (m, 2) array as an edge file."""
    with EdgeFileWriter(path, num_vertices=num_vertices) as w:
        w.append(np.asarray(edges))


def read_edge_file(path: str) -> tuple[np.ndarray, int]:
    """One-shot convenience: load the whole file (resident)."""
    with EdgeFileReader(path) as r:
        return r.read_all(), r.num_vertices
