"""External (on-disk) chunked shuffle of a binary edge-stream file.

Stream-order sensitivity experiments (paper §IV — file order vs adversarial
random order) need a *shuffled copy* of the stream. In-memory that is
``EdgeStream.shuffled``; out-of-core it is the classic recursive external
shuffle:

  scatter: read the source in bounded chunks; deal each row uniformly at
    random into one of B bucket files. B is capped at ``max_open`` (file-
    descriptor budget — a 1e9-row shuffle must not open 30k files at once).
  gather: for each bucket in order — if it fits the chunk budget, load it,
    permute it in memory, append to the destination; otherwise scatter it
    again recursively (depth is log_B(m / chunk), i.e. 2 for anything that
    fits on one disk).

Dealing rows to uniform buckets and uniformly permuting each bucket yields a
uniform permutation of the file, deterministic in ``seed`` (a single
generator threads through scatter and gather in bucket order). Peak edge
memory is O(chunk + max_open); open files are O(max_open).
"""
from __future__ import annotations

import itertools
import os
import tempfile
from typing import Optional

import numpy as np

from repro.graph.io.format import EdgeFileReader, EdgeFileWriter

__all__ = ["shuffle_file"]

_MAX_OPEN = 256  # simultaneous bucket files per scatter level


def _scatter(chunks, n_rows: int, chunk_edges: int, rng, td: str, ids):
    """Deal rows from a chunk iterator into <= _MAX_OPEN bucket files.

    Returns the bucket paths (creation order == gather order)."""
    n_buckets = min(max(1, -(-2 * n_rows // chunk_edges)), _MAX_OPEN)
    paths = [os.path.join(td, f"bucket_{next(ids)}.bin") for _ in range(n_buckets)]
    handles = [open(p, "wb") for p in paths]
    try:
        for chunk in chunks:
            which = rng.integers(0, n_buckets, size=len(chunk))
            # One stable sort groups the chunk by bucket (a per-bucket mask
            # loop would re-scan the chunk n_buckets times).
            order = np.argsort(which, kind="stable")
            grouped = chunk[order]
            counts = np.bincount(which, minlength=n_buckets)
            stops = np.cumsum(counts)
            for b in range(n_buckets):
                if counts[b]:
                    rows = grouped[stops[b] - counts[b] : stops[b]]
                    handles[b].write(np.ascontiguousarray(rows).tobytes())
    finally:
        for f in handles:
            f.close()
    return paths


def _raw_chunks(path: str, chunk_edges: int):
    """Iterate a raw headerless int32-pair file in bounded chunks."""
    with open(path, "rb") as f:
        while True:
            raw = np.fromfile(f, dtype=np.int32, count=chunk_edges * 2)
            if raw.size == 0:
                return
            yield raw.reshape(-1, 2)


def _gather(paths, chunk_edges: int, rng, td: str, ids, emit) -> None:
    """Permute each bucket into ``emit``; oversized buckets scatter again."""
    for p in paths:
        n_rows = os.path.getsize(p) // 8
        if n_rows <= max(2 * chunk_edges, 1):
            raw = np.fromfile(p, dtype=np.int32)
            rows = raw.reshape(-1, 2)
            emit(rows[rng.permutation(len(rows))])
        else:
            sub = _scatter(_raw_chunks(p, chunk_edges), n_rows, chunk_edges,
                           rng, td, ids)
            _gather(sub, chunk_edges, rng, td, ids, emit)
        os.remove(p)


def shuffle_file(
    src: str,
    dst: str,
    *,
    seed: int = 0,
    chunk_edges: int = 1 << 16,
    tmpdir: Optional[str] = None,
) -> None:
    """Write a uniformly shuffled copy of edge file ``src`` to ``dst``."""
    assert chunk_edges >= 1
    rng = np.random.default_rng(seed)
    ids = itertools.count()
    with EdgeFileReader(src) as r:
        m, n = r.num_edges, r.num_vertices
        with tempfile.TemporaryDirectory(dir=tmpdir) as td:
            paths = _scatter(r.chunks(chunk_edges), m, chunk_edges, rng, td, ids)
            with EdgeFileWriter(dst, num_vertices=n) as w:
                _gather(paths, chunk_edges, rng, td, ids, w.append)
