"""External (on-disk) chunked shuffle of a binary edge-stream file.

Stream-order sensitivity experiments (paper §IV — file order vs adversarial
random order) need a *shuffled copy* of the stream. In-memory that is
``EdgeStream.shuffled``; out-of-core it is the classic recursive external
shuffle:

  scatter: read the source in bounded chunks; deal each row uniformly at
    random into one of B bucket files. B is capped at ``max_open`` (file-
    descriptor budget — a 1e9-row shuffle must not open 30k files at once).
  gather: for each bucket in order — if it fits the chunk budget, load it,
    permute it in memory, append to the destination; otherwise scatter it
    again recursively (depth is log_B(m / chunk), i.e. 2 for anything that
    fits on one disk).

The resident-memory bound is **hard**, not expected-case: a bucket is only
ever loaded whole once it holds at most ``2 * chunk_edges`` rows — any
larger bucket (whether from the ``max_open`` cap, an adversarial seed, or a
pathologically skewed source order) is re-scattered instead, and the bound
is asserted at every load. :class:`ShuffleReport` surfaces the realized
maxima (``max_loaded_rows``, recursion ``depth``, ``buckets``) so tests and
benches can prove the bound rather than trust it.

Dealing rows to uniform buckets and uniformly permuting each bucket yields a
uniform permutation of the file, deterministic in ``seed`` (a single
generator threads through scatter and gather in bucket order). Peak edge
memory is O(chunk); open files are O(max_open).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import tempfile
from typing import Optional

import numpy as np

from repro.graph.io.format import EdgeFileReader, EdgeFileWriter

__all__ = ["shuffle_file", "ShuffleReport"]

_MAX_OPEN = 256  # default simultaneous bucket files per scatter level


@dataclasses.dataclass
class ShuffleReport:
    """Realized resource profile of one external shuffle."""

    num_edges: int
    chunk_edges: int
    max_open: int
    buckets: int = 0  # bucket files created across all levels
    depth: int = 0  # deepest recursive re-scatter level reached
    max_loaded_rows: int = 0  # largest bucket permuted in memory

    @property
    def bound_rows(self) -> int:
        """The hard in-memory bound every loaded bucket satisfied."""
        return max(2 * self.chunk_edges, 1)


def _scatter(chunks, n_rows: int, chunk_edges: int, max_open: int, rng, td,
             ids, report: ShuffleReport):
    """Deal rows from a chunk iterator into <= max_open bucket files.

    Returns the bucket paths (creation order == gather order)."""
    n_buckets = min(max(1, -(-2 * n_rows // chunk_edges)), max_open)
    paths = [os.path.join(td, f"bucket_{next(ids)}.bin") for _ in range(n_buckets)]
    report.buckets += n_buckets
    handles = [open(p, "wb") for p in paths]
    try:
        for chunk in chunks:
            which = rng.integers(0, n_buckets, size=len(chunk))
            # One stable sort groups the chunk by bucket (a per-bucket mask
            # loop would re-scan the chunk n_buckets times).
            order = np.argsort(which, kind="stable")
            grouped = chunk[order]
            counts = np.bincount(which, minlength=n_buckets)
            stops = np.cumsum(counts)
            for b in range(n_buckets):
                if counts[b]:
                    rows = grouped[stops[b] - counts[b] : stops[b]]
                    handles[b].write(np.ascontiguousarray(rows).tobytes())
    finally:
        for f in handles:
            f.close()
    return paths


def _raw_chunks(path: str, chunk_edges: int):
    """Iterate a raw headerless int32-pair file in bounded chunks."""
    with open(path, "rb") as f:
        while True:
            raw = np.fromfile(f, dtype=np.int32, count=chunk_edges * 2)
            if raw.size == 0:
                return
            yield raw.reshape(-1, 2)


def _gather(paths, chunk_edges: int, max_open: int, rng, td, ids, emit,
            report: ShuffleReport, depth: int = 0) -> None:
    """Permute each bucket into ``emit``; oversized buckets scatter again."""
    report.depth = max(report.depth, depth)
    bound = max(2 * chunk_edges, 1)
    for p in paths:
        n_rows = os.path.getsize(p) // 8
        if n_rows <= bound:
            raw = np.fromfile(p, dtype=np.int32)
            rows = raw.reshape(-1, 2)
            # The hard O(chunk) residency bound: every whole-bucket load is
            # within 2x the chunk budget, no matter how skewed the input or
            # how small max_open forced the fan-out to be.
            assert len(rows) <= bound, (len(rows), bound)
            report.max_loaded_rows = max(report.max_loaded_rows, len(rows))
            emit(rows[rng.permutation(len(rows))])
        else:
            # Re-scatter an oversized bucket. n_rows > 2*chunk forces
            # n_buckets = min(ceil(2*n/chunk), max_open) >= min(5, max_open),
            # and max_open >= 2 is enforced at the entry point, so the
            # expected bucket size strictly shrinks every level — the
            # recursion terminates with probability 1 and each level is
            # logged in the report.
            sub = _scatter(_raw_chunks(p, chunk_edges), n_rows, chunk_edges,
                           max_open, rng, td, ids, report)
            _gather(sub, chunk_edges, max_open, rng, td, ids, emit, report,
                    depth + 1)
        os.remove(p)


def shuffle_file(
    src: str,
    dst: str,
    *,
    seed: int = 0,
    chunk_edges: int = 1 << 16,
    max_open: Optional[int] = None,
    tmpdir: Optional[str] = None,
) -> ShuffleReport:
    """Write a uniformly shuffled copy of edge file ``src`` to ``dst``.

    Returns a :class:`ShuffleReport` with the realized bucket/recursion
    profile (``max_loaded_rows <= 2 * chunk_edges`` is the hard memory
    bound). ``max_open`` caps simultaneously open bucket files per scatter
    level; small values force deeper recursion, never larger buckets.
    """
    assert chunk_edges >= 1
    if max_open is None:
        max_open = _MAX_OPEN  # resolved at call time (tests patch the module)
    if max_open < 2:
        raise ValueError(
            f"max_open must be >= 2 (a single bucket cannot shrink on "
            f"re-scatter), got {max_open}"
        )
    rng = np.random.default_rng(seed)
    ids = itertools.count()
    with EdgeFileReader(src) as r:
        m, n = r.num_edges, r.num_vertices
        report = ShuffleReport(num_edges=m, chunk_edges=chunk_edges,
                               max_open=max_open)
        with tempfile.TemporaryDirectory(dir=tmpdir) as td:
            paths = _scatter(r.chunks(chunk_edges), m, chunk_edges, max_open,
                             rng, td, ids, report)
            with EdgeFileWriter(dst, num_vertices=n) as w:
                _gather(paths, chunk_edges, max_open, rng, td, ids, w.append,
                        report)
    return report
