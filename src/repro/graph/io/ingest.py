"""One-pass text → binary edge-stream ingestion (SNAP-style edge lists).

Accepts the format real graph dumps (SNAP Orkut / LiveJournal / web graphs)
ship in: one ``u v`` pair per line, arbitrary whitespace between fields,
``#`` / ``%`` / ``//`` comment lines, blank lines, optional trailing fields
(weights / timestamps — ignored). Edges keep file order (stream order),
self-loops and duplicates are preserved — the file IS the stream, cleaning
it is a policy decision that belongs to the consumer, not the ingester.

Memory is O(chunk): lines are read in batches, parsed into one (c, 2) array,
and appended to an :class:`repro.graph.io.format.EdgeFileWriter` (which
back-patches m/n on close). With ``relabel=True`` vertex ids are mapped to a
dense [0, n) space in first-appearance order (the id map is O(V) — vertex-
sized state, like every streaming partitioner's tables; *edge* memory stays
bounded by the chunk).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import numpy as np

from repro.graph.io.format import EdgeFileWriter, _pack_header

__all__ = ["IngestReport", "ingest_text"]

_COMMENT_PREFIXES = ("#", "%", "//")
_I32_MAX = np.iinfo(np.int32).max


class _DenseIdMap:
    """Incremental raw-id → dense-id map in global first-appearance order.

    Fully vectorized (a sorted key table + ``searchsorted``, merged as new
    ids appear) — a per-element dict loop would cost ~2 Python lookups per
    edge, dwarfing the parse time on real SNAP-scale inputs.
    """

    def __init__(self):
        self._keys = np.empty((0,), np.int64)  # sorted raw ids
        self._vals = np.empty((0,), np.int64)  # dense id per sorted key

    def __len__(self) -> int:
        return len(self._keys)

    def translate(self, flat: np.ndarray) -> np.ndarray:
        flat = np.asarray(flat, np.int64)
        if len(self._keys):
            pos = np.searchsorted(self._keys, flat)
            pos_c = np.minimum(pos, len(self._keys) - 1)
            known = self._keys[pos_c] == flat
        else:
            known = np.zeros(flat.shape, bool)
        if not known.all():
            fresh = flat[~known]
            # Unique new ids, ordered by first appearance within this chunk
            # (earlier chunks are already in the table, so this IS the global
            # first-appearance order).
            uniq, first = np.unique(fresh, return_index=True)
            order = np.argsort(first, kind="stable")
            new_keys = uniq[order]
            new_vals = len(self._keys) + np.arange(len(new_keys), dtype=np.int64)
            keys = np.concatenate([self._keys, new_keys])
            vals = np.concatenate([self._vals, new_vals])
            resort = np.argsort(keys, kind="stable")
            self._keys, self._vals = keys[resort], vals[resort]
        return self._vals[np.searchsorted(self._keys, flat)]


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """What one ingest pass did (``bytes_read`` drives the MB/s bench)."""

    num_edges: int
    num_vertices: int
    lines: int
    comment_lines: int
    blank_lines: int
    bytes_read: int
    wall_s: float
    relabeled: bool


def _parse_batch(batch: list[tuple[int, str]], path: str) -> np.ndarray:
    """Parse (lineno, line) pairs into an (c, 2) int64 array."""
    rows = np.empty((len(batch), 2), dtype=np.int64)
    for i, (lineno, line) in enumerate(batch):
        parts = line.split()
        if len(parts) < 2:
            raise ValueError(
                f"{path}:{lineno}: expected at least two fields, got {line.strip()!r}"
            )
        try:
            rows[i, 0] = int(parts[0])
            rows[i, 1] = int(parts[1])
        except ValueError:
            raise ValueError(
                f"{path}:{lineno}: non-integer vertex id in {line.strip()!r}"
            ) from None
    return rows


def ingest_text(
    src: str,
    dst: str,
    *,
    relabel: bool = False,
    num_vertices: Optional[int] = None,
    chunk_lines: int = 1 << 16,
) -> IngestReport:
    """Convert a text edge list at ``src`` into a binary edge file at ``dst``.

    Args:
      relabel: map vertex ids to a dense [0, n) space in first-appearance
        order (required for files with sparse / huge / negative ids).
        Without it, ids must fit non-negative int32 and n is inferred as
        ``max id + 1``.
      num_vertices: pin n instead of inferring it (ignored with ``relabel``,
        where n is the number of distinct ids).
      chunk_lines: lines parsed per batch — the O(chunk) memory bound.

    Returns an :class:`IngestReport`; raises ``ValueError`` on malformed
    lines (with file:line in the message) and on out-of-range ids.
    """
    t0 = time.perf_counter()
    lines = comments = blanks = 0
    max_id = -1
    id_map = _DenseIdMap()

    def densify(rows: np.ndarray, first_lineno: int) -> np.ndarray:
        nonlocal max_id
        if relabel:
            return id_map.translate(rows.reshape(-1)).reshape(-1, 2)
        if rows.size and int(rows.min()) < 0:
            raise ValueError(
                f"{src}: negative vertex id {int(rows.min())} near line "
                f"{first_lineno} (pass relabel=True)"
            )
        if rows.size and int(rows.max()) >= _I32_MAX:
            raise ValueError(
                f"{src}: vertex id {int(rows.max())} overflows int32 "
                "(pass relabel=True to densify)"
            )
        if rows.size:
            max_id = max(max_id, int(rows.max()))
            if num_vertices is not None and max_id >= num_vertices:
                raise ValueError(
                    f"{src}: vertex id {max_id} >= pinned num_vertices="
                    f"{num_vertices} near line {first_lineno}"
                )
        return rows

    with open(src, "r") as f, EdgeFileWriter(dst, num_vertices=None) as w:
        batch: list[tuple[int, str]] = []
        for line in f:
            lines += 1
            s = line.strip()
            if not s:
                blanks += 1
                continue
            if s.startswith(_COMMENT_PREFIXES):
                comments += 1
                continue
            batch.append((lines, line))
            if len(batch) >= chunk_lines:
                rows = densify(_parse_batch(batch, src), batch[0][0])
                w.append(rows.astype(np.int32))
                batch = []
        if batch:
            rows = densify(_parse_batch(batch, src), batch[0][0])
            w.append(rows.astype(np.int32))
        m = w.num_edges
    # The writer inferred n = max id + 1 (== max_id + 1 here); re-patch when
    # the caller pinned n or relabeling fixed it as the distinct-id count.
    if relabel:
        n_final = len(id_map)
        _patch_header(dst, m, n_final)
    elif num_vertices is not None:
        n_final = num_vertices
        _patch_header(dst, m, n_final)
    else:
        n_final = max_id + 1
    return IngestReport(
        num_edges=m,
        num_vertices=n_final,
        lines=lines,
        comment_lines=comments,
        blank_lines=blanks,
        bytes_read=os.path.getsize(src),
        wall_s=time.perf_counter() - t0,
        relabeled=relabel,
    )


def _patch_header(path: str, m: int, n: int) -> None:
    with open(path, "r+b") as f:
        f.write(_pack_header(m, n))
