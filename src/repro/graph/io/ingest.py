"""One-pass text → binary edge-stream ingestion (SNAP-style edge lists).

Accepts the format real graph dumps (SNAP Orkut / LiveJournal / web graphs)
ship in: one ``u v`` pair per line, arbitrary whitespace between fields,
``#`` / ``%`` / ``//`` comment lines, blank lines, optional trailing fields
(weights / timestamps — ignored). Edges keep file order (stream order),
self-loops and duplicates are preserved — the file IS the stream, cleaning
it is a policy decision that belongs to the consumer, not the ingester.

Two parsers, one semantics:

* ``parser="bytes"`` (default) — the vectorized fast path: the file is read
  in newline-aligned binary blocks, each block is dropped into one
  ``np.frombuffer`` uint8 array, and comment/blank classification, token
  boundaries, and integer values all come out of whole-block numpy ops (no
  per-line Python). A block containing anything the vector path does not
  model exactly (a ``+`` sign, underscore separators, non-ASCII digits,
  malformed rows) falls back to the per-line parser *for that block*, which
  reproduces the reference semantics — including the exact ``file:line``
  error messages — bit for bit.
* ``parser="python"`` — the original per-line ``str.split`` loop, kept as
  the parity oracle (tests assert both parsers produce identical binaries
  and reports on the same input).

Parity bound: on a file with ONE problem, both parsers raise the identical
error (message, id, exact line). When several *distinct* problems coexist
tens of thousands of lines apart, which one is reported first depends on
chunk granularity — inherently so: the reference parser itself reports a
different error for different ``chunk_lines`` settings (parse errors raise
while batching, id-policy errors raise per flushed batch). Each parser
still reports a real problem with its exact line.

Memory is O(chunk) either way: blocks/batches are parsed into one (c, 2)
array and appended to an :class:`repro.graph.io.format.EdgeFileWriter`
(which back-patches m/n on close). With ``relabel=True`` vertex ids are
mapped to a dense [0, n) space in first-appearance order (the id map is
O(V) — vertex-sized state, like every streaming partitioner's tables;
*edge* memory stays bounded by the chunk).
"""
from __future__ import annotations

import dataclasses
import io
import os
import time
import warnings
from typing import Iterator, Optional

import numpy as np

from repro.graph.io.format import EdgeFileWriter, _pack_header

__all__ = ["IngestReport", "ingest_text"]

_COMMENT_PREFIXES = ("#", "%", "//")
_I32_MAX = np.iinfo(np.int32).max
_POW10 = 10 ** np.arange(19, dtype=np.int64)  # int64 holds < 9.3e18


def _classify_line(line: str) -> str:
    """'blank' | 'comment' | 'data' — THE reference classification. Every
    per-line code path (the python parser, the bytes tiers' fallback, and
    the error-line resolver) must share this single definition; the
    vectorized byte-level classification in :func:`_parse_block_bytes`
    mirrors it and is pinned to it by the parity tests."""
    s = line.strip()
    if not s:
        return "blank"
    if s.startswith(_COMMENT_PREFIXES):
        return "comment"
    return "data"


class _DenseIdMap:
    """Incremental raw-id → dense-id map in global first-appearance order.

    Fully vectorized (a sorted key table + ``searchsorted``, merged as new
    ids appear) — a per-element dict loop would cost ~2 Python lookups per
    edge, dwarfing the parse time on real SNAP-scale inputs.
    """

    def __init__(self):
        self._keys = np.empty((0,), np.int64)  # sorted raw ids
        self._vals = np.empty((0,), np.int64)  # dense id per sorted key

    def __len__(self) -> int:
        return len(self._keys)

    def translate(self, flat: np.ndarray) -> np.ndarray:
        flat = np.asarray(flat, np.int64)
        if len(self._keys):
            pos = np.searchsorted(self._keys, flat)
            pos_c = np.minimum(pos, len(self._keys) - 1)
            known = self._keys[pos_c] == flat
        else:
            known = np.zeros(flat.shape, bool)
        if not known.all():
            fresh = flat[~known]
            # Unique new ids, ordered by first appearance within this chunk
            # (earlier chunks are already in the table, so this IS the global
            # first-appearance order).
            uniq, first = np.unique(fresh, return_index=True)
            order = np.argsort(first, kind="stable")
            new_keys = uniq[order]
            new_vals = len(self._keys) + np.arange(len(new_keys), dtype=np.int64)
            keys = np.concatenate([self._keys, new_keys])
            vals = np.concatenate([self._vals, new_vals])
            resort = np.argsort(keys, kind="stable")
            self._keys, self._vals = keys[resort], vals[resort]
        return self._vals[np.searchsorted(self._keys, flat)]


@dataclasses.dataclass(frozen=True)
class IngestReport:
    """What one ingest pass did (``bytes_read`` drives the MB/s bench)."""

    num_edges: int
    num_vertices: int
    lines: int
    comment_lines: int
    blank_lines: int
    bytes_read: int
    wall_s: float
    relabeled: bool
    parser: str = "python"


def _parse_batch(batch: list[tuple[int, str]], path: str) -> np.ndarray:
    """Parse (lineno, line) pairs into an (c, 2) int64 array (the reference
    per-line parser — also the fallback target of the vectorized path)."""
    rows = np.empty((len(batch), 2), dtype=np.int64)
    for i, (lineno, line) in enumerate(batch):
        parts = line.split()
        if len(parts) < 2:
            raise ValueError(
                f"{path}:{lineno}: expected at least two fields, got {line.strip()!r}"
            )
        try:
            rows[i, 0] = int(parts[0])
            rows[i, 1] = int(parts[1])
        except (ValueError, OverflowError):
            raise ValueError(
                f"{path}:{lineno}: non-integer vertex id in {line.strip()!r}"
            ) from None
    return rows


# ----------------------------------------------------------------------------
# Vectorized bytes-level block parser
# ----------------------------------------------------------------------------


def _parse_block_python(
    block: bytes, lineno0: int, path: str
) -> tuple[np.ndarray, int, int, int]:
    """Reference per-line parse of one newline-terminated block; returns
    (rows int64[c, 2], lines, comments, blanks). Raises the exact reference
    errors (with absolute line numbers) on malformed content."""
    # Universal-newline translation, exactly as text-mode file iteration
    # does it (\r\n and lone \r both become \n; splitlines() would also
    # split on \v / \f / \x85, which file iteration does not).
    text = block.decode().replace("\r\n", "\n").replace("\r", "\n")
    batch: list[tuple[int, str]] = []
    comments = blanks = nlines = 0
    for i, line in enumerate(text.split("\n")[:-1]):
        nlines += 1
        cls = _classify_line(line)
        if cls == "blank":
            blanks += 1
        elif cls == "comment":
            comments += 1
        else:
            batch.append((lineno0 + i, line))
    rows = _parse_batch(batch, path) if batch else np.empty((0, 2), np.int64)
    return rows, nlines, comments, blanks


# Byte-class lookup table: one gather replaces a cascade of comparisons.
_SEP_LUT = np.zeros(256, bool)
_SEP_LUT[[9, 10, 11, 12, 13, 32]] = True  # \t \n \v \f \r ' '


def _universal_nl_idx(a: np.ndarray) -> np.ndarray:
    """Positions of universal-newline terminators in a byte array, exactly
    as text-mode iteration counts lines: \\n terminates, a lone \\r
    terminates, \\r\\n counts once (its \\r half is plain whitespace then).
    Blocks only ever split at \\n, so a \\r\\n pair is never torn apart.
    Block segmentation and token parsing MUST share this definition — the
    dirty-segment line offsets are computed from it."""
    is_lf = a == 10
    is_cr = a == 13
    before_lf = np.empty_like(is_lf)
    before_lf[-1] = False
    before_lf[:-1] = is_lf[1:]
    return np.flatnonzero(is_lf | (is_cr & ~before_lf))

# Bytes a block may contain for the tier-0 (np.loadtxt C tokenizer) path:
# digits, signs, and ASCII whitespace sans \r. Anything else — comment
# chars, '.', '_', letters — means loadtxt could diverge from the reference
# semantics, so such blocks take the numpy tier instead. One C-speed
# ``bytes.translate`` scan decides.
_STRICT_BYTES = bytes(sorted(b"0123456789+-\t\n\x0b\x0c "))
_WS_BYTES = b" \t\x0b\x0c\n"


_STRICT_LUT = np.zeros(256, bool)
_STRICT_LUT[list(_STRICT_BYTES)] = True


def _parse_strict(block: bytes):
    """Tier-0 parse via numpy's C loadtxt tokenizer (~10-20x the per-line
    reference parser) for a segment already verified to contain ONLY the
    strict digit/sign/whitespace byte set. Returns None when loadtxt cannot
    prove equivalence after all (a row it rejects, or an overflow) — the
    caller re-parses the segment through the exact tiers, which own ALL
    error reporting (this tier never raises toward the user).

    Within the strict byte set the semantics provably coincide: no comment
    or blank-classification ambiguity can occur, ``usecols=(0, 1)`` takes
    the first two whitespace fields exactly like ``line.split()[:2]``, and
    float64 holds every integer below 4e15 exactly.
    """
    nlines = block.count(b"\n")
    if not block.strip(_WS_BYTES):
        return np.empty((0, 2), np.int64), nlines, 0, nlines
    try:
        with warnings.catch_warnings():
            # loadtxt falls back to a *silently wrapping* float path for
            # ints beyond int64 and warns (DeprecationWarning today,
            # FutureWarning is the usual next stop); escalating exactly
            # those makes overflow land in the exact tiers instead, while
            # benign warning categories cannot silently demote every clean
            # block to the slow tiers. The overflow parity test pins this:
            # if numpy moves the warning category, that test fails loudly.
            warnings.simplefilter("error", DeprecationWarning)
            warnings.simplefilter("error", FutureWarning)
            rows = np.loadtxt(
                io.BytesIO(block), dtype=np.int64, usecols=(0, 1), ndmin=2,
                comments=None,
            )
    except Exception:
        return None  # the exact tiers reproduce the reference error
    return rows, nlines, 0, nlines - len(rows)


def _parse_block(block: bytes, lineno0: int, path: str):
    """Parse one newline-terminated block through the fastest applicable
    tier. A fully strict block goes straight to loadtxt; otherwise the
    *lines* containing non-strict bytes (comments, \\r, exotic tokens) are
    segmented out — each maximal dirty run parses through the vectorized
    numpy tier (tier 1, which itself may delegate to the per-line reference
    parser), while the clean runs between them still ride tier 0. A SNAP
    file's ``#`` header therefore costs a few header-sized segments, not the
    whole surrounding block.
    """
    clean = block.find(b"\r") < 0 and not block.translate(None, _STRICT_BYTES)
    if clean:
        parsed = _parse_strict(block)
        return parsed if parsed is not None else _parse_block_bytes(
            block, lineno0, path
        )
    a = np.frombuffer(block, np.uint8)
    if (a >= 128).any():
        # The text-mode reference parser decodes every byte of the file;
        # invalid UTF-8 must fail here exactly as it fails there (valid
        # non-ASCII text — accented comments, unicode whitespace — then
        # flows through the dirty-line tiers, whose python fallback applies
        # the reference str semantics).
        block.decode()
    ok = _STRICT_LUT[a]
    # Segment in UNIVERSAL-newline space (lone \r terminates a line in text
    # mode): line numbers handed to sub-parsers must match the reference
    # parser's counting even when \r-terminated lines precede a bad line.
    # Every \r byte is outside the strict set, so \r-bearing lines are
    # always dirty lines — clean segments never contain one.
    nl_idx = _universal_nl_idx(a)
    bad_line = np.unique(np.searchsorted(nl_idx, np.flatnonzero(~ok)))
    runs = np.split(bad_line, np.flatnonzero(np.diff(bad_line) > 1) + 1)
    segs = []  # (line0, line1, dirty)
    cur = 0
    for r in runs:
        l0, l1 = int(r[0]), int(r[-1]) + 1
        if l0 > cur:
            segs.append((cur, l0, False))
        segs.append((l0, l1, True))
        cur = l1
    if cur < len(nl_idx):
        segs.append((cur, len(nl_idx), False))
    rows_parts, nlines = [], 0
    comments = blanks = 0
    for l0, l1, dirty in segs:
        b0 = 0 if l0 == 0 else int(nl_idx[l0 - 1]) + 1
        b1 = int(nl_idx[l1 - 1]) + 1
        seg = block[b0:b1]
        if not seg.endswith(b"\n"):
            # A lone-\r terminator ended this (necessarily dirty) segment;
            # completing it with \n forms a \r\n pair — still one line.
            seg += b"\n"
        parsed = None if dirty else _parse_strict(seg)
        if parsed is None:
            parsed = _parse_block_bytes(seg, lineno0 + l0, path)
        rows, nl, nc, nb = parsed
        rows_parts.append(rows)
        nlines += nl
        comments += nc
        blanks += nb
    rows = (
        np.concatenate(rows_parts) if rows_parts else np.empty((0, 2), np.int64)
    )
    return rows, nlines, comments, blanks


def _token_values(a: np.ndarray, ts_s: np.ndarray, te_s: np.ndarray):
    """int64 values of the tokens spanning [ts_s, te_s] bytes of ``a``, or
    None when any token is not ``-?[0-9]{1,18}`` (fallback trigger).

    Right-aligned digit matrix: one broadcast gather pulls every token's
    last ``lmax`` bytes into an (nt, lmax) block (column j = the 10^j
    place), masked by token length and contracted against the power table —
    a handful of whole-matrix C ops, no per-character index arrays and no
    per-token Python.
    """
    nt = len(ts_s)
    neg = a[ts_s] == 45
    if nt == 0:
        return np.zeros(0, np.int64), neg
    length = te_s - ts_s + 1 - neg
    lmax = int(length.max())
    if int(length.min()) < 1 or lmax > 18:
        return None, None  # lone '-' or an id beyond the int64 digit budget
    # 9 digits fit int32 — half the matrix traffic for typical SNAP ids.
    dt = np.int64 if lmax > 9 else np.int32
    places = np.arange(lmax)
    # Negative indices only occur in masked (j >= length) cells and wrap
    # safely within the block.
    digits = a[te_s[:, None] - places[None, :]].astype(dt)
    digits -= 48
    mask = places[None, :] < length[:, None]
    if (((digits < 0) | (digits > 9)) & mask).any():
        # '+' signs, '_' separators, unicode digits, stray punctuation — the
        # reference parser decides (accepts or raises) per line.
        return None, None
    np.multiply(digits, mask, out=digits, casting="unsafe")
    vals = (digits @ _POW10[:lmax].astype(dt)).astype(np.int64)
    return np.where(neg, -vals, vals), neg


def _parse_block_bytes(
    block: bytes, lineno0: int, path: str
) -> tuple[np.ndarray, int, int, int]:
    """Vectorized parse of one newline-terminated block.

    One ``np.frombuffer`` view; newline positions, token boundaries,
    comment/blank classes, and the integer values themselves are all
    whole-block numpy ops. Anything the vector model does not cover exactly
    (``+`` signs, ``_`` separators, unicode digits, malformed rows,
    > 18-digit ids) delegates the block to :func:`_parse_block_python`,
    which preserves the reference semantics and error messages.
    """
    a = np.frombuffer(block, np.uint8)
    assert a[-1] == 10, "blocks must be newline-terminated"
    if block.find(b"\r") < 0:
        nl_idx = np.flatnonzero(a == 10)
    else:
        nl_idx = _universal_nl_idx(a)  # rare path: \r-bearing segment
    nlines = len(nl_idx)
    tok = ~_SEP_LUT[a]
    dt = np.diff(tok.view(np.int8))
    tr = np.flatnonzero(dt)  # one pass finds every token boundary
    sign = dt[tr]
    ts = tr[sign == 1] + 1  # first byte of every token
    if tok[0]:
        ts = np.concatenate([np.zeros(1, ts.dtype), ts])
    te = tr[sign == -1]  # last byte (block ends with \n: every token closes)
    if len(ts) == 0:
        return np.empty((0, 2), np.int64), nlines, 0, nlines
    # Tokens per line, line-major: the number of token starts before each
    # terminator is cumulative, so one searchsorted of the (smaller) line
    # array into the token starts yields every per-line count.
    cnt = np.searchsorted(ts, nl_idx)
    line_counts = np.diff(cnt, prepend=0)
    nonblank = line_counts > 0
    n_nonblank = int(nonblank.sum())
    blanks = nlines - n_nonblank
    first_tok = (cnt - line_counts)[nonblank]  # first token index per line
    # Comment classification off the first token: '#', '%', or '//' (the
    # second byte is in-bounds — every line ends with \n past the token).
    c0 = a[ts[first_tok]]
    comment = (c0 == 35) | (c0 == 37) | ((c0 == 47) & (a[ts[first_tok] + 1] == 47))
    comments = int(comment.sum())
    if comments == n_nonblank:
        return np.empty((0, 2), np.int64), nlines, comments, blanks

    counts = line_counts[nonblank]
    if comments == 0 and len(ts) == 2 * n_nonblank and (counts == 2).all():
        # Dominant clean shape: every non-blank line is exactly ``u v`` —
        # skip the per-line rank machinery entirely.
        vals, _ = _token_values(a, ts, te)
        if vals is None:
            return _parse_block_python(block, lineno0, path)
        return vals.reshape(-1, 2), nlines, comments, blanks

    data_line = ~comment
    if (counts[data_line] < 2).any():
        # A data line with < 2 fields — the reference parser raises with the
        # exact file:line message.
        return _parse_block_python(block, lineno0, path)
    rank = np.arange(len(ts)) - np.repeat(first_tok, counts)
    sel = np.repeat(data_line, counts) & (rank < 2)
    vals, _ = _token_values(a, ts[sel], te[sel])
    if vals is None:
        return _parse_block_python(block, lineno0, path)
    return vals.reshape(-1, 2), nlines, comments, blanks


def _newline_blocks(f, chunk_bytes: int) -> Iterator[bytes]:
    """Yield newline-terminated byte blocks of ~chunk_bytes (a final line
    without a trailing newline is completed with one)."""
    rem = b""
    while True:
        buf = f.read(chunk_bytes)
        if not buf:
            if rem:
                yield rem + b"\n"
            return
        if rem:
            buf = rem + buf
        cut = buf.rfind(b"\n")
        if cut < 0:
            rem = buf
            continue
        yield buf[: cut + 1]
        rem = buf[cut + 1 :]


# ----------------------------------------------------------------------------
# The ingest driver
# ----------------------------------------------------------------------------


class _Densifier:
    """Shared id policy of both parsers: relabel to dense first-appearance
    ids, or validate raw ids against int32 / a pinned n.

    ``lineno_of(i)`` maps the i-th data row of the batch/block to its exact
    file line — resolved only on the error path, so the happy path stays
    vectorized while every id-policy error points at the offending line
    (identically for both parsers)."""

    def __init__(self, src: str, relabel: bool, num_vertices: Optional[int]):
        self.src = src
        self.relabel = relabel
        self.num_vertices = num_vertices
        self.max_id = -1
        self.id_map = _DenseIdMap()

    def __call__(self, rows: np.ndarray, lineno_of) -> np.ndarray:
        if self.relabel:
            return self.id_map.translate(rows.reshape(-1)).reshape(-1, 2)
        if not rows.size:
            return rows
        # One combined mask, first violation in STREAM order: the raised
        # error is then independent of batch/block granularity, so both
        # parsers report the identical id and line no matter how their
        # chunking differs.
        flat = rows.reshape(-1)
        hi = _I32_MAX if self.num_vertices is None else min(
            _I32_MAX, self.num_vertices
        )
        bad = np.flatnonzero((flat < 0) | (flat >= hi))
        if len(bad):
            i = int(bad[0])
            v = int(flat[i])
            if v < 0:
                raise ValueError(
                    f"{self.src}: negative vertex id {v} near line "
                    f"{lineno_of(i // 2)} (pass relabel=True)"
                )
            if v >= _I32_MAX:
                raise ValueError(
                    f"{self.src}: vertex id {v} overflows int32 "
                    "(pass relabel=True to densify)"
                )
            raise ValueError(
                f"{self.src}: vertex id {v} >= pinned "
                f"num_vertices={self.num_vertices} near line "
                f"{lineno_of(i // 2)}"
            )
        self.max_id = max(self.max_id, int(rows.max()))
        return rows


def _data_lineno_resolver(block: bytes, lineno0: int):
    """Error-path-only map from data-row index (within one block) to its
    absolute file line, replaying the reference classification (universal
    newlines, comment/blank skipping) — every tier yields exactly one row
    per data line, so the i-th row IS the i-th data line."""

    def lineno_of(i: int) -> int:
        text = block.decode().replace("\r\n", "\n").replace("\r", "\n")
        count = 0
        for j, line in enumerate(text.split("\n")[:-1]):
            if _classify_line(line) != "data":
                continue
            if count == i:
                return lineno0 + j
            count += 1
        return lineno0

    return lineno_of


def ingest_text(
    src: str,
    dst: str,
    *,
    relabel: bool = False,
    num_vertices: Optional[int] = None,
    chunk_lines: int = 1 << 16,
    parser: str = "bytes",
    chunk_bytes: int = 1 << 24,
) -> IngestReport:
    """Convert a text edge list at ``src`` into a binary edge file at ``dst``.

    Args:
      relabel: map vertex ids to a dense [0, n) space in first-appearance
        order (required for files with sparse / huge / negative ids).
        Without it, ids must fit non-negative int32 and n is inferred as
        ``max id + 1``.
      num_vertices: pin n instead of inferring it (ignored with ``relabel``,
        where n is the number of distinct ids).
      chunk_lines: lines parsed per batch under ``parser="python"`` — the
        O(chunk) memory bound of the reference parser.
      parser: ``"bytes"`` (vectorized block parser, the default) or
        ``"python"`` (the reference per-line loop — the parity oracle).
      chunk_bytes: bytes per block under ``parser="bytes"`` — the O(chunk)
        memory bound of the fast parser.

    Returns an :class:`IngestReport`; raises ``ValueError`` on malformed
    lines (with file:line in the message) and on out-of-range ids.
    """
    if parser not in ("bytes", "python"):
        raise ValueError(f"parser must be 'bytes' or 'python', got {parser!r}")
    t0 = time.perf_counter()
    lines = comments = blanks = 0
    densify = _Densifier(src, relabel, num_vertices)

    if parser == "bytes":
        with open(src, "rb") as f, EdgeFileWriter(dst, num_vertices=None) as w:
            for block in _newline_blocks(f, chunk_bytes):
                rows, nlines, ncomment, nblank = _parse_block(
                    block, lines + 1, src
                )
                if len(rows):
                    w.append(
                        densify(
                            rows, _data_lineno_resolver(block, lines + 1)
                        ).astype(np.int32)
                    )
                lines += nlines
                comments += ncomment
                blanks += nblank
            m = w.num_edges
    else:
        with open(src, "r") as f, EdgeFileWriter(dst, num_vertices=None) as w:
            batch: list[tuple[int, str]] = []
            for line in f:
                lines += 1
                cls = _classify_line(line)
                if cls == "blank":
                    blanks += 1
                    continue
                if cls == "comment":
                    comments += 1
                    continue
                batch.append((lines, line))
                if len(batch) >= chunk_lines:
                    rows = densify(_parse_batch(batch, src),
                                   lambda i, b=batch: b[i][0])
                    w.append(rows.astype(np.int32))
                    batch = []
            if batch:
                rows = densify(_parse_batch(batch, src),
                               lambda i, b=batch: b[i][0])
                w.append(rows.astype(np.int32))
            m = w.num_edges
    # The writer inferred n = max id + 1 (== max_id + 1 here); re-patch when
    # the caller pinned n or relabeling fixed it as the distinct-id count.
    if relabel:
        n_final = len(densify.id_map)
        _patch_header(dst, m, n_final)
    elif num_vertices is not None:
        n_final = num_vertices
        _patch_header(dst, m, n_final)
    else:
        n_final = densify.max_id + 1
    return IngestReport(
        num_edges=m,
        num_vertices=n_final,
        lines=lines,
        comment_lines=comments,
        blank_lines=blanks,
        bytes_read=os.path.getsize(src),
        wall_s=time.perf_counter() - t0,
        relabeled=relabel,
        parser=parser,
    )


def _patch_header(path: str, m: int, n: int) -> None:
    with open(path, "r+b") as f:
        f.write(_pack_header(m, n))
