"""Partitioning-quality metrics (Eq. 1 and Eq. 2 of the paper).

All metrics operate on an *assignment* array: ``assign[m] in [0, k)`` giving
the partition of every edge in stream order. Streaming partitioners can
legitimately emit ``-1`` ("unassigned") entries mid-run — re-streaming
revokes assignments, spotlight instances fill disjoint chunks — so every
metric here takes an explicit ``unassigned=`` policy:

  * ``"raise"`` (default): a ``-1`` entry raises ``ValueError``. Quality
    numbers computed over a partially-assigned stream are meaningless, and
    the historical behaviour was worse than meaningless — ``np.bincount``
    crashed on negatives while fancy-indexing silently *wrapped* ``-1``
    into partition ``k-1``, corrupting replication-degree and balance.
  * ``"drop"``: unassigned edges are masked out and the metric is computed
    over the assigned subset only. Use together with
    :func:`unassigned_count` so the dropped mass is always reported.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "replica_sets_from_assignment",
    "replica_sets_from_chunks",
    "replication_degree",
    "partition_sizes",
    "partition_balance",
    "sync_volume",
    "unassigned_count",
    "quality_from_chunks",
]


def unassigned_count(assign: np.ndarray) -> int:
    """Number of unassigned (``< 0``) entries in an assignment array."""
    assign = np.asarray(assign)
    return int((assign < 0).sum())


def _assigned_mask(assign: np.ndarray, k: int, unassigned: str) -> np.ndarray:
    """Validate ``assign`` against ``[0, k)`` and return the assigned mask."""
    if unassigned not in ("raise", "drop"):
        raise ValueError(f"unassigned policy must be 'raise' or 'drop', got {unassigned!r}")
    assign = np.asarray(assign)
    neg = assign < 0
    n_neg = int(neg.sum())
    if n_neg and unassigned == "raise":
        raise ValueError(
            f"assignment contains {n_neg} unassigned (-1) edges; pass "
            "unassigned='drop' to compute the metric over the assigned subset"
        )
    if assign.size and int(assign.max()) >= k:
        raise ValueError(f"assignment contains partition id {int(assign.max())} >= k={k}")
    return ~neg


def replica_sets_from_assignment(
    edges: np.ndarray,
    assign: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    unassigned: str = "raise",
) -> np.ndarray:
    """bool[V, k]: replicas[v, p] == vertex v has >=1 incident edge on partition p.

    Unassigned (``-1``) edges contribute no replicas under ``"drop"`` —
    fancy-indexing with ``-1`` would silently attribute them to partition
    ``k-1`` — and raise under the default policy.
    """
    assign = np.asarray(assign)
    ok = _assigned_mask(assign, k, unassigned)
    rep = np.zeros((num_vertices, k), dtype=bool)
    rep[edges[ok, 0], assign[ok]] = True
    rep[edges[ok, 1], assign[ok]] = True
    return rep


def replication_degree(replicas: np.ndarray) -> float:
    """Eq. 1: mean |R_v| over vertices that appear in the graph."""
    counts = replicas.sum(axis=1)
    present = counts > 0
    if not present.any():
        return 0.0
    return float(counts[present].mean())


def partition_sizes(
    assign: np.ndarray, k: int, *, unassigned: str = "raise"
) -> np.ndarray:
    """int64[k]: edges per partition. ``-1`` entries raise or are dropped —
    ``np.bincount`` raises on negatives, so they never reach it either way."""
    assign = np.asarray(assign)
    ok = _assigned_mask(assign, k, unassigned)
    return np.bincount(assign[ok], minlength=k).astype(np.int64)


def partition_balance(
    assign: np.ndarray, k: int, *, unassigned: str = "raise"
) -> float:
    """Imbalance iota = (maxsize - minsize) / maxsize  (0 = perfectly balanced)."""
    sizes = partition_sizes(assign, k, unassigned=unassigned)
    mx = sizes.max()
    if mx == 0:
        return 0.0
    return float((mx - sizes.min()) / mx)


def replica_sets_from_chunks(
    pairs,
    num_vertices: int,
    k: int,
    *,
    unassigned: str = "raise",
) -> np.ndarray:
    """Chunked accumulation of :func:`replica_sets_from_assignment`.

    ``pairs`` is an iterable of ``(edges_chunk, assign_chunk)`` — e.g. a
    zip of ``EdgeFileReader.chunks(c)`` with slices of an assignment spill
    memmap — so replica tables for file-resident graphs build with O(chunk)
    edge memory (the (V, k) bool table is vertex-sized state, as everywhere).
    Bitwise identical to the in-memory function on the concatenated stream.
    """
    rep = np.zeros((num_vertices, k), dtype=bool)
    for edges, assign in pairs:
        assign = np.asarray(assign)
        assert len(edges) == len(assign), (len(edges), len(assign))
        ok = _assigned_mask(assign, k, unassigned)
        rep[edges[ok, 0], assign[ok]] = True
        rep[edges[ok, 1], assign[ok]] = True
    return rep


def quality_from_chunks(
    pairs,
    num_vertices: int,
    k: int,
    *,
    unassigned: str = "raise",
) -> dict:
    """One chunked pass → the standard quality dict for a file-driven run:
    ``replication_degree`` (Eq. 1), ``imbalance`` (iota), ``sizes``,
    ``unassigned``, plus the accumulated ``replicas`` table itself (callers
    that need both the numbers and the table — e.g. re-streaming warm starts
    — get them from the single read). Matches the in-memory metrics exactly.
    """
    rep = np.zeros((num_vertices, k), dtype=bool)
    sizes = np.zeros(k, dtype=np.int64)
    n_unassigned = 0
    for edges, assign in pairs:
        assign = np.asarray(assign)
        assert len(edges) == len(assign), (len(edges), len(assign))
        ok = _assigned_mask(assign, k, unassigned)
        n_unassigned += int((~ok).sum())
        rep[edges[ok, 0], assign[ok]] = True
        rep[edges[ok, 1], assign[ok]] = True
        sizes += np.bincount(assign[ok], minlength=k).astype(np.int64)
    mx = sizes.max() if k else 0
    imbalance = float((mx - sizes.min()) / mx) if mx > 0 else 0.0
    return dict(
        replication_degree=replication_degree(rep),
        imbalance=imbalance,
        sizes=sizes,
        unassigned=n_unassigned,
        sync_volume=sync_volume(rep),
        replicas=rep,
    )


def sync_volume(replicas: np.ndarray, bytes_per_replica: int = 8) -> int:
    """Per-iteration replica-synchronisation traffic.

    Every replicated vertex must exchange its accumulator with its master each
    superstep; a vertex with |R_v| replicas costs (|R_v| - 1) messages up and
    (|R_v| - 1) messages down. This is the quantity the paper's 'processing
    latency' is driven by (GrapH replica synchronisation).
    """
    counts = replicas.sum(axis=1)
    msgs = np.maximum(counts - 1, 0).sum() * 2
    return int(msgs) * bytes_per_replica
