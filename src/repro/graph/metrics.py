"""Partitioning-quality metrics (Eq. 1 and Eq. 2 of the paper).

All metrics operate on an *assignment* array: ``assign[m] in [0, k)`` giving
the partition of every edge in stream order.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "replica_sets_from_assignment",
    "replication_degree",
    "partition_sizes",
    "partition_balance",
    "sync_volume",
]


def replica_sets_from_assignment(
    edges: np.ndarray, assign: np.ndarray, num_vertices: int, k: int
) -> np.ndarray:
    """bool[V, k]: replicas[v, p] == vertex v has >=1 incident edge on partition p."""
    rep = np.zeros((num_vertices, k), dtype=bool)
    rep[edges[:, 0], assign] = True
    rep[edges[:, 1], assign] = True
    return rep


def replication_degree(replicas: np.ndarray) -> float:
    """Eq. 1: mean |R_v| over vertices that appear in the graph."""
    counts = replicas.sum(axis=1)
    present = counts > 0
    if not present.any():
        return 0.0
    return float(counts[present].mean())


def partition_sizes(assign: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(assign, minlength=k).astype(np.int64)


def partition_balance(assign: np.ndarray, k: int) -> float:
    """Imbalance iota = (maxsize - minsize) / maxsize  (0 = perfectly balanced)."""
    sizes = partition_sizes(assign, k)
    mx = sizes.max()
    if mx == 0:
        return 0.0
    return float((mx - sizes.min()) / mx)


def sync_volume(replicas: np.ndarray, bytes_per_replica: int = 8) -> int:
    """Per-iteration replica-synchronisation traffic.

    Every replicated vertex must exchange its accumulator with its master each
    superstep; a vertex with |R_v| replicas costs (|R_v| - 1) messages up and
    (|R_v| - 1) messages down. This is the quantity the paper's 'processing
    latency' is driven by (GrapH replica synchronisation).
    """
    counts = replicas.sum(axis=1)
    msgs = np.maximum(counts - 1, 0).sum() * 2
    return int(msgs) * bytes_per_replica
