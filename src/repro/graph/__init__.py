"""Graph substrate: synthetic generators, edge streams, storage, metrics."""
from repro.graph.generate import (
    barabasi_albert,
    erdos_renyi,
    rmat,
    watts_strogatz,
    make_graph,
    GRAPH_PRESETS,
)
from repro.graph.stream import EdgeStream
from repro.graph.metrics import (
    replication_degree,
    partition_balance,
    partition_sizes,
    quality_from_chunks,
    replica_sets_from_assignment,
    replica_sets_from_chunks,
    sync_volume,
    unassigned_count,
)

__all__ = [
    "barabasi_albert",
    "erdos_renyi",
    "rmat",
    "watts_strogatz",
    "make_graph",
    "GRAPH_PRESETS",
    "EdgeStream",
    "replication_degree",
    "partition_balance",
    "partition_sizes",
    "quality_from_chunks",
    "replica_sets_from_assignment",
    "replica_sets_from_chunks",
    "sync_volume",
    "unassigned_count",
]
