"""Edge-stream abstraction.

A streaming partitioner consumes edges in a fixed order, in chunks. The
stream also supports splitting into ``z`` disjoint sub-streams for parallel
loading (one per partitioner instance, as in the paper's evaluation setup
where each of 8 machines loads 1/8 of the graph).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

__all__ = ["EdgeStream"]


@dataclasses.dataclass
class EdgeStream:
    """An ordered stream of graph edges.

    Attributes:
      edges: (m, 2) int32 array in stream order.
      num_vertices: |V|.
    """

    edges: np.ndarray
    num_vertices: int

    def __post_init__(self) -> None:
        assert self.edges.ndim == 2 and self.edges.shape[1] == 2, self.edges.shape
        self.edges = np.ascontiguousarray(self.edges, dtype=np.int32)

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    def shuffled(self, seed: int = 0) -> "EdgeStream":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(self.num_edges)
        return EdgeStream(self.edges[perm], self.num_vertices)

    def chunks(self, chunk_size: int) -> Iterator[np.ndarray]:
        for start in range(0, self.num_edges, chunk_size):
            yield self.edges[start : start + chunk_size]

    def split(self, z: int) -> Sequence["EdgeStream"]:
        """Split into z contiguous disjoint sub-streams (parallel loading model).

        Instance boundaries are the ceil(m/z)-row chunks of
        :meth:`split_padded`, so the sequential (loop) and batched spotlight
        backends govern identical edge ranges per instance for any z and m
        (trailing instances may be shorter or empty when z does not divide m).
        """
        bounds = self.split_bounds(self.num_edges, z)
        return [
            EdgeStream(self.edges[bounds[i] : bounds[i + 1]], self.num_vertices)
            for i in range(z)
        ]

    @staticmethod
    def split_bounds(m: int, z: int) -> np.ndarray:
        """(z+1,) int64 instance boundaries shared by split / split_padded."""
        per = -(-m // z) if m else 0
        return np.minimum(np.arange(z + 1, dtype=np.int64) * per, m)

    def split_padded(self, z: int) -> tuple[np.ndarray, np.ndarray]:
        """Split into z equal, padded chunks.

        Returns (edges[z, ceil(m/z), 2], valid[z, ceil(m/z)]); padding edges are
        (0, 0) with valid=False. Suitable for vmap/shard_map parallel loading.
        """
        per = -(-self.num_edges // z)
        padded = np.zeros((z * per, 2), dtype=np.int32)
        padded[: self.num_edges] = self.edges
        valid = np.zeros((z * per,), dtype=bool)
        valid[: self.num_edges] = True
        return padded.reshape(z, per, 2), valid.reshape(z, per)

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.num_vertices, dtype=np.int64)
        np.add.at(deg, self.edges[:, 0], 1)
        np.add.at(deg, self.edges[:, 1], 1)
        return deg

    def save(self, path: str) -> None:
        np.savez_compressed(path, edges=self.edges, num_vertices=self.num_vertices)

    @staticmethod
    def load(path: str) -> "EdgeStream":
        # NpzFile holds the archive open until closed; copy the arrays out
        # under a context manager so the file handle never leaks.
        with np.load(path) as data:
            return EdgeStream(data["edges"].copy(), int(data["num_vertices"]))

    def to_file(self, path: str) -> None:
        """Write as a binary edge-stream file (`repro.graph.io` format)."""
        from repro.graph.io.format import write_edge_file

        write_edge_file(path, self.edges, self.num_vertices)

    @staticmethod
    def from_file(path: str) -> "EdgeStream":
        """Load a binary edge-stream file fully resident (small graphs /
        tests; large graphs should stay behind an ``EdgeFileReader``)."""
        from repro.graph.io.format import read_edge_file

        edges, n = read_edge_file(path)
        return EdgeStream(edges, n)
