"""Synthetic graph generators.

The paper evaluates on Orkut (social, low clustering c=0.04), Brain
(biological, moderate clustering c=0.51) and Web (very high clustering
c=0.82). Those datasets are not available offline, so we provide generators
whose knobs reproduce the *properties the paper's claims depend on*: degree
skew (power-law) and local clustering coefficient. Presets ``orkut_like``,
``brain_like`` and ``web_like`` are calibrated stand-ins at CPU-feasible
scale.

All generators return an int32 edge array of shape (m, 2) plus the vertex
count. Edges are undirected conceptually; they are stored as (u, v) pairs in
*stream order* (the order a streaming partitioner would see them). Use
``repro.graph.stream.EdgeStream`` to reshuffle / chunk.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

__all__ = [
    "rmat",
    "barabasi_albert",
    "watts_strogatz",
    "erdos_renyi",
    "make_graph",
    "GRAPH_PRESETS",
    "clustering_coefficient",
]


def _dedupe(edges: np.ndarray, n: int) -> np.ndarray:
    """Remove self loops and duplicate (u,v)/(v,u) edges, keep first occurrence order."""
    u, v = edges[:, 0], edges[:, 1]
    mask = u != v
    edges = edges[mask]
    lo = np.minimum(edges[:, 0], edges[:, 1]).astype(np.int64)
    hi = np.maximum(edges[:, 0], edges[:, 1]).astype(np.int64)
    key = lo * np.int64(n) + hi
    _, first_idx = np.unique(key, return_index=True)
    first_idx.sort()
    return edges[first_idx]


def rmat(
    n_log2: int,
    m: int,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> tuple[np.ndarray, int]:
    """R-MAT power-law generator (Chakrabarti et al.).

    Produces a skewed degree distribution similar to social graphs. ``a,b,c``
    are the recursive quadrant probabilities (d = 1-a-b-c).
    """
    rng = np.random.default_rng(seed)
    n = 1 << n_log2
    # Oversample; dedupe trims self-loops/duplicates.
    factor = 1.35
    num = int(m * factor)
    probs = np.array([a, b, c, 1.0 - a - b - c])
    quadrants = rng.choice(4, size=(num, n_log2), p=probs)
    # quadrant 0 -> (0,0), 1 -> (0,1), 2 -> (1,0), 3 -> (1,1)
    row_bits = (quadrants >= 2).astype(np.int64)
    col_bits = (quadrants % 2).astype(np.int64)
    weights = 1 << np.arange(n_log2 - 1, -1, -1, dtype=np.int64)
    u = (row_bits * weights).sum(axis=1)
    v = (col_bits * weights).sum(axis=1)
    edges = np.stack([u, v], axis=1).astype(np.int32)
    edges = _dedupe(edges, n)[:m]
    return edges, n


def barabasi_albert(n: int, m_per_node: int, seed: int = 0) -> tuple[np.ndarray, int]:
    """Barabási–Albert preferential attachment: power-law, low clustering."""
    rng = np.random.default_rng(seed)
    edges = []
    # Start with a small clique.
    core = m_per_node + 1
    for i in range(core):
        for j in range(i + 1, core):
            edges.append((i, j))
    # Repeated-endpoint list approximates preferential attachment.
    targets = [e for pair in edges for e in pair]
    for v in range(core, n):
        chosen = set()
        while len(chosen) < m_per_node:
            chosen.add(targets[rng.integers(0, len(targets))])
        for u in chosen:
            edges.append((u, v))
            targets.extend((u, v))
    arr = np.array(edges, dtype=np.int32)
    return _dedupe(arr, n), n


def watts_strogatz(n: int, k: int, beta: float, seed: int = 0) -> tuple[np.ndarray, int]:
    """Watts–Strogatz small-world: high clustering coefficient (ring + rewiring)."""
    rng = np.random.default_rng(seed)
    src = np.repeat(np.arange(n, dtype=np.int64), k // 2)
    offsets = np.tile(np.arange(1, k // 2 + 1, dtype=np.int64), n)
    dst = (src + offsets) % n
    rewire = rng.random(src.shape[0]) < beta
    dst[rewire] = rng.integers(0, n, size=int(rewire.sum()))
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    return _dedupe(edges, n), n


def erdos_renyi(n: int, m: int, seed: int = 0) -> tuple[np.ndarray, int]:
    rng = np.random.default_rng(seed)
    num = int(m * 1.15)
    u = rng.integers(0, n, size=num)
    v = rng.integers(0, n, size=num)
    edges = np.stack([u, v], axis=1).astype(np.int32)
    return _dedupe(edges, n)[:m], n


def clustered_powerlaw(
    n: int,
    m: int,
    community_size: int,
    p_intra: float,
    seed: int = 0,
) -> tuple[np.ndarray, int]:
    """Power-law hubs + strong communities (Brain/Web-like).

    Vertices are grouped into communities of ``community_size``. With
    probability ``p_intra`` an edge is drawn inside a community (producing
    high local clustering), otherwise endpoints follow a Zipf-ish hub
    distribution (producing skew). This mirrors the stereotypical structure in
    Fig. 5 of the paper: cliquish low-degree regions connected through
    high-degree hubs.
    """
    rng = np.random.default_rng(seed)
    num = int(m * 1.3)
    n_comm = max(1, n // community_size)
    intra = rng.random(num) < p_intra
    # Intra-community edges.
    comm = rng.integers(0, n_comm, size=num)
    base = comm * community_size
    iu = base + rng.integers(0, community_size, size=num)
    iv = base + rng.integers(0, community_size, size=num)
    # Hub edges: Zipf exponent ~2 over vertices.
    hub_u = (rng.zipf(1.8, size=num) - 1) % n
    hv = rng.integers(0, n, size=num)
    u = np.where(intra, iu, hub_u).astype(np.int64) % n
    v = np.where(intra, iv, hv).astype(np.int64) % n
    edges = np.stack([u, v], axis=1).astype(np.int32)
    return _dedupe(edges, n)[:m], n


def clustering_coefficient(edges: np.ndarray, n: int, sample: int = 400, seed: int = 0) -> float:
    """Approximate average local clustering coefficient over a vertex sample."""
    rng = np.random.default_rng(seed)
    adj: Dict[int, set] = {}
    for u, v in edges:
        adj.setdefault(int(u), set()).add(int(v))
        adj.setdefault(int(v), set()).add(int(u))
    verts = [v for v in adj if len(adj[v]) >= 2]
    if not verts:
        return 0.0
    picks = rng.choice(len(verts), size=min(sample, len(verts)), replace=False)
    total = 0.0
    for i in picks:
        v = verts[i]
        nbrs = list(adj[v])
        d = len(nbrs)
        links = 0
        for a in range(d):
            sa = adj[nbrs[a]]
            for b in range(a + 1, d):
                if nbrs[b] in sa:
                    links += 1
        total += 2.0 * links / (d * (d - 1))
    return total / len(picks)


@dataclasses.dataclass(frozen=True)
class GraphPreset:
    """Named generator configuration (paper-graph stand-in)."""

    name: str
    fn: Callable[..., tuple[np.ndarray, int]]
    kwargs: dict
    description: str


GRAPH_PRESETS: Dict[str, GraphPreset] = {
    # Social graph, low clustering (paper: Orkut, c~0.04) — RMAT skew.
    "orkut_like": GraphPreset(
        "orkut_like",
        rmat,
        dict(n_log2=16, m=400_000),
        "power-law social graph, low clustering (Orkut proxy)",
    ),
    # Biological, moderate clustering (paper: Brain, c~0.51).
    "brain_like": GraphPreset(
        "brain_like",
        clustered_powerlaw,
        dict(n=40_000, m=400_000, community_size=28, p_intra=0.62),
        "moderately clustered hub graph (Brain proxy)",
    ),
    # Web graph, very high clustering (paper: Web, c~0.82).
    "web_like": GraphPreset(
        "web_like",
        clustered_powerlaw,
        dict(n=60_000, m=500_000, community_size=40, p_intra=0.9),
        "highly clustered web-like graph (Web proxy)",
    ),
    # Small variants for tests.
    "tiny_social": GraphPreset("tiny_social", rmat, dict(n_log2=10, m=4_000), "tiny RMAT"),
    "tiny_clustered": GraphPreset(
        "tiny_clustered",
        clustered_powerlaw,
        dict(n=1_000, m=5_000, community_size=20, p_intra=0.8),
        "tiny clustered",
    ),
}


def make_graph(
    preset: str, seed: int = 0, scale: float = 1.0, order: str = "file"
) -> tuple[np.ndarray, int]:
    """Instantiate a preset; ``scale`` multiplies edge/vertex counts.

    order: 'file' (default) sorts edges by source vertex — the order real
    edge-list files (Orkut/Brain/Web adjacency dumps) are stored in and what
    a streaming partitioner actually consumes. This stream *locality* is what
    window/clustering scores and the spotlight optimization exploit (paper
    §III-C/D). 'random' shuffles (adversarial stream).
    """
    p = GRAPH_PRESETS[preset]
    kw = dict(p.kwargs)
    for key in ("m", "n"):
        if key in kw:
            kw[key] = max(64, int(kw[key] * scale))
    if "n_log2" in kw and scale != 1.0:
        kw["n_log2"] = max(8, kw["n_log2"] + int(np.round(np.log2(scale))))
    edges, n = p.fn(seed=seed, **kw)
    if order == "file":
        idx = np.argsort(edges[:, 0], kind="stable")
        edges = edges[idx]
    elif order == "random":
        rng = np.random.default_rng(seed + 777)
        edges = edges[rng.permutation(len(edges))]
    else:
        raise ValueError(order)
    return edges, n
