"""Host-side span tracer for the streaming-partition pipeline.

Span model
----------
A *span* is a named interval ``[t0, t1]`` on the monotonic clock
(``time.perf_counter``), tagged with a category and structured attrs and
placed on a *track*. Tracks default to the recording thread's name (the
main stepping loop records onto ``main``, the read-ahead worker onto
``adwise-readahead``); callers can override with ``track=`` to create
virtual lanes (restream passes use ``restream-pass-<j>``). Nesting is
by timestamp containment per track — exactly how Perfetto renders
Chrome trace events — so spans carry no explicit parent pointers.

Two recording paths, by temperature:

* ``with tracer.span(name, cat=...):`` — context manager, for coarse
  spans (passes, phases, supersteps, CLI sections).
* ``tracer.add_span(name, cat, t0, t1)`` — explicit timestamps, for hot
  loops. The caller takes ``perf_counter()`` itself, which lets a span
  share the *exact* float pair that also feeds a stats counter (e.g. the
  blocking-refill span reuses the timestamps behind ``h2d_wait_s``), so
  category wall totals reconcile with the scalar counters bit-for-bit.

Overhead contract
-----------------
Hot paths gate on ``tracer.enabled`` (a plain class attribute — one
attribute load) and only then take timestamps or build attr dicts. With
tracing disabled callers hold :data:`NULL_TRACER`, a module-level
singleton whose ``span()`` returns a shared no-op span object: the
disabled path allocates nothing per call and records nothing, which is
what lets the driver keep a tracer on its hottest loops unconditionally.

Everything here is host-side and stdlib-only by design: spans must wrap
dispatch and host waits only — never values still on device. Calling the
tracer *inside* a jit-traced step closure would concretize tracers and
add a per-step host sync; ``tools/staticcheck`` rule SC003 flags exactly
that (see ``tools/staticcheck/README.md``).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "resolve_tracer",
    "TraceSummary",
    "SpanRecord",
]


class SpanRecord(NamedTuple):
    """One recorded interval. ``t0``/``t1`` are perf_counter seconds."""

    name: str
    cat: str
    track: str
    thread: str
    t0: float
    t1: float
    attrs: Dict[str, Any]


class InstantRecord(NamedTuple):
    name: str
    cat: str
    track: str
    thread: str
    t: float
    attrs: Dict[str, Any]


class CounterRecord(NamedTuple):
    name: str
    track: str
    t: float
    value: float


class TraceSummary(NamedTuple):
    """Per-category wall totals over a tracer's recorded spans.

    ``categories`` maps category -> ``{"count": n, "wall_s": total}``;
    the totals are sums of span durations (concurrent spans in one
    category double-count, by design — they reconcile with the *scalar*
    counters, which accumulate the same way: the ``refill`` category
    total equals ``h2d_wait_s``, the ``stage`` total equals
    ``prestage_wall_s``, and the ``scan`` count equals ``scan_calls``).
    """

    events: int
    wall_s: float
    categories: Dict[str, Dict[str, float]]
    tracks: Tuple[str, ...]

    def as_dict(self) -> Dict[str, Any]:
        return {
            "events": self.events,
            "wall_s": self.wall_s,
            "categories": self.categories,
            "tracks": list(self.tracks),
        }


class _Span:
    """Context-manager span; records itself on ``__exit__``."""

    __slots__ = ("_tracer", "_name", "_cat", "_track", "_attrs", "_t0")

    def __init__(
        self,
        tracer: "Tracer",
        name: str,
        cat: str,
        track: Optional[str],
        attrs: Dict[str, Any],
    ) -> None:
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._track = track
        self._attrs = attrs
        self._t0 = 0.0

    def set(self, **attrs: Any) -> "_Span":
        """Attach attrs discovered mid-span (e.g. per-pass quality)."""
        self._attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self._tracer.add_span(
            self._name,
            self._cat,
            self._t0,
            time.perf_counter(),
            track=self._track,
            attrs=self._attrs,
        )


class _NullSpan:
    """Shared no-op span: zero allocation on the disabled path."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans/instants/counters; thread-safe; export-ready.

    The epoch ``t0`` is taken at construction; exported timestamps are
    relative to it. All recording methods may be called from any thread.
    """

    enabled: bool = True

    def __init__(self) -> None:
        self.t0 = time.perf_counter()
        self._lock = threading.Lock()
        self.spans: List[SpanRecord] = []
        self.instants: List[InstantRecord] = []
        self.counters: List[CounterRecord] = []

    # -- recording ---------------------------------------------------------
    def _track(self, track: Optional[str]) -> str:
        if track is not None:
            return track
        name = threading.current_thread().name
        return "main" if name == "MainThread" else name

    def span(
        self, name: str, cat: str = "misc", track: Optional[str] = None, **attrs: Any
    ) -> _Span:
        """Open a context-manager span (coarse path)."""
        return _Span(self, name, cat, track, attrs)

    def add_span(
        self,
        name: str,
        cat: str,
        t0: float,
        t1: float,
        track: Optional[str] = None,
        attrs: Optional[Dict[str, Any]] = None,
    ) -> None:
        """Record a finished interval with caller-taken timestamps."""
        rec = SpanRecord(
            name,
            cat,
            self._track(track),
            threading.current_thread().name,
            t0,
            t1,
            attrs if attrs is not None else {},
        )
        with self._lock:
            self.spans.append(rec)

    def instant(
        self, name: str, cat: str = "misc", track: Optional[str] = None, **attrs: Any
    ) -> None:
        rec = InstantRecord(
            name,
            cat,
            self._track(track),
            threading.current_thread().name,
            time.perf_counter(),
            attrs,
        )
        with self._lock:
            self.instants.append(rec)

    def gauge(self, name: str, value: float, track: Optional[str] = None) -> None:
        rec = CounterRecord(name, self._track(track), time.perf_counter(), float(value))
        with self._lock:
            self.counters.append(rec)

    # -- reading -----------------------------------------------------------
    def summary(self) -> TraceSummary:
        """Per-category totals over everything recorded so far.

        Cumulative over the tracer's lifetime: a tracer threaded through
        several restream passes summarizes all of them.
        """
        with self._lock:
            spans = list(self.spans)
            n_other = len(self.instants) + len(self.counters)
        cats: Dict[str, Dict[str, float]] = {}
        tracks: Dict[str, None] = {}
        lo, hi = float("inf"), float("-inf")
        for s in spans:
            c = cats.setdefault(s.cat, {"count": 0, "wall_s": 0.0})
            c["count"] += 1
            c["wall_s"] += s.t1 - s.t0
            tracks.setdefault(s.track)
            lo, hi = min(lo, s.t0), max(hi, s.t1)
        return TraceSummary(
            events=len(spans) + n_other,
            wall_s=(hi - lo) if spans else 0.0,
            categories=cats,
            tracks=tuple(tracks),
        )

    def export(self, path: str) -> int:
        """Write a Chrome trace-event JSON; returns the event count."""
        from .export import export_chrome_trace

        return export_chrome_trace(self, path)


class NullTracer:
    """API-compatible no-op. ``enabled`` is False; hot paths branch on it
    and skip even timestamp-taking; the coarse path gets a shared no-op
    span object, so the disabled path allocates nothing per call."""

    __slots__ = ()
    enabled: bool = False
    t0: float = 0.0

    def span(
        self, name: str, cat: str = "misc", track: Optional[str] = None, **attrs: Any
    ) -> _NullSpan:
        return _NULL_SPAN

    def add_span(
        self,
        name: str,
        cat: str,
        t0: float,
        t1: float,
        track: Optional[str] = None,
        attrs: Optional[Dict[str, Any]] = None,
    ) -> None:
        return None

    def instant(
        self, name: str, cat: str = "misc", track: Optional[str] = None, **attrs: Any
    ) -> None:
        return None

    def gauge(self, name: str, value: float, track: Optional[str] = None) -> None:
        return None

    def summary(self) -> TraceSummary:
        return TraceSummary(events=0, wall_s=0.0, categories={}, tracks=())

    def export(self, path: str) -> int:
        raise RuntimeError("cannot export from a NullTracer (tracing is disabled)")


NULL_TRACER = NullTracer()


def resolve_tracer(trace: Any) -> Any:
    """``None`` -> the module-level null singleton; anything else passes
    through. The single entry point every ``trace=`` kwarg funnels into."""
    return NULL_TRACER if trace is None else trace
