"""Chrome trace-event JSON export + schema validation.

Emits the JSON-object flavor of the Chrome trace-event format:
``{"traceEvents": [...]}`` with

* ``"X"`` complete events (one per span: ``ts``/``dur`` in microseconds
  relative to the tracer's epoch),
* ``"i"`` instant events,
* ``"C"`` counter events (gauges render as counter tracks),
* ``"M"`` metadata events naming the process and one thread per track.

Load the file at https://ui.perfetto.dev or chrome://tracing. Perfetto
nests ``X`` events on a track by timestamp containment, so the span tree
needs no explicit depth. Tracks map to synthetic tids in first-seen
order; virtual lanes (e.g. ``restream-pass-2``) are just extra tids.

``validate_chrome_trace`` is the schema check tools/ci.sh runs on the
traced-smoke artifact; tests import it too.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

__all__ = ["chrome_trace", "export_chrome_trace", "validate_chrome_trace"]

_PID = 1


def _san(v: Any) -> Any:
    """JSON-safe attr values. numpy scalars arrive because hot-loop spans
    must not call int()/float() on host mirrors of traced values (that is
    an SC003 sync pattern); they are unwrapped here, at export time."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return item()
        except (TypeError, ValueError):
            pass
    return str(v)


def chrome_trace(tracer: Any) -> Dict[str, Any]:
    """Build the trace-event document from a :class:`~repro.obs.Tracer`."""
    with tracer._lock:
        spans = list(tracer.spans)
        instants = list(tracer.instants)
        counters = list(tracer.counters)
    epoch = tracer.t0
    tids: Dict[str, int] = {}

    def tid(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids) + 1
        return tids[track]

    def us(t: float) -> float:
        return round((t - epoch) * 1e6, 3)

    events: List[Dict[str, Any]] = []
    for s in spans:
        events.append(
            {
                "name": s.name,
                "cat": s.cat,
                "ph": "X",
                "ts": us(s.t0),
                "dur": round(max(s.t1 - s.t0, 0.0) * 1e6, 3),
                "pid": _PID,
                "tid": tid(s.track),
                "args": {k: _san(v) for k, v in s.attrs.items()},
            }
        )
    for i in instants:
        events.append(
            {
                "name": i.name,
                "cat": i.cat,
                "ph": "i",
                "s": "t",
                "ts": us(i.t),
                "pid": _PID,
                "tid": tid(i.track),
                "args": {k: _san(v) for k, v in i.attrs.items()},
            }
        )
    for c in counters:
        events.append(
            {
                "name": c.name,
                "ph": "C",
                "ts": us(c.t),
                "pid": _PID,
                "tid": tid(c.track),
                "args": {c.name: c.value},
            }
        )
    events.sort(key=lambda e: e["ts"])
    meta: List[Dict[str, Any]] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": _PID,
            "args": {"name": "adwise-pipeline"},
        }
    ]
    for track, t in sorted(tids.items(), key=lambda kv: kv[1]):
        meta.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": _PID,
                "tid": t,
                "args": {"name": track},
            }
        )
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def export_chrome_trace(tracer: Any, path: str) -> int:
    doc = chrome_trace(tracer)
    with open(path, "w") as f:
        json.dump(doc, f, indent=None, separators=(",", ":"), default=str)
    return len(doc["traceEvents"])


def validate_chrome_trace(doc: Any) -> List[str]:
    """Schema check: required keys per phase, non-negative monotonic ts.

    Returns a list of human-readable problems (empty == valid). This is
    the gate tools/ci.sh applies to the traced-smoke artifact.
    """
    errors: List[str] = []
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        return ["document is not an object with a 'traceEvents' list"]
    last_ts = float("-inf")
    for n, e in enumerate(doc["traceEvents"]):
        if not isinstance(e, dict):
            errors.append(f"event {n}: not an object")
            continue
        ph = e.get("ph")
        if ph not in ("X", "i", "C", "M"):
            errors.append(f"event {n}: unknown ph {ph!r}")
            continue
        for key in ("name", "pid") + (() if ph == "M" else ("ts", "tid")):
            if key not in e:
                errors.append(f"event {n} (ph={ph}): missing key {key!r}")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {n}: ts must be a non-negative number, got {ts!r}")
            continue
        if ts < last_ts:
            errors.append(f"event {n}: ts {ts} not monotonic (prev {last_ts})")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {n}: X event needs non-negative dur, got {dur!r}")
        if ph == "i" and e.get("s") not in ("t", "p", "g"):
            errors.append(f"event {n}: instant needs scope s in t/p/g")
    if not any(e.get("ph") == "X" for e in doc["traceEvents"] if isinstance(e, dict)):
        errors.append("no complete ('X') span events present")
    return errors
