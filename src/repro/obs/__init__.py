"""Zero-dep tracing + metrics for the streaming-partition pipeline.

See README.md in this directory for the span model, track layout, and
overhead guarantees; see tracer.py / export.py for the API.

    from repro.obs import Tracer
    tr = Tracer()
    res = partition_file(reader, "hdrf", 8, z=2, trace=tr)
    tr.export("trace.json")          # open in https://ui.perfetto.dev
    print(res.stats["trace_summary"])
"""
from .export import chrome_trace, export_chrome_trace, validate_chrome_trace
from .tracer import (
    NULL_TRACER,
    NullTracer,
    SpanRecord,
    Tracer,
    TraceSummary,
    resolve_tracer,
)

__all__ = [
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "resolve_tracer",
    "TraceSummary",
    "SpanRecord",
    "chrome_trace",
    "export_chrome_trace",
    "validate_chrome_trace",
]
