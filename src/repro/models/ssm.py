"""Linear-recurrence sequence mixers: RWKV-6 (Finch) and Mamba-2 (SSD).

Both are implemented in the chunked-parallel form (the TPU-native adaptation:
intra-chunk work becomes MXU matmuls, inter-chunk state is a short lax.scan),
plus a single-token recurrent step for decode. fp32 state/decay numerics.

RWKV-6: per-channel data-dependent decay w_t ∈ (0,1)^{Dh} per head,
  S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ,   o_t = S_{t-1}ᵀ r_t + (r_t·(u⊙k_t)) v_t

Mamba-2 (SSD): scalar per-head decay a_t,
  h_t = a_t·h_{t-1} + B_t (Δ_t x_t)ᵀ,   y_t = C_tᵀ h_t + D ⊙ x_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm


# ----------------------------------------------------------------------------
# RWKV-6
# ----------------------------------------------------------------------------

def init_rwkv6(key, d_model: int, n_heads: int, dh: int, dtype):
    ks = jax.random.split(key, 8)
    lora = 64
    return dict(
        mu=0.5 * jnp.ones((5, d_model), dtype),  # token-shift mixes (r,k,v,g,w)
        w0=jnp.full((d_model,), -0.6, jnp.float32),  # decay base (log-log space)
        w_a=dense_init(ks[0], (d_model, lora), jnp.float32, scale=1e-2),
        w_b=dense_init(ks[1], (lora, d_model), jnp.float32, scale=1e-2),
        u=dense_init(ks[2], (n_heads, dh), jnp.float32, scale=0.5),
        wr=dense_init(ks[3], (d_model, d_model), dtype),
        wk=dense_init(ks[4], (d_model, d_model), dtype),
        wv=dense_init(ks[5], (d_model, d_model), dtype),
        wg=dense_init(ks[6], (d_model, d_model), dtype),
        wo=dense_init(ks[7], (d_model, d_model), dtype),
        ln_x=jnp.ones((d_model,), jnp.float32),
    )


def _rwkv6_chunk_scan(r, k, v, logw, u, s0, chunk: int):
    """Chunked GLA with per-channel decay.

    r,k,v,logw: (B, T, H, N) fp32 (logw ≤ 0);  u: (H, N);  s0: (B, H, N, N).
    Returns (o (B,T,H,N), s_final).

    All O(T·C) / O(T·N) matmul work is vectorized over chunks OUTSIDE the
    scan; the lax.scan body is only the tiny state recurrence
    S ← exp(p_last)·S + contrib. This is both the TPU-efficient form (bigger
    MXU ops, trivial sequential tail) and keeps XLA cost analysis exact
    (while-loop bodies are counted once by HLO cost analysis).
    """
    b, t, h, n = r.shape
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))  # logw=0: no decay
    csh = (b, nc, chunk, h, n)
    rc, kc, vc, wc = (x.reshape(csh) for x in (r, k, v, logw))
    pcum = jnp.cumsum(wc, axis=2)  # inclusive Σ log w
    pprev = pcum - wc  # exclusive
    plast = pcum[:, :, -1]  # (B, NC, H, N)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strict lower: j < t
    r_in = rc * jnp.exp(pprev)
    k_in = kc * jnp.exp(-pcum)

    # Intra-chunk attention + diagonal bonus (vectorized over chunks).
    a = jnp.einsum("bcthn,bcshn->bchts", r_in, k_in)
    a = jnp.where(tri[None, None, None], a, 0.0)
    o = jnp.einsum("bchts,bcshn->bcthn", a, vc)
    bonus = jnp.einsum("bcthn,hn,bcthn->bcth", rc, u, kc)
    o = o + bonus[..., None] * vc

    # Per-chunk state contributions (decay-to-end ≤ 1: stable).
    k_end = kc * jnp.exp(plast[:, :, None] - pcum)
    contrib = jnp.einsum("bcthn,bcthm->bchnm", k_end, vc)  # (B, NC, H, N, N)
    decay = jnp.exp(plast)  # (B, NC, H, N)

    # Tiny sequential recurrence; ys = state at each chunk START.
    def body(s, xs):
        d, c_ = xs
        return d[..., None] * s + c_, s

    s_fin, s_starts = jax.lax.scan(
        body,
        s0,
        (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(contrib, 1, 0)),
    )
    s_starts = jnp.moveaxis(s_starts, 0, 1)  # (B, NC, H, N, N)

    # Inter-chunk contribution (vectorized over chunks).
    o = o + jnp.einsum("bcthn,bchnm->bcthm", r_in, s_starts)
    o = o.reshape(b, nc * chunk, h, n)
    return o[:, :t], s_fin


def rwkv6_mixer(
    params,
    x: jax.Array,  # (B, T, D)
    *,
    n_heads: int,
    dh: int,
    state: Optional[jax.Array] = None,  # (B, H, N, N) fp32
    last_x: Optional[jax.Array] = None,  # (B, D) — token-shift carry
    chunk: int = 32,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out (B,T,D), new_state, new_last_x)."""
    b, t, d = x.shape
    prev = jnp.zeros((b, 1, d), x.dtype) if last_x is None else last_x[:, None]
    xx = jnp.concatenate([prev, x[:, :-1]], axis=1)  # token shift

    def mixed(i):
        return x + (xx - x) * params["mu"][i]

    heads = lambda y: y.reshape(b, t, n_heads, dh)
    r = heads(mixed(0) @ params["wr"]).astype(jnp.float32)
    k = heads(mixed(1) @ params["wk"]).astype(jnp.float32)
    v = heads(mixed(2) @ params["wv"]).astype(jnp.float32)
    g = mixed(3) @ params["wg"]
    w_raw = (
        params["w0"]
        + jnp.tanh(mixed(4).astype(jnp.float32) @ params["w_a"]) @ params["w_b"]
    )
    logw = -jnp.exp(w_raw).reshape(b, t, n_heads, dh)  # log w ≤ 0

    s0 = (
        jnp.zeros((b, n_heads, dh, dh), jnp.float32) if state is None else state
    )
    o, s_fin = _rwkv6_chunk_scan(r, k, v, logw, params["u"], s0, chunk)
    o = o.reshape(b, t, d)
    o = rms_norm(o.astype(x.dtype), params["ln_x"].astype(x.dtype))
    o = o * jax.nn.silu(g)
    return o @ params["wo"], s_fin, x[:, -1]


def init_rwkv6_cm(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        mu=0.5 * jnp.ones((2, d_model), dtype),  # (k, r) token-shift mixes
        wk=dense_init(k1, (d_model, d_ff), dtype),
        wv=dense_init(k2, (d_ff, d_model), dtype),
        wr=dense_init(k3, (d_model, d_model), dtype),
    )


def rwkv6_channel_mix(params, x, last_x=None):
    """RWKV channel-mix: squared-ReLU MLP with token shift and r-gate."""
    b, t, d = x.shape
    prev = jnp.zeros((b, 1, d), x.dtype) if last_x is None else last_x[:, None]
    xx = jnp.concatenate([prev, x[:, :-1]], axis=1)
    xk = x + (xx - x) * params["mu"][0]
    xr = x + (xx - x) * params["mu"][1]
    kk = jnp.square(jax.nn.relu(xk @ params["wk"]))
    return jax.nn.sigmoid(xr @ params["wr"]) * (kk @ params["wv"]), x[:, -1]


# ----------------------------------------------------------------------------
# Mamba-2 (SSD)
# ----------------------------------------------------------------------------

def init_mamba2(key, d_model: int, n_heads: int, d_state: int, dtype, expand: int = 2):
    """Projections kept SEPARATE (not one fused w_in) so the head-major dims
    (z/x: d_in = H·P, dt: H) can be TP-sharded on head boundaries — a fused
    in-projection has mixed-layout columns that cannot shard (§Perf C)."""
    d_in = expand * d_model
    ks = jax.random.split(key, 6)
    return dict(
        w_z=dense_init(ks[0], (d_model, d_in), dtype),
        w_x=dense_init(ks[1], (d_model, d_in), dtype),
        w_B=dense_init(ks[2], (d_model, d_state), dtype),
        w_C=dense_init(ks[3], (d_model, d_state), dtype),
        w_dt=dense_init(ks[4], (d_model, n_heads), dtype),
        a_log=jnp.zeros((n_heads,), jnp.float32),  # A = -exp(a_log) = -1
        dt_bias=jnp.full((n_heads,), -2.0, jnp.float32),  # softplus(-2) ≈ 0.13
        d_skip=jnp.ones((n_heads,), jnp.float32),
        norm=jnp.ones((d_in,), jnp.float32),
        w_out=dense_init(ks[5], (d_in, d_model), dtype),
    )


def _ssd_chunk_scan(xh, bc, cc, loga, s0, chunk: int):
    """Chunked SSD. xh: (B,T,H,P) Δ-scaled inputs; bc/cc: (B,T,N); loga: (B,T,H).

    s0: (B,H,N,P). Returns (y (B,T,H,P), s_final). Diagonal included (j ≤ t).
    """
    b, t, h, p = xh.shape
    n = bc.shape[-1]
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    xc = xh.reshape(b, nc, chunk, h, p)
    bcc = bc.reshape(b, nc, chunk, n)
    ccc = cc.reshape(b, nc, chunk, n)
    lac = loga.reshape(b, nc, chunk, h)
    pcum = jnp.cumsum(lac, axis=2)  # (B, NC, C, H) inclusive
    plast = pcum[:, :, -1]  # (B, NC, H)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))  # j ≤ t

    # Intra-chunk (vectorized over chunks; see _rwkv6_chunk_scan note).
    ldiff = pcum[:, :, :, None, :] - pcum[:, :, None, :, :]  # (B, NC, C, C, H)
    lmat = jnp.where(tri[None, None, :, :, None], jnp.exp(ldiff), 0.0)
    scores = jnp.einsum("bctn,bcsn->bcts", ccc, bcc)  # shared across heads
    y = jnp.einsum("bcts,bctsh,bcshp->bcthp", scores, lmat, xc)

    # Per-chunk state contributions.
    wgt = jnp.exp(plast[:, :, None] - pcum)  # (B, NC, C, H)
    contrib = jnp.einsum("bctn,bcth,bcthp->bchnp", bcc, wgt, xc)
    decay = jnp.exp(plast)  # (B, NC, H)

    def body(s, xs):
        d, c_ = xs
        return d[..., None, None] * s + c_, s

    s_fin, s_starts = jax.lax.scan(
        body, s0, (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(contrib, 1, 0))
    )
    s_starts = jnp.moveaxis(s_starts, 0, 1)  # (B, NC, H, N, P)

    y = y + jnp.einsum("bctn,bcth,bchnp->bcthp", ccc, jnp.exp(pcum), s_starts)
    y = y.reshape(b, nc * chunk, h, p)
    return y[:, :t], s_fin


def mamba2_mixer(
    params,
    x: jax.Array,  # (B, T, D)
    *,
    n_heads: int,
    d_state: int,
    state: Optional[jax.Array] = None,  # (B, H, N, P)
    chunk: int = 64,
    expand: int = 2,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,T,D), new_state)."""
    b, t, d = x.shape
    d_in = expand * d
    p = d_in // n_heads
    z = x @ params["w_z"]
    xs = x @ params["w_x"]
    bc = x @ params["w_B"]
    cc = x @ params["w_C"]
    dt = x @ params["w_dt"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    loga = -jnp.exp(params["a_log"])[None, None] * dt  # ≤ 0
    xh = xs.reshape(b, t, n_heads, p).astype(jnp.float32) * dt[..., None]
    s0 = (
        jnp.zeros((b, n_heads, d_state, p), jnp.float32) if state is None else state
    )
    y, s_fin = _ssd_chunk_scan(
        xh, bc.astype(jnp.float32), cc.astype(jnp.float32), loga, s0, chunk
    )
    y = y + params["d_skip"][None, None, :, None] * xs.reshape(b, t, n_heads, p).astype(
        jnp.float32
    )
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"].astype(x.dtype))
    return y @ params["w_out"], s_fin
