"""Transformer building blocks (pure-functional, GSPMD-friendly).

Everything is a function over nested-dict param pytrees; sharding is decided
entirely by `repro.launch.sharding` PartitionSpecs — no sharding logic here.
Attention uses a q-block-scanned online-softmax formulation so the compiled
memory footprint stays bounded for 32k prefill (XLA path; the Pallas
flash_attention kernel is the TPU-native alternative validated in tests).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

NEG_INF = -1e30


# ----------------------------------------------------------------------------
# Initializers / norms / rope
# ----------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype) * gamma


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., T, H, Dh); positions: (T,) or (B, T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # Broadcast over the head axis: (..., T, 1, half).
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Attention (GQA; q-block scanned softmax; optional KV cache)
# ----------------------------------------------------------------------------

def init_attention(key, d_model: int, h: int, kv: int, dh: int, dtype, qkv_bias: bool):
    ks = jax.random.split(key, 4)
    p = dict(
        wq=dense_init(ks[0], (d_model, h * dh), dtype),
        wk=dense_init(ks[1], (d_model, kv * dh), dtype),
        wv=dense_init(ks[2], (d_model, kv * dh), dtype),
        wo=dense_init(ks[3], (h * dh, d_model), dtype),
    )
    if qkv_bias:
        p.update(
            bq=jnp.zeros((h * dh,), dtype),
            bk=jnp.zeros((kv * dh,), dtype),
            bv=jnp.zeros((kv * dh,), dtype),
        )
    return p


def _blocked_softmax_attn(
    q: jax.Array,  # (B, H, Tq, Dh) — already scaled & roped
    k: jax.Array,  # (B, KV, Tk, Dh)
    v: jax.Array,  # (B, KV, Tk, Dh)
    causal: bool,
    q_offset,  # int or () array: absolute position of q[0]
    q_block: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Online-softmax over q blocks: bounds live logits to (B,H,BQ,Tk) fp32.

    `unroll=True` (dry-run) python-loops over at most 8 larger q blocks so
    XLA cost analysis sees every matmul (lax.map hides loop-body flops)."""
    b, h, tq, dh = q.shape
    kvh = k.shape[1]
    group = h // kvh
    tk = k.shape[2]
    if unroll:
        q_block = max(q_block, -(-tq // 8))
    qb = min(q_block, tq)
    n_blocks = -(-tq // qb)
    tq_pad = n_blocks * qb
    if tq_pad != tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_pad - tq), (0, 0)))
    qs = q.reshape(b, h, n_blocks, qb, dh).transpose(2, 0, 1, 3, 4)
    kg = k.astype(jnp.float32)
    vg = v.astype(jnp.float32)

    def one_block(i, qi):
        qi = qi.reshape(b, kvh, group, qb, dh).astype(jnp.float32)
        logits = jnp.einsum("bkgqd,bksd->bkgqs", qi, kg)
        if causal:
            qpos = q_offset + i * qb + jnp.arange(qb)
            mask = qpos[:, None] >= jnp.arange(tk)[None, :]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vg)
        return out.reshape(b, h, qb, dh)

    if unroll:
        outs = jnp.stack([one_block(i, qs[i]) for i in range(n_blocks)])
    else:
        outs = jax.lax.map(lambda args: one_block(*args), (jnp.arange(n_blocks), qs))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, tq_pad, dh)
    return out[:, :, :tq].astype(v.dtype)


def attention(
    params: Params,
    x: jax.Array,  # (B, T, D)
    *,
    h: int,
    kv: int,
    dh: int,
    rope_theta: float | None,
    causal: bool = True,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (k,v): (B, KV, S, Dh)
    cache_pos: Optional[jax.Array] = None,  # () int32 — write offset
    xattn_kv: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attention K/V
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """GQA attention. Returns (out (B,T,D), updated cache)."""
    b, t, d = x.shape
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(b, t, h, dh)

    if xattn_kv is not None:
        kk, vv = xattn_kv
        new_cache = None
        pos = jnp.zeros((), jnp.int32)
    else:
        kx = x @ params["wk"]
        vx = x @ params["wv"]
        if "bk" in params:
            kx, vx = kx + params["bk"], vx + params["bv"]
        kx = kx.reshape(b, t, kv, dh).transpose(0, 2, 1, 3)  # (B, KV, T, Dh)
        vx = vx.reshape(b, t, kv, dh).transpose(0, 2, 1, 3)
        pos = cache_pos if cache_pos is not None else jnp.zeros((), jnp.int32)
        if rope_theta:
            kpos = pos + jnp.arange(t)
            kx = rope(kx.transpose(0, 2, 1, 3), kpos, rope_theta).transpose(0, 2, 1, 3)
        if cache is not None:
            ck, cv = cache
            ck = jax.lax.dynamic_update_slice(ck, kx.astype(ck.dtype), (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(cv, vx.astype(cv.dtype), (0, 0, pos, 0))
            kk, vv = ck, cv
            new_cache = (ck, cv)
        else:
            kk, vv = kx, vx
            new_cache = None

    if rope_theta and xattn_kv is None:
        qpos = pos + jnp.arange(t)
        q = rope(q, qpos, rope_theta)
    q = (q * (dh**-0.5)).transpose(0, 2, 1, 3)  # (B, H, T, Dh)

    if cache is not None and t > 1:
        # Prefill-from-zero: attend within the fresh segment via the blocked
        # path (the cache is only *written*). Chunked prefill (pos > 0 with
        # t > 1) is intentionally unsupported — see DESIGN.md.
        out = _blocked_softmax_attn(q, kx, vx, causal, 0, unroll=unroll)
    elif cache is not None:
        # Decode: single new token attends the whole cache ≤ pos.
        # No dtype casts on the cache operands: einsum accumulates fp32 via
        # preferred_element_type — casting kk/vv materialized TWO full fp32
        # copies of the cache per layer (measured 17.9 GB/device on zamba2
        # long_500k; see EXPERIMENTS.md §Perf). Unwritten cache positions are
        # zeros (init) and excluded by the NEG_INF mask.
        s = kk.shape[2]
        live = jnp.arange(s) < (pos + t)
        logits_mask = jnp.where(live, 0.0, NEG_INF)
        group = h // kv
        qg = q.reshape(b, kv, group, t, dh)
        logits = jnp.einsum(
            "bkgqd,bksd->bkgqs", qg, kk, preferred_element_type=jnp.float32
        )
        logits = logits + logits_mask[None, None, None, None, :]
        if causal and t > 1:
            qpos = pos + jnp.arange(t)
            cmask = qpos[:, None] >= jnp.arange(s)[None, :]
            logits = jnp.where(cmask[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum(
            "bkgqs,bksd->bkgqd", probs.astype(vv.dtype), vv,
            preferred_element_type=jnp.float32,
        )
        out = out.reshape(b, h, t, dh).astype(x.dtype)
    else:
        out = _blocked_softmax_attn(
            q, kk, vv, causal and xattn_kv is None, 0, unroll=unroll
        )

    out = out.transpose(0, 2, 1, 3).reshape(b, t, h * dh)
    return out @ params["wo"], new_cache


# ----------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# ----------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return dict(
        w_gate=dense_init(k1, (d_model, d_ff), dtype),
        w_up=dense_init(k2, (d_model, d_ff), dtype),
        w_down=dense_init(k3, (d_ff, d_model), dtype),
    )


def mlp(params: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return dict(
        router=dense_init(k1, (d_model, n_experts), jnp.float32),
        w_gate=dense_init(k2, (n_experts, d_model, d_ff), dtype),
        w_up=dense_init(k3, (n_experts, d_model, d_ff), dtype),
        w_down=dense_init(k4, (n_experts, d_ff, d_model), dtype, scale=d_ff**-0.5),
    )


def moe_ffn(
    params: Params,
    x: jax.Array,  # (B, T, D)
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    router_bias: Optional[jax.Array] = None,  # (E,) — ADWISE-balance hook
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sort-based capacity-constrained top-k MoE (token-drop on overflow).

    Returns (out (B,T,D), aux_loss (), expert_load (E,)).
    `router_bias` lets `repro.core.moe_balance` inject the paper-style
    adaptive balance score into routing (beyond-paper integration).
    """
    b, t, d = x.shape
    n_tok = b * t
    e, k = n_experts, top_k
    cap = int(capacity_factor * n_tok * k / e)
    cap = max(8, -(-cap // 8) * 8)
    xf = x.reshape(n_tok, d)

    logits = (xf.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    if router_bias is not None:
        logits = logits + router_bias[None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): E · Σ_e f_e · p_e.
    me = probs.mean(axis=0)
    onehot_top1 = jax.nn.one_hot(gate_idx[:, 0], e)
    fe = onehot_top1.mean(axis=0)
    aux = e * jnp.sum(fe * me)

    flat_e = gate_idx.reshape(-1)  # (T·k,)
    flat_t = jnp.repeat(jnp.arange(n_tok), k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n_tok * k) - starts[se]
    keep = rank < cap
    dest = jnp.where(keep, se * cap + rank, e * cap)  # overflow -> dump row

    xs = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xf[st_])
    xs = xs[:-1].reshape(e, cap, d)
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xs, params["w_gate"]))
    hidden = hidden * jnp.einsum("ecd,edf->ecf", xs, params["w_up"])
    ys = jnp.einsum("ecf,efd->ecd", hidden, params["w_down"])  # (E, C, D)

    y_rows = ys.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None], y_rows[jnp.minimum(dest, e * cap - 1)], 0.0)
    out = (
        jnp.zeros((n_tok, d), jnp.float32)
        .at[st_]
        .add(gathered.astype(jnp.float32) * sw[:, None])
    )
    return out.reshape(b, t, d).astype(x.dtype), aux, counts.astype(jnp.float32)
