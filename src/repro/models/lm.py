"""Unified LM supporting all assigned architecture families.

  dense / moe / vlm : scanned GQA decoder blocks (SwiGLU or top-k MoE FFN)
  ssm (rwkv6)       : scanned RWKV-6 blocks (time-mix + channel-mix)
  hybrid (zamba2)   : Mamba-2 SSD layers + one shared-weight attention block
                      applied every `shared_every` layers
  encdec (whisper)  : bidirectional encoder over stub frame embeddings +
                      causal decoder with cross-attention

Three entry points (all pure functions of a nested-dict param pytree):
  init_params(cfg, key, tp)                      — tp: tensor-parallel degree,
                                                   only used for head padding
  forward_train(params, cfg, batch, tp)          — logits for next-token loss
  init_cache(cfg, batch, max_seq, tp) +
  forward_cached(params, cfg, cache, tokens, pos, ...) — prefill/decode

Layers are scanned (stacked params, lax.scan) so the HLO stays compact for
80-layer configs; train blocks are wrapped in jax.checkpoint (remat).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S

Params = Dict[str, Any]


def _constrain(x, batch_axes):
    """Anchor activation sharding: batch over the DP axes, rest replicated
    (the feature/vocab dims re-shard where the consumer demands — this only
    pins the batch dim so GSPMD never trades DP for parameter sharding)."""
    if batch_axes is None:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, *([None] * (x.ndim - 1)))
    )


def _maybe_scan(f, init, xs, unroll: bool):
    """lax.scan, or a Python unroll (exact XLA cost analysis for dry-runs)."""
    if not unroll:
        return jax.lax.scan(f, init, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    carry, ys = init, []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys


@dataclasses.dataclass(frozen=True)
class ModelDims:
    h: int
    kv: int
    dh: int
    policy: str  # 'shard' | 'shard_q' | 'pad' | 'replicate'


def model_dims(cfg: ArchConfig, tp: int = 1) -> ModelDims:
    h, kv, policy = cfg.padded_heads(tp)
    return ModelDims(h, kv, cfg.d_head, policy)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, dims: ModelDims, cross: bool = False):
    """One decoder block's params (attention + FFN [+ cross-attn])."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = dict(
        ln1=jnp.ones((cfg.d_model,), dt),
        attn=L.init_attention(
            ks[0], cfg.d_model, dims.h, dims.kv, dims.dh, dt, cfg.qkv_bias
        ),
        ln2=jnp.ones((cfg.d_model,), dt),
    )
    if cfg.moe:
        p["moe"] = L.init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.moe.n_experts, dt)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
    if cross:
        p["ln_x"] = jnp.ones((cfg.d_model,), dt)
        p["xattn"] = L.init_attention(
            ks[2], cfg.d_model, dims.h, dims.kv, dims.dh, dt, cfg.qkv_bias
        )
    if dims.policy == "pad" and cfg.n_heads < dims.h:
        # Extra padded heads must not change the function: zero their wo rows.
        wo = p["attn"]["wo"]
        mask = (jnp.arange(dims.h) < cfg.n_heads).repeat(dims.dh)
        p["attn"]["wo"] = wo * mask[:, None].astype(wo.dtype)
        if cross:
            p["xattn"]["wo"] = p["xattn"]["wo"] * mask[:, None].astype(wo.dtype)
    return p


def _stack(keys, fn):
    return jax.vmap(fn)(keys)


def init_params(cfg: ArchConfig, key: jax.Array, tp: int = 1) -> Params:
    dt = _dtype(cfg)
    dims = model_dims(cfg, tp)
    keys = jax.random.split(key, 8)
    params: Params = dict(
        embed=L.dense_init(keys[0], (cfg.vocab, cfg.d_model), dt, scale=0.02),
        ln_f=jnp.ones((cfg.d_model,), dt),
    )
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(keys[1], (cfg.d_model, cfg.vocab), dt)

    if cfg.family in ("dense", "moe", "vlm"):
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["blocks"] = _stack(lkeys, lambda k: _init_block(k, cfg, dims))
        if cfg.family == "vlm":
            params["vit_proj"] = L.dense_init(keys[3], (cfg.d_model, cfg.d_model), dt)
    elif cfg.family == "ssm":
        def one(k):
            k1, k2 = jax.random.split(k)
            return dict(
                ln1=jnp.ones((cfg.d_model,), dt),
                att=S.init_rwkv6(k1, cfg.d_model, cfg.n_heads, cfg.d_head, dt),
                ln2=jnp.ones((cfg.d_model,), dt),
                cm=S.init_rwkv6_cm(k2, cfg.d_model, cfg.d_ff, dt),
            )

        params["blocks"] = _stack(jax.random.split(keys[2], cfg.n_layers), one)
    elif cfg.family == "hybrid":
        def one(k):
            return dict(
                ln=jnp.ones((cfg.d_model,), dt),
                mamba=S.init_mamba2(k, cfg.d_model, cfg.n_heads, cfg.ssm_state, dt),
            )

        params["blocks"] = _stack(jax.random.split(keys[2], cfg.n_layers), one)
        params["shared"] = _init_block(keys[3], cfg, dims)  # ONE shared block
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stack(
            jax.random.split(keys[2], cfg.n_enc_layers),
            lambda k: _init_block(k, cfg, dims),
        )
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), dt)
        params["blocks"] = _stack(
            jax.random.split(keys[3], cfg.n_layers),
            lambda k: _init_block(k, cfg, dims, cross=True),
        )
    else:
        raise ValueError(cfg.family)
    return params


# ----------------------------------------------------------------------------
# Blocks (shared by train and cached paths)
# ----------------------------------------------------------------------------

def _attn_block(
    p, x, cfg: ArchConfig, dims: ModelDims, *, causal=True,
    cache=None, pos=None, xattn_kv=None, enc_out=None, rope=True, unroll=False,
):
    """Residual attention + FFN block. Returns (x, new_cache, aux)."""
    h = L.rms_norm(x, p["ln1"])
    out, new_kv = L.attention(
        p["attn"], h, h=dims.h, kv=dims.kv, dh=dims.dh,
        rope_theta=cfg.rope_theta if rope else None,
        causal=causal, cache=cache, cache_pos=pos, unroll=unroll,
    )
    x = x + out
    if "xattn" in p and (xattn_kv is not None or enc_out is not None):
        hx = L.rms_norm(x, p["ln_x"])
        if xattn_kv is None:
            ek = (enc_out @ p["xattn"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], dims.kv, dims.dh
            ).transpose(0, 2, 1, 3)
            ev = (enc_out @ p["xattn"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], dims.kv, dims.dh
            ).transpose(0, 2, 1, 3)
            xattn_kv = (ek, ev)
        xout, _ = L.attention(
            p["xattn"], hx, h=dims.h, kv=dims.kv, dh=dims.dh,
            rope_theta=None, causal=False, xattn_kv=xattn_kv, unroll=unroll,
        )
        x = x + xout
    h2 = L.rms_norm(x, p["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        f, aux, _ = L.moe_ffn(
            p["moe"], h2, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
        )
    else:
        f = L.mlp(p["mlp"], h2)
    return x + f, new_kv, aux, xattn_kv


def _rwkv_block(p, x, cfg, *, state=None, lx_att=None, lx_cm=None):
    h = L.rms_norm(x, p["ln1"])
    out, s_new, lxa = S.rwkv6_mixer(
        p["att"], h, n_heads=cfg.n_heads, dh=cfg.d_head, state=state, last_x=lx_att
    )
    x = x + out
    h2 = L.rms_norm(x, p["ln2"])
    out2, lxc = S.rwkv6_channel_mix(p["cm"], h2, last_x=lx_cm)
    return x + out2, s_new, lxa, lxc


def _mamba_block(p, x, cfg, *, state=None):
    h = L.rms_norm(x, p["ln"])
    out, s_new = S.mamba2_mixer(
        p["mamba"], h, n_heads=cfg.n_heads, d_state=cfg.ssm_state, state=state
    )
    return x + out, s_new


# ----------------------------------------------------------------------------
# Train forward
# ----------------------------------------------------------------------------

def forward_train(
    params: Params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    tp: int = 1,
    remat: bool = True,
    unroll: bool = False,
    batch_axes=None,
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, V), moe_aux_loss ()); hidden states if asked."""
    dims = model_dims(cfg, tp)
    tokens = batch["tokens"]  # (B, S)
    x = _constrain(params["embed"][tokens], batch_axes)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "vlm":
        patches = batch["patches"] @ params["vit_proj"]  # (B, P, D) stub frontend
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, p):
            xc, aux = carry
            xn, _, a, _ = _attn_block(p, xc, cfg, dims, unroll=unroll)
            return (_constrain(xn, batch_axes), aux + a), None

        fn = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = _maybe_scan(fn, (x, aux_total), params["blocks"], unroll)

    elif cfg.family == "ssm":
        def body(xc, p):
            xn, _, _, _ = _rwkv_block(p, xc, cfg)
            return _constrain(xn, batch_axes), None

        fn = jax.checkpoint(body) if remat else body
        x, _ = _maybe_scan(fn, x, params["blocks"], unroll)

    elif cfg.family == "hybrid":
        def body(xc, p):
            xn, _ = _mamba_block(p, xc, cfg)
            return _constrain(xn, batch_axes), None

        fn = jax.checkpoint(body) if remat else body
        se = cfg.shared_every
        n_apps = cfg.n_layers // se
        blocks = params["blocks"]
        for g in range(n_apps):
            seg = jax.tree.map(lambda a: a[g * se : (g + 1) * se], blocks)
            x, _ = _maybe_scan(fn, x, seg, unroll)
            x, _, _, _ = _attn_block(params["shared"], x, cfg, dims, unroll=unroll)
        rem = cfg.n_layers - n_apps * se
        if rem:
            seg = jax.tree.map(lambda a: a[n_apps * se :], blocks)
            x, _ = _maybe_scan(fn, x, seg, unroll)

    elif cfg.family == "encdec":
        frames = batch["frames"].astype(x.dtype)  # (B, S_enc, D) stub embeddings

        def enc_body(xc, p):
            xn, _, _, _ = _attn_block(p, xc, cfg, dims, causal=False, unroll=unroll)
            return _constrain(xn, batch_axes), None

        enc_fn = jax.checkpoint(enc_body) if remat else enc_body
        enc_out, _ = _maybe_scan(enc_fn, frames, params["enc_blocks"], unroll)
        enc_out = L.rms_norm(enc_out, params["enc_ln_f"])

        def dec_body(xc, p):
            xn, _, _, _ = _attn_block(p, xc, cfg, dims, enc_out=enc_out, unroll=unroll)
            return _constrain(xn, batch_axes), None

        dec_fn = jax.checkpoint(dec_body) if remat else dec_body
        x, _ = _maybe_scan(dec_fn, x, params["blocks"], unroll)
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["ln_f"])
    if cfg.family == "vlm":
        x = x[:, batch["patches"].shape[1] :]  # text positions only
    if return_hidden:
        return x, aux_total
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ head, aux_total


def loss_fn(
    params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], tp: int = 1,
    remat: bool = True, aux_weight: float = 0.01, unroll: bool = False,
    batch_axes=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross-entropy (+ MoE aux). batch['tokens']: (B, S+1)."""
    inp = dict(batch)
    inp["tokens"] = batch["tokens"][:, :-1]
    labels = batch["tokens"][:, 1:]
    hidden, aux = forward_train(
        params, cfg, inp, tp=tp, remat=remat, unroll=unroll,
        batch_axes=batch_axes, return_hidden=True,
    )
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    ce = _chunked_ce(hidden, head, labels, remat=remat)
    return ce + aux_weight * aux, dict(ce=ce, moe_aux=aux)


def _chunked_ce(
    hidden: jax.Array,  # (B, S, D)
    head: jax.Array,  # (D, V) — vocab-sharded
    labels: jax.Array,  # (B, S)
    n_chunks: int = 8,
    remat: bool = True,
) -> jax.Array:
    """Sequence-chunked cross-entropy.

    The (B, S, V) logits tensor is the largest buffer in LM training; it is
    never needed whole. Chunking the head matmul + CE over S and remat-ing
    each chunk keeps only one (B, S/n, V) slice live (fwd AND bwd), at the
    cost of recomputing the chunk matmul in the backward pass — the standard
    fused/chunked-CE memory optimization. Vocab stays 'model'-sharded; the
    fused one-hot reduction below avoids a vocab-dim gather (which would
    all-gather logits under GSPMD)."""
    b, s, d = hidden.shape
    n_chunks = min(n_chunks, s)
    cs = -(-s // n_chunks)

    def chunk_loss(x_c, y_c):
        logits = (x_c @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vocab_iota == y_c[..., None], logits, 0.0), -1)
        return jnp.sum(logz - gold)

    fn = jax.checkpoint(chunk_loss) if remat else chunk_loss
    total = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        lo = i * cs
        hi = min(s, lo + cs)
        total = total + fn(hidden[:, lo:hi], labels[:, lo:hi])
    return total / (b * s)


# ----------------------------------------------------------------------------
# Serving: cache init + prefill/decode forward
# ----------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int, tp: int = 1) -> Params:
    dims = model_dims(cfg, tp)
    dt = _dtype(cfg)
    lg = cfg.n_layers

    def kv(n, s):
        return (
            jnp.zeros((n, batch, dims.kv, s, dims.dh), dt),
            jnp.zeros((n, batch, dims.kv, s, dims.dh), dt),
        )

    if cfg.family in ("dense", "moe"):
        return dict(kv=kv(lg, max_seq))
    if cfg.family == "vlm":
        return dict(kv=kv(lg, max_seq + cfg.vlm_patches))
    if cfg.family == "ssm":
        return dict(
            s=jnp.zeros((lg, batch, cfg.n_heads, cfg.d_head, cfg.d_head), jnp.float32),
            lx_att=jnp.zeros((lg, batch, cfg.d_model), dt),
            lx_cm=jnp.zeros((lg, batch, cfg.d_model), dt),
        )
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.shared_every
        d_in = 2 * cfg.d_model
        # Shared-attn caches as per-application LEAVES (not one stacked
        # array): prevents XLA from hoisting dtype conversions / updates over
        # the whole (n_apps, ...) stack (§Perf A2).
        one = lambda: (
            jnp.zeros((batch, dims.kv, max_seq, dims.dh), dt),
            jnp.zeros((batch, dims.kv, max_seq, dims.dh), dt),
        )
        return dict(
            s=jnp.zeros(
                (lg, batch, cfg.n_heads, cfg.ssm_state, d_in // cfg.n_heads),
                jnp.float32,
            ),
            kv=[one() for _ in range(n_apps)],
        )
    if cfg.family == "encdec":
        s_enc = max(max_seq // 2, 1)
        return dict(kv=kv(lg, max_seq), xkv=kv(lg, s_enc))
    raise ValueError(cfg.family)


def forward_cached(
    params: Params,
    cfg: ArchConfig,
    cache: Params,
    tokens: jax.Array,  # (B, T) — T=1 decode, T>1 prefill
    pos: jax.Array,  # () int32 — absolute position of tokens[:, 0]
    tp: int = 1,
    frames: Optional[jax.Array] = None,  # whisper prefill
    patches: Optional[jax.Array] = None,  # vlm prefill
    unroll: bool = False,
    batch_axes=None,
) -> Tuple[jax.Array, Params]:
    """Returns (logits (B, T', V), new cache)."""
    dims = model_dims(cfg, tp)
    x = _constrain(params["embed"][tokens], batch_axes)

    if cfg.family == "vlm" and patches is not None:
        proj = patches @ params["vit_proj"]
        x = jnp.concatenate([proj.astype(x.dtype), x], axis=1)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            xc, p_ = carry
            blk, (ck, cv) = xs
            xn, new_kv, _, _ = _attn_block(
                blk, xc, cfg, dims, cache=(ck, cv), pos=p_, unroll=unroll
            )
            return (_constrain(xn, batch_axes), p_), new_kv

        (x, _), new_kv = _maybe_scan(
            body, (x, pos), (params["blocks"], cache["kv"]), unroll
        )
        new_cache = dict(kv=new_kv)

    elif cfg.family == "ssm":
        def body(carry, xs):
            xc = carry
            blk, s0, lxa0, lxc0 = xs
            xn, s1, lxa, lxc = _rwkv_block(
                blk, xc, cfg, state=s0, lx_att=lxa0, lx_cm=lxc0
            )
            return _constrain(xn, batch_axes), (s1, lxa, lxc)

        x, (s1, lxa, lxc) = _maybe_scan(
            body, x, (params["blocks"], cache["s"], cache["lx_att"], cache["lx_cm"]),
            unroll,
        )
        new_cache = dict(s=s1, lx_att=lxa, lx_cm=lxc)

    elif cfg.family == "hybrid":
        se = cfg.shared_every
        n_apps = cfg.n_layers // se
        s_all = []
        new_kv = []

        def body(xc, xs):
            blk, s0 = xs
            xn, s1 = _mamba_block(blk, xc, cfg, state=s0)
            return _constrain(xn, batch_axes), s1

        for g in range(n_apps):
            sl = lambda a: a[g * se : (g + 1) * se]
            x, s1 = _maybe_scan(
                body, x, (jax.tree.map(sl, params["blocks"]), sl(cache["s"])), unroll
            )
            s_all.append(s1)
            x, nkv, _, _ = _attn_block(
                params["shared"], x, cfg, dims, cache=cache["kv"][g], pos=pos,
                unroll=unroll,
            )
            new_kv.append(nkv)
        rem = cfg.n_layers - n_apps * se
        if rem:
            sl = lambda a: a[n_apps * se :]
            x, s1 = _maybe_scan(
                body, x, (jax.tree.map(sl, params["blocks"]), sl(cache["s"])), unroll
            )
            s_all.append(s1)
        new_cache = dict(s=jnp.concatenate(s_all, axis=0), kv=new_kv)

    elif cfg.family == "encdec":
        xk, xv = cache["xkv"]
        if frames is not None:  # prefill: run the encoder, fill cross cache
            def enc_body(xc, p):
                xn, _, _, _ = _attn_block(
                    p, xc, cfg, dims, causal=False, unroll=unroll
                )
                return xn, None

            enc_out, _ = _maybe_scan(
                enc_body, frames.astype(x.dtype), params["enc_blocks"], unroll
            )
            enc_out = L.rms_norm(enc_out, params["enc_ln_f"])

            def proj_kv(blk):
                b_, t_ = enc_out.shape[:2]
                ek = (enc_out @ blk["xattn"]["wk"]).reshape(
                    b_, t_, dims.kv, dims.dh
                ).transpose(0, 2, 1, 3)
                ev = (enc_out @ blk["xattn"]["wv"]).reshape(
                    b_, t_, dims.kv, dims.dh
                ).transpose(0, 2, 1, 3)
                return ek, ev

            xk, xv = jax.vmap(proj_kv)(params["blocks"])

        def body(carry, xs):
            xc, p_ = carry
            blk, (ck, cv), (exk, exv) = xs
            xn, new_kv, _, _ = _attn_block(
                blk, xc, cfg, dims, cache=(ck, cv), pos=p_, xattn_kv=(exk, exv),
                unroll=unroll,
            )
            return (_constrain(xn, batch_axes), p_), new_kv

        (x, _), new_kv = _maybe_scan(
            body, (x, pos), (params["blocks"], cache["kv"], (xk, xv)), unroll
        )
        new_cache = dict(kv=new_kv, xkv=(xk, xv))
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["ln_f"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ head
    if cfg.family == "vlm" and patches is not None:
        logits = logits[:, patches.shape[1] :]
    return logits, new_cache
