"""Deterministic synthetic token pipeline.

Production frameworks must feed every data-parallel shard a disjoint,
deterministic, resumable stream. This pipeline derives each example from
(seed, step, global_example_index) with a counter-based generator so that:
  * restarts resume bit-exactly from the checkpointed step,
  * elastic re-meshes re-slice the same global batch order (a host only
    needs its new index range),
  * no host ever materializes another host's shard.

Token sequences are Zipf-distributed (vocab skew like natural text) with a
deterministic per-example offset so the loss is learnable (next-token
structure exists: tokens follow arithmetic progressions modulo vocab).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

__all__ = ["SyntheticTokens", "make_batch_spec"]


_K1 = np.uint64(0x9E3779B97F4A7C15)
_K2 = np.uint64(0xBF58476D1CE4E5B9)
_K3 = np.uint64(0x94D049BB133111EB)


def _splitmix(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — counter-based randomness, vectorized."""
    x = (x + _K1).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= _K2
    x ^= x >> np.uint64(27)
    x *= _K3
    x ^= x >> np.uint64(31)
    return x


def _uniform(seed: int, step: int, idx: np.ndarray, pos: np.ndarray,
             salt: int) -> np.ndarray:
    """u ∈ (0,1) keyed by (seed, step, example, position, salt) — the value of
    any (example, position) cell never depends on which shard computes it."""
    with np.errstate(over="ignore"):  # uint64 wraparound is intentional
        h = _splitmix(
            np.uint64(seed) * _K2
            ^ np.uint64(step) * _K3
            ^ np.uint64(salt) * _K1
            ^ (idx.astype(np.uint64) << np.uint64(20))
            ^ pos.astype(np.uint64)
        )
    return ((h >> np.uint64(11)).astype(np.float64) + 0.5) * 2.0**-53


def _zipf_like(u: np.ndarray, a: float = 1.3) -> np.ndarray:
    """Inverse-transform Zipf-ish skew (heavier head than uniform)."""
    return np.floor(np.minimum(u ** (-1.0 / (a - 1.0)), 2**31)).astype(np.int64)


def _normal(seed, step, idx, pos, salt):
    u1 = _uniform(seed, step, idx, pos, salt)
    u2 = _uniform(seed, step, idx, pos, salt + 101)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2 * np.pi * u2)


class SyntheticTokens:
    """Iterator of training batches for an (arch, shape) cell.

    Args:
      cfg / shape: architecture and input-shape cell.
      seed: global data seed.
      shard: (index, count) — this host's slice of the global batch.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        shape: ShapeConfig,
        seed: int = 0,
        shard: tuple[int, int] = (0, 1),
    ):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.shard_idx, self.shard_count = shard
        assert shape.global_batch % self.shard_count == 0
        self.local_batch = shape.global_batch // self.shard_count

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shp = self.cfg, self.shape
        b, s = self.local_batch, shp.seq_len
        lo = self.shard_idx * b
        idx = np.arange(lo, lo + b, dtype=np.int64)[:, None]
        pos = np.arange(s + 1, dtype=np.int64)[None, :]
        # Zipf-skewed base tokens + per-example deterministic progression
        # (so a next-token structure exists and the loss is learnable).
        base = _zipf_like(_uniform(self.seed, step, idx, pos, 1))
        prog = idx * 7 + pos * 3
        tokens = ((base + prog) % cfg.vocab).astype(np.int32)
        out: Dict[str, np.ndarray] = {"tokens": tokens}
        if cfg.family == "encdec":
            fpos = np.arange(max(s // 2, 1) * cfg.d_model, dtype=np.int64)[None, :]
            out["frames"] = _normal(self.seed, step, idx, fpos, 2).reshape(
                b, max(s // 2, 1), cfg.d_model
            ).astype(np.float32)
        if cfg.family == "vlm":
            ppos = np.arange(cfg.vlm_patches * cfg.d_model, dtype=np.int64)[None, :]
            out["patches"] = _normal(self.seed, step, idx, ppos, 3).reshape(
                b, cfg.vlm_patches, cfg.d_model
            ).astype(np.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_spec(
    cfg: ArchConfig, shape: ShapeConfig, extra_token: bool = True
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    spec = {
        "tokens": jax.ShapeDtypeStruct((b, s + (1 if extra_token else 0)), np.int32)
    }
    if cfg.family == "encdec":
        spec["frames"] = jax.ShapeDtypeStruct((b, max(s // 2, 1), cfg.d_model), np.float32)
    if cfg.family == "vlm":
        spec["patches"] = jax.ShapeDtypeStruct((b, cfg.vlm_patches, cfg.d_model), np.float32)
    return spec
