"""Deterministic synthetic data pipeline (shard-aware, resumable)."""
from repro.data.pipeline import SyntheticTokens, make_batch_spec

__all__ = ["SyntheticTokens", "make_batch_spec"]
