"""repro.compat — JAX version-portability layer.

The repo must run on whatever JAX the container ships, and the surfaces we
depend on have moved between releases:

  * ``shard_map``: new JAX exposes ``jax.shard_map`` with a ``check_vma``
    kwarg; 0.4.x has ``jax.experimental.shard_map.shard_map`` with
    ``check_rep`` instead. :func:`shard_map` resolves the callable once at
    import and adapts the replication-check kwarg by signature inspection.
  * ``make_mesh``: newer convenience constructor; older JAX only has
    ``jax.sharding.Mesh``. :func:`make_mesh` prefers the former and falls
    back to reshaping the device list into a ``Mesh`` by hand.
  * Pallas: the kernels in :mod:`repro.kernels` lower for real only on TPU;
    elsewhere they run in interpret mode — and on installs where
    ``jax.experimental.pallas`` is absent entirely they must be skipped in
    favour of the XLA reference ops. :data:`HAS_PALLAS` /
    :func:`pallas_interpret` are the probe the kernel wrappers consult.

Everything engine/kernel/launch code needs from JAX's moving surface goes
through here; nothing else in the repo should touch
``jax.experimental.shard_map`` or version-sniff JAX directly.
"""
from __future__ import annotations

import inspect
from typing import Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = [
    "shard_map",
    "SHARD_MAP_ORIGIN",
    "REP_CHECK_KWARG",
    "make_mesh",
    "HAS_PALLAS",
    "HAS_PALLAS_TPU",
    "HAS_PREFETCH_GRID",
    "has_pallas",
    "has_pallas_cpu_lowering",
    "pallas_interpret",
    "pallas",
    "pallas_tpu",
]


# ----------------------------------------------------------------------------
# shard_map resolution
# ----------------------------------------------------------------------------

def _resolve_shard_map() -> tuple[Callable, str]:
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "jax.shard_map"
    from jax.experimental.shard_map import shard_map as fn  # JAX <= 0.4.x
    return fn, "jax.experimental.shard_map.shard_map"


_SHARD_MAP_RAW, SHARD_MAP_ORIGIN = _resolve_shard_map()


def _rep_check_kwarg() -> str | None:
    try:
        params = inspect.signature(_SHARD_MAP_RAW).parameters
    except (TypeError, ValueError):  # e.g. C-accelerated wrapper
        return None
    for name in ("check_vma", "check_rep"):
        if name in params:
            return name
    return None


REP_CHECK_KWARG = _rep_check_kwarg()


def shard_map(f, mesh, in_specs, out_specs, check_replication: bool = True):
    """Version-portable ``shard_map``.

    ``check_replication`` maps onto whichever of ``check_vma`` (new JAX) /
    ``check_rep`` (0.4.x) the installed version accepts, and is dropped
    silently if neither exists.
    """
    kwargs = {}
    if REP_CHECK_KWARG is not None:
        kwargs[REP_CHECK_KWARG] = check_replication
    return _SHARD_MAP_RAW(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# ----------------------------------------------------------------------------
# Mesh construction
# ----------------------------------------------------------------------------

def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    devices: Sequence | np.ndarray | None = None,
) -> Mesh:
    """``jax.make_mesh`` when available, else a hand-rolled ``Mesh``."""
    shape = tuple(int(s) for s in axis_shapes)
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        try:
            return mk(shape, tuple(axis_names), devices=devices)
        except TypeError:  # very old make_mesh without the devices kwarg
            if devices is None:
                return mk(shape, tuple(axis_names))
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n] if devices is None else devices)
    if devs.size != n:
        raise ValueError(f"need {n} devices for mesh {shape}, got {devs.size}")
    return Mesh(devs.reshape(shape), tuple(axis_names))


# ----------------------------------------------------------------------------
# Pallas availability probe
# ----------------------------------------------------------------------------

try:
    from jax.experimental import pallas  # noqa: F401
    HAS_PALLAS = True
except Exception:  # pragma: no cover - missing/broken pallas install
    pallas = None
    HAS_PALLAS = False

try:
    from jax.experimental.pallas import tpu as pallas_tpu  # noqa: F401
    HAS_PALLAS_TPU = True
except Exception:  # pragma: no cover
    pallas_tpu = None
    HAS_PALLAS_TPU = False

# Deprecated upstream; segment_sum's ragged-block steering still needs it.
HAS_PREFETCH_GRID = HAS_PALLAS_TPU and hasattr(pallas_tpu, "PrefetchScalarGridSpec")


def has_pallas(require_tpu_support: bool = False) -> bool:
    return HAS_PALLAS_TPU if require_tpu_support else HAS_PALLAS


def pallas_interpret() -> bool:
    """True when Pallas kernels must run in interpret mode (non-TPU backend)."""
    return jax.default_backend() != "tpu"


# Lazy: probing requires compiling a (tiny) kernel, so it must not run at
# import time. None = not probed yet.
_PALLAS_CPU_LOWERING: bool | None = None


def has_pallas_cpu_lowering() -> bool:
    """True when this JAX can *lower* (not interpret) Pallas on the CPU backend.

    Newer JAX grows a real CPU lowering path for ``pallas_call``; 0.4.x raises
    ``Only interpret mode is supported on CPU backend``. The kernel tier
    resolver (:mod:`repro.kernels.ops`) consults this once: when it is False
    the ``pallas-cpu`` tier is simply unavailable and dispatch lands on XLA —
    never on silent interpret-mode emulation. Probed by compiling a trivial
    copy kernel the first time it is asked; the answer is cached for the
    process.
    """
    global _PALLAS_CPU_LOWERING
    if _PALLAS_CPU_LOWERING is not None:
        return _PALLAS_CPU_LOWERING
    if not HAS_PALLAS or jax.default_backend() == "tpu":
        _PALLAS_CPU_LOWERING = False
        return False
    import jax.numpy as jnp

    def _copy(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    try:
        out = pallas.pallas_call(
            _copy,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            interpret=False,
        )(jnp.zeros((8, 128), jnp.float32))
        jax.block_until_ready(out)
        _PALLAS_CPU_LOWERING = True
    except Exception:  # ValueError on 0.4.x; be permissive about the message
        _PALLAS_CPU_LOWERING = False
    return _PALLAS_CPU_LOWERING
