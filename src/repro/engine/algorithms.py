"""Graph algorithms on the vertex-cut engine (the paper's §IV workloads).

  pagerank          — light compute/comm (paper Fig. 7a-c)
  coloring          — greedy conflict-resolution coloring (paper Fig. 7e, [4])
  label_propagation — connected components (min-label flooding)
  triangle_count    — heavy neighbourhood-intersection workload: the stand-in
                      for the paper's NP-complete subgraph-isomorphism /
                      clique searches (Fig. 7d/f) — compute- and
                      communication-heavy per superstep.

Each returns (result, info) where info carries superstep counts the latency
model converts into cluster processing latency.

When no mesh is passed, each workload builds one via `engine_mesh(k=g.k)`
(see `repro.compat` for the version-portable mesh/shard_map plumbing); the
partition axis is padded inside `make_superstep` so any device count shards
evenly (empty slabs are masked out of the gather and the replica sync).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.engine.gas import engine_mesh, make_superstep
from repro.engine.partitioned import PartitionedGraph

__all__ = ["pagerank", "label_propagation", "coloring", "triangle_count"]


def pagerank(
    g: PartitionedGraph, iters: int = 20, damping: float = 0.85,
    mesh: Mesh | None = None, trace=None,
) -> Tuple[np.ndarray, dict]:
    mesh = mesh or engine_mesh(k=g.k)
    v = g.num_vertices

    def msg(x_u, x_v, deg_u, deg_v):
        # Push current rank mass along both directions (undirected).
        return x_u / jnp.maximum(deg_u, 1)[:, None], x_v / jnp.maximum(deg_v, 1)[:, None]

    def apply(state, synced, degrees):
        return (1.0 - damping) / v + damping * synced

    step = make_superstep(g, msg, apply, mesh, trace=trace)
    state = jnp.full((v, 1), 1.0 / v, jnp.float32)
    for _ in range(iters):
        state = step(state)
    return np.asarray(state[:, 0]), dict(supersteps=iters, msg_width=1)


def label_propagation(
    g: PartitionedGraph, max_iters: int = 64, mesh: Mesh | None = None,
    trace=None,
) -> Tuple[np.ndarray, dict]:
    """Connected components by min-label flooding; converged when stable."""
    mesh = mesh or engine_mesh(k=g.k)
    v = g.num_vertices

    def msg(x_u, x_v, deg_u, deg_v):
        return x_u, x_v  # forward the neighbour's current label

    def apply(state, synced, degrees):
        has_nbr = synced < 3.0e38
        return jnp.where(has_nbr, jnp.minimum(state, synced), state)

    step = make_superstep(g, msg, apply, mesh, combine="min", trace=trace)
    state = jnp.arange(v, dtype=jnp.float32)[:, None]
    it = 0
    for it in range(1, max_iters + 1):
        new = step(state)
        if bool(jnp.all(new == state)):
            state = new
            break
        state = new
    return np.asarray(state[:, 0]).astype(np.int64), dict(supersteps=it, msg_width=1)


def coloring(
    g: PartitionedGraph, max_colors: int = 64, max_iters: int = 256,
    mesh: Mesh | None = None, trace=None,
) -> Tuple[np.ndarray, dict]:
    """Largest-priority-first greedy coloring (Jones–Plassmann schedule).

    A vertex finalizes once every *unfinalized* neighbour has lower priority,
    taking the smallest color unused by finalized neighbours — exactly the
    sequential greedy order, so the result is always a proper coloring.

    State (min-combined) per vertex: [a | b_0..b_{C-1}] with
      a   = −(prio+1) while unfinalized, +BIG once finalized
      b_j = 0 if finalized with color j else 1
    so synced_a = −(max unfinalized neighbour prio+1) and synced_b_j = 0 iff
    some finalized neighbour holds color j.
    """
    mesh = mesh or engine_mesh(k=g.k)
    v, c = g.num_vertices, max_colors
    rng = np.random.default_rng(0)
    prio = jnp.asarray((rng.permutation(v) + 1).astype(np.float32))
    big = jnp.float32(3.0e38)

    def msg(x_u, x_v, deg_u, deg_v):
        return x_u, x_v

    def apply(state, synced, degrees):
        a = state[:, 0]
        finalized = a > 0
        # No unfinalized higher-priority neighbour (priorities are distinct).
        can = (~finalized) & (synced[:, 0] > -prio)
        free = jnp.argmax(synced[:, 1:] > 0.5, axis=1)  # smallest unused color
        b = jnp.where(
            can[:, None],
            1.0 - jax.nn.one_hot(free, c, dtype=jnp.float32),
            state[:, 1:],
        )
        a_new = jnp.where(can, big, a)
        return jnp.concatenate([a_new[:, None], b], axis=1)

    step = make_superstep(g, msg, apply, mesh, combine="min", trace=trace)
    state = jnp.concatenate([(-prio)[:, None], jnp.ones((v, c), jnp.float32)], axis=1)
    it = 0
    for it in range(1, max_iters + 1):
        new = step(state)
        if bool(jnp.all(new[:, 0] > 0)) or bool(jnp.all(new == state)):
            state = new
            break
        state = new
    colors = np.asarray(jnp.argmin(state[:, 1:], axis=1))
    return colors, dict(supersteps=it, msg_width=1 + c)


def triangle_count(
    g: PartitionedGraph, sketch_bits: int = 256, mesh: Mesh | None = None,
    trace=None,
) -> Tuple[int, dict]:
    """Heavy workload: approximate triangle counting via neighbourhood sketches.

    Each vertex carries a `sketch_bits`-wide simhash-style neighbourhood
    bitmap; one superstep broadcasts sketches to neighbours, a second
    accumulates |N(u) ∩ N(v)| estimates per edge. Exact for graphs with
    ≤ sketch_bits distinct neighbour hashes per vertex — tests use exact mode
    (sketch_bits ≥ V). Models the paper's SI/clique workloads: wide messages
    (msg_width = sketch_bits/32 words ≫ PageRank's 1) and heavy per-edge work.
    """
    mesh = mesh or engine_mesh(k=g.k)
    v, b = g.num_vertices, sketch_bits
    slot = np.arange(v) % b  # vertex -> sketch bit (exact when b >= V)

    def msg(x_u, x_v, deg_u, deg_v):
        return x_u, x_v

    def apply(state, synced, degrees):
        return jnp.minimum(synced, 1.0)  # OR of neighbour one-bit ids

    # Round 1: build neighbourhood bitmaps.
    step = make_superstep(g, msg, apply, mesh, trace=trace)
    ident = jax.nn.one_hot(jnp.asarray(slot), b, dtype=jnp.float32)
    bitmaps = step(ident)  # (V, b) — 1 iff some neighbour hashes to bit j

    # Round 2: per-edge intersection of endpoint bitmaps (local, heavy).
    edges, evalid = np.asarray(g.edges), np.asarray(g.evalid)
    bm = np.asarray(bitmaps) > 0
    ident_np = np.asarray(ident) > 0
    u, w = edges[..., 0], edges[..., 1]
    # |bits(N(u)) ∩ bits(N(w))| counts common neighbours exactly for b ≥ V
    # (u ∉ N(u): self-loops are removed at graph build, so the endpoints'
    # own bits never appear in the intersection).
    inter = (bm[u] & bm[w]).sum(axis=-1)
    del ident_np  # endpoints' own bits are excluded by construction
    per_edge = inter * evalid
    total = int(per_edge.sum()) // 3  # each triangle counted by 3 edges
    return total, dict(supersteps=2, msg_width=b // 32)
