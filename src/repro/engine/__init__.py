"""Vertex-cut graph processing engine (shard_map GAS + workloads + cost model)."""
from repro.engine.partitioned import PartitionedGraph, build_partitioned_graph
from repro.engine.gas import engine_mesh, make_superstep
from repro.engine.algorithms import (
    pagerank,
    label_propagation,
    coloring,
    triangle_count,
)
from repro.engine.latency_model import (
    ClusterProfile,
    PAPER_CLUSTER,
    TPU_POD,
    partition_latency,
    process_latency,
)

__all__ = [
    "PartitionedGraph",
    "build_partitioned_graph",
    "engine_mesh",
    "make_superstep",
    "pagerank",
    "label_propagation",
    "coloring",
    "triangle_count",
    "ClusterProfile",
    "PAPER_CLUSTER",
    "TPU_POD",
    "partition_latency",
    "process_latency",
]
