"""Gather-Apply-Scatter supersteps over `shard_map`.

The engine executes vertex programs on a vertex-cut partitioned graph. Each
device owns a slab of partitions (axis `parts`); one superstep is:

  gather : per-partition edge aggregation into local vertex accumulators
           (the `segment_sum` kernel's job on TPU; `.at[].add` under XLA)
  sync   : replica synchronisation — combine accumulators across the
           partitions a vertex is replicated on (lax.psum over `parts`)
  apply  : vertex update function on the synchronised accumulator

The dense psum is the XLA-friendly stand-in for the sparse point-to-point
replica sync a cluster engine (GrapH) performs; the *modeled* traffic —
what the paper's processing latency is driven by — is derived from the
replica table in `latency_model.py`. On a real TPU pod the psum itself also
shrinks with replication degree when the accumulator is masked to local
replicas, which we do (zeros compress under sparse collectives; on GPU/IB
clusters the mask is what a ragged all-to-all would send).

All JAX version-variant surfaces (`shard_map` location and its
replication-check kwarg, `make_mesh`) are reached through `repro.compat`, so
the engine runs unchanged on 0.4.x and current JAX, single- or multi-device.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.engine.partitioned import PartitionedGraph
from repro.obs import resolve_tracer

__all__ = ["make_superstep", "engine_mesh", "gather_local"]


def engine_mesh(n_devices: int | None = None, k: int | None = None) -> Mesh:
    """1-D engine mesh over the local devices.

    Args:
      n_devices: cap on the device count (default: all local devices).
      k: number of graph partitions about to be sharded over the mesh. Any
        device count works — `make_superstep` pads the partition axis up to
        a multiple of the mesh size with empty slabs (no edges, no replicas)
        that are masked out of the gather/sync — so the mesh keeps ALL
        devices instead of trimming to a divisor of k. Only when k is
        *smaller* than the device count is the mesh capped at k devices
        (extra devices would carry nothing but padding).
    """
    devs = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    if k is not None:
        devs = devs[: max(min(len(devs), int(k)), 1)]
    return compat.make_mesh((len(devs),), ("parts",), devices=np.array(devs))


BIG = jnp.float32(3.0e38)


def gather_local(
    edges: jax.Array,  # (kp, E, 2) — this shard's partitions
    evalid: jax.Array,  # (kp, E)
    vertex_data: jax.Array,  # (V, d) — replicated current state
    degrees: jax.Array,  # (V,)
    msg_fn: Callable,  # (x_u, x_v, deg_u, deg_v) -> (msg_to_v, msg_to_u)
    num_vertices: int,
    agg: str = "add",
) -> jax.Array:
    """Per-shard edge aggregation: (kp, V, d) local accumulators."""

    def one_partition(e, valid):
        u, v = e[:, 0], e[:, 1]
        mu, mv = msg_fn(vertex_data[u], vertex_data[v], degrees[u], degrees[v])
        if agg == "add":
            w = valid[:, None].astype(mu.dtype)
            acc = jnp.zeros((num_vertices, mu.shape[-1]), mu.dtype)
            acc = acc.at[v].add(mu * w)  # message flowing u -> v
            acc = acc.at[u].add(mv * w)  # message flowing v -> u (undirected)
        elif agg == "min":
            mu = jnp.where(valid[:, None], mu, BIG)
            mv = jnp.where(valid[:, None], mv, BIG)
            acc = jnp.full((num_vertices, mu.shape[-1]), BIG, mu.dtype)
            acc = acc.at[v].min(mu)
            acc = acc.at[u].min(mv)
        else:
            raise ValueError(agg)
        return acc

    return jax.vmap(one_partition)(edges, evalid)


def make_superstep(
    g: PartitionedGraph,
    msg_fn: Callable,
    apply_fn: Callable,  # (state, synced_acc, degrees) -> state
    mesh: Mesh,
    combine: str = "add",
    trace=None,
):
    """Build a jitted superstep: state (V, d) -> state (V, d).

    The partition axis of `g.edges` is sharded over the mesh's `parts` axis;
    vertex state is replicated (small next to edges, the usual vertex-cut
    regime). Accumulators are masked to each partition's replica set before
    the cross-partition combine — the masked entries are the engine's real
    traffic.

    When the mesh size does not divide k, the partition axis is padded up to
    the next multiple with empty slabs: no valid edges (`evalid` False ⇒
    zero / identity contributions in `gather_local`) and no replicas (the
    replica mask zeroes the slab out of the cross-partition combine). This
    is what lets `engine_mesh` keep every device for any k.

    Slab balance: pad slabs are interleaved so per-device REAL slab counts
    differ by at most one (appending them at the end would pile every pad
    onto the last devices — they idle while earlier devices carry full
    slabs, and the psum stalls on the stragglers). The cross-partition
    combine is permutation-invariant, so reordering slabs never changes
    results. The returned callable exposes the placement as
    ``.slab_occupancy`` (real slabs per device) and the traced superstep
    span carries it for Perfetto visibility.
    """
    v, k = g.num_vertices, g.k
    n_shards = int(mesh.devices.size)
    k_pad = -(-k // n_shards) * n_shards
    edges_d, evalid_d = g.edges, g.evalid
    repl_t = jnp.asarray(np.asarray(g.replicas).T)  # (k, V)
    kp_per = k_pad // n_shards
    base, rem = divmod(k, n_shards)
    occupancy = np.full(n_shards, base, np.int64)
    occupancy[:rem] += 1
    if k_pad != k:
        pad = k_pad - k
        edges_d = jnp.concatenate(
            [edges_d, jnp.zeros((pad,) + edges_d.shape[1:], edges_d.dtype)]
        )
        evalid_d = jnp.concatenate(
            [evalid_d, jnp.zeros((pad,) + evalid_d.shape[1:], bool)]
        )
        repl_t = jnp.concatenate(
            [repl_t, jnp.zeros((pad, repl_t.shape[1]), repl_t.dtype)]
        )
        # Device d's contiguous shard_map slab holds occupancy[d] real
        # partitions followed by its share of the pads.
        perm = np.empty(k_pad, np.int64)
        next_real, next_pad, pos = 0, k, 0
        for d in range(n_shards):
            c = int(occupancy[d])
            perm[pos : pos + c] = np.arange(next_real, next_real + c)
            perm[pos + c : pos + kp_per] = np.arange(
                next_pad, next_pad + kp_per - c
            )
            next_real += c
            next_pad += kp_per - c
            pos += kp_per
        edges_d = edges_d[perm]
        evalid_d = evalid_d[perm]
        repl_t = repl_t[perm]

    def step(state, edges, evalid, replicas_t, degrees):
        acc = gather_local(edges, evalid, state, degrees, msg_fn, v, agg=combine)
        if combine == "add":
            local = (acc * replicas_t[:, :, None]).sum(axis=0)  # mask to replicas
            synced = jax.lax.psum(local, "parts")
        elif combine == "min":
            local = jnp.where(replicas_t[:, :, None] > 0, acc, BIG).min(axis=0)
            synced = jax.lax.pmin(local, "parts")
        else:
            raise ValueError(combine)
        return apply_fn(state, synced, degrees)

    shard_step = compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P("parts"), P("parts"), P("parts"), P()),
        out_specs=P(),
        check_replication=False,
    )

    @jax.jit
    def superstep(state):
        return shard_step(state, edges_d, evalid_d, repl_t, g.degrees)

    slab_occupancy = tuple(int(c) for c in occupancy)
    tr = resolve_tracer(trace)
    if not tr.enabled:
        # jit-wrapped callables reject attribute assignment; a plain
        # closure carries the placement metadata either way.
        def plain_superstep(state):
            return superstep(state)

        plain_superstep.slab_occupancy = slab_occupancy
        return plain_superstep

    # Tracing wraps the jitted call from the host side: the span covers
    # dispatch only (no block_until_ready, no added sync) and lives outside
    # the traced program, so the compiled superstep is unchanged.
    def traced_superstep(state):
        with tr.span("superstep", cat="engine", k=k, combine=combine,
                     n_shards=n_shards, slab_occupancy=list(slab_occupancy)):
            return superstep(state)

    traced_superstep.slab_occupancy = slab_occupancy
    return traced_superstep
