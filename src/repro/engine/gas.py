"""Gather-Apply-Scatter supersteps over `shard_map`.

The engine executes vertex programs on a vertex-cut partitioned graph. Each
device owns a slab of partitions (axis `parts`); one superstep is:

  gather : per-partition edge aggregation into local vertex accumulators
           (the `segment_sum` kernel's job on TPU; `.at[].add` under XLA)
  sync   : replica synchronisation — combine accumulators across the
           partitions a vertex is replicated on (lax.psum over `parts`)
  apply  : vertex update function on the synchronised accumulator

The dense psum is the XLA-friendly stand-in for the sparse point-to-point
replica sync a cluster engine (GrapH) performs; the *modeled* traffic —
what the paper's processing latency is driven by — is derived from the
replica table in `latency_model.py`. On a real TPU pod the psum itself also
shrinks with replication degree when the accumulator is masked to local
replicas, which we do (zeros compress under sparse collectives; on GPU/IB
clusters the mask is what a ragged all-to-all would send).

All JAX version-variant surfaces (`shard_map` location and its
replication-check kwarg, `make_mesh`) are reached through `repro.compat`, so
the engine runs unchanged on 0.4.x and current JAX, single- or multi-device.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.engine.partitioned import PartitionedGraph

__all__ = ["make_superstep", "engine_mesh", "gather_local"]


def engine_mesh(n_devices: int | None = None, k: int | None = None) -> Mesh:
    """1-D engine mesh over the local devices.

    Args:
      n_devices: cap on the device count (default: all local devices).
      k: number of graph partitions about to be sharded over the mesh. The
        `parts` axis length must divide k, so when given, the mesh is trimmed
        to the largest device count that does — e.g. k=6 on 4 devices yields
        a 3-device mesh, and k < n_devices yields a k-device mesh.
    """
    devs = jax.devices() if n_devices is None else jax.devices()[:n_devices]
    n = len(devs)
    if k is not None:
        while n > 1 and k % n != 0:
            n -= 1
        devs = devs[:n]
    return compat.make_mesh((len(devs),), ("parts",), devices=np.array(devs))


BIG = jnp.float32(3.0e38)


def gather_local(
    edges: jax.Array,  # (kp, E, 2) — this shard's partitions
    evalid: jax.Array,  # (kp, E)
    vertex_data: jax.Array,  # (V, d) — replicated current state
    degrees: jax.Array,  # (V,)
    msg_fn: Callable,  # (x_u, x_v, deg_u, deg_v) -> (msg_to_v, msg_to_u)
    num_vertices: int,
    agg: str = "add",
) -> jax.Array:
    """Per-shard edge aggregation: (kp, V, d) local accumulators."""

    def one_partition(e, valid):
        u, v = e[:, 0], e[:, 1]
        mu, mv = msg_fn(vertex_data[u], vertex_data[v], degrees[u], degrees[v])
        if agg == "add":
            w = valid[:, None].astype(mu.dtype)
            acc = jnp.zeros((num_vertices, mu.shape[-1]), mu.dtype)
            acc = acc.at[v].add(mu * w)  # message flowing u -> v
            acc = acc.at[u].add(mv * w)  # message flowing v -> u (undirected)
        elif agg == "min":
            mu = jnp.where(valid[:, None], mu, BIG)
            mv = jnp.where(valid[:, None], mv, BIG)
            acc = jnp.full((num_vertices, mu.shape[-1]), BIG, mu.dtype)
            acc = acc.at[v].min(mu)
            acc = acc.at[u].min(mv)
        else:
            raise ValueError(agg)
        return acc

    return jax.vmap(one_partition)(edges, evalid)


def make_superstep(
    g: PartitionedGraph,
    msg_fn: Callable,
    apply_fn: Callable,  # (state, synced_acc, degrees) -> state
    mesh: Mesh,
    combine: str = "add",
):
    """Build a jitted superstep: state (V, d) -> state (V, d).

    The partition axis of `g.edges` is sharded over the mesh's `parts` axis;
    vertex state is replicated (small next to edges, the usual vertex-cut
    regime). Accumulators are masked to each partition's replica set before
    the cross-partition combine — the masked entries are the engine's real
    traffic.
    """
    v, k = g.num_vertices, g.k
    repl_t = jnp.asarray(np.asarray(g.replicas).T)  # (k, V)

    def step(state, edges, evalid, replicas_t, degrees):
        acc = gather_local(edges, evalid, state, degrees, msg_fn, v, agg=combine)
        if combine == "add":
            local = (acc * replicas_t[:, :, None]).sum(axis=0)  # mask to replicas
            synced = jax.lax.psum(local, "parts")
        elif combine == "min":
            local = jnp.where(replicas_t[:, :, None] > 0, acc, BIG).min(axis=0)
            synced = jax.lax.pmin(local, "parts")
        else:
            raise ValueError(combine)
        return apply_fn(state, synced, degrees)

    shard_step = compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P("parts"), P("parts"), P("parts"), P()),
        out_specs=P(),
        check_replication=False,
    )

    @jax.jit
    def superstep(state):
        return shard_step(state, g.edges, g.evalid, repl_t, g.degrees)

    return superstep
