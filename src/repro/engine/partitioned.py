"""Partitioned-graph device representation for the vertex-cut engine.

A `PartitionedGraph` is what the engine consumes after a partitioner ran:
per-partition padded edge lists (static shapes for JAX) + the replica table.
The replica table is exactly the structure whose row sums give Eq. 1's
replication degree — the engine's replica-synchronisation volume is derived
from it, which is how partitioning quality turns into processing latency.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import metrics

__all__ = ["PartitionedGraph", "build_partitioned_graph"]


@dataclasses.dataclass
class PartitionedGraph:
    """Static-shape vertex-cut partitioned graph.

    Attributes:
      edges: (k, e_max, 2) int32 — global vertex ids, zero-padded.
      evalid: (k, e_max) bool — padding mask.
      replicas: (V, k) bool — R_v membership.
      masters: (V,) int32 — owning partition per vertex (first replica).
      degrees: (V,) int32 — global degrees (undirected).
      num_vertices, k: sizes.
    """

    edges: jax.Array
    evalid: jax.Array
    replicas: jax.Array
    masters: jax.Array
    degrees: jax.Array
    num_vertices: int
    k: int

    @property
    def replication_degree(self) -> float:
        return metrics.replication_degree(np.asarray(self.replicas))

    @property
    def sync_volume_bytes(self) -> int:
        return metrics.sync_volume(np.asarray(self.replicas))

    @property
    def edges_per_partition(self) -> np.ndarray:
        return np.asarray(self.evalid.sum(axis=1))


def build_partitioned_graph(
    edges: np.ndarray, assign: np.ndarray, num_vertices: int, k: int,
    pad_multiple: int = 8,
) -> PartitionedGraph:
    """Scatter the edge stream into per-partition padded lists."""
    edges = np.asarray(edges, np.int32)
    assign = np.asarray(assign, np.int32)
    m = len(edges)
    assert assign.shape == (m,)
    # The fancy-indexing below (argsort buckets, replica sets) would silently
    # wrap -1 entries into partition k-1 — the same hazard graph/metrics.py
    # hard-fails on. An engine build needs a total assignment.
    bad = (assign < 0) | (assign >= k)
    if bad.any():
        idx = int(np.flatnonzero(bad)[0])
        raise ValueError(
            f"build_partitioned_graph: {int(bad.sum())} of {m} edges have "
            f"partition ids outside [0, {k}) (first: assign[{idx}] = "
            f"{int(assign[idx])}). Unassigned (-1) edges cannot be built "
            "into an engine graph — partition the full stream, or drop "
            "unassigned edges before building."
        )
    sizes = np.bincount(assign, minlength=k)
    e_max = max(int(sizes.max()), 1)
    e_max = -(-e_max // pad_multiple) * pad_multiple
    part_edges = np.zeros((k, e_max, 2), np.int32)
    evalid = np.zeros((k, e_max), bool)
    order = np.argsort(assign, kind="stable")
    offs = np.concatenate([[0], np.cumsum(sizes)])
    for p in range(k):
        rows = order[offs[p] : offs[p + 1]]
        part_edges[p, : len(rows)] = edges[rows]
        evalid[p, : len(rows)] = True
    replicas = metrics.replica_sets_from_assignment(edges, assign, num_vertices, k)
    # Master = lowest partition id holding the vertex (vertices absent from the
    # graph point at partition 0; they never participate).
    first = np.where(replicas.any(axis=1), replicas.argmax(axis=1), 0)
    degrees = np.zeros(num_vertices, np.int64)
    np.add.at(degrees, edges[:, 0], 1)
    np.add.at(degrees, edges[:, 1], 1)
    return PartitionedGraph(
        edges=jnp.asarray(part_edges),
        evalid=jnp.asarray(evalid),
        replicas=jnp.asarray(replicas),
        masters=jnp.asarray(first.astype(np.int32)),
        degrees=jnp.asarray(degrees.astype(np.int32)),
        num_vertices=num_vertices,
        k=k,
    )
