"""Cluster processing-latency cost model.

The container is a single CPU host, so distributed graph *processing* latency
cannot be measured directly. Following the paper's own analysis (§IV: replica
synchronisation traffic drives processing latency), the model converts the
partitioned graph's measurable structure into per-superstep seconds for a
given cluster profile:

  t_step = t_compute + t_sync
  t_compute = max_p(edges_p) · msg_width · C_EDGE           (straggler = max)
  t_sync    = ceil(sync_bytes/nodes) / BW + 2·RTT
  sync_bytes = Σ_v (|R_v|−1) · 2 · msg_width · 4 B          (Eq. 1 traffic)

Profiles: the paper's evaluation cluster (8 nodes, 1 GbE) and a TPU-pod ICI
profile. Constants are calibrated so PageRank on the Brain-like proxy lands in
the paper's reported magnitude (hundreds of seconds per 100 iterations on
8×1 GbE); all benchmark *claims* are relative across partitioners, which the
model preserves exactly — traffic is linear in replication degree.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.engine.partitioned import PartitionedGraph

__all__ = ["ClusterProfile", "PAPER_CLUSTER", "TPU_POD", "process_latency"]


@dataclasses.dataclass(frozen=True)
class ClusterProfile:
    name: str
    nodes: int
    link_bw_Bps: float  # per-node usable bandwidth
    rtt_s: float
    edge_cost_s: float  # per (edge · message word)
    replica_cost_s: float  # per replica bookkeeping op


PAPER_CLUSTER = ClusterProfile(
    name="8x1GbE (paper)",
    nodes=8,
    link_bw_Bps=117e6,
    rtt_s=2e-4,
    edge_cost_s=9e-9,
    replica_cost_s=40e-9,
)

TPU_POD = ClusterProfile(
    name="v5e pod ICI",
    nodes=256,
    link_bw_Bps=5e10,
    rtt_s=1e-6,
    edge_cost_s=2e-10,
    replica_cost_s=1e-9,
)


# Streaming-partitioner cost constants calibrated to the paper's setup
# (HDRF on Brain: ~20.6M edges/instance on one 3 GHz Xeon core in O(100 s)
# ⇒ ~0.2 µs per (edge, partition) score evaluation + ~1 µs/edge stream IO).
# SCORE_COST_S is the *fallback*: when the kernel autotune table holds a
# measured window_score wall for this backend (see
# `repro.kernels.ops.measured_score_cost_s`), compute is billed at that
# measured tier instead of the paper's Xeon calibration.
SCORE_COST_S = 2.3e-7
EDGE_IO_COST_S = 1.0e-6


def _score_cost_s() -> float:
    """Per-score cost at the measured kernel tier, else the calibrated
    constant. Import is lazy/defensive: the model must keep working on
    installs where the kernels package cannot load."""
    try:
        from repro.kernels.ops import measured_score_cost_s

        measured = measured_score_cost_s()
    except Exception:
        measured = None
    return SCORE_COST_S if measured is None else measured
# Host→device stream-buffer bandwidth (PCIe-gen4-class x16 sustained). The
# scan drivers count every byte they ship (`h2d_bytes` in partition stats —
# O(m) for the ring-buffer file path, O(m) once for resident uploads); the
# model bills the transfer so buffer-management regressions (e.g. re-uploading
# a full ring per scan call) show up as modeled latency, not just wall noise.
H2D_BW_BPS = 16e9


def partition_latency(
    stats: dict, m: int, k: int, *, score_cost_s: float | None = None
) -> float:
    """Modeled cluster partitioning latency from the algorithm's own
    complexity counters (score computations — the paper's §III-B metric).

    Uses stats['score_rows'] (windowed partitioners) or stats['score_count']
    (single-edge: m·k) when present; hash-family partitioners cost IO only.
    Multi-pass strategies read the stream once per pass: the IO term is
    ``reads * m * EDGE_IO_COST_S`` with ``reads`` taken from
    stats['stream_reads'] (re-streaming reports passes_run there, 2PS
    reports 2), falling back to stats['passes_run'] / stats['passes'] and
    finally a single read — so Fig. 7-style plots bill re-streaming fairly
    with ``m`` being the plain stream length everywhere. Device-offloaded
    scans additionally bill their host→device stream traffic — the
    *measured* stall (stats['h2d_wait_s']: wall the driver actually spent
    blocked in refills) when the driver reports one, else the modeled
    transfer (stats['h2d_bytes'] / :data:`H2D_BW_BPS`).

    Overlap-aware billing: when the refill pipeline is active
    (stats['prefetch_depth'] > 0) the stream IO, the h2d transfer, and the
    scoring compute run concurrently by construction (the read-ahead worker
    reads while the scan computes, and the speculative refill ships while
    the scan is in flight), so the model bills ``max(compute, io, h2d)``
    instead of their sum. Without prefetch the classic additive model
    stands. The *measured* CPU wall-clock stays in stats['wall_time_s'] for
    reference — the model keeps partitioning and processing in the same
    cluster units.
    """
    if "score_rows" in stats:
        scores = stats["score_rows"] * k
    else:
        scores = stats.get("score_count", 0)
    reads = int(
        stats.get("stream_reads")
        or stats.get("passes_run")
        or stats.get("passes")
        or 1
    )
    # Compute is billed at the measured kernel tier when the autotune table
    # has one for this backend; callers can pin the cost explicitly.
    compute = scores * (_score_cost_s() if score_cost_s is None else score_cost_s)
    io = reads * m * EDGE_IO_COST_S
    # Measured refill stall exists only when the ring driver ran refills
    # (refill_spans > 0); resident uploads report a structurally-zero wait
    # and keep the modeled transfer bill.
    if int(stats.get("refill_spans", 0) or 0) > 0 and "h2d_wait_s" in stats:
        h2d = float(stats["h2d_wait_s"])
    else:
        h2d = float(stats.get("h2d_bytes", 0)) / H2D_BW_BPS
    if int(stats.get("prefetch_depth", 0) or 0) > 0:
        return max(compute, io, h2d)
    return compute + io + h2d


def process_latency(
    g: PartitionedGraph,
    supersteps: int,
    msg_width: int,
    profile: ClusterProfile = PAPER_CLUSTER,
) -> dict:
    """Modeled processing latency (seconds) for `supersteps` rounds."""
    counts = np.asarray(g.replicas).sum(axis=1)
    n_replicas = int(counts.sum())
    sync_msgs = int(np.maximum(counts - 1, 0).sum()) * 2
    sync_bytes = sync_msgs * msg_width * 4
    edges_per = g.edges_per_partition
    # Partitions are distributed over the profile's nodes; a node's compute is
    # the sum of its partitions, the straggler is the max node.
    k = g.k
    per_node = np.add.reduceat(
        np.sort(edges_per)[::-1],
        np.arange(0, k, max(k // profile.nodes, 1)),
    )
    t_compute = float(per_node.max()) * msg_width * profile.edge_cost_s
    t_compute += n_replicas * profile.replica_cost_s
    t_sync = (sync_bytes / profile.nodes) / profile.link_bw_Bps + 2 * profile.rtt_s
    t_step = t_compute + t_sync
    return dict(
        profile=profile.name,
        supersteps=supersteps,
        t_step_s=t_step,
        t_total_s=t_step * supersteps,
        t_compute_s=t_compute,
        t_sync_s=t_sync,
        sync_bytes_per_step=sync_bytes,
        replication_degree=g.replication_degree,
    )
