"""Serving launcher: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch import sharding as shg
from repro.launch.mesh import make_local_mesh
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh(args.tp)
    tp = args.tp
    rng = np.random.default_rng(args.seed)

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed), tp=tp)
    max_seq = args.prompt_len + args.gen
    cache = lm.init_cache(cfg, args.batch, max_seq, tp=tp)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, max(args.prompt_len // 2, 1), cfg.d_model)),
            jnp.dtype(cfg.dtype),
        )
    if cfg.family == "vlm":
        kw["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vlm_patches, cfg.d_model)),
            jnp.dtype(cfg.dtype),
        )

    @jax.jit
    def decode_step(params, cache, tok, pos):
        logits, cache = lm.forward_cached(params, cfg, cache, tok, pos, tp=tp)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    t0 = time.perf_counter()
    logits, cache = lm.forward_cached(
        params, cfg, cache, prompts, jnp.int32(0), tp=tp, **kw
    )
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    offset = cfg.vlm_patches if cfg.family == "vlm" else 0
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        pos = jnp.int32(offset + args.prompt_len + i)
        tok, cache = decode_step(params, cache, tok, pos)
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print("generated:", gen[:, :12].tolist())
    tokens = args.batch * (args.gen - 1)
    print(
        f"prefill {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f} ms; "
        f"decode {tokens} tok in {t_decode*1e3:.1f} ms "
        f"({tokens/max(t_decode,1e-9):.1f} tok/s)"
    )
    return gen


if __name__ == "__main__":
    main()
