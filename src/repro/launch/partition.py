"""Graph partition→process launcher — the paper's pipeline as a job type.

    PYTHONPATH=src python -m repro.launch.partition --graph brain_like --scale 0.1 \
        --strategy adwise --k 32 --z 8 --spread 4 --budget 2.0 \
        --workload pagerank --iters 100

    # out-of-core: partition a file-resident graph with bounded edge memory
    PYTHONPATH=src python -m repro.launch.partition --graph /data/orkut.adw \
        --strategy adwise-restream --passes 3 --k 32 --chunk-edges 262144
    # text edge list (SNAP format): ingest to binary first, then partition
    PYTHONPATH=src python -m repro.launch.partition --graph /data/orkut.txt \
        --ingest --relabel --strategy hdrf --k 32

Runs: stream partitioning (any strategy in the `repro.core.registry` —
adwise / adwise-restream / 2ps / 2ps-l / hdrf / dbh / greedy / hash / grid —
optionally under spotlight parallel loading) → vertex-cut engine build →
workload → total latency report (measured partitioning wall-clock + modeled
cluster processing latency, cf. DESIGN.md §3). New partitioners registered
in `repro/core/registry.py` show up in `--strategy` automatically;
`--passes` / `--eps` set the re-streaming pass count / early-stop for
adwise-restream. `2ps-l` is the linear-run-time 2PS variant (2PS phase-1
clustering, then a single windowless cluster-score pass as its own
step-core); it takes no AdwiseConfig knobs — its `cluster_slack=` / `lam=` /
`cap_slack=` defaults are the registry's. With `--z N` (alias `--parallel`)
the z spotlight instances run as ONE batched (vmapped / multi-device
shard_mapped) program for EVERY registry strategy — each strategy is a
device-resident step-core behind one scan driver — and `--backend loop`
forces the sequential per-instance path (bit-identical escape hatch).

`--graph` also takes a *path* instead of a preset name: a binary edge-stream
file (`repro.graph.io` format) is partitioned out-of-core through
`repro.core.oocore.partition_file` — resident edge memory stays bounded by
`--chunk-edges`, assignments spill to disk, quality metrics accumulate in
chunks, and the report includes the measured ingest wall / stream reads.
`--ingest` converts a SNAP-style text edge list to the binary format first
(one pass, O(chunk) memory; `--relabel` densifies sparse vertex ids).
`--prefetch N` sets the double-buffered ring-refill depth (0 = synchronous
escape hatch); the report then shows the measured h2d stall and the fraction
of refill spans the read-ahead worker had prestaged.

`--trace out.json` records a span timeline of the run with
`repro.obs.Tracer` and writes it as Chrome trace-event JSON — open it in
https://ui.perfetto.dev (or chrome://tracing). Tracks: the main stepping
loop (`scan`/`refill`/`phase` spans), the `adwise-readahead` worker
(`stage` spans + queue-depth counter), and one `restream-pass-<j>` lane per
re-streaming pass. The result's `stats["trace_summary"]` carries the
aggregate view (`events`, `wall_s`, per-category `{count, wall_s}`,
`tracks`); the same dict is printed at the end of a traced run. Tracing is
host-side only — spans wrap dispatch and host waits, never adding a device
sync — so `--trace` does not perturb the measured pipeline.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import (
    AdwiseConfig,
    available_strategies,
    partition_file,
    run_partitioner,
    spotlight_partition,
)
from repro.engine import (
    PAPER_CLUSTER,
    build_partitioned_graph,
    coloring,
    label_propagation,
    pagerank,
    process_latency,
    triangle_count,
)
from repro.graph import (
    make_graph,
    partition_balance,
    replica_sets_from_assignment,
    replication_degree,
    unassigned_count,
)

# Strategies that take AdwiseConfig-style knobs from the CLI.
_ADWISE_LIKE = ("adwise", "adwise-restream", "2ps")


def adwise_cfg_kwargs(args) -> dict:
    return dict(
        window_max=args.window_max,
        latency_budget=args.budget,
        use_clustering=not args.no_cs,
    )


def strategy_cfg_kwargs(args) -> dict:
    """Registry-style **cfg for the active strategy (file-driven path)."""
    cfg = {}
    if args.strategy in _ADWISE_LIKE:
        cfg = adwise_cfg_kwargs(args)
    if args.strategy == "adwise-restream":
        cfg["passes"] = args.passes
        if args.eps is not None:
            cfg["eps"] = args.eps
    return cfg


def run_partition_file(path, args, trace=None):
    """Out-of-core path: ingest (optional) → partition_file → chunked metrics."""
    from repro.graph.io import EdgeFileReader, ingest_text

    if args.oracle:
        raise SystemExit(
            "--oracle (the sequential Algorithm-1 reference) has no "
            "out-of-core driver; run it on a generator preset instead"
        )
    if args.backend in ("batched", "loop"):
        print(f"note: --backend {args.backend} has no file-driven equivalent; "
              "using 'auto' (every scan-core strategy rides the batched ring "
              "buffer; only the stateless hashes run a per-instance loop)")
    ingest_tmp = None
    if args.ingest:
        # The cache name keys on --relabel: the two settings produce
        # different id spaces, so they must never reuse each other's binary.
        suffix = ".relabel.adw" if args.relabel else ".adw"
        binary = path + suffix
        if not os.access(os.path.dirname(os.path.abspath(path)) or ".", os.W_OK):
            # Read-only dataset mount: put the binary in the spill dir (kept)
            # or a temp dir the end of the run removes.
            if args.spill_dir is None:
                ingest_tmp = tempfile.mkdtemp(prefix="adwise-ingest-")
            else:
                os.makedirs(args.spill_dir, exist_ok=True)
            binary = os.path.join(
                args.spill_dir or ingest_tmp, os.path.basename(path) + suffix
            )
        if (os.path.exists(binary)
                and os.path.getmtime(binary) >= os.path.getmtime(path)):
            print(f"reusing up-to-date binary {binary} (delete it to re-ingest)")
        else:
            rep = ingest_text(path, binary, relabel=args.relabel)
            mb = rep.bytes_read / 1e6
            print(
                f"ingested {path}: {rep.num_edges} edges, {rep.num_vertices} "
                f"vertices, {rep.comment_lines} comments, {rep.blank_lines} "
                f"blanks in {rep.wall_s:.2f}s "
                f"({mb / max(rep.wall_s, 1e-9):.1f} MB/s) -> {binary}"
            )
        path = binary
    reader = EdgeFileReader(path)
    print(
        f"graph={path} |V|={reader.num_vertices} |E|={reader.num_edges} "
        f"k={args.k} (out-of-core, chunk={args.chunk_edges})"
    )
    backend = args.backend if args.backend not in ("batched", "loop") else "auto"
    spill_tmp = None if args.spill_dir else tempfile.mkdtemp(prefix="adwise-oocore-")
    res = partition_file(
        reader, args.strategy, args.k, z=args.parallel,
        spread=args.spread if args.parallel > 1 else None, seed=args.seed,
        chunk_edges=args.chunk_edges, backend=backend,
        spill_dir=args.spill_dir or spill_tmp, prefetch=args.prefetch,
        trace=trace, **strategy_cfg_kwargs(args),
    )
    return reader, res, spill_tmp, ingest_tmp


def run_partition(edges, n, args, trace=None):
    from repro.obs import resolve_tracer

    tr = resolve_tracer(trace)
    # In-memory paths get one coarse phase span (spotlight/registry routes
    # don't thread a tracer); the file-driven path traces the full pipeline.
    with tr.span("partition", cat="phase", strategy=args.strategy, k=args.k):
        return _run_partition(edges, n, args)


def _run_partition(edges, n, args):
    if args.parallel > 1:
        cfg = None
        strategy_cfg = None
        if args.strategy == "adwise":
            cfg = AdwiseConfig(k=args.k, **adwise_cfg_kwargs(args))
        elif args.strategy in _ADWISE_LIKE:
            strategy_cfg = adwise_cfg_kwargs(args)
            if args.strategy == "adwise-restream":
                strategy_cfg["passes"] = args.passes
                if args.eps is not None:
                    strategy_cfg["eps"] = args.eps
        return spotlight_partition(
            edges, n, args.k, z=args.parallel, spread=args.spread,
            strategy=args.strategy, cfg=cfg, seed=args.seed,
            strategy_cfg=strategy_cfg, backend=args.backend,
        )
    cfg = {}
    if args.strategy in _ADWISE_LIKE:
        cfg = adwise_cfg_kwargs(args)
    if args.strategy == "adwise":
        cfg["oracle"] = args.oracle
    elif args.strategy == "adwise-restream":
        cfg["passes"] = args.passes
        if args.eps is not None:
            cfg["eps"] = args.eps
    return run_partitioner(args.strategy, edges, n, args.k, seed=args.seed, **cfg)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="brain_like",
                    help="generator preset (brain_like/orkut_like/web_like/...)"
                         " OR a path to a graph file: a binary edge-stream "
                         "file (repro.graph.io format) is partitioned "
                         "out-of-core with bounded edge memory; with "
                         "--ingest, a SNAP-style text edge list is converted "
                         "to the binary format first")
    ap.add_argument("--ingest", action="store_true",
                    help="treat --graph as a text edge list (u v per line, "
                         "#/% comments, blank lines) and ingest it to "
                         "<graph>.adw before partitioning (one pass, "
                         "O(chunk) memory)")
    ap.add_argument("--relabel", action="store_true",
                    help="with --ingest: map vertex ids to a dense [0, n) "
                         "space in first-appearance order (required for "
                         "sparse or negative ids)")
    ap.add_argument("--chunk-edges", type=int, default=1 << 16,
                    help="out-of-core chunk size: resident edge rows are "
                         "bounded by ~2x this per spotlight instance "
                         "(file-driven path only)")
    ap.add_argument("--spill-dir", default=None,
                    help="directory for the assignment spill (file-driven "
                         "path). Default: a temp dir, removed when the run "
                         "finishes; pass a path to keep the spill")
    ap.add_argument("--prefetch", type=int, default=None,
                    help="read-ahead depth for the file-driven ring refill "
                         "pipeline: 0 = synchronous (bit-identical escape "
                         "hatch), N>=1 overlaps file read + h2d staging with "
                         "the running scan. Default: $ADWISE_PREFETCH or 2")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--strategy", default="adwise",
                    choices=available_strategies())
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--parallel", "--z", type=int, default=1, dest="parallel",
                    help="z partitioner instances (spotlight parallel loading)")
    ap.add_argument("--spread", type=int, default=4)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "batched", "vmap", "shard_map", "loop"],
                    help="spotlight execution: one batched program for all z "
                         "instances (auto — every registry strategy batches) "
                         "or the sequential per-instance loop")
    ap.add_argument("--budget", type=float, default=None, help="latency preference L (s)")
    ap.add_argument("--window-max", type=int, default=256)
    ap.add_argument("--passes", type=int, default=2,
                    help="re-streaming passes (adwise-restream)")
    ap.add_argument("--eps", type=float, default=None,
                    help="early-stop re-streaming when a pass improves RD by "
                         "less than this (adwise-restream)")
    ap.add_argument("--no-cs", action="store_true", help="disable clustering score")
    ap.add_argument("--oracle", action="store_true", help="sequential reference impl")
    ap.add_argument("--workload", default="pagerank",
                    choices=["pagerank", "coloring", "wcc", "triangles", "none"])
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record a span timeline of the run (repro.obs) and "
                         "write Chrome trace-event JSON here — open in "
                         "https://ui.perfetto.dev. Host-side only: no added "
                         "device syncs")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        from repro.obs import Tracer

        tracer = Tracer()

    from_file = args.ingest or os.path.exists(args.graph)
    reader = None
    spill_tmp = ingest_tmp = None
    if from_file:
        reader, res, spill_tmp, ingest_tmp = run_partition_file(
            args.graph, args, trace=tracer)
        n = reader.num_vertices
        edges = None  # never resident during partitioning
    else:
        edges, n = make_graph(args.graph, seed=args.seed, scale=args.scale)
        print(f"graph={args.graph} |V|={n} |E|={len(edges)} k={args.k}")
        res = run_partition(edges, n, args, trace=tracer)
    # The unassigned count is reported explicitly, so quality metrics run
    # under the 'drop' policy: a partial assignment yields numbers over the
    # assigned subset *plus* a nonzero unassigned= field — never a silent
    # mis-count (and never a crash before the count is printed).
    n_unassigned = unassigned_count(res.assign)
    if from_file:
        # Chunked metric accumulation: the quality numbers for a file-driven
        # run never materialize the edge array either.
        from repro.graph import quality_from_chunks

        assign = res.assign
        pairs = (
            (chunk, assign[s : s + len(chunk)])
            for s, chunk in zip(
                range(0, reader.num_edges, args.chunk_edges),
                reader.chunks(args.chunk_edges),
            )
        )
        q = quality_from_chunks(pairs, n, args.k, unassigned="drop")
        rd, imb = q["replication_degree"], q["imbalance"]
    else:
        rep = replica_sets_from_assignment(edges, res.assign, n, args.k,
                                           unassigned="drop")
        rd = replication_degree(rep)
        imb = partition_balance(res.assign, args.k, unassigned="drop")
    t_part = res.stats.get("wall_time_s", 0.0)
    print(f"partitioner={args.strategy} RD={rd:.3f} imbalance={imb:.4f} "
          f"unassigned={n_unassigned} partition_latency={t_part:.2f}s")
    if from_file:
        print(
            f"io: {res.stats['rows_read']} rows read "
            f"({res.stats['stream_reads_measured']} stream reads, billed "
            f"{res.stats['stream_reads']}), io_wall={res.stats['io_wall_s']:.2f}s, "
            f"resident edges <= {res.stats['peak_resident_edges']}, "
            f"h2d={res.stats.get('h2d_bytes', 0) / 1e6:.2f} MB "
            f"({res.stats.get('h2d_rows', 0)} rows over "
            f"{res.stats.get('scan_calls', 0)} scan calls, "
            f"ring={res.stats.get('buffer_rows', 0)} rows), "
            f"spill={res.stats['spill_path']}"
        )
        spans = int(res.stats.get("refill_spans", 0) or 0)
        if spans:
            pre = int(res.stats.get("spans_prestaged", 0) or 0)
            wait = float(res.stats.get("h2d_wait_s", 0.0) or 0.0)
            prestage = float(res.stats.get("prestage_wall_s", 0.0) or 0.0)
            # Measured overlap: fraction of the worker's staging wall hidden
            # from the driver's critical path (1 - stall/staging).
            overlap = max(0.0, 1.0 - wait / prestage) if prestage > 0 else 0.0
            print(
                f"pipeline: prefetch={res.stats.get('prefetch_depth', 0)}, "
                f"h2d_wait={wait:.3f}s, prestage_wall={prestage:.3f}s, "
                f"spans={spans} ({pre} prestaged / "
                f"{int(res.stats.get('spans_missed', 0) or 0)} missed), "
                f"overlap={overlap:.0%}"
            )

    out = dict(
        graph=args.graph, strategy=args.strategy, k=args.k,
        replication_degree=rd, imbalance=imb, unassigned=n_unassigned,
        partition_latency_s=t_part,
        stats={k: v for k, v in res.stats.items()
               if isinstance(v, (int, float, str))
               or (isinstance(v, list)
                   and all(isinstance(x, (int, float)) for x in v))},
    )
    if args.workload != "none":
        if from_file:
            # Partitioning ran out-of-core; the *processing* engine builds a
            # resident partitioned graph, so the edges are loaded only now.
            print("loading edges for the processing engine (partitioning "
                  "itself ran out-of-core)")
            edges = reader.read_all()
        g = build_partitioned_graph(edges, res.assign, n, args.k)
        t0 = time.perf_counter()
        if args.workload == "pagerank":
            _, info = pagerank(g, iters=min(args.iters, 30), trace=tracer)
            info["supersteps"] = args.iters
        elif args.workload == "coloring":
            _, info = coloring(g, trace=tracer)
        elif args.workload == "wcc":
            _, info = label_propagation(g, trace=tracer)
        else:
            _, info = triangle_count(g, trace=tracer)
        t_proc_local = time.perf_counter() - t0
        model = process_latency(g, info["supersteps"], info["msg_width"], PAPER_CLUSTER)
        total = t_part + model["t_total_s"]
        print(
            f"workload={args.workload} supersteps={info['supersteps']} "
            f"modeled_processing={model['t_total_s']:.2f}s (cluster: {model['profile']}) "
            f"local_exec={t_proc_local:.2f}s\n"
            f"TOTAL latency (partition + modeled processing) = {total:.2f}s"
        )
        out.update(
            workload=args.workload,
            processing_model=model,
            total_latency_s=total,
        )
    if tracer is not None:
        n_events = tracer.export(args.trace)
        summ = tracer.summary()
        cats = ", ".join(
            f"{c}:{d['count']}x/{d['wall_s']:.3f}s"
            for c, d in sorted(summ.categories.items())
        )
        print(f"trace: {n_events} events -> {args.trace} "
              f"(wall={summ.wall_s:.3f}s; {cats})")
        out["trace"] = dict(path=args.trace, **summ.as_dict())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    if from_file:
        # The temp spill (|E|*4 bytes) dies with the run; metrics and the
        # workload are done with it (POSIX keeps the live mapping valid past
        # the unlink). --spill-dir keeps it instead. The reader FD always
        # closes (in-process callers — benches, tests — must not leak one
        # per run); the ingest temp dir follows the spill's lifetime.
        reader.close()
        if spill_tmp is not None:
            shutil.rmtree(spill_tmp, ignore_errors=True)
        if ingest_tmp is not None:
            shutil.rmtree(ingest_tmp, ignore_errors=True)
    return out


if __name__ == "__main__":
    main()
