"""Graph partition→process launcher — the paper's pipeline as a job type.

    PYTHONPATH=src python -m repro.launch.partition --graph brain_like --scale 0.1 \
        --strategy adwise --k 32 --z 8 --spread 4 --budget 2.0 \
        --workload pagerank --iters 100

Runs: stream partitioning (any strategy in the `repro.core.registry` —
adwise / adwise-restream / 2ps / hdrf / dbh / greedy / hash / grid —
optionally under spotlight parallel loading) → vertex-cut engine build →
workload → total latency report (measured partitioning wall-clock + modeled
cluster processing latency, cf. DESIGN.md §3). New partitioners registered
in `repro/core/registry.py` show up in `--strategy` automatically;
`--passes` / `--eps` set the re-streaming pass count / early-stop for
adwise-restream. With `--z N` (alias `--parallel`) the z spotlight instances
run as ONE batched (vmapped / multi-device shard_mapped) program for
adwise-family strategies — `--backend loop` forces the sequential
per-instance path (the only mode for the masked baselines).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (
    AdwiseConfig,
    available_strategies,
    run_partitioner,
    spotlight_partition,
)
from repro.engine import (
    PAPER_CLUSTER,
    build_partitioned_graph,
    coloring,
    label_propagation,
    pagerank,
    process_latency,
    triangle_count,
)
from repro.graph import (
    make_graph,
    partition_balance,
    replica_sets_from_assignment,
    replication_degree,
    unassigned_count,
)

# Strategies that take AdwiseConfig-style knobs from the CLI.
_ADWISE_LIKE = ("adwise", "adwise-restream", "2ps")


def adwise_cfg_kwargs(args) -> dict:
    return dict(
        window_max=args.window_max,
        latency_budget=args.budget,
        use_clustering=not args.no_cs,
    )


def run_partition(edges, n, args):
    if args.parallel > 1:
        cfg = None
        strategy_cfg = None
        if args.strategy == "adwise":
            cfg = AdwiseConfig(k=args.k, **adwise_cfg_kwargs(args))
        elif args.strategy in _ADWISE_LIKE:
            strategy_cfg = adwise_cfg_kwargs(args)
            if args.strategy == "adwise-restream":
                strategy_cfg["passes"] = args.passes
                if args.eps is not None:
                    strategy_cfg["eps"] = args.eps
        return spotlight_partition(
            edges, n, args.k, z=args.parallel, spread=args.spread,
            strategy=args.strategy, cfg=cfg, seed=args.seed,
            strategy_cfg=strategy_cfg, backend=args.backend,
        )
    cfg = {}
    if args.strategy in _ADWISE_LIKE:
        cfg = adwise_cfg_kwargs(args)
    if args.strategy == "adwise":
        cfg["oracle"] = args.oracle
    elif args.strategy == "adwise-restream":
        cfg["passes"] = args.passes
        if args.eps is not None:
            cfg["eps"] = args.eps
    return run_partitioner(args.strategy, edges, n, args.k, seed=args.seed, **cfg)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="brain_like")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--strategy", default="adwise",
                    choices=available_strategies())
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--parallel", "--z", type=int, default=1, dest="parallel",
                    help="z partitioner instances (spotlight parallel loading)")
    ap.add_argument("--spread", type=int, default=4)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "batched", "vmap", "shard_map", "loop"],
                    help="spotlight execution: one batched program for all z "
                         "instances (auto for adwise/adwise-restream) or the "
                         "sequential per-instance loop")
    ap.add_argument("--budget", type=float, default=None, help="latency preference L (s)")
    ap.add_argument("--window-max", type=int, default=256)
    ap.add_argument("--passes", type=int, default=2,
                    help="re-streaming passes (adwise-restream)")
    ap.add_argument("--eps", type=float, default=None,
                    help="early-stop re-streaming when a pass improves RD by "
                         "less than this (adwise-restream)")
    ap.add_argument("--no-cs", action="store_true", help="disable clustering score")
    ap.add_argument("--oracle", action="store_true", help="sequential reference impl")
    ap.add_argument("--workload", default="pagerank",
                    choices=["pagerank", "coloring", "wcc", "triangles", "none"])
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    edges, n = make_graph(args.graph, seed=args.seed, scale=args.scale)
    print(f"graph={args.graph} |V|={n} |E|={len(edges)} k={args.k}")

    res = run_partition(edges, n, args)
    # The unassigned count is reported explicitly, so quality metrics run
    # under the 'drop' policy: a partial assignment yields numbers over the
    # assigned subset *plus* a nonzero unassigned= field — never a silent
    # mis-count (and never a crash before the count is printed).
    n_unassigned = unassigned_count(res.assign)
    rep = replica_sets_from_assignment(edges, res.assign, n, args.k,
                                       unassigned="drop")
    rd = replication_degree(rep)
    imb = partition_balance(res.assign, args.k, unassigned="drop")
    t_part = res.stats.get("wall_time_s", 0.0)
    print(f"partitioner={args.strategy} RD={rd:.3f} imbalance={imb:.4f} "
          f"unassigned={n_unassigned} partition_latency={t_part:.2f}s")

    out = dict(
        graph=args.graph, strategy=args.strategy, k=args.k,
        replication_degree=rd, imbalance=imb, unassigned=n_unassigned,
        partition_latency_s=t_part,
        stats={k: v for k, v in res.stats.items()
               if isinstance(v, (int, float, str))
               or (isinstance(v, list)
                   and all(isinstance(x, (int, float)) for x in v))},
    )
    if args.workload != "none":
        g = build_partitioned_graph(edges, res.assign, n, args.k)
        t0 = time.perf_counter()
        if args.workload == "pagerank":
            _, info = pagerank(g, iters=min(args.iters, 30))
            info["supersteps"] = args.iters
        elif args.workload == "coloring":
            _, info = coloring(g)
        elif args.workload == "wcc":
            _, info = label_propagation(g)
        else:
            _, info = triangle_count(g)
        t_proc_local = time.perf_counter() - t0
        model = process_latency(g, info["supersteps"], info["msg_width"], PAPER_CLUSTER)
        total = t_part + model["t_total_s"]
        print(
            f"workload={args.workload} supersteps={info['supersteps']} "
            f"modeled_processing={model['t_total_s']:.2f}s (cluster: {model['profile']}) "
            f"local_exec={t_proc_local:.2f}s\n"
            f"TOTAL latency (partition + modeled processing) = {total:.2f}s"
        )
        out.update(
            workload=args.workload,
            processing_model=model,
            total_latency_s=total,
        )
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    main()
