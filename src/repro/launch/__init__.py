"""Launchers: production meshes, sharding rules, dry-run, train/serve/partition."""
