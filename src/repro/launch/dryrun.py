"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices host the production mesh; every cell must `.lower().compile()`
and report memory_analysis / cost_analysis / collective bytes.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
"""
# The VERY FIRST lines — before any other import (jax locks the device count
# on first init):
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.data import make_batch_spec  # noqa: E402
from repro.launch import sharding as shg  # noqa: E402
from repro.launch.mesh import MODEL_PARALLEL, make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.optim import adamw_init, adamw_update, cosine_schedule  # noqa: E402

# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8}
# `%name = <output types> <op>(operands...)`; async starts counted, dones not.
_COLL_RE = re.compile(
    r"=\s*(\(?[^=]*?)\s*(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str, trip_counts: dict | None = None) -> dict:
    """Sum output bytes of every collective op in post-SPMD optimized HLO.

    Bytes = per-device output size of each collective (the data each chip
    receives). Ops inside `while` bodies are scaled by the loop trip count
    when `trip_counts` maps computation-name → trips (unrolled dry-runs don't
    need it).
    """
    out: dict = {}
    scale = 1
    for line in hlo_text.splitlines():
        if trip_counts:
            for comp, trips in trip_counts.items():
                if line.strip().startswith(f"%{comp}") or line.strip().startswith(comp):
                    scale = trips
        m = _COLL_RE.search(line)
        if not m or m.group(3) == "-done" or "-done(" in line:
            continue
        op = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = int(np.prod([int(d) for d in dims.split(",") if d])) if dims else 1
            nbytes += n * _DTYPE_BYTES.get(dt, 4)
        out[op] = out.get(op, 0) + nbytes * scale
    out["total"] = sum(v for k, v in out.items())
    return out


# ----------------------------------------------------------------------------
# Step builders (shared with launch.train / launch.serve)
# ----------------------------------------------------------------------------

def make_train_step(cfg, tp: int, unroll: bool = False, batch_axes=None):
    lr_fn = cosine_schedule(3e-4, 100, 10_000)

    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            partial(lm.loss_fn, cfg=cfg, tp=tp, unroll=unroll,
                    batch_axes=batch_axes), has_aux=True
        )(params, batch=batch)
        params, opt = adamw_update(grads, opt, params, lr_fn(opt["step"]))
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt, metrics

    return train_step


def make_decode_step(cfg, tp: int, unroll: bool = False, batch_axes=None):
    def serve_step(params, cache, tokens, pos):
        logits, cache = lm.forward_cached(
            params, cfg, cache, tokens, pos, tp=tp, unroll=unroll,
            batch_axes=batch_axes,
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return serve_step


def make_prefill_step(cfg, tp: int, unroll: bool = False, batch_axes=None):
    def prefill_step(params, cache, tokens, frames=None, patches=None):
        kw = {}
        if frames is not None:
            kw["frames"] = frames
        if patches is not None:
            kw["patches"] = patches
        logits, cache = lm.forward_cached(
            params, cfg, cache, tokens, jnp.zeros((), jnp.int32), tp=tp,
            unroll=unroll, batch_axes=batch_axes, **kw
        )
        return logits[:, -1:], cache

    return prefill_step


def cell_supported(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, "full-attention arch: 500k decode needs sub-quadratic mixer"
    return True, ""


# ----------------------------------------------------------------------------
# Lower + compile one cell
# ----------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             serve_sharding: bool = False, ep_override=None,
             scan_only: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = dict(arch=arch, shape=shape_name, multi_pod=multi_pod,
               serve_sharding=serve_sharding)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = MODEL_PARALLEL
    dp_axes = shg.fsdp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    batch_axes = (
        (dp_axes if len(dp_axes) > 1 else dp_axes[0])
        if shape.global_batch % dp_size == 0
        else None
    )
    t0 = time.time()
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(partial(lm.init_params, cfg, tp=tp), key)
    mode = "serve" if (serve_sharding and shape.kind != "train") else "train"
    pspecs = shg.param_specs(cfg, mesh, tp, params_shape, mode=mode,
                             ep_override=ep_override)
    pshard = shg.to_shardings(mesh, pspecs)

    # Two compiles per cell:
    #  * scan-over-layers (the production program): memory_analysis — XLA
    #    reuses loop-body buffers, so temp/device is the deployable footprint;
    #  * unrolled layers: cost_analysis + collective parse — HLO cost
    #    analysis counts while bodies once, unrolling makes FLOPs/bytes exact.
    # Multi-pod cells prove the 'pod' axis shards (scan compile only); the
    # roofline table (exact unrolled cost analysis) is single-pod per spec.
    # scan_only: for the largest configs (80-layer qwen110) the unrolled
    # compile exceeds the container budget — compile-proof + memory stay
    # valid, cost columns are marked non-exact.
    modes = (False,) if (multi_pod or scan_only) else (False, True)
    compiled_by_mode = {}
    with mesh:
        for unroll in modes:
            if shape.kind == "train":
                opt_shape = jax.eval_shape(adamw_init, params_shape)
                ospecs = shg.opt_specs(cfg, mesh, tp, opt_shape, pspecs)
                oshard = shg.to_shardings(mesh, ospecs)
                batch_shape = make_batch_spec(cfg, shape)
                bspecs = shg.batch_specs(cfg, mesh, batch_shape)
                bshard = shg.to_shardings(mesh, bspecs)
                step = make_train_step(cfg, tp, unroll=unroll, batch_axes=batch_axes)
                jitted = jax.jit(
                    step,
                    in_shardings=(pshard, oshard, bshard),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(params_shape, opt_shape, batch_shape)
                tokens_per_step = shape.global_batch * shape.seq_len
                model_flops = 6 * cfg.active_param_count() * tokens_per_step
            else:
                cache_shape = jax.eval_shape(
                    partial(lm.init_cache, cfg, shape.global_batch, shape.seq_len, tp=tp)
                )
                cspecs = shg.cache_specs(cfg, mesh, tp, cache_shape)
                cshard = shg.to_shardings(mesh, cspecs)
                if shape.kind == "decode":
                    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
                    pos = jax.ShapeDtypeStruct((), jnp.int32)
                    step = make_decode_step(cfg, tp, unroll=unroll, batch_axes=batch_axes)
                    jitted = jax.jit(
                        step,
                        in_shardings=(pshard, cshard, shg.to_shardings(
                            mesh, shg.batch_specs(cfg, mesh, {"tokens": tok})
                        )["tokens"], None),
                        out_shardings=(None, cshard),
                        donate_argnums=(1,),
                    )
                    lowered = jitted.lower(params_shape, cache_shape, tok, pos)
                    model_flops = 2 * cfg.active_param_count() * shape.global_batch
                else:  # prefill
                    spec = make_batch_spec(cfg, shape, extra_token=False)
                    bspecs = shg.batch_specs(cfg, mesh, spec)
                    bshard = shg.to_shardings(mesh, bspecs)
                    step = make_prefill_step(cfg, tp, unroll=unroll, batch_axes=batch_axes)
                    args = [params_shape, cache_shape, spec["tokens"]]
                    in_sh = [pshard, cshard, bshard["tokens"]]
                    kw = {}
                    if cfg.family == "encdec":
                        kw["frames"] = spec["frames"]
                    if cfg.family == "vlm":
                        kw["patches"] = spec["patches"]
                    jitted = jax.jit(
                        step,
                        in_shardings=tuple(in_sh) + tuple(
                            bshard[k] for k in kw
                        ),
                        out_shardings=(None, cshard),
                        donate_argnums=(1,),
                    )
                    lowered = jitted.lower(*args, *kw.values())
                    model_flops = (
                        2 * cfg.active_param_count() * shape.global_batch * shape.seq_len
                    )
                model_flops = float(model_flops)

            compiled_by_mode[unroll] = lowered.compile()
        t_compile = time.time() - t0
        t_lower = 0.0

    mem = compiled_by_mode[False].memory_analysis()  # production (scanned) program
    compiled = compiled_by_mode[max(modes)]  # exact cost analysis when unrolled
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    n_chips = np.prod(mesh.devices.shape)

    # cost_analysis() of the SPMD-partitioned module reports PER-DEVICE
    # flops/bytes; the roofline terms divide by per-chip rates directly
    # (equivalent to global量 / (chips × rate)).
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_hbm / HBM_BW
    t_coll = coll["total"] / ICI_BW
    dominant = max(
        [("compute", t_comp), ("memory", t_mem), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]
    rec.update(
        status="ok",
        cost_exact=bool(max(modes)),
        n_chips=int(n_chips),
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        hlo_flops=flops,
        hlo_bytes=bytes_hbm,
        collective_bytes=coll,
        model_flops=float(model_flops),
        useful_flops_ratio=(
            float(model_flops / (flops * n_chips)) if flops else None
        ),
        t_compute_s=t_comp,
        t_memory_s=t_mem,
        t_collective_s=t_coll,
        dominant=dominant,
        bytes_per_device=dict(  # memory_analysis is per-device under SPMD
            argument=getattr(mem, "argument_size_in_bytes", 0),
            output=getattr(mem, "output_size_in_bytes", 0),
            alias=getattr(mem, "alias_size_in_bytes", 0),
            temp=getattr(mem, "temp_size_in_bytes", 0),
            peak=getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0),
        ),
    )
    if verbose:
        bpd = rec["bytes_per_device"]
        print(
            f"[{arch} × {shape_name} × {'2pod' if multi_pod else '1pod'}] OK "
            f"compile={t_compile:.0f}s flops={flops:.3g} bytes={bytes_hbm:.3g} "
            f"coll={coll['total']:.3g} dominant={dominant} "
            f"arg/dev={bpd['argument']/1e9:.2f}GB temp/dev={bpd['temp']/1e9:.2f}GB"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--serve-sharding", action="store_true",
                    help="TP-only (replicated-over-data) weights for serving cells")
    ap.add_argument("--no-ep", action="store_true",
                    help="force expert-ff TP instead of expert parallelism (MoE)")
    ap.add_argument("--scan-only", action="store_true",
                    help="skip the unrolled cost-analysis compile (largest configs)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                meshes = [False, True] if args.both_meshes else [args.multi_pod]
                for mp in meshes:
                    cells.append((arch, shape, mp))
    else:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    results = []
    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
        done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}
        cells = [c for c in cells if c not in done]

    for arch, shape, mp in cells:
        try:
            rec = run_cell(arch, shape, mp, serve_sharding=args.serve_sharding,
                           ep_override=False if args.no_ep else None,
                           scan_only=args.scan_only)
        except Exception as e:  # record the failure — it is a bug to fix
            rec = dict(arch=arch, shape=shape, multi_pod=mp,
                       status="error", error=f"{type(e).__name__}: {e}")
            print(f"[{arch} × {shape} × {'2pod' if mp else '1pod'}] FAIL {rec['error']}")
        results.append(rec)
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            json.dump(results, open(args.out, "w"), indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"dry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
