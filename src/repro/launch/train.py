"""Training launcher (CPU-runnable on reduced configs; mesh-agnostic).

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
        --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Wires together every substrate: data pipeline, sharded step (same builder the
dry-run lowers), checkpoint manager, fault-tolerant loop, straggler monitor,
optional top-k gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticTokens
from repro.launch import sharding as shg
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.optim import adamw_init, adamw_update, cosine_schedule, topk_compress_allreduce
from repro.runtime import FaultTolerantLoop, StepFailure, StragglerMonitor


def build_state(cfg, mesh, tp, seed=0):
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(cfg, key, tp=tp)
    opt = adamw_init(params)
    pspecs = shg.param_specs(cfg, mesh, tp, params)
    pshard = shg.to_shardings(mesh, pspecs)
    oshard = shg.to_shardings(mesh, shg.opt_specs(cfg, mesh, tp, opt, pspecs))
    params = jax.device_put(params, pshard)
    opt = jax.device_put(opt, oshard)
    return params, opt, pshard, oshard


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", type=float, default=0.0,
                    help="top-k compression ratio (0 = exact reduction)")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a transient failure at this step (testing)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    mesh = make_local_mesh(args.tp)
    tp = args.tp

    params, opt, pshard, oshard = build_state(cfg, mesh, tp, args.seed)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M mesh={dict(mesh.shape)}")

    data = SyntheticTokens(cfg, shape, seed=args.seed)
    lr_fn = cosine_schedule(args.lr, max(args.steps // 10, 1), args.steps)
    compress = args.grad_compress

    def step_fn_inner(params, opt, residual, batch):
        (loss, metrics), grads = jax.value_and_grad(
            partial(lm.loss_fn, cfg=cfg, tp=tp), has_aux=True
        )(params, batch=batch)
        if compress > 0:
            grads, residual = topk_compress_allreduce(grads, residual, None, compress)
        params, opt = adamw_update(grads, opt, params, lr_fn(opt["step"]))
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt, residual, metrics

    jitted = jax.jit(step_fn_inner, donate_argnums=(0, 1, 2))
    residual0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0
    state = dict(params=params, opt=opt, residual=residual0)
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, manifest = ckpt.restore(state)
        start_step = manifest["step"]
        print(f"resumed from step {start_step}")

    def step_fn(state, batch):
        p, o, r, metrics = jitted(state["params"], state["opt"], state["residual"], batch)
        return dict(params=p, opt=o, residual=r), {
            k: float(v) for k, v in metrics.items()
        }

    def save_fn(step, state):
        if ckpt:
            ckpt.save(step, state, meta=dict(arch=cfg.name))

    def restore_fn():
        assert ckpt is not None, "restore requires --ckpt-dir"
        st, manifest = ckpt.restore(state)
        return st, manifest["step"]

    def failure_hook(step):
        if step == args.inject_failure_at:
            args.inject_failure_at = -1  # fire once
            raise StepFailure("transient", "injected test failure")

    monitor = StragglerMonitor(hosts=1)
    loop = FaultTolerantLoop(
        step_fn, save_fn, restore_fn, ckpt_every=args.ckpt_every,
        failure_hook=failure_hook,
    )

    def batches(step):
        b = data.batch_at(step)
        return {k: jnp.asarray(v) for k, v in b.items()}

    state, history = loop.run(state, batches, start_step, args.steps)
    for step, m in history[:3] + history[-3:]:
        print(f"step {step:5d} loss={m['loss']:.4f} t={m['step_time_s']*1e3:.0f}ms")
        monitor.observe(np.array([m["step_time_s"]]))
    losses = [m["loss"] for _, m in history]
    print(
        f"done: steps={loop.stats.steps_done} retries={loop.stats.retries} "
        f"restores={loop.stats.restores} loss {losses[0]:.4f} -> {losses[-1]:.4f}"
    )
    return losses


if __name__ == "__main__":
    main()
