"""Sharding rules: DP/FSDP + TP (+ EP/SP) PartitionSpecs for every pytree.

Policy (mesh axes ('pod',)? + ('data', 'model')):
  * batch        → ('pod','data')  (DP)
  * weights      → FSDP-shard the non-parallel dim over ('pod','data') AND
                   TP-shard the parallel dim over 'model' (ZeRO-3-style fully
                   sharded params; optimizer moments inherit the same specs =
                   sharded optimizer). This is what fits grok-1/qwen-110B in
                   16 GB/chip — see EXPERIMENTS.md §Dry-run memory table.
  * attn heads   → 'model' when divisible (policy from ArchConfig.padded_heads:
                   'shard'/'shard_q'/'pad'/'replicate')
  * MoE experts  → 'model' on the expert dim when n_experts % tp == 0 (EP,
                   granite), else 'model' on d_ff inside each expert (grok)
  * KV cache     → batch over ('pod','data') when divisible, sequence over
                   'model' (SP — this is what makes decode_32k/long_500k fit;
                   softmax over the sharded axis becomes a psum, flash-
                   decoding style)
  * SSM state    → heads over 'model', batch over ('pod','data') if divisible

All rules are mesh-shape agnostic (elastic re-mesh re-derives them).
"""
from __future__ import annotations

import fnmatch
from functools import partial
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import lm

__all__ = [
    "param_specs",
    "batch_specs",
    "cache_specs",
    "opt_specs",
    "to_shardings",
    "fsdp_axes",
]


def fsdp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp(mesh: Mesh):
    ax = fsdp_axes(mesh)
    return ax if len(ax) > 1 else ax[0]


def _rules(cfg: ArchConfig, mesh: Mesh, tp: int, ep_override=None):
    F = _dp(mesh)  # FSDP axes for weight sharding
    _, _, policy = cfg.padded_heads(tp)
    kv_shard = "model" if policy == "shard" else None
    q_shard = "model" if policy in ("shard", "shard_q", "pad") else None
    ep = cfg.moe is not None and cfg.moe.n_experts % tp == 0
    if ep_override is not None:
        ep = ep_override
    # (pattern, base_spec) — first match wins; leading stack dims padded later.
    return [
        # Embed: vocab over 'model' ONLY — FSDP-sharding its d_model dim over
        # 'data' would make the gather output's feature dim compete with the
        # batch dim for the data axis and GSPMD replicates the batch instead
        # (measured: 40 GB/device logits buffers). See EXPERIMENTS.md §Perf.
        ("embed", P("model", None)),
        ("head", P(F, "model")),
        ("vit_proj", P(F, None)),
        # Attention projections.
        ("*attn/wq", P(F, q_shard)),
        ("*attn/wk", P(F, kv_shard)),
        ("*attn/wv", P(F, kv_shard)),
        ("*attn/wo", P(q_shard, F)),
        ("*attn/bq", P(q_shard)),
        ("*attn/bk", P(kv_shard)),
        ("*attn/bv", P(kv_shard)),
        # Dense MLP.
        ("*mlp/w_gate", P(F, "model")),
        ("*mlp/w_up", P(F, "model")),
        ("*mlp/w_down", P("model", F)),
        # MoE.
        ("*moe/router", P(F, None)),
        ("*moe/w_gate", P("model", F, None) if ep else P(None, F, "model")),
        ("*moe/w_up", P("model", F, None) if ep else P(None, F, "model")),
        ("*moe/w_down", P("model", None, F) if ep else P(None, "model", F)),
        # RWKV-6 time-mix / channel-mix.
        ("*att/wr", P(F, "model")),
        ("*att/wk", P(F, "model")),
        ("*att/wv", P(F, "model")),
        ("*att/wg", P(F, "model")),
        ("*att/wo", P("model", F)),
        ("*att/w_a", P(F, None)),
        ("*att/w_b", P(None, F)),
        ("*att/u", P("model", None) if cfg.n_heads % tp == 0 else P(None, None)),
        ("*cm/wk", P(F, "model")),
        ("*cm/wv", P("model", F)),
        ("*cm/wr", P(F, "model")),
        # Mamba-2: head-aligned TP (z/x out dims are head-major H·P; dt is H).
        # B/C are shared across heads (N=64) — replicated. §Perf iteration C'.
        ("*mamba/w_z", P(F, "model")),
        ("*mamba/w_x", P(F, "model")),
        ("*mamba/w_B", P(F, None)),
        ("*mamba/w_C", P(F, None)),
        ("*mamba/w_dt", P(F, "model")),
        ("*mamba/a_log", P("model")),
        ("*mamba/dt_bias", P("model")),
        ("*mamba/d_skip", P("model")),
        ("*mamba/norm", P("model")),
        ("*mamba/w_out", P("model", F)),
        # Everything small (norms, mixes, decays, biases): replicated.
        ("*", P()),
    ]


def _match(path: str, shape, rules, axis_sizes):
    for pat, spec in rules:
        if fnmatch.fnmatch(path, pat) or fnmatch.fnmatch(path, "*/" + pat):
            base = tuple(spec)
            if len(base) > len(shape):  # 1-D bias matched by 2-D-ish rule
                base = base[-len(shape):] if len(shape) else ()
            pad = (None,) * (len(shape) - len(base))
            full = list(pad + base)
            # jit input shardings must divide the dim evenly; drop the
            # assignment otherwise (e.g. whisper/granite vocab % 16 != 0 →
            # embedding replicated, a few tens of MB).
            for i, ax in enumerate(full):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                total = int(np.prod([axis_sizes[a] for a in axes]))
                if shape[i] % total != 0:
                    full[i] = None
            return P(*full)
    return P()


def _path_str(path) -> str:
    return "/".join(
        str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path
    )


def param_specs(
    cfg: ArchConfig, mesh: Mesh, tp: int, params_shape: Any,
    mode: str = "train", ep_override=None,
) -> Any:
    """mode='train': FSDP+TP (fully sharded params — optimizer must fit).
    mode='serve': TP-only — weights replicated across the data axis. A decode
    step reads EVERY weight once per token, so FSDP sharding would all-gather
    the full model every step; serving replicas trade HBM for zero
    weight-gather traffic (§Perf hillclimb B)."""
    rules = _rules(cfg, mesh, tp, ep_override=ep_override)
    axis_sizes = dict(mesh.shape)

    def drop_fsdp(spec):
        fs = set(fsdp_axes(mesh))
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a not in fs)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                out.append(None if entry in fs else entry)
        return P(*out)

    def one(path, leaf):
        spec = _match(_path_str(path), leaf.shape, rules, axis_sizes)
        return drop_fsdp(spec) if mode == "serve" else spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_specs(cfg: ArchConfig, mesh: Mesh, tp: int, opt_shape: Any, pspecs: Any) -> Any:
    """Adam moments inherit the param specs; step is replicated."""
    return dict(m=pspecs, v=pspecs, step=P())


def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_shape: Any) -> Any:
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes(mesh)]))

    def one(path, leaf):
        b = leaf.shape[0]
        lead = dp if b % dp_size == 0 else None
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(cfg: ArchConfig, mesh: Mesh, tp: int, cache_shape: Any) -> Any:
    dp = _dp(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes(mesh)]))
    heads_ok = cfg.n_heads % tp == 0

    def one(path, leaf):
        p = _path_str(path)
        shp = leaf.shape
        if p.startswith("kv") or p.startswith("xkv"):
            if len(shp) == 5:  # (L, B, KV, S, Dh): sequence over 'model'.
                bdim = dp if shp[1] % dp_size == 0 else None
                return P(None, bdim, None, "model", None)
            # per-app leaf (B, KV, S, Dh) — hybrid shared-attn caches.
            bdim = dp if shp[0] % dp_size == 0 else None
            return P(bdim, None, "model", None)
        bdim = dp if shp[1] % dp_size == 0 else None
        if p.startswith("s"):
            # (L, B, H, N, P): heads over 'model'.
            return P(None, bdim, "model" if heads_ok else None, None, None)
        if p.startswith("lx"):
            return P(None, bdim, None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def to_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
