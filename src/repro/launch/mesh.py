"""Production meshes.

Everything is a function (no module-level jax device-state access) so imports
never lock the device count — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use.
Mesh construction goes through `repro.compat.make_mesh` so the same code
works on JAX versions without `jax.make_mesh`.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import compat

__all__ = ["make_production_mesh", "make_local_mesh", "MODEL_PARALLEL"]

MODEL_PARALLEL = 16  # TP degree of the production meshes


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over the actually-available local devices (tests, examples)."""
    n = jax.device_count()
    assert n % model_parallel == 0
    return compat.make_mesh((n // model_parallel, model_parallel), ("data", "model"))
