"""Grok-1 314B — 8-expert top-2 MoE [hf:xai-org/grok-1]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_head=128,
    d_ff=32768,
    vocab=131072,
    moe=MoEConfig(n_experts=8, top_k=2),
    notes="8 experts: expert-ff TP sharding (8 % 16 != 0 -> no pure EP)",
)
