"""Granite-3.0-1B-A400M — 32-expert top-8 MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=8,
    d_head=64,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=32, top_k=8),
    notes="fine-grained experts; EP-shardable (32 % 16 == 0)",
)
