"""RWKV-6 'Finch' 7B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,           # rwkv6 heads = d_model / 64
    n_kv=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    supports_long=True,   # linear recurrence: sub-quadratic, runs long_500k
    notes="attn-free linear recurrence; per-channel data-dependent decay",
)
