"""InternVL2-26B — InternViT frontend (stub) + InternLM2 backbone
[arXiv:2404.16821]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_head=128,
    d_ff=16384,
    vocab=92553,
    vlm_patches=256,      # precomputed patch embeddings (stub frontend)
)
