"""Whisper-tiny — enc-dec; conv frontend is a stub (precomputed frame
embeddings via input_specs) [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,           # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_head=64,
    d_ff=1536,
    vocab=51865,
    notes="audio backbone only; 6 heads -> attention replicated over TP axis",
)
