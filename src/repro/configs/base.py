"""Architecture configuration system.

One `ArchConfig` per assigned architecture (exact public-literature sizes in
`repro/configs/<id>.py`), consumed by `repro.models.lm` (model build),
`repro.launch.sharding` (partition specs) and `repro.launch.dryrun`
(ShapeDtypeStruct inputs). `reduced()` yields the CPU-smoke variant of the
same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence

__all__ = ["ArchConfig", "MoEConfig", "get_config", "ARCH_IDS", "SHAPES", "ShapeConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Transformer-family architecture description.

    family: 'dense' | 'moe' | 'ssm' (rwkv6) | 'hybrid' (mamba2+shared attn)
            | 'encdec' (whisper) | 'vlm' (internvl)
    layer kinds are derived from the family; `shared_every` controls the
    zamba2 shared-attention cadence.
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e4
    moe: Optional[MoEConfig] = None
    ssm_state: int = 64  # mamba2 state width / rwkv6 head dim
    shared_every: int = 6  # zamba2: shared attn block cadence
    n_enc_layers: int = 0  # whisper encoder depth
    vlm_patches: int = 256  # internvl: image patch tokens (stub frontend)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Which shape cells apply (long_500k only for sub-quadratic mixers).
    supports_long: bool = False
    has_decoder: bool = True
    notes: str = ""

    @property
    def attn_dims(self) -> tuple[int, int, int]:
        return self.n_heads, self.n_kv, self.d_head

    def padded_heads(self, tp: int) -> tuple[int, int, str]:
        """Resolve the attention TP policy for tensor-parallel degree `tp`.

        Returns (H_pad, KV_pad, policy):
          'shard'     — H and KV divisible: full head sharding.
          'shard_q'   — H divisible, KV replicated across TP.
          'pad'       — H padded to the next multiple of tp (zero extra heads).
          'replicate' — attention replicated over the model axis (tiny archs).
        """
        h, kv = self.n_heads, self.n_kv
        if h % tp == 0 and kv % tp == 0:
            return h, kv, "shard"
        if h % tp == 0:
            return h, kv, "shard_q"
        h_pad = -(-h // tp) * tp
        if h_pad <= h * 1.5:  # ≤50% extra attention FLOPs: pad
            return h_pad, kv, "pad"
        return h, kv, "replicate"

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        h, kv, dh = self.n_heads, self.n_kv, self.d_head
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.qkv_bias:
            attn += (h + 2 * kv) * dh
        if self.moe:
            mlp = 3 * d * ff * self.moe.n_experts + d * self.moe.n_experts
        else:
            mlp = 3 * d * ff
        norms = 2 * d
        if self.family == "ssm":  # rwkv6: r,k,v,g,o + decay params per layer
            mix = 5 * d * d + 2 * d + 4 * d * 64  # lora-ish decay/mix params
            per_layer = mix + mlp + norms
        elif self.family == "hybrid":
            d_in = 2 * d  # mamba2 expand=2
            mamba = d * (2 * d_in + 2 * self.ssm_state) + d_in * d + d_in
            n_shared = self.n_layers // self.shared_every
            n_mamba = self.n_layers - n_shared
            return (
                n_mamba * (mamba + norms)
                + (attn + mlp + 2 * norms)  # one shared block
                + v * d * (1 if self.tie_embeddings else 2)
                + d
            )
        else:
            per_layer = attn + mlp + norms
        if self.family in ("ssm",):
            total = self.n_layers * per_layer
        else:
            total = self.n_layers * per_layer
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp + norms) + self.n_layers * (
                attn + norms
            )  # cross-attention blocks
        total += v * d * (1 if self.tie_embeddings else 2) + d
        return int(total)

    def active_param_count(self) -> int:
        """N_active for MoE (experts scaled by top_k/n_experts)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        full_mlp = 3 * d * ff * self.moe.n_experts
        active_mlp = 3 * d * ff * self.moe.top_k
        return int(self.param_count() - self.n_layers * (full_mlp - active_mlp))

    def reduced(self) -> "ArchConfig":
        """CPU-smoke variant: same family/topology, tiny sizes."""
        kw = dataclasses.asdict(self)
        if self.moe:
            # Ample capacity: reduced configs must be drop-free so prefill /
            # decode / train paths are bit-consistent regardless of routing.
            kw["moe"] = MoEConfig(
                min(self.moe.n_experts, 4), min(self.moe.top_k, 2),
                capacity_factor=8.0,
            )
        kw.update(
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 5),
            d_model=128,
            n_heads=4,
            n_kv=max(1, min(self.n_kv, 2)),
            d_head=32,
            d_ff=256,
            vocab=512,
            ssm_state=16,
            shared_every=2,
            n_enc_layers=min(self.n_enc_layers, 2),
            vlm_patches=8,
            dtype="float32",
            name=self.name + "-smoke",
        )
        return ArchConfig(**kw)


ARCH_IDS = [
    "rwkv6_7b",
    "llama3_2_3b",
    "phi3_mini_3_8b",
    "qwen1_5_110b",
    "qwen1_5_0_5b",
    "zamba2_7b",
    "whisper_tiny",
    "granite_moe_1b",
    "grok_1_314b",
    "internvl2_26b",
]

_ALIASES = {
    "rwkv6-7b": "rwkv6_7b",
    "llama3.2-3b": "llama3_2_3b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "zamba2-7b": "zamba2_7b",
    "whisper-tiny": "whisper_tiny",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "grok-1-314b": "grok_1_314b",
    "internvl2-26b": "internvl2_26b",
}


def get_config(arch: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG
