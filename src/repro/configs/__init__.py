"""Assigned-architecture configs (public-literature sizes) + smoke variants."""
from repro.configs.base import ARCH_IDS, SHAPES, ArchConfig, MoEConfig, ShapeConfig, get_config

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MoEConfig", "ShapeConfig", "get_config"]
