"""Llama-3.2 3B — dense GQA decoder [hf:meta-llama/Llama-3.2-3B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    d_head=128,
    d_ff=8192,
    vocab=128256,
    rope_theta=5e5,
    tie_embeddings=True,
    notes="RoPE SwiGLU GQA; 24 heads pad to 32 under 16-way TP (see DESIGN.md)",
)
