"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,
    d_head=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    shared_every=6,       # one shared attn+MLP block applied every 6 layers
    supports_long=True,   # mamba2 recurrence carries long_500k decode
    notes="mamba2 SSD layers; single shared-weight attention block",
)
