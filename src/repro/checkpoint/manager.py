"""Checkpoint manager: atomic, async, keep-k, resumable.

Layout (one directory per step):
  <root>/step_000123.tmp-<pid>/   — written here first
      arrays.npz                  — flattened pytree (keypath -> array)
      manifest.json               — step, keypaths, shapes, dtypes, meta
  <root>/step_000123/             — atomic rename on completion

Atomic rename means a crashed writer never corrupts the latest checkpoint;
`latest_step()` only considers fully-renamed directories. Writes can run on a
background thread (async) so the train loop overlaps serialization with
compute; `wait()` joins before the next save or at exit (preemption-safe).

On multi-host deployments each host saves only its addressable shards under
`host_<i>/`; this container is single-host, so the host dimension is 1 —
the layout and restore path are host-count agnostic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(template, flat: Dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_p:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # -- writing ------------------------------------------------------------

    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> None:
        self.wait()
        flat = _flatten(jax.tree.map(np.asarray, tree))  # device→host before thread
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, flat, meta or {})

    def _write(self, step: int, flat: Dict[str, np.ndarray], meta: Dict) -> None:
        final = os.path.join(self.root, f"step_{step:09d}")
        tmp = f"{final}.tmp-{os.getpid()}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = dict(
            step=step,
            time=time.time(),
            keys=sorted(flat),
            shapes={k: list(v.shape) for k, v in flat.items()},
            meta=meta,
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"), ignore_errors=True)

    # -- reading ------------------------------------------------------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and ".tmp" not in name:
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None) -> tuple[Any, Dict]:
        """Restore into the structure/dtypes of `template` (shapes checked)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoint under {self.root}"
        d = os.path.join(self.root, f"step_{step:09d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        return _unflatten(template, flat), manifest
