"""Checkpointing: sharded, async, atomic, keep-k, bit-exact resume."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
