"""Shared dataclasses for partitioner configuration and results.

This module also owns the two *strategy-agnostic* state types of the
streaming-scan layer (`repro.core.driver`):

* :class:`WarmState` — the cross-pass warm-start bundle every step-core can
  resume from (replica table, degree table, partition loads, optional prior
  placements). Re-streaming, 2PS(-L) phase handoff, and spotlight × restream
  all speak WarmState; strategy-specific cores translate it into their own
  carry in ``warm_carry``.
* the **carry contract** (documented here, enforced by the driver): a
  step-core's carry is any pytree of arrays whose leaves all gain a leading
  ``(z,)`` instance axis under the driver, and which exposes two int32
  scalar leaves by attribute name —

    ``carry.cursor``    next stream row this instance will read (the ring
                        refill bound: the driver uploads rows ahead of it),
    ``carry.assigned``  edges placed so far (the driver's termination and
                        drain conditions).

  Everything else in the carry is the strategy's own business (vertex
  caches, window buffers, λ, counter-based tie seeds, ...).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, NamedTuple, Optional

import numpy as np

__all__ = ["AdwiseConfig", "PartitionResult", "WarmState"]


class WarmState(NamedTuple):
    """State carried between passes / phases of any step-core strategy.

    ``replicas``/``deg``/``sizes`` warm-start the vertex cache of the next
    pass; ``prev_assign`` (when given) enables buffered-re-streaming
    revocation: an edge's previous assignment is subtracted from the
    partition sizes at the moment the edge re-enters the window, so the
    balance terms always see the *net* partition loads while the pass
    re-places the stream. 2PS(-L) reuse ``replicas`` as the cluster→partition
    table: phase 1 leaves each clustered vertex with exactly one virtual
    replica on its cluster's partition.
    """

    replicas: np.ndarray  # (V, K) bool
    deg: np.ndarray  # (V,) int — full (or partial) streamed degrees
    sizes: np.ndarray  # (K,) int — partition loads at warm-start time
    prev_assign: Optional[np.ndarray] = None  # (m,) int32, -1 = none


@dataclasses.dataclass(frozen=True)
class AdwiseConfig:
    """Configuration of the ADWISE partitioner (paper §III defaults).

    Attributes:
      k: number of partitions.
      window_max: W_max — static capacity of the window buffer. The logical
        window size ``w`` adapts within [1, window_max].
      window_init: initial logical window size (paper: 1).
      latency_budget: latency preference L in seconds. None = no budget (the
        window grows while C1 holds).
      lam_init: initial adaptive balance weight λ (paper keeps λ ∈ [0.4, 5];
        the initial value is unspecified — we use 1.0).
      lam_lo / lam_hi: clip interval for λ (paper: [0.4, 5]).
      eps: ε used in B(p) denominator and the candidate threshold Θ = g_avg+ε.
      use_clustering: enable the clustering score CS (paper switches it off
        for low-clustering graphs such as Orkut).
      lazy: enable lazy window traversal (candidate/secondary sets).
      lazy_budget: max number of window slots rescored per step under lazy
        traversal (None = window_max // 8). Bounded staleness beyond the
        paper's candidate mechanism — see DESIGN.md §3.
      cap_slack: hard balance cap — partitions with more than
        cap_slack * m / k edges are masked out of the argmax. Guarantees the
        Eq. 2 constraint; set to None to rely purely on λ·B(p).
      assign_batch: number of vertex-disjoint assignments per scoring round.
        1 == paper-faithful sequential Algorithm 1. >1 is the beyond-paper
        SIMD batching documented in DESIGN.md.
      adapt: enable the adaptive window controller (C1/C2). When False the
        window stays at window_init.
      seed: tie-break seed.
    """

    k: int
    window_max: int = 256
    window_init: int = 1
    latency_budget: Optional[float] = None
    lam_init: float = 1.0
    lam_lo: float = 0.4
    lam_hi: float = 5.0
    eps: float = 0.01
    use_clustering: bool = True
    lazy: bool = True
    lazy_budget: Optional[int] = None
    cap_slack: Optional[float] = 1.15
    assign_batch: int = 1
    adapt: bool = True
    seed: int = 0

    def __post_init__(self) -> None:
        assert self.k >= 1
        assert 1 <= self.window_init <= self.window_max
        assert self.assign_batch >= 1

    # -- derived quantities (single source of truth for every scan caller;
    #    the streaming-scan driver in `repro.core.driver` resolves through
    #    these instead of re-deriving per entry point) -----------------------

    def resolve_r_sel(self) -> int:
        """Lazy-traversal rescore budget R_sel: how many stale window slots
        are rescored per step (§III-B). Non-lazy mode rescores the whole
        window."""
        if not self.lazy:
            return self.window_max
        return min(
            self.window_max,
            max(
                self.assign_batch,
                self.lazy_budget or max(8, self.window_max // 8),
            ),
        )

    def cap_value(self, m: int, n_allowed: int) -> int:
        """Hard per-partition capacity (Eq. 2 guarantee) for an instance
        streaming ``m`` edges into ``n_allowed`` partitions; BIG when the
        cap is disabled."""
        if self.cap_slack is None:
            return int(np.iinfo(np.int32).max)
        return int(math.ceil(self.cap_slack * m / max(n_allowed, 1))) + 1


@dataclasses.dataclass
class PartitionResult:
    """Outcome of a partitioning run.

    Attributes:
      assign: int32[m] — partition id per edge, in the original stream order.
      stats: counters — score computations, window-size trace, λ trace,
        wall-clock partitioning latency, etc. Device-offloaded runs add the
        transfer/pipeline counters from ``repro.core.driver``:
        ``h2d_rows``/``h2d_bytes`` (stream traffic actually shipped),
        ``h2d_wait_s`` (wall the driver spent blocked in non-speculative
        ring refills — the *measured* transfer stall),
        ``prefetch_depth`` (read-ahead depth; 0 = synchronous refills,
        resolved from the explicit argument, else ``$ADWISE_PREFETCH``,
        else 2), and ``refill_spans`` = ``spans_prestaged`` +
        ``spans_missed`` (whether each contiguous refill span was already
        staged by the read-ahead worker when the driver asked for it).
        ``repro.engine.latency_model.partition_latency`` prefers the
        measured stall over the modeled ``h2d_bytes`` bill when refills ran.
        ``prestage_wall_s`` is the read-ahead worker's staging wall (disk
        read + host stage, measured on the worker thread) — comparing it
        against ``h2d_wait_s`` gives the measured overlap efficiency: the
        fraction of staging wall hidden from the driver's critical path.
        Runs invoked with a live ``repro.obs.Tracer`` (``trace=``) also
        carry ``trace_summary``: the tracer's
        :meth:`~repro.obs.TraceSummary.as_dict` snapshot
        (``events``/``wall_s``/``categories``/``tracks``).
    """

    assign: np.ndarray
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def k(self) -> int:
        return int(self.stats.get("k", self.assign.max() + 1))
