"""ADWISE as a vectorized JAX streaming computation.

The paper's Algorithm 1 is a sequential loop: refill window → argmax over
(window × partitions) → assign → adapt. On accelerator hardware we express
one loop iteration as a fixed-shape masked update (see DESIGN.md §3) and run
the whole stream through `jax.lax.scan`:

  carry: vertex cache (replica table + versions), degree table, partition
         sizes, the window buffer (W_max slots + validity), lazy-traversal
         caches, λ, and the adaptive-window controller state.
  step : refill invalid slots from the stream, recompute the stale subset of
         window scores (lazy traversal budget R_sel), take the masked argmax
         over (W_max × k), emit the assignment, update the vertex cache and
         the controller.

This module owns the *per-step math* (the Carry / step function) and the
thin public entry points. The chunked stepping loop around the scan — carry
initialization, warm-state resume, r_sel/cap resolution, budget wiring and
recalibration, resident vs ring-buffer chunk sources — lives once in
:mod:`repro.core.driver`; `partition_stream`, `partition_stream_batched`,
the out-of-core path (`repro.core.oocore`) and every re-streaming pass are
all callers of the same :class:`~repro.core.driver.ScanDriver`.

Stream addressing: the step reads refill rows at ``src % m_pad``. For a
resident source ``m_pad`` is the (per-instance) stream length, so the mod is
the identity on every live index; for the out-of-core ring buffer it IS the
ring invariant (logical row ``s`` lives in slot ``s % B``). Padding reads
beyond the live range are masked by the ``fill`` mask, so both modes run the
very same trace with bit-identical outputs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.core.types import AdwiseConfig, PartitionResult, WarmState

__all__ = ["partition_stream", "partition_stream_batched", "WarmState"]

NEG_INF = scoring.NEG_INF
_BIG_I32 = np.int32(2**31 - 1)


class Carry(NamedTuple):
    # Vertex cache.
    replicas: jax.Array  # (V+1, K) bool — row V is a scatter dump.
    rep_version: jax.Array  # (V+1,) int32
    deg: jax.Array  # (V+1,) int32
    max_deg: jax.Array  # () int32
    # Partition state.
    sizes: jax.Array  # (K,) int32
    lam: jax.Array  # () f32
    # Window.
    w_cap: jax.Array  # () int32 — logical window size w
    cursor: jax.Array  # () int32 — next stream index
    n_valid: jax.Array  # () int32
    win_uv: jax.Array  # (W, 2) int32
    win_sidx: jax.Array  # (W,) int32 — stream index per slot
    win_valid: jax.Array  # (W,) bool
    # Lazy traversal caches.
    cached_rcs: jax.Array  # (W, K) f32 — cached R + CS per slot
    cached_ver_u: jax.Array  # (W,) int32
    cached_ver_v: jax.Array  # (W,) int32
    theta: jax.Array  # () f32 — candidate threshold Θ from previous step
    # Counters / controller.
    assigned: jax.Array  # () int32
    score_rows: jax.Array  # () int32 — number of (edge × all-partitions) evals
    c: jax.Array  # () int32 — assignments since last window adaptation
    sum_g: jax.Array  # () f32
    avg_g_prev: jax.Array  # () f32
    last_grew: jax.Array  # () bool
    budget_left: jax.Array  # () f32 seconds
    lat_ema: jax.Array  # () f32 — per-edge modeled latency EMA
    # Calibrated latency model (dynamic so recalibration does not recompile).
    cost_per_score: jax.Array  # () f32
    base_cost: jax.Array  # () f32

    @classmethod
    def warm_start(
        cls,
        cfg: "AdwiseConfig",
        num_vertices: int,
        budget: float,
        *,
        replicas: np.ndarray,  # (V, K) bool — replica table of the prior pass
        deg: np.ndarray,  # (V,) int — streamed degrees of the prior pass
        sizes: np.ndarray,  # (K,) int — partition loads of the prior pass
    ) -> "Carry":
        """Carry warm-started from a previous pass's tables (re-streaming).

        λ restarts at ``cfg.lam_init`` and re-anneals over the new pass
        (``assigned`` resets, so the Eq. 4 tolerance schedule replays); the
        window controller likewise starts fresh. Only the *graph knowledge*
        — replica table, degree table, partition loads — carries over.
        """
        base = _init_carry(cfg, num_vertices, budget)
        v1 = num_vertices + 1
        rep = jnp.zeros((v1, cfg.k), bool).at[:num_vertices].set(
            jnp.asarray(replicas, bool)
        )
        deg_j = jnp.zeros((v1,), jnp.int32).at[:num_vertices].set(
            jnp.asarray(deg, jnp.int32)
        )
        return base._replace(
            replicas=rep,
            deg=deg_j,
            max_deg=jnp.maximum(jnp.max(deg_j), 1),
            sizes=jnp.asarray(sizes, jnp.int32),
        )


class StepOut(NamedTuple):
    sidx: jax.Array  # (b,) int32 — stream index assigned this step (-1 = none)
    p: jax.Array  # (b,) int32
    w_cap: jax.Array  # () int32
    g_chosen: jax.Array  # () f32 — best score this step (diagnostics)


def _init_carry(cfg: AdwiseConfig, num_vertices: int, budget: float) -> Carry:
    v1 = num_vertices + 1
    w, k = cfg.window_max, cfg.k
    zi = jnp.zeros((), jnp.int32)
    zf = jnp.zeros((), jnp.float32)
    return Carry(
        replicas=jnp.zeros((v1, k), bool),
        rep_version=jnp.zeros((v1,), jnp.int32),
        deg=jnp.zeros((v1,), jnp.int32),
        max_deg=jnp.ones((), jnp.int32),
        sizes=jnp.zeros((k,), jnp.int32),
        lam=jnp.float32(cfg.lam_init),
        w_cap=jnp.int32(max(cfg.window_init, cfg.assign_batch)),
        cursor=zi,
        n_valid=zi,
        win_uv=jnp.zeros((w, 2), jnp.int32),
        win_sidx=jnp.full((w,), -1, jnp.int32),
        win_valid=jnp.zeros((w,), bool),
        cached_rcs=jnp.zeros((w, k), jnp.float32),
        cached_ver_u=jnp.full((w,), -1, jnp.int32),
        cached_ver_v=jnp.full((w,), -1, jnp.int32),
        theta=zf,
        assigned=zi,
        score_rows=zi,
        c=zi,
        sum_g=zf,
        avg_g_prev=jnp.float32(-jnp.inf),
        last_grew=jnp.asarray(True),
        budget_left=jnp.float32(budget),
        lat_ema=zf,
        cost_per_score=jnp.float32(1e-8),
        base_cost=jnp.float32(1e-7),
    )


def _make_step(
    cfg: AdwiseConfig,
    num_vertices: int,
    r_sel: int,
    stream: jax.Array,  # (m_pad, 2) int32 — full stream OR the ring buffer
    m_real: jax.Array,  # () int32
    allowed: jax.Array,  # (K,) bool
    cap: jax.Array,  # () int32 (BIG when disabled)
    has_budget: bool,
    prev_assign: jax.Array,  # (m_pad,) int32 — prior-pass partition, -1 = none
    update_deg: bool,  # False on warm-started passes (degrees already final)
):
    w_max, k, b = cfg.window_max, cfg.k, cfg.assign_batch
    v_dummy = num_vertices  # scatter dump row
    m_pad = stream.shape[0]
    slot_ids = jnp.arange(w_max, dtype=jnp.int32)

    def step(carry: Carry, _) -> tuple[Carry, StepOut]:
        # ---- 1) Refill invalid slots up to the logical window size w. ----
        need = jnp.clip(carry.w_cap - carry.n_valid, 0, w_max)
        avail = jnp.maximum(m_real - carry.cursor, 0)
        take = jnp.minimum(need, avail)
        inv = ~carry.win_valid
        rank = jnp.cumsum(inv.astype(jnp.int32)) - 1
        fill = inv & (rank < take)
        src = carry.cursor + rank
        # Ring addressing: logical row s lives at slot s % m_pad. For a
        # resident stream m_pad == m, so this is the identity on every live
        # index; reads past the live range are masked by `fill`.
        src_c = src % m_pad
        fill_uv = stream[src_c]
        win_uv = jnp.where(fill[:, None], fill_uv, carry.win_uv)
        win_sidx = jnp.where(fill, src, carry.win_sidx)
        win_valid = carry.win_valid | fill
        # Streamed degrees update on observation (first pass only — warm
        # passes inherit the final degree table and must not re-count).
        if update_deg:
            u_f = jnp.where(fill, fill_uv[:, 0], v_dummy)
            v_f = jnp.where(fill, fill_uv[:, 1], v_dummy)
            deg = carry.deg.at[u_f].add(1).at[v_f].add(1)
            seen = jnp.where(fill, jnp.maximum(deg[u_f], deg[v_f]), 0)
            max_deg = jnp.maximum(carry.max_deg, jnp.max(seen))
        else:
            deg = carry.deg
            max_deg = carry.max_deg
        # Buffered re-streaming revocation: the prior pass's assignment of an
        # edge is released when the edge enters the window, so balance/capacity
        # terms score against net loads while the pass re-places the stream.
        pa = prev_assign[src_c]
        dec = fill & (pa >= 0)
        sizes_net = carry.sizes.at[jnp.where(dec, pa, 0)].add(
            -dec.astype(jnp.int32)
        )
        cursor = carry.cursor + take
        n_valid = carry.n_valid + take

        u = win_uv[:, 0]
        v = win_uv[:, 1]

        # ---- 2) Lazy traversal: pick ≤ r_sel stale slots to rescore. ----
        ver_u = carry.rep_version[u]
        ver_v = carry.rep_version[v]
        if cfg.lazy:
            # A refilled slot's cache belongs to the previous occupant — always stale.
            stale = win_valid & (
                (ver_u != carry.cached_ver_u) | (ver_v != carry.cached_ver_v) | fill
            )
        else:
            # Faithful mode: every valid window edge is rescored every step
            # (CS depends on *other* window edges, which version stamps on the
            # own endpoints cannot see).
            stale = win_valid
        # Priority classes: fresh window entries first, then stale candidates
        # (cached score above Θ), then stale secondary edges (§III-B).
        cand = carry.cached_rcs.max(axis=1) >= carry.theta
        cls = jnp.where(fill, 0, jnp.where(cand, 1, 2)).astype(jnp.int32)
        key = jnp.where(stale, cls * w_max + slot_ids, _BIG_I32)
        order = jnp.argsort(key)[:r_sel]
        sel_live = jnp.sort(key)[:r_sel] < _BIG_I32
        sel_idx = jnp.where(sel_live, order, w_max)  # dummy slot w_max
        sel_c = jnp.clip(sel_idx, 0, w_max - 1)

        # ---- 3) Fresh R (+ CS) for the selected rows. ----
        rep_u = carry.replicas[u]  # (W, K)
        rep_v = carry.replicas[v]
        r_all = scoring.replication_score(rep_u, rep_v, deg[u], deg[v], max_deg)
        rcs_rows = r_all[sel_c]
        if cfg.use_clustering:
            u_s, v_s = u[sel_c], v[sel_c]
            keep = win_valid[None, :] & (sel_c[:, None] != slot_ids[None, :])
            a = ((u[None, :] == u_s[:, None]) | (u[None, :] == v_s[:, None])) & keep
            bm = ((v[None, :] == u_s[:, None]) | (v[None, :] == v_s[:, None])) & keep
            af = a.astype(jnp.float32)
            bf = bm.astype(jnp.float32)
            num = af @ rep_v.astype(jnp.float32) + bf @ rep_u.astype(jnp.float32)
            den = af.sum(axis=1) + bf.sum(axis=1)
            rcs_rows = rcs_rows + num / jnp.maximum(den, 1.0)[:, None]
        cached_rcs = (
            jnp.zeros((w_max + 1, k), jnp.float32)
            .at[:w_max]
            .set(carry.cached_rcs)
            .at[sel_idx]
            .set(rcs_rows)[:w_max]
        )
        pad1 = lambda x, fillv: jnp.concatenate([x, jnp.full((1,), fillv, x.dtype)])
        cached_ver_u = pad1(carry.cached_ver_u, -1).at[sel_idx].set(ver_u[sel_c])[:w_max]
        cached_ver_v = pad1(carry.cached_ver_v, -1).at[sel_idx].set(ver_v[sel_c])[:w_max]
        n_scored = jnp.sum(sel_live.astype(jnp.int32))
        score_rows = carry.score_rows + n_scored

        # ---- 4) Score matrix g = cached RCS + λ·B, masked. ----
        bal = scoring.balance_score(sizes_net, allowed, cfg.eps)
        ok_p = allowed & (sizes_net < cap)
        g = cached_rcs + carry.lam * bal[None, :]
        g = jnp.where(win_valid[:, None] & ok_p[None, :], g, NEG_INF)
        # Candidate threshold Θ = g_avg + ε (§III-B) in RCS units — it gates
        # the cached R+CS values, so exclude the λ·B term common to a column.
        rcs_max = cached_rcs.max(axis=1)
        nv = jnp.maximum(jnp.sum(win_valid.astype(jnp.float32)), 1.0)
        theta = jnp.sum(jnp.where(win_valid, rcs_max, 0.0)) / nv + cfg.eps

        # ---- 5) Assign the top-b vertex-disjoint window edges. ----
        def pick(i, st):
            g_m, ch_mask, ch_p, out_s, out_p, sum_gacc = st
            flat = jnp.argmax(g_m)
            slot = (flat // k).astype(jnp.int32)
            p = (flat % k).astype(jnp.int32)
            ok = g_m[slot, p] > NEG_INF / 2
            out_s = out_s.at[i].set(jnp.where(ok, win_sidx[slot], -1))
            out_p = out_p.at[i].set(jnp.where(ok, p, 0))
            share = (u == u[slot]) | (u == v[slot]) | (v == u[slot]) | (v == v[slot])
            g_m = jnp.where((share & ok)[:, None], NEG_INF, g_m)
            ch_mask = ch_mask.at[slot].max(ok)
            ch_p = ch_p.at[slot].set(jnp.where(ok, p, ch_p[slot]))
            sum_gacc = sum_gacc + jnp.where(ok, g[slot, p], 0.0)
            return (g_m, ch_mask, ch_p, out_s, out_p, sum_gacc)

        st0 = (
            g,
            jnp.zeros((w_max,), bool),
            jnp.zeros((w_max,), jnp.int32),
            jnp.full((b,), -1, jnp.int32),
            jnp.zeros((b,), jnp.int32),
            jnp.zeros((), jnp.float32),
        )
        if b == 1:
            st = pick(0, st0)
        else:
            st = jax.lax.fori_loop(0, b, pick, st0)
        _, ch, ch_p, out_s, out_p, g_sum = st
        n_ch = jnp.sum(ch.astype(jnp.int32))

        # ---- 6) Apply assignments to the vertex cache / partition state. ----
        chi = ch.astype(jnp.int32)
        sizes = sizes_net.at[ch_p].add(chi)  # adds 0 where not chosen
        u_c = jnp.where(ch, u, v_dummy)
        v_c = jnp.where(ch, v, v_dummy)
        old_u = carry.replicas[u_c, ch_p]
        old_v = carry.replicas[v_c, ch_p]
        replicas = carry.replicas.at[u_c, ch_p].max(ch).at[v_c, ch_p].max(ch)
        new_u = (ch & ~old_u).astype(jnp.int32)
        new_v = (ch & ~old_v).astype(jnp.int32)
        rep_version = carry.rep_version.at[u_c].add(new_u).at[v_c].add(new_v)
        win_valid = win_valid & ~ch
        n_valid = n_valid - n_ch
        assigned = carry.assigned + n_ch

        lam = scoring.lambda_update(
            carry.lam, sizes, allowed, assigned, m_real, cfg.lam_lo, cfg.lam_hi
        )

        # ---- 7) Modeled latency + adaptive window controller (§III-A). ----
        step_cost = n_scored.astype(jnp.float32) * jnp.float32(k) * carry.cost_per_score + carry.base_cost
        budget_left = carry.budget_left - step_cost
        lat_edge = step_cost / jnp.maximum(n_ch.astype(jnp.float32), 1.0)
        lat_ema = jnp.where(
            carry.assigned == 0, lat_edge, 0.9 * carry.lat_ema + 0.1 * lat_edge
        )
        c = carry.c + n_ch
        sum_g = carry.sum_g + g_sum
        trigger = jnp.asarray(cfg.adapt) & (c >= carry.w_cap)
        avg_g = sum_g / jnp.maximum(c.astype(jnp.float32), 1.0)
        c1 = (~carry.last_grew) | (avg_g >= carry.avg_g_prev)
        if has_budget:
            edges_left = jnp.maximum(m_real - assigned, 1).astype(jnp.float32)
            c2 = lat_ema < budget_left / edges_left
        else:
            c2 = jnp.asarray(True)
        grow = trigger & c1 & c2 & (carry.w_cap < w_max)
        shrink = trigger & ~c2
        w_lo = jnp.int32(max(1, b))
        w_new = jnp.where(
            grow,
            jnp.minimum(2 * carry.w_cap, w_max),
            jnp.where(shrink, jnp.maximum((carry.w_cap + 1) // 2, w_lo), carry.w_cap),
        )
        out = StepOut(sidx=out_s, p=out_p, w_cap=carry.w_cap, g_chosen=g_sum)
        new_carry = Carry(
            replicas=replicas,
            rep_version=rep_version,
            deg=deg,
            max_deg=max_deg,
            sizes=sizes,
            lam=lam,
            w_cap=w_new,
            cursor=cursor,
            n_valid=n_valid,
            win_uv=win_uv,
            win_sidx=win_sidx,
            win_valid=win_valid,
            cached_rcs=cached_rcs,
            cached_ver_u=cached_ver_u,
            cached_ver_v=cached_ver_v,
            theta=theta,
            assigned=assigned,
            score_rows=score_rows,
            c=jnp.where(trigger, 0, c),
            sum_g=jnp.where(trigger, 0.0, sum_g),
            avg_g_prev=jnp.where(trigger, avg_g, carry.avg_g_prev),
            last_grew=jnp.where(trigger, grow, carry.last_grew),
            budget_left=budget_left,
            lat_ema=lat_ema,
            cost_per_score=carry.cost_per_score,
            base_cost=carry.base_cost,
        )
        return new_carry, out

    return step


def _ceil_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1) — the length-bucket key."""
    return 1 << (max(int(x), 1) - 1).bit_length()


def partition_stream(
    edges: np.ndarray,
    num_vertices: int,
    cfg: AdwiseConfig,
    *,
    allowed: Optional[np.ndarray] = None,
    n_chunks: int = 8,
    cost_per_score: Optional[float] = None,
    warm: Optional[WarmState] = None,
    residency=None,
    trace=None,
) -> PartitionResult:
    """Partition an edge stream with ADWISE (vectorized scan).

    Thin caller of :class:`repro.core.driver.ScanDriver` over a single
    resident instance (z == 1).

    Args:
      edges: (m, 2) int32 edge stream.
      num_vertices: |V|.
      cfg: AdwiseConfig.
      allowed: optional bool (k,) mask of partitions this instance may fill
        (spotlight spread). Default: all partitions.
      n_chunks: stream is processed in this many scan calls; wall-clock
        between chunks recalibrates the (C2) latency model.
      cost_per_score: optional fixed seconds per (edge,partition) score
        evaluation; overrides calibration (deterministic tests).
      warm: optional :class:`WarmState` from a previous pass (re-streaming):
        the replica/degree tables and partition loads carry over, degrees are
        not re-counted, and — when ``warm.prev_assign`` is given — each
        edge's prior placement is revoked as it re-enters the window.
      residency: optional :class:`repro.core.driver.StreamResidency` shared
        across re-streaming passes over the SAME edges — later passes reuse
        the resident device stream array and ship only their prev table.
      trace: optional :class:`repro.obs.Tracer` recording per-scan-call
        spans (host dispatch/wait only); stats gain a ``trace_summary``.

    Returns: PartitionResult with assign (int32[m]) and stats.
    """
    from repro.core.driver import ResidentSource, ScanDriver

    m = int(len(edges))
    k = cfg.k
    if m == 0:
        return PartitionResult(np.zeros((0,), np.int32), dict(k=k, unassigned=0))
    source = ResidentSource(
        np.ascontiguousarray(edges, np.int32).reshape(1, m, 2),
        np.array([m], np.int64),
        residency=residency,
    )
    drv = ScanDriver(
        source, cfg, num_vertices,
        allowed=None if allowed is None else np.asarray(allowed, bool)[None],
        warm=None if warm is None else [warm],
        cost_per_score=cost_per_score,
        backend="vmap",
        trace=trace,
    )
    res = drv.run(n_chunks=n_chunks)
    sidx, pout = res.sidx[0], res.p[0]
    assign = np.full((m,), -1, np.int32)
    live = sidx >= 0
    assign[sidx[live]] = pout[live]
    unassigned = int((assign < 0).sum())
    assert unassigned == 0 and int(res.assigned[0]) == m, (
        f"partition_stream left {unassigned} of {m} edges unassigned "
        f"(scan assigned counter: {int(res.assigned[0])}) — drain loop failed"
    )
    stats = dict(
        drv.stats_base(res, 0),
        w_trace=res.w_trace[0],
        unassigned=unassigned,
    )
    if trace is not None and trace.enabled:
        stats["trace_summary"] = trace.summary().as_dict()
    return PartitionResult(assign, stats)


def partition_stream_batched(
    streams: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    cfg: Optional[AdwiseConfig],
    *,
    core=None,
    allowed: Optional[np.ndarray] = None,
    backend: str = "auto",
    n_chunks: int = 8,
    cost_per_score: Optional[float] = None,
    warm: Optional[Sequence[WarmState]] = None,
    residency=None,
    trace=None,
) -> list[PartitionResult]:
    """Run ``z`` independent instance scans as ONE batched program.

    This is the device-parallel spotlight entry point: the same step
    function `vmap`-ped over a leading instance axis — and, when multiple
    devices are visible, `shard_map`-ped over an ``("instances",)`` mesh
    axis so each device executes its slice of instances in parallel (the
    paper's z-machine parallel-loading model on real hardware). Thin caller
    of :class:`repro.core.driver.ScanDriver` over a z-instance resident
    source.

    Args:
      streams: (z, per, 2) int32 — per-instance padded edge chunks
        (:meth:`repro.graph.stream.EdgeStream.split_padded` layout).
      valid: (z, per) bool — per-row *prefix* mask; row i's real stream is
        ``streams[i, :valid[i].sum()]``.
      num_vertices: |V| (shared; instances keep independent vertex caches).
      cfg: AdwiseConfig (shared by all instances); may be None when ``core``
        is given.
      core: optional :class:`repro.core.driver.StepCore` — ANY step-core
        strategy (HdrfCore, GreedyCore, TpslCore, ...) vmaps over the z
        instance axis through the exact same driver path as ADWISE;
        per-instance state (e.g. HDRF's counter-based tie seeds ``seed+i``)
        comes from the core's ``seed_instances`` hook.
      allowed: optional (z, k) bool — per-instance spotlight spread masks.
        Default: every instance may fill every partition.
      backend: 'vmap' (single device), 'shard_map' (instances sharded over
        devices; z must have a divisor <= device_count > 1, else falls back
        to vmap), or 'auto' (shard_map iff multiple devices are visible).
      n_chunks / cost_per_score: as in :func:`partition_stream`.
      warm: optional length-z sequence of per-instance :class:`WarmState`
        (re-streaming composed with spotlight). All instances must agree on
        whether ``prev_assign`` is provided.
      residency: optional :class:`repro.core.driver.StreamResidency` shared
        across re-streaming passes over the SAME streams — later passes
        reuse the resident device array and ship only their prev table.

    Returns:
      A list of z :class:`PartitionResult`; entry i's ``assign`` covers
      instance i's real (un-padded) stream in local order. With z == 1 and
      identical inputs the assignment is bit-identical to
      :func:`partition_stream` — the batched step function is the same
      trace, vmapped.

    Length bucketing: instances are grouped by ``ceil_pow2(m_i)`` and each
    bucket runs as its own batched scan padded to
    ``min(ceil_pow2(max m_i in bucket), per)`` rows — the same
    bounded-kernel-shape discipline as the ring's pow2 ``Rq`` spans. Skewed
    per-instance lengths therefore compile at most
    ``ceil(log2(max_m / min_m)) + 1`` scan programs instead of padding
    every instance to the global maximum (and idling the short ones through
    the tail). When every instance lands in one bucket whose pow2 bound
    meets or exceeds ``per``, shapes — and thus programs, uploads, and
    assignments — are identical to the unbucketed layout. Results come back
    in the caller's instance order regardless of bucketing, and
    seed-deriving cores receive the *global* instance ids
    (:meth:`StepCore.seed_instances`), so assignments are bit-identical to
    the unbucketed program.
    """
    from repro.core.driver import ResidentSource, ScanDriver

    streams = np.ascontiguousarray(streams, np.int32)
    valid = np.asarray(valid, bool)
    assert streams.ndim == 3 and streams.shape[2] == 2, streams.shape
    z, per, _ = streams.shape
    assert valid.shape == (z, per), (valid.shape, streams.shape)
    # The refill logic consumes each instance stream sequentially from slot 0,
    # so validity must be a prefix per row.
    assert (valid[:, :-1] >= valid[:, 1:]).all() if per > 1 else True, (
        "valid must be a per-row prefix mask (padding only at the tail)"
    )
    assert core is not None or cfg is not None, "need a cfg or a step-core"
    k = core.k if core is not None else cfg.k
    m_per = valid.sum(axis=1).astype(np.int64)  # (z,)
    m_max = int(m_per.max()) if z else 0
    if allowed is not None:
        allowed = np.asarray(allowed, bool)
        assert allowed.shape == (z, k), (allowed.shape, (z, k))
    if warm is not None:
        warm = list(warm)
        assert len(warm) == z, f"need one WarmState per instance, got {len(warm)}"
    if m_max == 0:
        return [
            PartitionResult(np.zeros((0,), np.int32), dict(k=k, unassigned=0))
            for _ in range(z)
        ]

    # ---- pow2 length buckets --------------------------------------------
    # Bucket by the pow2 class of each instance's REAL length; the padded
    # width never exceeds the caller's layout, so a single-bucket batch is
    # shape-identical (same program, same h2d bytes) to the unbucketed one.
    buckets: dict[int, list[int]] = {}
    for i in range(z):
        buckets.setdefault(_ceil_pow2(int(m_per[i])), []).append(i)

    runs = []  # (global idx, driver, result, padded width) per bucket
    total_wall, total_h2d_rows, total_h2d_bytes = 0.0, 0, 0
    for key in sorted(buckets):
        idx = np.asarray(buckets[key], np.int64)
        width = min(key, per)
        drv = ScanDriver(
            ResidentSource(
                np.ascontiguousarray(streams[idx, :width]),
                m_per[idx],
                residency=residency,
            ),
            core if core is not None else cfg,
            num_vertices,
            allowed=None if allowed is None else allowed[idx],
            warm=None if warm is None else [warm[i] for i in idx],
            cost_per_score=cost_per_score,
            backend=backend,
            trace=trace,
            instance_ids=idx,
        )
        res_b = drv.run(n_chunks=n_chunks)
        total_wall += res_b.wall_time_s
        total_h2d_rows += res_b.h2d_rows
        total_h2d_bytes += res_b.h2d_bytes
        runs.append((idx, drv, res_b, width))
    tsum = (
        trace.summary().as_dict()
        if trace is not None and trace.enabled else None
    )
    results: list[Optional[PartitionResult]] = [None] * z
    for idx, drv, res_b, width in runs:
        for j, i in enumerate(int(g) for g in idx):
            m_i = int(m_per[i])
            assign = np.full((m_i,), -1, np.int32)
            live = res_b.sidx[j] >= 0
            assign[res_b.sidx[j][live]] = res_b.p[j][live]
            unassigned = int((assign < 0).sum())
            assert unassigned == 0 and int(res_b.assigned[j]) == m_i, (
                f"batched instance {i} left {unassigned} of {m_i} edges "
                f"unassigned (scan counter: {int(res_b.assigned[j])}) — "
                "drain failed"
            )
            stats = dict(
                drv.stats_base(res_b, j),
                batched=True,
                backend=res_b.backend,
                n_shards=res_b.n_shards,
                z=z,
                instance=i,
                # Buckets run back-to-back, so the batch's parallel-model
                # wall — and its upload bill — is the sum over buckets,
                # shared by every instance (one bucket degenerates to the
                # old single-program accounting).
                wall_time_s=total_wall,
                h2d_rows=total_h2d_rows,
                h2d_bytes=total_h2d_bytes,
                n_buckets=len(runs),
                bucket_rows=width,
                w_trace=res_b.w_trace[j],
                unassigned=unassigned,
            )
            if tsum is not None:
                stats["trace_summary"] = tsum
            results[i] = PartitionResult(assign, stats)
    assert all(r is not None for r in results)
    return results
