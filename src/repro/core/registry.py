"""Partitioner strategy registry.

Every streaming partitioner — ADWISE and the baselines it is compared
against — is registered here under one uniform call signature:

    fn(edges, num_vertices, k, seed=0, **cfg) -> PartitionResult

This is the framing of 2PS (Mayer et al.) and Buffered Streaming Edge
Partitioning (Chhabra et al.): partitioners are interchangeable strategies
behind one interface, so launchers, benchmarks and spotlight parallel
loading resolve strategies by *name* and new partitioners (or re-streaming
variants) land as registry entries, not CLI surgery.

Strategy-specific knobs travel in ``**cfg``; the adwise entry forwards them
into :class:`AdwiseConfig` (``window_max=``, ``latency_budget=``,
``use_clustering=``, ``oracle=True`` for the sequential Algorithm-1
reference, ...), baselines accept their own keyword args (e.g. HDRF's
``lam``). Unknown keys raise ``TypeError`` — a misspelled knob never gets
silently dropped.

Multi-pass strategies (`repro/core/restream.py`):

* ``adwise-restream`` — n-pass restreamed ADWISE. Knobs: every AdwiseConfig
  field, plus ``passes=`` (total passes, default 2), ``base=`` (registry
  strategy for pass 1, default 'adwise'), ``keep_best=`` (return the
  lowest-replication pass, default True — quality monotone in passes) and
  ``eps=`` (early-stop once a pass improves replication degree by < eps;
  default None = always run ``passes``; stats report ``passes_run`` and
  ``stream_reads`` for the latency model's per-read IO billing).
* ``2ps`` — two-phase streaming (phase 1 vertex clustering, phase 2
  cluster-aware scoring). Knobs: AdwiseConfig fields for phase 2
  (``window_max`` defaults to 32 here), plus ``cluster_slack=`` (phase-1
  cluster volume cap as a multiple of 2m/k, default 1.25).
* ``2ps-l`` — 2PS-L, the linear-run-time variant: same phase 1, but phase 2
  scores each edge once against its endpoints' cluster partitions (own
  step-core, no window). Knobs: ``cluster_slack=``, ``lam=``/``eps=``
  (balance weighting), ``cap_slack=`` (hard capacity), ``scan=False`` for
  the numpy parity oracle.

Usage:
    from repro.core.registry import run_partitioner, available_strategies
    res = run_partitioner("adwise", edges, n, k=8, window_max=64)
    res = run_partitioner("adwise-restream", edges, n, k=8, passes=3)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from repro.core import baselines
from repro.core.adwise import partition_stream
from repro.core.reference import ref_adwise_partition
from repro.core.types import AdwiseConfig, PartitionResult

__all__ = [
    "register",
    "get_partitioner",
    "run_partitioner",
    "available_strategies",
    "PartitionerFn",
]

PartitionerFn = Callable[..., PartitionResult]

_REGISTRY: Dict[str, PartitionerFn] = {}


def register(name: str) -> Callable[[PartitionerFn], PartitionerFn]:
    """Decorator: register ``fn`` as strategy ``name``."""

    def deco(fn: PartitionerFn) -> PartitionerFn:
        if name in _REGISTRY:
            raise ValueError(f"strategy {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def available_strategies() -> list[str]:
    return sorted(_REGISTRY)


def get_partitioner(name: str) -> PartitionerFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown partitioner strategy {name!r}; "
            f"available: {', '.join(available_strategies())}"
        ) from None


def run_partitioner(
    name: str,
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    seed: int = 0,
    **cfg,
) -> PartitionResult:
    """Resolve ``name`` and run it under the uniform signature."""
    return get_partitioner(name)(edges, num_vertices, k, seed=seed, **cfg)


# ----------------------------------------------------------------------------
# Built-in strategies
# ----------------------------------------------------------------------------

_ADWISE_FIELDS = {f.name for f in dataclasses.fields(AdwiseConfig)}


@register("adwise")
def _adwise(
    edges, num_vertices, k, seed=0, *, oracle=False, allowed=None, **cfg
) -> PartitionResult:
    """ADWISE (paper §III). cfg keys = AdwiseConfig fields; oracle=True runs
    the sequential Algorithm-1 reference instead of the vectorized scan;
    allowed= restricts scoring to a spotlight partition subset."""
    unknown = set(cfg) - _ADWISE_FIELDS
    if unknown:
        raise TypeError(f"adwise: unknown config keys {sorted(unknown)}")
    acfg = AdwiseConfig(k=k, seed=seed, **cfg)
    if oracle:
        if allowed is not None:
            raise ValueError("adwise oracle does not support allowed= masks")
        return ref_adwise_partition(edges, num_vertices, acfg)
    return partition_stream(edges, num_vertices, acfg, allowed=allowed)


@register("hdrf")
def _hdrf(edges, num_vertices, k, seed=0, *, scan=True, **cfg) -> PartitionResult:
    """HDRF (Petroni et al.). Runs as the :class:`~repro.core.baselines.
    HdrfCore` device-resident `lax.scan` by default; ``scan=False`` runs the
    per-edge numpy oracle (bit-identical — kept as the parity reference)."""
    if scan:
        return baselines.hdrf_partition_scan(
            edges, num_vertices, k, seed=seed, **cfg
        )
    return baselines.hdrf_partition(edges, num_vertices, k, seed=seed, **cfg)


@register("dbh")
def _dbh(edges, num_vertices, k, seed=0, **cfg) -> PartitionResult:
    return baselines.dbh_partition(edges, num_vertices, k, seed=seed, **cfg)


@register("greedy")
def _greedy(edges, num_vertices, k, seed=0, *, scan=True, **cfg) -> PartitionResult:
    """PowerGraph Greedy. Runs as the :class:`~repro.core.baselines.
    GreedyCore` device-resident `lax.scan` by default; ``scan=False`` runs
    the per-edge numpy oracle (bit-identical parity reference)."""
    if scan:
        return baselines.greedy_partition_scan(
            edges, num_vertices, k, seed=seed, **cfg
        )
    return baselines.greedy_partition(edges, num_vertices, k, seed=seed, **cfg)


@register("hash")
def _hash(edges, num_vertices, k, seed=0, **cfg) -> PartitionResult:
    return baselines.hash_partition(edges, num_vertices, k, seed=seed, **cfg)


@register("grid")
def _grid(edges, num_vertices, k, seed=0, **cfg) -> PartitionResult:
    return baselines.grid_partition(edges, num_vertices, k, seed=seed, **cfg)


# Multi-pass strategies register themselves on import (one-file entries).
# Imported last: restream.py itself imports `register` from this module.
from repro.core import restream as _restream  # noqa: E402,F401
