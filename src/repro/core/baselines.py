"""Baseline streaming vertex-cut partitioners the paper compares against.

* HDRF  (Petroni et al., CIKM'15)  — High-Degree Replicated First.
* DBH   (Xie et al., NIPS'14)      — Degree-Based Hashing.
* Greedy (PowerGraph, OSDI'12)     — replica-intersection heuristic.
* Hashing                          — edge hash (PowerGraph/GraphX default).
* Grid   (GraphBuilder)            — 2D grid-constrained hashing.

Every partitioner is factored into a *chunk-resumable core* — a state object
(vertex cache, partition loads) plus an ``assign_chunk`` step — so the
out-of-core driver (`repro.core.oocore.partition_file`) can stream a
file-resident graph through the identical math in bounded-size chunks: the
whole-array entry points below are exactly "init state, one chunk".

HDRF and Greedy additionally exist as **step-cores**
(:class:`HdrfCore` / :class:`GreedyCore`) — device-resident `lax.scan`
programs that plug into :class:`repro.core.driver.ScanDriver` and ride the
same resident / ring-buffer sources as ADWISE. To make the scan **bit-
identical** to the numpy loops (the parity oracle), the scoring is fully
integer-quantized:

* θ and the balance fraction are quantized to 1/64 steps
  (``tq = ((2A − d)·64) // A`` ∈ [64, 128] encodes ``(2 − θ)·64``;
  ``bal_q = (gap·64) // (eps_q + spread)`` ∈ [0, 64]); λ is quantized to
  ``round(λ·64)``. The combined score ``64·C_rep_q + λ_q·bal_q`` stays well
  inside int32 with degrees clamped at 2²².
* HDRF's tie-break noise is **counter-based**: a stateless uint32 hash of
  (stream row id, partition, seed) packed into the low
  :data:`TIE_BITS` bits of the argmax key — so any chunk geometry, the
  batched scan, and the numpy oracle all draw the very same noise.

Masked (spotlight) semantics: HDRF/Greedy accept an ``allowed`` partition
mask and score at *global* k with disallowed columns masked out (balance
over allowed loads only). Hash/DBH are stateless hashes; their masked form
hashes into the allowed set by rank (identical to running at local
k' = |allowed| and remapping).
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.driver import StepCore
from repro.core.types import PartitionResult, WarmState

__all__ = [
    "hdrf_partition",
    "dbh_partition",
    "greedy_partition",
    "hash_partition",
    "grid_partition",
    "HdrfState",
    "GreedyState",
    "HdrfCore",
    "GreedyCore",
    "hdrf_partition_scan",
    "greedy_partition_scan",
    "hash_assign",
    "grid_assign",
    "dbh_assign",
    "tie_break_hash",
]

# Quantization of the HDRF scoring (shared by the numpy oracle and the scan
# step-core; see module docstring).
QB = 64  # 1/64 resolution for θ / balance fractions
TIE_BITS = 10  # tie-noise bits packed under the quantized score
_TIE_MASK = (1 << TIE_BITS) - 1
_DEG_CLAMP = 1 << 22  # keeps 64·C_rep_q·2^TIE_BITS + λ_q·bal_q·2^TIE_BITS < 2^31
_LAM_Q_MAX = 4096  # λ ≤ 64 — far above the useful HDRF range
_U32 = np.uint64(0xFFFFFFFF)


def _hash_vec(x: np.ndarray, k: int, salt: int = 0x9E3779B9) -> np.ndarray:
    """Deterministic integer hash -> [0, k)."""
    h = (x.astype(np.uint64) + np.uint64(salt)) * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(33)
    h *= np.uint64(0xC2B2AE3D27D4EB4F)
    h ^= h >> np.uint64(29)
    return (h % np.uint64(k)).astype(np.int32)


def _lam_q(lam: float) -> int:
    return int(np.clip(round(float(lam) * QB), 0, _LAM_Q_MAX))


def _eps_q(eps: float) -> int:
    return max(int(round(float(eps))), 1)


def tie_break_hash(rows: np.ndarray, k: int, seed: int) -> np.ndarray:
    """Counter-based HDRF tie noise: uint32 hash of (row, partition, seed).

    Stateless in the stream position, so every chunk geometry — and the
    batched scan, which evaluates the same uint32 arithmetic on device —
    draws identical noise. Returns int64 (len(rows), k) in [0, 2^TIE_BITS).
    """
    r = (np.asarray(rows, np.uint64) & _U32)[:, None]
    p = np.arange(k, dtype=np.uint64)[None, :]
    s = np.uint64(int(seed) & 0xFFFFFFFF)
    h = (r * np.uint64(0x9E3779B9)) & _U32
    h = h ^ ((p * np.uint64(0x85EBCA6B)) & _U32)
    h = h ^ ((s * np.uint64(0xC2B2AE35)) & _U32)
    h ^= h >> np.uint64(16)
    h = (h * np.uint64(0x7FEB352D)) & _U32
    h ^= h >> np.uint64(15)
    h = (h * np.uint64(0x846CA68B)) & _U32
    h ^= h >> np.uint64(16)
    return (h & np.uint64(_TIE_MASK)).astype(np.int64)


def _tie_hash_j(row: jax.Array, k: int, seed: jax.Array) -> jax.Array:
    """Device twin of :func:`tie_break_hash` for one row: (k,) int32."""
    p = jnp.arange(k, dtype=jnp.uint32)
    h = row.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
    h = h ^ (p * jnp.uint32(0x85EBCA6B)) ^ (seed * jnp.uint32(0xC2B2AE35))
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    return (h & jnp.uint32(_TIE_MASK)).astype(jnp.int32)


def _local_to_global(allowed: np.ndarray) -> np.ndarray:
    l2g = np.flatnonzero(np.asarray(allowed, bool)).astype(np.int32)
    assert len(l2g) > 0, "allowed mask selects no partition"
    return l2g


# ----------------------------------------------------------------------------
# Stateless cores (vectorized; chunking is trivially exact)
# ----------------------------------------------------------------------------


def hash_assign(edges: np.ndarray, num_vertices: int, k: int, seed: int = 0) -> np.ndarray:
    key = edges[:, 0].astype(np.uint64) * np.uint64(num_vertices) + edges[:, 1].astype(np.uint64)
    return _hash_vec(key, k, salt=seed + 1)


def grid_assign(edges: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    g = max(int(np.floor(np.sqrt(k))), 1)
    ru = _hash_vec(edges[:, 0].astype(np.uint64), g, salt=seed + 11)
    cv = _hash_vec(edges[:, 1].astype(np.uint64), g, salt=seed + 13)
    return (ru * g + cv).astype(np.int32) % k


def dbh_assign(edges: np.ndarray, degrees: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """DBH placement given the *full-stream* degree table."""
    u, v = edges[:, 0], edges[:, 1]
    pick_u = degrees[u] < degrees[v]
    # Tie: lower id (deterministic).
    tie = degrees[u] == degrees[v]
    pick_u = np.where(tie, u < v, pick_u)
    key = np.where(pick_u, u, v).astype(np.uint64)
    return _hash_vec(key, k, salt=seed + 29)


def hash_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    seed: int = 0,
    allowed: Optional[np.ndarray] = None,
) -> PartitionResult:
    """Random edge hashing (the PowerGraph default loader).

    ``allowed`` restricts placements to a partition subset by hashing into
    it by rank (spotlight masked form).
    """
    t0 = time.perf_counter()
    if allowed is None:
        assign = hash_assign(edges, num_vertices, k, seed=seed)
    else:
        l2g = _local_to_global(allowed)
        assign = l2g[hash_assign(edges, num_vertices, len(l2g), seed=seed)]
    return PartitionResult(assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="hash"))


def grid_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    seed: int = 0,
    allowed: Optional[np.ndarray] = None,
) -> PartitionResult:
    """GraphBuilder grid hashing: p drawn from intersection of row(u) and col(v).

    Constrains each vertex's replicas to a sqrt(k)-sized subset.
    """
    if allowed is not None:
        raise ValueError(
            "grid imposes its own replica constraint and cannot honour a "
            "spotlight spread mask"
        )
    t0 = time.perf_counter()
    assign = grid_assign(edges, k, seed=seed)
    return PartitionResult(assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="grid"))


def dbh_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    seed: int = 0,
    degrees: Optional[np.ndarray] = None,
    allowed: Optional[np.ndarray] = None,
) -> PartitionResult:
    """Degree-Based Hashing: hash the lower-degree endpoint of each edge."""
    t0 = time.perf_counter()
    if degrees is None:
        degrees = np.zeros(num_vertices, dtype=np.int64)
        np.add.at(degrees, edges[:, 0], 1)
        np.add.at(degrees, edges[:, 1], 1)
    if allowed is None:
        assign = dbh_assign(edges, degrees, k, seed=seed)
    else:
        l2g = _local_to_global(allowed)
        assign = l2g[dbh_assign(edges, degrees, len(l2g), seed=seed)]
    return PartitionResult(assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="dbh"))


# ----------------------------------------------------------------------------
# Sequential cores: numpy oracles (stateful; chunk-resumable)
# ----------------------------------------------------------------------------


class HdrfState:
    """HDRF vertex cache + loads, resumable across chunks (parity oracle).

    Integer-quantized scoring with counter-based tie noise keyed on the
    running ``edges_seen`` row id — the assignment stream is invariant to
    chunk geometry and bit-identical to the :class:`HdrfCore` scan.
    """

    def __init__(self, num_vertices: int, k: int, lam: float = 1.1,
                 eps: float = 1.0, seed: int = 0,
                 allowed: Optional[np.ndarray] = None):
        self.k = k
        self.lam_q = _lam_q(lam)
        self.eps_q = _eps_q(eps)
        self.seed = int(seed)
        self.deg = np.zeros(num_vertices, dtype=np.int64)
        self.replicas = np.zeros((num_vertices, k), dtype=bool)
        self.sizes = np.zeros(k, dtype=np.int64)
        self.allowed = (
            np.ones(k, bool) if allowed is None else np.asarray(allowed, bool)
        )
        assert self.allowed.shape == (k,) and self.allowed.any()
        self.edges_seen = 0

    def assign_chunk(self, edges: np.ndarray) -> np.ndarray:
        """Place a chunk of the stream; state advances in stream order."""
        k, lam_q, eps_q = self.k, self.lam_q, self.eps_q
        deg, replicas, sizes = self.deg, self.replicas, self.sizes
        allowed = self.allowed
        aidx = np.flatnonzero(allowed)
        c = len(edges)
        assign = np.empty(c, dtype=np.int32)
        ties = tie_break_hash(
            np.arange(self.edges_seen, self.edges_seen + c), k, self.seed
        )
        for i in range(c):
            u, v = int(edges[i, 0]), int(edges[i, 1])
            deg[u] += 1
            deg[v] += 1
            du = min(int(deg[u]), _DEG_CLAMP)
            dv = min(int(deg[v]), _DEG_CLAMP)
            a = du + dv
            tq_u = ((2 * a - du) * QB) // a
            tq_v = ((2 * a - dv) * QB) // a
            sal = sizes[aidx]
            mx, mn = int(sal.max()), int(sal.min())
            gap = np.clip(mx - sizes, 0, _DEG_CLAMP)
            bal_q = (gap * QB) // (eps_q + min(mx - mn, _DEG_CLAMP))
            rep_q = replicas[u] * tq_u + replicas[v] * tq_v
            score_q = QB * rep_q.astype(np.int64) + lam_q * bal_q
            combined = np.where(allowed, (score_q << TIE_BITS) + ties[i], -1)
            p = int(np.argmax(combined))
            assign[i] = p
            sizes[p] += 1
            replicas[u, p] = True
            replicas[v, p] = True
        self.edges_seen += c
        return assign


class GreedyState:
    """PowerGraph Greedy vertex cache + loads, resumable across chunks."""

    def __init__(self, num_vertices: int, k: int,
                 allowed: Optional[np.ndarray] = None):
        self.k = k
        self.replicas = np.zeros((num_vertices, k), dtype=bool)
        self.sizes = np.zeros(k, dtype=np.int64)
        self.allowed = (
            np.ones(k, bool) if allowed is None else np.asarray(allowed, bool)
        )
        assert self.allowed.shape == (k,) and self.allowed.any()
        self.edges_seen = 0

    def assign_chunk(self, edges: np.ndarray) -> np.ndarray:
        replicas, sizes = self.replicas, self.sizes
        allowed = self.allowed
        c = len(edges)
        assign = np.empty(c, dtype=np.int32)
        for i in range(c):
            u, v = int(edges[i, 0]), int(edges[i, 1])
            ru, rv = replicas[u], replicas[v]
            inter = ru & rv
            # Replicas only ever grow inside `allowed`, so every candidate
            # set below is already a subset of the mask.
            if inter.any():
                cand = inter
            elif ru.any() and rv.any():
                cand = ru | rv
            elif ru.any():
                cand = ru
            elif rv.any():
                cand = rv
            else:
                cand = allowed
            masked = np.where(cand, sizes, np.iinfo(np.int64).max)
            p = int(np.argmin(masked))
            assign[i] = p
            sizes[p] += 1
            replicas[u, p] = True
            replicas[v, p] = True
        self.edges_seen += c
        return assign


def hdrf_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    lam: float = 1.1,
    eps: float = 1.0,
    seed: int = 0,
    allowed: Optional[np.ndarray] = None,
) -> PartitionResult:
    """HDRF single-edge streaming (Petroni et al.) — numpy oracle.

    score(e=(u,v), p) = C_rep + lam * C_bal with
      C_rep = g(u,p) + g(v,p),   g(x,p) = 1{p in R_x} * (1 + (1 - theta_x))
      theta_u = deg(u) / (deg(u) + deg(v))
      C_bal = (maxsize - size_p) / (eps + maxsize - minsize)
    quantized to 1/64 steps (see module docstring). Partial degrees are
    updated as the stream is consumed. lam=1.1 is the authors' recommended
    default (used in the paper's evaluation).
    """
    t0 = time.perf_counter()
    state = HdrfState(num_vertices, k, lam=lam, eps=eps, seed=seed,
                      allowed=allowed)
    assign = state.assign_chunk(edges)
    return PartitionResult(
        assign,
        dict(k=k, wall_time_s=time.perf_counter() - t0, name="hdrf",
             score_count=len(edges) * k),
    )


def greedy_partition(
    edges: np.ndarray, num_vertices: int, k: int, seed: int = 0,
    allowed: Optional[np.ndarray] = None,
) -> PartitionResult:
    """PowerGraph Greedy (Gonzalez et al., OSDI'12) placement rules.

    1. If R_u and R_v intersect: least-loaded partition in the intersection.
    2. Else if both non-empty: least-loaded partition in R_u | R_v.
    3. Else if one non-empty: least-loaded partition in it.
    4. Else: least-loaded allowed partition overall.
    """
    t0 = time.perf_counter()
    state = GreedyState(num_vertices, k, allowed=allowed)
    assign = state.assign_chunk(edges)
    return PartitionResult(
        assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="greedy")
    )


# ----------------------------------------------------------------------------
# Step-cores: the same math as a device-resident lax.scan
# ----------------------------------------------------------------------------


class HdrfCarry(NamedTuple):
    deg: jax.Array  # (V+1,) int32 — row V is a scatter dump
    replicas: jax.Array  # (V+1, K) bool
    sizes: jax.Array  # (K,) int32
    seed: jax.Array  # () uint32 — per-instance tie-hash seed
    cursor: jax.Array  # () int32
    assigned: jax.Array  # () int32


class GreedyCarry(NamedTuple):
    replicas: jax.Array  # (V+1, K) bool
    sizes: jax.Array  # (K,) int32
    cursor: jax.Array  # () int32
    assigned: jax.Array  # () int32


def _single_edge_out(live, cursor, p):
    from repro.core.adwise import StepOut

    return StepOut(
        sidx=jnp.where(live, cursor, -1)[None].astype(jnp.int32),
        p=jnp.where(live, p, 0)[None].astype(jnp.int32),
        w_cap=jnp.ones((), jnp.int32),
        g_chosen=jnp.zeros((), jnp.float32),
    )


@dataclasses.dataclass(frozen=True)
class HdrfCore(StepCore):
    """HDRF as a chunk-resumable step-core: one edge per scan step.

    Bit-identical to :class:`HdrfState` — integer-quantized scoring, tie
    noise from the counter-based hash of (cursor, partition, seed). The
    base ``seed`` is excluded from the jit cache key (it only enters the
    carry), so spotlight's per-instance ``seed + i`` shares one trace.
    """

    num_vertices: int
    k: int
    lam: float = 1.1
    eps: float = 1.0
    seed: int = dataclasses.field(default=0, compare=False)

    name = "hdrf"
    window_rows = 0
    rows_per_step = 1
    r_sel = 0
    has_budget = False

    def init_carry(self, budget: float) -> HdrfCarry:
        v1 = self.num_vertices + 1
        return HdrfCarry(
            deg=jnp.zeros((v1,), jnp.int32),
            replicas=jnp.zeros((v1, self.k), bool),
            sizes=jnp.zeros((self.k,), jnp.int32),
            seed=jnp.uint32(self.seed & 0xFFFFFFFF),
            cursor=jnp.zeros((), jnp.int32),
            assigned=jnp.zeros((), jnp.int32),
        )

    def warm_carry(self, budget: float, warm: WarmState) -> HdrfCarry:
        base = self.init_carry(budget)
        v = self.num_vertices
        return base._replace(
            deg=base.deg.at[:v].set(jnp.asarray(warm.deg, jnp.int32)),
            replicas=base.replicas.at[:v].set(jnp.asarray(warm.replicas, bool)),
            sizes=jnp.asarray(warm.sizes, jnp.int32),
        )

    def seed_instances(self, carry, z: int, ids=None):
        # Seeds key on the caller's *global* instance ids, not the batch
        # position, so pow2 length-bucketing (which permutes instances into
        # sub-batches) reproduces the unbucketed tie-break stream exactly.
        ids = np.arange(z) if ids is None else np.asarray(ids)
        seeds = jnp.asarray(
            (int(self.seed) + ids) & 0xFFFFFFFF, jnp.uint32
        )
        return carry._replace(seed=seeds)

    def counters(self, carry) -> dict:
        assigned = np.asarray(carry.assigned)
        z = assigned.shape[0]
        return dict(
            score_rows=assigned.astype(np.int64),
            final_w=np.ones((z,), np.int64),
            lam=np.full((z,), self.lam, np.float32),
            cost_per_score=np.zeros((z,), np.float32),
        )

    def make_step(self, stream, m_real, allowed, cap, prev_assign):
        k = self.k
        v_dummy = self.num_vertices
        m_pad = stream.shape[0]
        lam_q = jnp.int32(_lam_q(self.lam))
        eps_q = jnp.int32(_eps_q(self.eps))

        def step(carry: HdrfCarry, _):
            live = carry.cursor < m_real
            live_i = live.astype(jnp.int32)
            row = stream[carry.cursor % m_pad]
            u = jnp.where(live, row[0], v_dummy)
            v = jnp.where(live, row[1], v_dummy)
            deg = carry.deg.at[u].add(live_i).at[v].add(live_i)
            du = jnp.minimum(deg[u], _DEG_CLAMP)
            dv = jnp.minimum(deg[v], _DEG_CLAMP)
            a = jnp.maximum(du + dv, 1)
            tq_u = ((2 * a - du) * QB) // a
            tq_v = ((2 * a - dv) * QB) // a
            sizes = carry.sizes
            sal = jnp.where(allowed, sizes, jnp.int32(np.iinfo(np.int32).max))
            mx = jnp.max(jnp.where(allowed, sizes, jnp.int32(np.iinfo(np.int32).min)))
            mn = jnp.min(sal)
            gap = jnp.clip(mx - sizes, 0, _DEG_CLAMP)
            bal_q = (gap * QB) // (eps_q + jnp.minimum(mx - mn, _DEG_CLAMP))
            rep_q = (
                carry.replicas[u] * tq_u + carry.replicas[v] * tq_v
            ).astype(jnp.int32)
            score_q = QB * rep_q + lam_q * bal_q
            tie = _tie_hash_j(carry.cursor, k, carry.seed)
            combined = jnp.where(allowed, (score_q << TIE_BITS) + tie, -1)
            p = jnp.argmax(combined).astype(jnp.int32)
            u_w = jnp.where(live, u, v_dummy)
            v_w = jnp.where(live, v, v_dummy)
            new_carry = HdrfCarry(
                deg=deg,
                replicas=carry.replicas.at[u_w, p].max(live).at[v_w, p].max(live),
                sizes=sizes.at[p].add(live_i),
                seed=carry.seed,
                cursor=carry.cursor + live_i,
                assigned=carry.assigned + live_i,
            )
            return new_carry, _single_edge_out(live, carry.cursor, p)

        return step


@dataclasses.dataclass(frozen=True)
class GreedyCore(StepCore):
    """PowerGraph Greedy as a step-core: one edge per scan step.

    All-integer (argmin over masked loads, first-occurrence ties) — exactly
    the :class:`GreedyState` loop.
    """

    num_vertices: int
    k: int

    name = "greedy"
    window_rows = 0
    rows_per_step = 1
    r_sel = 0
    has_budget = False

    def init_carry(self, budget: float) -> GreedyCarry:
        v1 = self.num_vertices + 1
        return GreedyCarry(
            replicas=jnp.zeros((v1, self.k), bool),
            sizes=jnp.zeros((self.k,), jnp.int32),
            cursor=jnp.zeros((), jnp.int32),
            assigned=jnp.zeros((), jnp.int32),
        )

    def warm_carry(self, budget: float, warm: WarmState) -> GreedyCarry:
        base = self.init_carry(budget)
        v = self.num_vertices
        return base._replace(
            replicas=base.replicas.at[:v].set(jnp.asarray(warm.replicas, bool)),
            sizes=jnp.asarray(warm.sizes, jnp.int32),
        )

    def make_step(self, stream, m_real, allowed, cap, prev_assign):
        v_dummy = self.num_vertices
        m_pad = stream.shape[0]
        big = jnp.int32(np.iinfo(np.int32).max)

        def step(carry: GreedyCarry, _):
            live = carry.cursor < m_real
            live_i = live.astype(jnp.int32)
            row = stream[carry.cursor % m_pad]
            u = jnp.where(live, row[0], v_dummy)
            v = jnp.where(live, row[1], v_dummy)
            ru = carry.replicas[u]
            rv = carry.replicas[v]
            inter = ru & rv
            union = ru | rv
            has_u, has_v = jnp.any(ru), jnp.any(rv)
            cand = jnp.where(
                jnp.any(inter),
                inter,
                jnp.where(
                    has_u & has_v,
                    union,
                    jnp.where(has_u, ru, jnp.where(has_v, rv, allowed)),
                ),
            )
            masked = jnp.where(cand, carry.sizes, big)
            p = jnp.argmin(masked).astype(jnp.int32)
            u_w = jnp.where(live, u, v_dummy)
            v_w = jnp.where(live, v, v_dummy)
            new_carry = GreedyCarry(
                replicas=carry.replicas.at[u_w, p].max(live).at[v_w, p].max(live),
                sizes=carry.sizes.at[p].add(live_i),
                cursor=carry.cursor + live_i,
                assigned=carry.assigned + live_i,
            )
            return new_carry, _single_edge_out(live, carry.cursor, p)

        return step


def _scan_partition(
    core,
    edges: np.ndarray,
    *,
    allowed: Optional[np.ndarray] = None,
    warm: Optional[WarmState] = None,
    backend: str = "vmap",
    n_chunks: int = 8,
) -> PartitionResult:
    """Run a single-instance step-core over a resident stream."""
    from repro.core.driver import ResidentSource, ScanDriver

    m = int(len(edges))
    if m == 0:
        return PartitionResult(np.zeros((0,), np.int32), dict(k=core.k, unassigned=0))
    source = ResidentSource(
        np.ascontiguousarray(edges, np.int32).reshape(1, m, 2),
        np.array([m], np.int64),
    )
    drv = ScanDriver(
        source, core,
        allowed=None if allowed is None else np.asarray(allowed, bool)[None],
        warm=None if warm is None else [warm],
        backend=backend,
    )
    res = drv.run(n_chunks=n_chunks)
    sidx, pout = res.sidx[0], res.p[0]
    assign = np.full((m,), -1, np.int32)
    live = sidx >= 0
    assign[sidx[live]] = pout[live]
    unassigned = int((assign < 0).sum())
    assert unassigned == 0 and int(res.assigned[0]) == m, (
        f"{core.name} scan left {unassigned} of {m} edges unassigned"
    )
    return PartitionResult(assign, dict(drv.stats_base(res, 0), unassigned=0))


def hdrf_partition_scan(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    lam: float = 1.1,
    eps: float = 1.0,
    seed: int = 0,
    allowed: Optional[np.ndarray] = None,
    backend: str = "vmap",
) -> PartitionResult:
    """HDRF via the :class:`HdrfCore` lax.scan — bit-identical to
    :func:`hdrf_partition` (the numpy oracle)."""
    core = HdrfCore(num_vertices=int(num_vertices), k=int(k),
                    lam=float(lam), eps=float(eps), seed=int(seed))
    return _scan_partition(core, edges, allowed=allowed, backend=backend)


def greedy_partition_scan(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    seed: int = 0,
    allowed: Optional[np.ndarray] = None,
    backend: str = "vmap",
) -> PartitionResult:
    """Greedy via the :class:`GreedyCore` lax.scan — bit-identical to
    :func:`greedy_partition` (the numpy oracle)."""
    core = GreedyCore(num_vertices=int(num_vertices), k=int(k))
    return _scan_partition(core, edges, allowed=allowed, backend=backend)
