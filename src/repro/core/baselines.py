"""Baseline streaming vertex-cut partitioners the paper compares against.

* HDRF  (Petroni et al., CIKM'15)  — High-Degree Replicated First.
* DBH   (Xie et al., NIPS'14)      — Degree-Based Hashing.
* Greedy (PowerGraph, OSDI'12)     — replica-intersection heuristic.
* Hashing                          — edge hash (PowerGraph/GraphX default).
* Grid   (GraphBuilder)            — 2D grid-constrained hashing.

HDRF and Greedy are sequential by nature (they read the evolving vertex
cache); they are implemented as tight numpy loops. DBH / Hashing / Grid are
stateless given degrees and fully vectorized.

Every partitioner is factored into a *chunk-resumable core* — a state object
(vertex cache, partition loads, RNG) plus an ``assign_chunk`` step — so the
out-of-core driver (`repro.core.oocore.partition_file`) can stream a
file-resident graph through the identical math in bounded-size chunks: the
whole-array entry points below are exactly "init state, one chunk". HDRF's
tie-break noise draws from the state's generator as the stream is consumed
(numpy Generators fill sequentially, so any chunking of the stream sees the
same noise sequence as the one-shot draw did).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.core.types import PartitionResult

__all__ = [
    "hdrf_partition",
    "dbh_partition",
    "greedy_partition",
    "hash_partition",
    "grid_partition",
    "HdrfState",
    "GreedyState",
    "hash_assign",
    "grid_assign",
    "dbh_assign",
]


def _hash_vec(x: np.ndarray, k: int, salt: int = 0x9E3779B9) -> np.ndarray:
    """Deterministic integer hash -> [0, k)."""
    h = (x.astype(np.uint64) + np.uint64(salt)) * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(33)
    h *= np.uint64(0xC2B2AE3D27D4EB4F)
    h ^= h >> np.uint64(29)
    return (h % np.uint64(k)).astype(np.int32)


# ----------------------------------------------------------------------------
# Stateless cores (vectorized; chunking is trivially exact)
# ----------------------------------------------------------------------------


def hash_assign(edges: np.ndarray, num_vertices: int, k: int, seed: int = 0) -> np.ndarray:
    key = edges[:, 0].astype(np.uint64) * np.uint64(num_vertices) + edges[:, 1].astype(np.uint64)
    return _hash_vec(key, k, salt=seed + 1)


def grid_assign(edges: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    g = max(int(np.floor(np.sqrt(k))), 1)
    ru = _hash_vec(edges[:, 0].astype(np.uint64), g, salt=seed + 11)
    cv = _hash_vec(edges[:, 1].astype(np.uint64), g, salt=seed + 13)
    return (ru * g + cv).astype(np.int32) % k


def dbh_assign(edges: np.ndarray, degrees: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """DBH placement given the *full-stream* degree table."""
    u, v = edges[:, 0], edges[:, 1]
    pick_u = degrees[u] < degrees[v]
    # Tie: lower id (deterministic).
    tie = degrees[u] == degrees[v]
    pick_u = np.where(tie, u < v, pick_u)
    key = np.where(pick_u, u, v).astype(np.uint64)
    return _hash_vec(key, k, salt=seed + 29)


def hash_partition(edges: np.ndarray, num_vertices: int, k: int, seed: int = 0) -> PartitionResult:
    """Random edge hashing (the PowerGraph default loader)."""
    t0 = time.perf_counter()
    assign = hash_assign(edges, num_vertices, k, seed=seed)
    return PartitionResult(assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="hash"))


def grid_partition(edges: np.ndarray, num_vertices: int, k: int, seed: int = 0) -> PartitionResult:
    """GraphBuilder grid hashing: p drawn from intersection of row(u) and col(v).

    Constrains each vertex's replicas to a sqrt(k)-sized subset.
    """
    t0 = time.perf_counter()
    assign = grid_assign(edges, k, seed=seed)
    return PartitionResult(assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="grid"))


def dbh_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    seed: int = 0,
    degrees: Optional[np.ndarray] = None,
) -> PartitionResult:
    """Degree-Based Hashing: hash the lower-degree endpoint of each edge."""
    t0 = time.perf_counter()
    if degrees is None:
        degrees = np.zeros(num_vertices, dtype=np.int64)
        np.add.at(degrees, edges[:, 0], 1)
        np.add.at(degrees, edges[:, 1], 1)
    assign = dbh_assign(edges, degrees, k, seed=seed)
    return PartitionResult(assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="dbh"))


# ----------------------------------------------------------------------------
# Sequential cores (stateful; chunk-resumable)
# ----------------------------------------------------------------------------


class HdrfState:
    """HDRF vertex cache + loads + tie-break RNG, resumable across chunks."""

    def __init__(self, num_vertices: int, k: int, lam: float = 1.1,
                 eps: float = 1.0, seed: int = 0):
        self.k = k
        self.lam = lam
        self.eps = eps
        self.deg = np.zeros(num_vertices, dtype=np.int64)
        self.replicas = np.zeros((num_vertices, k), dtype=bool)
        self.sizes = np.zeros(k, dtype=np.int64)
        self.rng = np.random.default_rng(seed)
        self.edges_seen = 0

    def assign_chunk(self, edges: np.ndarray) -> np.ndarray:
        """Place a chunk of the stream; state advances in stream order."""
        k, lam, eps = self.k, self.lam, self.eps
        deg, replicas, sizes = self.deg, self.replicas, self.sizes
        c = len(edges)
        assign = np.empty(c, dtype=np.int32)
        # Sequential draws from the persistent generator: identical to the
        # one-shot rng.random((m,)) of the whole stream, however chunked.
        tie_noise = self.rng.random((c,)) * 1e-9
        for i in range(c):
            u, v = int(edges[i, 0]), int(edges[i, 1])
            deg[u] += 1
            deg[v] += 1
            du, dv = deg[u], deg[v]
            theta_u = du / (du + dv)
            theta_v = 1.0 - theta_u
            mx, mn = sizes.max(), sizes.min()
            c_bal = (mx - sizes) / (eps + mx - mn)
            c_rep = replicas[u] * (2.0 - theta_u) + replicas[v] * (2.0 - theta_v)
            score = c_rep + lam * c_bal
            p = int(np.argmax(score + tie_noise[i]))
            assign[i] = p
            sizes[p] += 1
            replicas[u, p] = True
            replicas[v, p] = True
        self.edges_seen += c
        return assign


class GreedyState:
    """PowerGraph Greedy vertex cache + loads, resumable across chunks."""

    def __init__(self, num_vertices: int, k: int):
        self.k = k
        self.replicas = np.zeros((num_vertices, k), dtype=bool)
        self.sizes = np.zeros(k, dtype=np.int64)
        self.edges_seen = 0

    def assign_chunk(self, edges: np.ndarray) -> np.ndarray:
        k = self.k
        replicas, sizes = self.replicas, self.sizes
        c = len(edges)
        assign = np.empty(c, dtype=np.int32)
        for i in range(c):
            u, v = int(edges[i, 0]), int(edges[i, 1])
            ru, rv = replicas[u], replicas[v]
            inter = ru & rv
            if inter.any():
                cand = inter
            elif ru.any() and rv.any():
                cand = ru | rv
            elif ru.any():
                cand = ru
            elif rv.any():
                cand = rv
            else:
                cand = np.ones(k, dtype=bool)
            masked = np.where(cand, sizes, np.iinfo(np.int64).max)
            p = int(np.argmin(masked))
            assign[i] = p
            sizes[p] += 1
            replicas[u, p] = True
            replicas[v, p] = True
        self.edges_seen += c
        return assign


def hdrf_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    lam: float = 1.1,
    eps: float = 1.0,
    seed: int = 0,
) -> PartitionResult:
    """HDRF single-edge streaming (Petroni et al.).

    score(e=(u,v), p) = C_rep + lam * C_bal with
      C_rep = g(u,p) + g(v,p),   g(x,p) = 1{p in R_x} * (1 + (1 - theta_x))
      theta_u = deg(u) / (deg(u) + deg(v))
      C_bal = (maxsize - size_p) / (eps + maxsize - minsize)
    Partial degrees are updated as the stream is consumed. lam=1.1 is the
    authors' recommended default (used in the paper's evaluation).
    """
    t0 = time.perf_counter()
    state = HdrfState(num_vertices, k, lam=lam, eps=eps, seed=seed)
    assign = state.assign_chunk(edges)
    return PartitionResult(
        assign,
        dict(k=k, wall_time_s=time.perf_counter() - t0, name="hdrf",
             score_count=len(edges) * k),
    )


def greedy_partition(
    edges: np.ndarray, num_vertices: int, k: int, seed: int = 0
) -> PartitionResult:
    """PowerGraph Greedy (Gonzalez et al., OSDI'12) placement rules.

    1. If R_u and R_v intersect: least-loaded partition in the intersection.
    2. Else if both non-empty: least-loaded partition in R_u | R_v.
    3. Else if one non-empty: least-loaded partition in it.
    4. Else: least-loaded partition overall.
    """
    t0 = time.perf_counter()
    state = GreedyState(num_vertices, k)
    assign = state.assign_chunk(edges)
    return PartitionResult(
        assign, dict(k=k, wall_time_s=time.perf_counter() - t0, name="greedy")
    )
