"""Pure-Python sequential oracle of ADWISE Algorithm 1.

This is the *exact* semantics of the paper (window as a set, argmax over
W × P, candidate/secondary lazy traversal, adaptive window, adaptive λ,
set-semantics clustering score). It is deliberately unoptimized: it exists to
(a) pin the semantics the vectorized JAX implementation must match and
(b) serve as the correctness oracle in tests.

Use `repro.core.adwise.partition_stream` for anything larger than ~100k edges.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.types import AdwiseConfig, PartitionResult

__all__ = ["ref_adwise_partition"]


class _State:
    def __init__(self, num_vertices: int, k: int, cfg: AdwiseConfig, m: int):
        self.replicas: List[Set[int]] = [set() for _ in range(num_vertices)]
        self.rep_version = np.zeros(num_vertices, dtype=np.int64)
        self.deg = np.zeros(num_vertices, dtype=np.int64)
        self.max_deg = 1
        self.sizes = np.zeros(k, dtype=np.int64)
        self.lam = cfg.lam_init
        self.assigned = 0
        self.m = m
        self.score_count = 0

    def balance(self, p: int, eps: float) -> float:
        mx, mn = self.sizes.max(), self.sizes.min()
        return float((mx - self.sizes[p]) / (mx - mn + eps))

    def imbalance(self) -> float:
        mx = self.sizes.max()
        return float((mx - self.sizes.min()) / mx) if mx > 0 else 0.0


def _replication_score(st: _State, u: int, v: int, p: int) -> float:
    """Eq. 5: R((u,v),p) = 1{p∈R_u}(2-Ψ_u) + 1{p∈R_v}(2-Ψ_v), Ψ_x=deg(x)/2maxDeg."""
    psi_u = st.deg[u] / (2.0 * st.max_deg)
    psi_v = st.deg[v] / (2.0 * st.max_deg)
    r = 0.0
    if p in st.replicas[u]:
        r += 2.0 - psi_u
    if p in st.replicas[v]:
        r += 2.0 - psi_v
    return r


def _clustering_score(
    st: _State, window: List[Tuple[int, int, int]], i: int, p: int
) -> float:
    """Eq. 6 with exact set semantics; N(·) computed window-locally."""
    u, v = window[i][0], window[i][1]
    neigh: Set[int] = set()
    for j, (a, b, _) in enumerate(window):
        if j == i:
            continue
        if a == u or a == v:
            neigh.add(b)
        if b == u or b == v:
            neigh.add(a)
    neigh.discard(u)
    neigh.discard(v)
    if not neigh:
        return 0.0
    hits = sum(1 for x in neigh if p in st.replicas[x])
    return hits / len(neigh)


def _score(
    st: _State, window: List[Tuple[int, int, int]], i: int, p: int, cfg: AdwiseConfig
) -> float:
    """g(e,p) = λ(ι,α)·B(p) + R(e,p) + CS(e,p)  (Eq. 7)."""
    u, v = window[i][0], window[i][1]
    st.score_count += 1
    g = st.lam * st.balance(p, cfg.eps) + _replication_score(st, u, v, p)
    if cfg.use_clustering:
        g += _clustering_score(st, window, i, p)
    return g


def ref_adwise_partition(
    edges: np.ndarray,
    num_vertices: int,
    cfg: AdwiseConfig,
    cost_per_score: Optional[float] = None,
) -> PartitionResult:
    """Sequential Algorithm 1 with lazy traversal and the adaptive window.

    Args:
      edges: (m, 2) int32 stream.
      num_vertices: |V|.
      cfg: AdwiseConfig (assign_batch must be 1 — the oracle is sequential).
      cost_per_score: if given, (C2) uses ``score_count_delta * cost_per_score``
        as the modeled per-edge latency instead of wall-clock — this makes the
        oracle deterministic and lets tests compare against the JAX scan which
        uses the same model.
    """
    assert cfg.assign_batch == 1, "oracle implements the paper's sequential loop"
    m = len(edges)
    k = cfg.k
    st = _State(num_vertices, k, cfg, m)
    assign = np.full(m, -1, dtype=np.int32)
    cap = int(cfg.cap_slack * m / k) + 1 if cfg.cap_slack else None

    # Window entries: (u, v, stream_index).
    window: List[Tuple[int, int, int]] = []
    cursor = 0
    w = cfg.window_init
    c = 0
    sum_g, period_n = 0.0, 0
    avg_g_prev = -np.inf
    last_grew = True  # treat the initial window as "just grown" so C1 is evaluable
    w_trace: List[int] = []
    lam_trace: List[float] = []
    budget = cfg.latency_budget
    t_start = time.perf_counter()
    score_count_last = 0

    # Lazy traversal caches: per window slot, max-over-p score + best p,
    # validity stamped with endpoint replica versions.
    cache: Dict[int, Tuple[float, int, int, int]] = {}  # stream_idx -> (g, p, ver_u, ver_v)

    def load_edge() -> None:
        nonlocal cursor
        u, v = int(edges[cursor, 0]), int(edges[cursor, 1])
        window.append((u, v, cursor))
        # Streamed partial degrees are updated on observation (DESIGN.md §3).
        st.deg[u] += 1
        st.deg[v] += 1
        st.max_deg = max(st.max_deg, int(st.deg[u]), int(st.deg[v]))
        cursor += 1

    def best_for_edge(i: int) -> Tuple[float, int]:
        best_g, best_p = -np.inf, 0
        for p in range(k):
            if cap is not None and st.sizes[p] >= cap:
                continue
            g = _score(st, window, i, p, cfg)
            if g > best_g:
                best_g, best_p = g, p
        return best_g, best_p

    while cursor < m or window:
        # Alg. 1 line 5: top the window up by one edge.
        while len(window) < w and cursor < m:
            load_edge()

        # --- GETBESTASSIGNMENT with lazy traversal (§III-B) ---
        best = (-np.inf, 0, 0)  # (g, slot, p)
        for i, (u, v, sidx) in enumerate(window):
            entry = cache.get(sidx)
            fresh = (
                entry is not None
                and cfg.lazy
                and entry[2] == st.rep_version[u]
                and entry[3] == st.rep_version[v]
            )
            if fresh:
                g, p = entry[0], entry[1]
            else:
                g, p = best_for_edge(i)
                cache[sidx] = (g, p, int(st.rep_version[u]), int(st.rep_version[v]))
            if g > best[0]:
                best = (g, i, p)
        g_hat, i_hat, p_hat = best
        u, v, sidx = window.pop(i_hat)
        cache.pop(sidx, None)

        # Assign ê to p̂.
        assign[sidx] = p_hat
        st.sizes[p_hat] += 1
        for x in (u, v):
            if p_hat not in st.replicas[x]:
                st.replicas[x].add(p_hat)
                st.rep_version[x] += 1
        st.assigned += 1
        sum_g += g_hat
        period_n += 1
        c += 1

        # Adaptive λ (Eq. 4).
        alpha = st.assigned / m
        tol = max(0.0, 1.0 - alpha)
        st.lam = float(np.clip(st.lam + (st.imbalance() - tol), cfg.lam_lo, cfg.lam_hi))
        lam_trace.append(st.lam)

        # Adaptive window (§III-A), every w assignments.
        if cfg.adapt and c % max(w, 1) == 0:
            avg_g = sum_g / max(period_n, 1)
            edges_left = m - st.assigned
            if budget is not None:
                if cost_per_score is not None:
                    elapsed = st.score_count * cost_per_score
                else:
                    elapsed = time.perf_counter() - t_start
                budget_left = budget - elapsed
                per_edge = (
                    (st.score_count - score_count_last) * (cost_per_score or 0.0) / max(period_n, 1)
                    if cost_per_score is not None
                    else elapsed / max(st.assigned, 1)
                )
                c2 = edges_left == 0 or per_edge < budget_left / max(edges_left, 1)
            else:
                c2 = True
            c1 = (not last_grew) or (avg_g >= avg_g_prev)
            if c1 and c2 and w < cfg.window_max:
                w = min(2 * w, cfg.window_max)
                last_grew = True
                while len(window) < w and cursor < m:
                    load_edge()
            elif not c2:
                w = max(1, -(-w // 2))
                last_grew = False
            else:
                last_grew = False
            avg_g_prev = avg_g
            sum_g, period_n = 0.0, 0
            score_count_last = st.score_count
            c = 0
        w_trace.append(w)

    wall = time.perf_counter() - t_start
    return PartitionResult(
        assign=assign,
        stats=dict(
            k=k,
            score_count=int(st.score_count),
            wall_time_s=wall,
            w_trace=np.array(w_trace, dtype=np.int32),
            lam_trace=np.array(lam_trace, dtype=np.float32),
            final_w=w,
        ),
    )
