"""Multi-pass re-streaming partitioning: restreamed ADWISE and 2PS.

The paper's thesis is that *investing* partitioning latency buys
disproportionately lower processing latency; the knob it turns is window
size. This module adds the orthogonal knob named by ROADMAP and the registry
docstring: **pass count**. Two strategies ride on one warm-start mechanism
(:meth:`repro.core.adwise.Carry.warm_start`):

* ``adwise-restream`` — n-pass re-streaming (Nishimura & Ugander restreaming
  framing; buffered re-streaming per Chhabra et al., arXiv:2402.11980).
  Pass 1 runs any registered strategy (default ADWISE). Every later pass
  re-runs the ADWISE scan over the same stream warm-started from the
  previous pass's replica table, full degree table, and partition loads;
  each edge's prior placement is *revoked* the moment it re-enters the
  window (``WarmState.prev_assign``), so balance terms always see net
  loads. λ re-anneals per pass (the Eq. 4 tolerance schedule replays).
  With ``keep_best=True`` the lowest-replication pass wins, so quality is
  monotone in invested latency by construction.

* ``2ps`` — the 2PS two-phase design (Mayer et al., arXiv:2001.07086).
  Phase 1 streams a volume-capped vertex clustering (2PS-L style local
  moves) and bin-packs clusters onto partitions. Phase 2 re-streams the
  edges through the ADWISE scan warm-started with *virtual replicas*: every
  clustered vertex starts with a replica on its cluster's partition, so the
  existing Eq. 5 replication term in ``scoring.py`` becomes the
  cluster-affinity score — no new scoring code, phase 2 literally reuses
  the scoring terms the single-pass partitioner compiles.

* ``2ps-l`` — 2PS-L (Mayer et al., arXiv:2203.12721), the linear-run-time
  variant. Same phase 1 (the clustering scan above IS 2PS-L's phase 1),
  but phase 2 drops the windowed rescoring entirely: each edge is scored
  once against its endpoints' cluster→partition placements plus the
  quantized HDRF balance term, under a hard capacity cap (eligible =
  allowed ∧ size < cap, so the least-loaded fallback of the paper's
  Algorithm 2 emerges from the same argmax instead of a separate branch).
  Phase 2 is its own step-core (:class:`TpslCore`) riding the shared
  :class:`~repro.core.driver.ScanDriver`, with :class:`TpslState` as the
  per-edge numpy parity oracle — deterministic, no tie noise.

All are one-file registry entries; launchers and benchmarks pick them up
by name.

Every pass here is a thin call into :func:`repro.core.adwise.partition_stream`
/ :func:`~repro.core.adwise.partition_stream_batched`, which route through
the unified :class:`repro.core.driver.ScanDriver` — carry warm-starting,
r_sel/cap resolution, and budget wiring live there, not per pass. Stats
aggregate the per-pass host→device stream traffic (``h2d_rows`` /
``h2d_bytes``). Re-streaming passes share ONE device stream upload through
a :class:`repro.core.driver.StreamResidency` holder: pass 1 ships the
stream, every later pass reuses the resident device array and ships only
its new ``prev`` table — so a p-pass in-memory re-stream bills one stream
upload plus (p − 1) prev tables, not p stream uploads.
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.core.adwise import WarmState, partition_stream, partition_stream_batched
from repro.core.baselines import (
    QB,
    _DEG_CLAMP,
    _eps_q,
    _lam_q,
    _scan_partition,
    _single_edge_out,
)
from repro.core.driver import StepCore, StreamResidency
from repro.obs import resolve_tracer
from repro.core.types import AdwiseConfig, PartitionResult
from repro.graph import metrics

__all__ = [
    "warm_from_assignment",
    "restream_partition",
    "restream_partition_batched",
    "two_phase_partition",
    "two_phase_linear_partition",
    "two_phase_partition_batched",
    "streaming_vertex_clustering",
    "streaming_vertex_clustering_np",
    "VertexClusteringState",
    "TpslCore",
    "TpslState",
]


def _degrees(edges: np.ndarray, num_vertices: int) -> np.ndarray:
    deg = np.zeros(num_vertices, dtype=np.int64)
    if len(edges):
        deg += np.bincount(edges[:, 0], minlength=num_vertices)
        deg += np.bincount(edges[:, 1], minlength=num_vertices)
    return deg


def warm_from_assignment(
    edges: np.ndarray, assign: np.ndarray, num_vertices: int, k: int
) -> WarmState:
    """WarmState for the next pass, derived from a completed assignment."""
    replicas = metrics.replica_sets_from_assignment(
        edges, assign, num_vertices, k, unassigned="drop"
    )
    sizes = metrics.partition_sizes(assign, k, unassigned="drop")
    return WarmState(
        replicas=replicas,
        deg=_degrees(edges, num_vertices),
        sizes=sizes,
        prev_assign=np.asarray(assign, np.int32),
    )


def _rd(edges: np.ndarray, assign: np.ndarray, num_vertices: int, k: int) -> float:
    return metrics.replication_degree(
        metrics.replica_sets_from_assignment(edges, assign, num_vertices, k)
    )


def restream_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    passes: int = 2,
    base: str = "adwise",
    keep_best: bool = True,
    eps: Optional[float] = None,
    seed: int = 0,
    n_chunks: int = 8,
    allowed: Optional[np.ndarray] = None,
    trace=None,
    **adwise_cfg,
) -> PartitionResult:
    """n-pass re-streaming: warm-started ADWISE over a base pass.

    Args:
      passes: total passes over the stream (1 == just the base strategy).
      base: registry strategy for pass 1. Non-adwise bases take no cfg here.
      allowed: optional (k,) bool partition mask — every pass (base pass
        included) scores only the allowed partitions (the spotlight loop
        backend routes per-instance spread masks through here).
      keep_best: return the pass with the lowest replication degree (quality
        is then non-increasing in ``passes``); False returns the last pass.
      eps: early-stop threshold on replication degree — stop re-streaming
        when a pass improves RD over the previous pass by less than ``eps``
        (None, the default, always runs the fixed ``passes`` count).
        ``stats['passes_run']`` reports how many passes actually ran; this
        ``eps`` is the restream knob, distinct from ``AdwiseConfig.eps``
        (the Eq. 3/Θ score epsilon, which stays at its default here).
      trace: optional :class:`repro.obs.Tracer` — records one ``pass``-
        category span per restream pass (lane ``restream-pass-<j>``) and
        threads through to the per-pass scan drivers. None disables tracing.
      adwise_cfg: AdwiseConfig fields for the ADWISE passes (pass 1 included
        when ``base == 'adwise'``), e.g. ``window_max=64``.
    """
    if passes < 1:
        raise ValueError(f"passes must be >= 1, got {passes}")
    tr = resolve_tracer(trace)
    cfg = AdwiseConfig(k=k, seed=seed, **adwise_cfg)
    base_kw = {} if allowed is None else {"allowed": allowed}
    # Every ADWISE pass streams the same edges: share one device upload
    # across passes (later passes ship only their prev table).
    residency = StreamResidency()
    t_p1 = time.perf_counter()
    if base == "adwise":
        res = partition_stream(
            edges, num_vertices, cfg, n_chunks=n_chunks, allowed=allowed,
            residency=residency, trace=trace,
        )
    else:
        res = registry.run_partitioner(
            base, edges, num_vertices, k, seed=seed, **base_kw
        )

    def _score_rows(stats: dict) -> int:
        # Baselines report score_count = m·k but no score_rows; both count
        # toward invested latency (partition_latency's §III-B metric).
        return int(stats.get("score_rows", stats.get("score_count", 0) // max(k, 1)))

    pass_rd: List[float] = [_rd(edges, res.assign, num_vertices, k)]
    if tr.enabled:
        # Each restream pass gets its own lane; attrs carry the pass quality.
        tr.add_span(
            "pass-1", "pass", t_p1, time.perf_counter(),
            track="restream-pass-1", attrs=dict(base=base, rd=pass_rd[0]),
        )
    pass_imbalance: List[float] = [metrics.partition_balance(res.assign, k)]
    pass_wall: List[float] = [float(res.stats.get("wall_time_s", 0.0))]
    pass_score_rows: List[int] = [_score_rows(res.stats)]
    h2d_rows = int(res.stats.get("h2d_rows", 0))
    h2d_bytes = int(res.stats.get("h2d_bytes", 0))
    best_res, best_rd, best_pass = res, pass_rd[0], 1
    warm_wall = 0.0

    for j in range(1, passes):
        t_w = time.perf_counter()
        warm = warm_from_assignment(edges, res.assign, num_vertices, k)
        warm_wall += time.perf_counter() - t_w
        res = partition_stream(
            edges, num_vertices, cfg, n_chunks=n_chunks, warm=warm,
            allowed=allowed, residency=residency, trace=trace,
        )
        pass_rd.append(_rd(edges, res.assign, num_vertices, k))
        if tr.enabled:
            tr.add_span(
                f"pass-{j + 1}", "pass", t_w, time.perf_counter(),
                track=f"restream-pass-{j + 1}",
                attrs=dict(rd=pass_rd[-1],
                           rd_delta=pass_rd[-2] - pass_rd[-1]),
            )
        pass_imbalance.append(metrics.partition_balance(res.assign, k))
        pass_wall.append(float(res.stats.get("wall_time_s", 0.0)))
        pass_score_rows.append(_score_rows(res.stats))
        h2d_rows += int(res.stats.get("h2d_rows", 0))
        h2d_bytes += int(res.stats.get("h2d_bytes", 0))
        if pass_rd[-1] <= best_rd:
            best_res, best_rd, best_pass = res, pass_rd[-1], len(pass_rd)
        if eps is not None and (pass_rd[-2] - pass_rd[-1]) < eps:
            break  # diminishing returns — stop investing passes

    passes_run = len(pass_rd)
    final = best_res if keep_best else res
    score_rows = int(sum(pass_score_rows))
    stats = dict(
        final.stats,
        name="adwise-restream",
        base=base,
        passes=passes,
        passes_run=passes_run,
        # Each pass is one full read of the edge stream — the latency model
        # bills IO per read (engine/latency_model.py::partition_latency).
        stream_reads=passes_run,
        eps=eps,
        best_pass=best_pass if keep_best else passes_run,
        pass_rd=pass_rd,
        pass_imbalance=pass_imbalance,
        pass_wall_s=pass_wall,
        pass_score_rows=pass_score_rows,
        score_rows=score_rows,
        score_count=score_rows * k,
        h2d_rows=h2d_rows,
        h2d_bytes=h2d_bytes,
        # Pure partitioning wall: per-pass scan walls + warm-state handoff.
        # Quality metrics computed for stats are measurement, not work.
        wall_time_s=float(sum(pass_wall)) + warm_wall,
        unassigned=metrics.unassigned_count(final.assign),
    )
    if tr.enabled:
        # final.stats carries the summary snapshot from its own pass; refresh
        # so the returned stats see every pass's spans.
        stats["trace_summary"] = tr.summary().as_dict()
    return PartitionResult(final.assign, stats)


def restream_partition_batched(
    streams: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    allowed: Optional[np.ndarray] = None,
    passes: int = 2,
    base: str = "adwise",
    keep_best: bool = True,
    eps: Optional[float] = None,
    seed: int = 0,
    n_chunks: int = 8,
    backend: str = "auto",
    trace=None,
    **adwise_cfg,
) -> List[PartitionResult]:
    """n-pass re-streaming over ``z`` batched spotlight instances.

    Composes the two invested-latency mechanisms (ROADMAP item c): every
    pass runs ALL z instance scans as one vmapped/shard_mapped program
    (:func:`repro.core.adwise.partition_stream_batched`), and between passes
    each instance derives its own :class:`WarmState` from its own sub-stream
    assignment — replica table, degree table, partition loads, and the
    prior placements revoked as edges re-enter the window. Instances never
    communicate (the paper's parallel loading model); ``keep_best`` picks
    each instance's best pass independently, while ``eps`` early-stops
    globally once NO instance improves its replication degree by >= eps
    (passes are batched, so all instances run the same pass count).

    Args mirror :func:`restream_partition` plus the batched stream layout of
    :func:`partition_stream_batched` (``streams[z, per, 2]``,
    ``valid[z, per]``, per-instance ``allowed[z, k]`` spread masks) — except
    ``base``: only ``'adwise'`` batches (pass 1 is the same batched scan);
    a non-adwise base pass needs the sequential per-instance path
    (``spotlight_partition(..., backend='loop')`` routes there, and
    spotlight's ``backend='auto'`` does so automatically).

    Returns one PartitionResult per instance (local stream order).
    """
    if passes < 1:
        raise ValueError(f"passes must be >= 1, got {passes}")
    if base != "adwise":
        raise ValueError(
            f"restream_partition_batched only batches base='adwise' (got "
            f"{base!r}): a non-adwise pass 1 runs per-instance baselines — "
            "use spotlight_partition(..., backend='loop')"
        )
    tr = resolve_tracer(trace)
    cfg = AdwiseConfig(k=k, seed=seed, **adwise_cfg)
    z = int(streams.shape[0])
    valid = np.asarray(valid, bool)
    m_per = valid.sum(axis=1).astype(np.int64)
    edges_i = [streams[i, : m_per[i]] for i in range(z)]

    t0 = time.perf_counter()
    # Shared device upload across passes (pass 2+ ships prev tables only).
    residency = StreamResidency()
    results = partition_stream_batched(
        streams, valid, num_vertices, cfg,
        allowed=allowed, backend=backend, n_chunks=n_chunks,
        residency=residency, trace=trace,
    )
    pass_rd = [[_rd(edges_i[i], results[i].assign, num_vertices, k)]
               for i in range(z)]
    if tr.enabled:
        tr.add_span(
            "pass-1", "pass", t0, time.perf_counter(),
            track="restream-pass-1",
            attrs=dict(base=base, z=z,
                       rd_mean=float(np.mean([r[0] for r in pass_rd]))),
        )
    pass_score_rows = [[int(results[i].stats.get("score_rows", 0))]
                       for i in range(z)]
    # h2d counters are run-level (one batched program per pass).
    h2d_rows = int(results[0].stats.get("h2d_rows", 0))
    h2d_bytes = int(results[0].stats.get("h2d_bytes", 0))
    best = list(results)
    best_rd = [pass_rd[i][0] for i in range(z)]
    best_pass = [1] * z

    for j in range(1, passes):
        t_pass = time.perf_counter()
        warms = [
            warm_from_assignment(edges_i[i], results[i].assign,
                                 num_vertices, k)
            for i in range(z)
        ]
        results = partition_stream_batched(
            streams, valid, num_vertices, cfg,
            allowed=allowed, backend=backend, n_chunks=n_chunks, warm=warms,
            residency=residency, trace=trace,
        )
        h2d_rows += int(results[0].stats.get("h2d_rows", 0))
        h2d_bytes += int(results[0].stats.get("h2d_bytes", 0))
        improved = 0.0
        for i in range(z):
            rd = _rd(edges_i[i], results[i].assign, num_vertices, k)
            improved = max(improved, pass_rd[i][-1] - rd)
            pass_rd[i].append(rd)
            pass_score_rows[i].append(int(results[i].stats.get("score_rows", 0)))
            if rd <= best_rd[i]:
                best[i], best_rd[i], best_pass[i] = results[i], rd, len(pass_rd[i])
        if tr.enabled:
            tr.add_span(
                f"pass-{j + 1}", "pass", t_pass, time.perf_counter(),
                track=f"restream-pass-{j + 1}",
                attrs=dict(z=z, rd_delta_max=improved,
                           rd_mean=float(np.mean([r[-1] for r in pass_rd]))),
            )
        if eps is not None and improved < eps:
            break

    passes_run = len(pass_rd[0])
    wall = time.perf_counter() - t0
    finals = best if keep_best else results
    tsum = tr.summary().as_dict() if tr.enabled else None
    out = []
    for i in range(z):
        rows = int(sum(pass_score_rows[i]))
        stats = dict(
            finals[i].stats,
            name="adwise-restream",
            passes=passes,
            passes_run=passes_run,
            stream_reads=passes_run,
            eps=eps,
            best_pass=best_pass[i] if keep_best else passes_run,
            pass_rd=pass_rd[i],
            pass_score_rows=pass_score_rows[i],
            score_rows=rows,
            score_count=rows * k,
            h2d_rows=h2d_rows,
            h2d_bytes=h2d_bytes,
            # All passes ran as batched programs; the accumulated batched
            # wall is shared by every instance (parallel model).
            wall_time_s=wall,
            unassigned=metrics.unassigned_count(finals[i].assign),
        )
        if tsum is not None:
            stats["trace_summary"] = tsum
        out.append(PartitionResult(finals[i].assign, stats))
    return out


# ----------------------------------------------------------------------------
# 2PS: phase-1 streaming vertex clustering
# ----------------------------------------------------------------------------


def _volume_cap(m: int, k: int, cluster_slack: float) -> int:
    """Integer volume cap. Volumes are integer degree sums, so the float cap
    ``max(cluster_slack * 2m/k, 1.0)`` gates exactly like its floor — using
    the integer form makes the lax.scan port and the numpy oracle agree
    bit-for-bit regardless of accumulator dtype."""
    max_vol = max(cluster_slack * 2.0 * m / max(k, 1), 1.0)
    return int(min(math.floor(max_vol), np.iinfo(np.int32).max - 1))


@partial(jax.jit, static_argnames=("num_vertices",))
def _cluster_scan(cl, vols, nxt, edges, live, deg, cap, *, num_vertices):
    """One `lax.scan` over a chunk of edges, advancing the clustering state.

    State: ``cl`` (V+1,) int32 cluster per vertex (-1 = unclustered; row V is
    a scatter dump), ``vols`` (V+3,) int32 cluster volumes (slots are created
    in `nxt` order; the last row is a scatter dump), ``nxt`` () int32 next
    cluster id. ``live`` masks padding rows (their steps are no-ops), so any
    chunking of the stream yields the exact state the one-shot scan yields.
    """
    n = num_vertices
    dummy_v = jnp.int32(vols.shape[0] - 1)
    dummy_c = jnp.int32(n)

    def step(carry, xs):
        cl, vols, nxt = carry
        uv, lv = xs
        u, v = uv[0], uv[1]
        du, dv = deg[u], deg[v]
        cu, cv = cl[u], cl[v]
        cu_ok = cu >= 0
        cv_ok = cv >= 0
        vol_cu = vols[jnp.where(cu_ok, cu, dummy_v)]
        vol_cv = vols[jnp.where(cv_ok, cv, dummy_v)]
        selfloop = u == v
        both_new = ~cu_ok & ~cv_ok
        u_new = ~cu_ok & cv_ok
        v_new = cu_ok & ~cv_ok
        both_old = cu_ok & cv_ok & (cu != cv)

        # Case A: both unclustered — found together (cap / self-loop) or apart.
        a_join = both_new & (selfloop | (du + dv <= cap))
        a_split = both_new & ~a_join
        # Case B / C: one endpoint joins the other's cluster if it fits,
        # else founds its own.
        b_fits = u_new & (vol_cv + du <= cap)
        b_new = u_new & ~b_fits
        c_fits = v_new & (vol_cu + dv <= cap)
        c_new = v_new & ~c_fits
        # Case D: 2PS-L local move — endpoint in the lighter cluster moves.
        move_u = both_old & (vol_cu <= vol_cv)
        move_v = both_old & ~(vol_cu <= vol_cv)
        d_u = move_u & (vol_cv + du <= cap)
        d_v = move_v & (vol_cu + dv <= cap)

        wu = lv & (a_join | a_split | b_fits | b_new | d_u)
        new_cl_u = jnp.where(b_fits | d_u, cv, nxt)
        wv = lv & (a_join | a_split | c_fits | c_new | d_v)
        new_cl_v = jnp.where(
            c_fits | d_v, cu, jnp.where(a_split, nxt + 1, nxt)
        )
        # u then v; the only u/v collision is the self-loop join, where both
        # write the same id.
        cl = cl.at[jnp.where(wu, u, dummy_c)].set(new_cl_u)
        cl = cl.at[jnp.where(wv, v, dummy_c)].set(new_cl_v)

        lvi = lv.astype(jnp.int32)
        add_nxt = jnp.where(
            a_join,
            du + jnp.where(selfloop, 0, dv),
            jnp.where(a_split | b_new, du, jnp.where(c_new, dv, 0)),
        )
        add_nxt1 = jnp.where(a_split, dv, 0)
        delta_cv = jnp.where(b_fits, du, 0) + jnp.where(d_u, du, 0) - jnp.where(d_v, dv, 0)
        delta_cu = jnp.where(c_fits, dv, 0) + jnp.where(d_v, dv, 0) - jnp.where(d_u, du, 0)
        vols = (
            vols.at[jnp.where(lv, nxt, dummy_v)].add(lvi * add_nxt)
            .at[jnp.where(lv, nxt + 1, dummy_v)].add(lvi * add_nxt1)
            .at[jnp.where(lv & cv_ok, cv, dummy_v)].add(lvi * delta_cv)
            .at[jnp.where(lv & cu_ok, cu, dummy_v)].add(lvi * delta_cu)
        )
        nxt = nxt + lvi * jnp.where(
            a_join, 1, jnp.where(a_split, 2, jnp.where(b_new | c_new, 1, 0))
        )
        return (cl, vols, nxt), None

    (cl, vols, nxt), _ = jax.lax.scan(step, (cl, vols, nxt), (edges, live))
    return cl, vols, nxt


class VertexClusteringState:
    """Chunk-resumable phase-1 clustering (the `lax.scan` port of the numpy
    per-edge loop — ROADMAP open item (a)).

    Feed the stream through :meth:`update` in any chunking; the state after
    the final chunk equals the one-shot run exactly (integer carries, masked
    no-op padding steps). ``deg`` must be the *full-stream* degree table and
    ``num_edges`` the full stream length — both known up front in memory, and
    after one counting pass out-of-core.
    """

    def __init__(
        self,
        num_vertices: int,
        k: int,
        num_edges: int,
        deg: np.ndarray,
        *,
        cluster_slack: float = 1.25,
        chunk_edges: Optional[int] = None,
    ):
        self.num_vertices = num_vertices
        self.cap = _volume_cap(num_edges, k, cluster_slack)
        self._pad = max(int(chunk_edges or num_edges), 1)
        self._deg = jnp.asarray(np.asarray(deg), jnp.int32)
        self._cl = jnp.full((num_vertices + 1,), -1, jnp.int32)
        self._vols = jnp.zeros((num_vertices + 3,), jnp.int32)
        self._nxt = jnp.zeros((), jnp.int32)

    def update(self, edges: np.ndarray) -> None:
        c = len(edges)
        assert c <= self._pad, f"chunk of {c} rows > declared chunk_edges={self._pad}"
        if c == 0:
            return
        padded = np.zeros((self._pad, 2), np.int32)
        padded[:c] = edges
        live = np.zeros((self._pad,), bool)
        live[:c] = True
        self._cl, self._vols, self._nxt = _cluster_scan(
            self._cl, self._vols, self._nxt,
            jnp.asarray(padded), jnp.asarray(live), self._deg,
            jnp.int32(self.cap), num_vertices=self.num_vertices,
        )

    def finalize(self) -> tuple[np.ndarray, np.ndarray]:
        """(cluster_id int64[V] (-1 = never streamed), volumes float64[C])."""
        cl = np.asarray(self._cl)[: self.num_vertices].astype(np.int64)
        nxt = int(self._nxt)
        vols = np.asarray(self._vols)[:nxt].astype(np.float64)
        return cl, vols


def streaming_vertex_clustering(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    cluster_slack: float = 1.25,
) -> tuple[np.ndarray, np.ndarray]:
    """One streaming pass of volume-capped vertex clustering (2PS-L style),
    as a vectorized `lax.scan` (the numpy loop survives as
    :func:`streaming_vertex_clustering_np`, the parity oracle in tests).

    Cluster *volume* is the sum of member degrees; the cap
    ``cluster_slack * 2m / k`` keeps every cluster small enough to fit a
    partition. Rules per edge (u, v): unclustered endpoints join the other
    endpoint's cluster (or found a new one together) when the cap allows;
    when both are clustered apart, the endpoint in the lower-volume cluster
    moves to the other cluster if it fits (the 2PS-L local move).

    Returns (cluster_id int64[V] (-1 = never streamed), volumes float64[C]).
    """
    state = VertexClusteringState(
        num_vertices, k, len(edges), _degrees(edges, num_vertices),
        cluster_slack=cluster_slack,
    )
    state.update(np.asarray(edges, np.int32))
    return state.finalize()


def streaming_vertex_clustering_np(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    cluster_slack: float = 1.25,
) -> tuple[np.ndarray, np.ndarray]:
    """Reference numpy per-edge loop (parity oracle for the scan port)."""
    deg = _degrees(edges, num_vertices)
    m = len(edges)
    max_vol = max(cluster_slack * 2.0 * m / max(k, 1), 1.0)
    cl = np.full(num_vertices, -1, dtype=np.int64)
    vols: List[float] = []
    for i in range(m):
        u, v = int(edges[i, 0]), int(edges[i, 1])
        cu, cv = cl[u], cl[v]
        if cu < 0 and cv < 0:
            if u == v or deg[u] + deg[v] <= max_vol:
                cl[u] = cl[v] = len(vols)
                vols.append(float(deg[u] + (deg[v] if u != v else 0)))
            else:
                cl[u] = len(vols)
                vols.append(float(deg[u]))
                cl[v] = len(vols)
                vols.append(float(deg[v]))
        elif cu < 0:
            if vols[cv] + deg[u] <= max_vol:
                cl[u] = cv
                vols[cv] += float(deg[u])
            else:
                cl[u] = len(vols)
                vols.append(float(deg[u]))
        elif cv < 0:
            if vols[cu] + deg[v] <= max_vol:
                cl[v] = cu
                vols[cu] += float(deg[v])
            else:
                cl[v] = len(vols)
                vols.append(float(deg[v]))
        elif cu != cv:
            if vols[cu] <= vols[cv]:
                x, src, dst = u, cu, cv
            else:
                x, src, dst = v, cv, cu
            if vols[dst] + deg[x] <= max_vol:
                cl[x] = dst
                vols[src] -= float(deg[x])
                vols[dst] += float(deg[x])
    return cl, np.asarray(vols, dtype=np.float64)


def _pack_clusters(vols: np.ndarray, k: int) -> np.ndarray:
    """LPT greedy: int32[C] partition per cluster, heaviest cluster first."""
    part = np.zeros(len(vols), dtype=np.int32)
    loads = np.zeros(k, dtype=np.float64)
    for c in np.argsort(vols)[::-1]:
        p = int(np.argmin(loads))
        part[c] = p
        loads[p] += vols[c]
    return part


def _phase1_warm(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    allowed: Optional[np.ndarray],
    cluster_slack: float,
) -> tuple[WarmState, int]:
    """Phase 1 shared by 2PS and 2PS-L: volume-capped streaming clustering,
    LPT packing, and the virtual-replica :class:`WarmState` for phase 2.

    ``allowed`` restricts the instance to its spotlight partition set: the
    clustering volume cap divides by n_allowed (each instance balances its
    own sub-stream over its own partitions) and clusters are packed onto
    the allowed partition ids only. Returns ``(warm, n_clusters)``.
    """
    allowed_np = None if allowed is None else np.asarray(allowed, bool)
    n_allowed = k if allowed_np is None else max(int(allowed_np.sum()), 1)
    deg = _degrees(edges, num_vertices)
    state = VertexClusteringState(
        num_vertices, n_allowed, len(edges), deg, cluster_slack=cluster_slack
    )
    state.update(np.asarray(edges, np.int32))
    cl, vols = state.finalize()
    part_of_cluster = (
        _pack_clusters(vols, n_allowed) if len(vols) else np.zeros(0, np.int32)
    )
    if allowed_np is not None:
        part_of_cluster = np.flatnonzero(allowed_np).astype(np.int32)[
            part_of_cluster
        ]
    replicas = np.zeros((num_vertices, k), dtype=bool)
    clustered = np.flatnonzero(cl >= 0)
    if len(clustered):
        replicas[clustered, part_of_cluster[cl[clustered]]] = True
    warm = WarmState(
        replicas=replicas,
        deg=deg,
        sizes=np.zeros(k, dtype=np.int64),
        prev_assign=None,
    )
    return warm, int(len(vols))


def two_phase_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    cluster_slack: float = 1.25,
    seed: int = 0,
    n_chunks: int = 8,
    allowed: Optional[np.ndarray] = None,
    **adwise_cfg,
) -> PartitionResult:
    """2PS: streaming vertex clustering, then cluster-aware edge scoring.

    Phase 2 runs the ADWISE scan warm-started with virtual replicas — each
    clustered vertex starts replicated on its cluster's partition — so the
    shared Eq. 5 replication term *is* the cluster-affinity score, and λ·B
    plus the capacity cap keep the result balanced. ``allowed`` restricts
    both phases to a spotlight partition subset.
    """
    adwise_cfg.setdefault("window_max", 32)
    adwise_cfg.setdefault(
        "window_init", max(1, min(8, adwise_cfg["window_max"]))
    )
    cfg = AdwiseConfig(k=k, seed=seed, **adwise_cfg)
    t0 = time.perf_counter()
    warm, n_clusters = _phase1_warm(
        edges, num_vertices, k, allowed, cluster_slack
    )
    t_phase1 = time.perf_counter() - t0
    res = partition_stream(
        edges, num_vertices, cfg, n_chunks=n_chunks, warm=warm, allowed=allowed
    )
    stats = dict(
        res.stats,
        name="2ps",
        n_clusters=n_clusters,
        cluster_slack=cluster_slack,
        phase1_wall_s=t_phase1,
        # Clustering pass + scoring pass — two full stream reads, billed by
        # the latency model's IO term.
        stream_reads=2,
        wall_time_s=time.perf_counter() - t0,
        unassigned=metrics.unassigned_count(res.assign),
    )
    return PartitionResult(res.assign, stats)


# ----------------------------------------------------------------------------
# 2PS-L: linear-time phase 2 as its own step-core
# ----------------------------------------------------------------------------


class TpslCarry(NamedTuple):
    vp: jax.Array  # (V+1,) int32 — partition of each vertex's cluster, -1 none
    deg: jax.Array  # (V+1,) int32 — full-stream degrees (static in phase 2)
    sizes: jax.Array  # (K,) int32
    cursor: jax.Array  # () int32
    assigned: jax.Array  # () int32


class TpslState:
    """2PS-L phase 2 as a per-edge numpy loop (parity oracle for
    :class:`TpslCore`).

    Linear-time cluster-score placement: each edge is scored ONCE per
    partition — the HDRF degree-weighted replication term rewards the two
    endpoints' cluster partitions (``vp``), the quantized balance term and
    a hard capacity cap keep loads even. Partitions at the cap are masked
    *ineligible*, so when neither endpoint's cluster partition is open the
    argmax degenerates to least-loaded — the paper's fallback branch, free.
    Deterministic: no tie noise, first-occurrence argmax.
    """

    def __init__(
        self,
        num_vertices: int,
        k: int,
        vp: np.ndarray,
        deg: np.ndarray,
        *,
        lam: float = 1.1,
        eps: float = 1.0,
        cap: Optional[int] = None,
        allowed: Optional[np.ndarray] = None,
    ):
        self.k = k
        self.lam_q = _lam_q(lam)
        self.eps_q = _eps_q(eps)
        self.vp = np.asarray(vp, np.int64)
        self.deg = np.asarray(deg, np.int64)
        self.sizes = np.zeros(k, dtype=np.int64)
        self.cap = int(cap) if cap is not None else int(np.iinfo(np.int32).max)
        self.allowed = (
            np.ones(k, bool) if allowed is None else np.asarray(allowed, bool)
        )
        assert self.allowed.shape == (k,) and self.allowed.any()
        self.edges_seen = 0

    def assign_chunk(self, edges: np.ndarray) -> np.ndarray:
        k, lam_q, eps_q = self.k, self.lam_q, self.eps_q
        vp, deg, sizes, allowed = self.vp, self.deg, self.sizes, self.allowed
        aidx = np.flatnonzero(allowed)
        arange = np.arange(k)
        c = len(edges)
        assign = np.empty(c, dtype=np.int32)
        for i in range(c):
            u, v = int(edges[i, 0]), int(edges[i, 1])
            du = min(int(deg[u]), _DEG_CLAMP)
            dv = min(int(deg[v]), _DEG_CLAMP)
            a = max(du + dv, 1)
            tq_u = ((2 * a - du) * QB) // a
            tq_v = ((2 * a - dv) * QB) // a
            sal = sizes[aidx]
            mx, mn = int(sal.max()), int(sal.min())
            gap = np.clip(mx - sizes, 0, _DEG_CLAMP)
            bal_q = (gap * QB) // (eps_q + min(mx - mn, _DEG_CLAMP))
            rep_q = (arange == vp[u]) * tq_u + (arange == vp[v]) * tq_v
            score_q = QB * rep_q.astype(np.int64) + lam_q * bal_q
            eligible = allowed & (sizes < self.cap)
            combined = np.where(eligible, score_q, -1)
            p = int(np.argmax(combined))
            assign[i] = p
            sizes[p] += 1
        self.edges_seen += c
        return assign


@dataclasses.dataclass(frozen=True)
class TpslCore(StepCore):
    """2PS-L phase 2 as a chunk-resumable step-core: one edge per scan step.

    Bit-identical to :class:`TpslState`. Cold start is a contract error —
    phase 2 only makes sense resumed from the phase-1 WarmState (virtual
    replicas encode the cluster→partition table; ``warm_carry`` collapses
    them to the per-vertex ``vp``). The capacity cap
    ``ceil(cap_slack·m/n_allowed)+1`` guarantees an eligible partition
    always exists (pigeonhole), so the scan can never strand an edge.
    """

    num_vertices: int
    k: int
    lam: float = 1.1
    eps: float = 1.0
    cap_slack: float = 1.15

    name = "2ps-l"
    window_rows = 0
    rows_per_step = 1
    r_sel = 0
    has_budget = False

    def cap_value(self, m: int, n_allowed: int) -> int:
        return int(math.ceil(self.cap_slack * m / max(n_allowed, 1))) + 1

    def init_carry(self, budget: float) -> TpslCarry:
        raise ValueError(
            "2ps-l phase 2 always resumes from a phase-1 WarmState — "
            "run the clustering pass and pass warm="
        )

    def warm_carry(self, budget: float, warm: WarmState) -> TpslCarry:
        v = self.num_vertices
        rep = np.asarray(warm.replicas, bool)
        vp = np.full((v + 1,), -1, np.int32)
        vp[:v] = np.where(rep.any(axis=1), rep.argmax(axis=1), -1)
        deg = np.zeros((v + 1,), np.int32)
        deg[:v] = np.minimum(np.asarray(warm.deg), _DEG_CLAMP)
        return TpslCarry(
            vp=jnp.asarray(vp),
            deg=jnp.asarray(deg),
            sizes=jnp.asarray(warm.sizes, jnp.int32),
            cursor=jnp.zeros((), jnp.int32),
            assigned=jnp.zeros((), jnp.int32),
        )

    def counters(self, carry) -> dict:
        assigned = np.asarray(carry.assigned)
        z = assigned.shape[0]
        return dict(
            score_rows=assigned.astype(np.int64),
            final_w=np.ones((z,), np.int64),
            lam=np.full((z,), self.lam, np.float32),
            cost_per_score=np.zeros((z,), np.float32),
        )

    def make_step(self, stream, m_real, allowed, cap, prev_assign):
        k = self.k
        v_dummy = self.num_vertices
        m_pad = stream.shape[0]
        lam_q = jnp.int32(_lam_q(self.lam))
        eps_q = jnp.int32(_eps_q(self.eps))
        arange = jnp.arange(k, dtype=jnp.int32)

        def step(carry: TpslCarry, _):
            live = carry.cursor < m_real
            live_i = live.astype(jnp.int32)
            row = stream[carry.cursor % m_pad]
            u = jnp.where(live, row[0], v_dummy)
            v = jnp.where(live, row[1], v_dummy)
            du = jnp.minimum(carry.deg[u], _DEG_CLAMP)
            dv = jnp.minimum(carry.deg[v], _DEG_CLAMP)
            a = jnp.maximum(du + dv, 1)
            tq_u = ((2 * a - du) * QB) // a
            tq_v = ((2 * a - dv) * QB) // a
            sizes = carry.sizes
            sal = jnp.where(allowed, sizes, jnp.int32(np.iinfo(np.int32).max))
            mx = jnp.max(
                jnp.where(allowed, sizes, jnp.int32(np.iinfo(np.int32).min))
            )
            mn = jnp.min(sal)
            gap = jnp.clip(mx - sizes, 0, _DEG_CLAMP)
            bal_q = (gap * QB) // (eps_q + jnp.minimum(mx - mn, _DEG_CLAMP))
            rep_q = (
                (arange == carry.vp[u]) * tq_u + (arange == carry.vp[v]) * tq_v
            ).astype(jnp.int32)
            score_q = QB * rep_q + lam_q * bal_q
            eligible = allowed & (sizes < cap)
            combined = jnp.where(eligible, score_q, -1)
            p = jnp.argmax(combined).astype(jnp.int32)
            new_carry = TpslCarry(
                vp=carry.vp,
                deg=carry.deg,
                sizes=sizes.at[p].add(live_i),
                cursor=carry.cursor + live_i,
                assigned=carry.assigned + live_i,
            )
            return new_carry, _single_edge_out(live, carry.cursor, p)

        return step


def two_phase_linear_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    cluster_slack: float = 1.25,
    lam: float = 1.1,
    eps: float = 1.0,
    cap_slack: float = 1.15,
    seed: int = 0,
    allowed: Optional[np.ndarray] = None,
    scan: bool = True,
    backend: str = "vmap",
    n_chunks: int = 8,
) -> PartitionResult:
    """2PS-L: streaming clustering, then the linear-time scoring pass.

    ``scan=True`` (default) runs phase 2 as the :class:`TpslCore` lax.scan
    through the shared ScanDriver; ``scan=False`` runs the
    :class:`TpslState` numpy oracle — bit-identical by construction (the
    benchmarks report both walls). ``seed`` is accepted for registry
    uniformity; 2PS-L is deterministic (no tie noise).
    """
    m = len(edges)
    if m == 0:
        return PartitionResult(
            np.zeros((0,), np.int32),
            dict(k=k, name="2ps-l", n_clusters=0, stream_reads=2,
                 wall_time_s=0.0, unassigned=0),
        )
    t0 = time.perf_counter()
    warm, n_clusters = _phase1_warm(
        edges, num_vertices, k, allowed, cluster_slack
    )
    t_phase1 = time.perf_counter() - t0
    core = TpslCore(
        num_vertices=int(num_vertices), k=int(k), lam=float(lam),
        eps=float(eps), cap_slack=float(cap_slack),
    )
    if scan:
        res = _scan_partition(
            core, edges, allowed=allowed, warm=warm, backend=backend,
            n_chunks=n_chunks,
        )
        assign, stats = res.assign, dict(res.stats)
    else:
        n_allowed = (
            k if allowed is None else max(int(np.asarray(allowed, bool).sum()), 1)
        )
        rep = warm.replicas
        vp = np.where(rep.any(axis=1), rep.argmax(axis=1), -1)
        state = TpslState(
            num_vertices, k, vp, warm.deg, lam=lam, eps=eps,
            cap=core.cap_value(m, n_allowed), allowed=allowed,
        )
        assign = state.assign_chunk(np.asarray(edges))
        stats = dict(score_rows=m, score_count=m * k)
    stats.update(
        k=k,
        name="2ps-l",
        n_clusters=n_clusters,
        cluster_slack=cluster_slack,
        phase1_wall_s=t_phase1,
        # Clustering pass + scoring pass, same IO billing as 2ps.
        stream_reads=2,
        wall_time_s=time.perf_counter() - t0,
        unassigned=int((np.asarray(assign) < 0).sum()),
    )
    return PartitionResult(np.asarray(assign, np.int32), stats)


def two_phase_partition_batched(
    streams: np.ndarray,
    valid: np.ndarray,
    num_vertices: int,
    k: int,
    *,
    variant: str = "2ps",
    allowed: Optional[np.ndarray] = None,
    cluster_slack: float = 1.25,
    seed: int = 0,
    n_chunks: int = 8,
    backend: str = "auto",
    lam: float = 1.1,
    eps: float = 1.0,
    cap_slack: float = 1.15,
    **adwise_cfg,
) -> List[PartitionResult]:
    """2PS / 2PS-L over ``z`` batched spotlight instances.

    Phase 1 runs per instance on the host (each instance clusters its own
    sub-stream against its own ``allowed`` partition budget); phase 2 runs
    ALL z instances as one batched scan — the ADWISE scan for
    ``variant='2ps'`` (``adwise_cfg`` keys apply, window_max defaults to
    32) or the :class:`TpslCore` step-core for ``variant='2ps-l'`` (which
    takes ``lam``/``eps``/``cap_slack`` instead). Bit-identical per
    instance to the sequential :func:`two_phase_partition` /
    :func:`two_phase_linear_partition` calls.
    """
    if variant not in ("2ps", "2ps-l"):
        raise ValueError(f"unknown two-phase variant {variant!r}")
    z = int(streams.shape[0])
    valid = np.asarray(valid, bool)
    m_per = valid.sum(axis=1).astype(np.int64)
    t0 = time.perf_counter()
    warms, n_clusters = [], []
    for i in range(z):
        a_i = None if allowed is None else np.asarray(allowed[i], bool)
        w, nc = _phase1_warm(
            streams[i, : m_per[i]], num_vertices, k, a_i, cluster_slack
        )
        warms.append(w)
        n_clusters.append(nc)
    t_phase1 = time.perf_counter() - t0
    if variant == "2ps":
        adwise_cfg.setdefault("window_max", 32)
        adwise_cfg.setdefault(
            "window_init", max(1, min(8, adwise_cfg["window_max"]))
        )
        cfg = AdwiseConfig(k=k, seed=seed, **adwise_cfg)
        results = partition_stream_batched(
            streams, valid, num_vertices, cfg, allowed=allowed, warm=warms,
            backend=backend, n_chunks=n_chunks,
        )
    else:
        if adwise_cfg:
            raise TypeError(
                f"2ps-l: unknown config keys {sorted(adwise_cfg)}"
            )
        core = TpslCore(
            num_vertices=int(num_vertices), k=int(k), lam=float(lam),
            eps=float(eps), cap_slack=float(cap_slack),
        )
        results = partition_stream_batched(
            streams, valid, num_vertices, None, core=core, allowed=allowed,
            warm=warms, backend=backend, n_chunks=n_chunks,
        )
    wall = time.perf_counter() - t0
    out = []
    for i, res in enumerate(results):
        stats = dict(
            res.stats,
            name=variant,
            n_clusters=n_clusters[i],
            cluster_slack=cluster_slack,
            phase1_wall_s=t_phase1,
            stream_reads=2,
            # Phase 2 ran as one batched program; the shared wall covers
            # every instance (parallel loading model).
            wall_time_s=wall,
            unassigned=metrics.unassigned_count(res.assign),
        )
        out.append(PartitionResult(res.assign, stats))
    return out


# ----------------------------------------------------------------------------
# Registry entries
# ----------------------------------------------------------------------------

_ADWISE_FIELDS = {f.name for f in dataclasses.fields(AdwiseConfig)} - {"k", "seed"}


def _check_cfg(name: str, cfg: dict, extra: frozenset) -> None:
    unknown = set(cfg) - _ADWISE_FIELDS - set(extra)
    if unknown:
        raise TypeError(f"{name}: unknown config keys {sorted(unknown)}")


@registry.register("adwise-restream")
def _adwise_restream(
    edges, num_vertices, k, seed=0, *, passes=2, base="adwise",
    keep_best=True, eps=None, allowed=None, **cfg,
) -> PartitionResult:
    """n-pass restreamed ADWISE. cfg keys = AdwiseConfig fields plus
    ``passes=`` / ``base=`` / ``keep_best=`` / ``eps=`` (early-stop on RD
    improvement; stats report ``passes_run``) / ``allowed=`` (spotlight
    partition mask) / ``n_chunks=`` (see restream_partition)."""
    _check_cfg("adwise-restream", cfg, frozenset({"n_chunks"}))
    return restream_partition(
        edges, num_vertices, k, passes=passes, base=base,
        keep_best=keep_best, eps=eps, seed=seed, allowed=allowed, **cfg,
    )


@registry.register("2ps")
def _two_ps(
    edges, num_vertices, k, seed=0, *, cluster_slack=1.25, allowed=None, **cfg
) -> PartitionResult:
    """2PS two-phase partitioner. cfg keys = AdwiseConfig fields (phase 2;
    window_max defaults to 32) plus ``cluster_slack=`` (phase-1 volume cap),
    ``allowed=`` (spotlight partition mask), and ``n_chunks=``."""
    _check_cfg("2ps", cfg, frozenset({"n_chunks"}))
    return two_phase_partition(
        edges, num_vertices, k, cluster_slack=cluster_slack, seed=seed,
        allowed=allowed, **cfg,
    )


@registry.register("2ps-l")
def _two_ps_l(
    edges, num_vertices, k, seed=0, *, cluster_slack=1.25, lam=1.1, eps=1.0,
    cap_slack=1.15, allowed=None, scan=True, backend="vmap", n_chunks=8,
) -> PartitionResult:
    """2PS-L linear-run-time two-phase partitioner (arXiv:2203.12721).
    Shares phase 1 with 2ps; phase 2 is the single-score cluster-affinity
    pass (no window, no tie noise). cfg keys: ``cluster_slack=`` (phase-1
    volume cap), ``lam=``/``eps=`` (balance weighting), ``cap_slack=``
    (hard capacity), ``allowed=`` (spotlight partition mask), ``scan=``
    (False runs the numpy parity oracle), ``backend=``, ``n_chunks=``."""
    return two_phase_linear_partition(
        edges, num_vertices, k, cluster_slack=cluster_slack, lam=lam,
        eps=eps, cap_slack=cap_slack, seed=seed, allowed=allowed,
        scan=scan, backend=backend, n_chunks=n_chunks,
    )
