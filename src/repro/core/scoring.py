"""Vectorized ADWISE scoring (Eq. 3-7) in pure jnp.

These functions are shared by the lax.scan partitioner (`core/adwise.py`),
the Pallas kernel oracle (`kernels/ref.py`) and the tests. Shapes:

  W = window capacity (static), K = number of partitions (static).

All scores are computed for the whole (W, K) grid; masking decides validity.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "balance_score",
    "replication_score",
    "clustering_terms",
    "window_scores",
    "lambda_update",
]

NEG_INF = -1e30


def balance_score(sizes: jax.Array, allowed: jax.Array, eps: float) -> jax.Array:
    """Eq. 3: B(p) = (maxsize - |p|) / (maxsize - minsize + eps), masked to allowed."""
    mx = jnp.max(jnp.where(allowed, sizes, jnp.iinfo(jnp.int32).min))
    mn = jnp.min(jnp.where(allowed, sizes, jnp.iinfo(jnp.int32).max))
    return (mx - sizes).astype(jnp.float32) / (mx - mn + eps).astype(jnp.float32)


def replication_score(
    rep_u: jax.Array,  # (W, K) bool — replicas of u_i
    rep_v: jax.Array,  # (W, K) bool
    deg_u: jax.Array,  # (W,) int32 partial degrees
    deg_v: jax.Array,  # (W,)
    max_deg: jax.Array,  # () int32
) -> jax.Array:
    """Eq. 5 with the *absolute* degree normalisation Ψ_x = deg(x)/(2·maxDeg)."""
    denom = 2.0 * jnp.maximum(max_deg, 1).astype(jnp.float32)
    psi_u = deg_u.astype(jnp.float32) / denom
    psi_v = deg_v.astype(jnp.float32) / denom
    return rep_u * (2.0 - psi_u)[:, None] + rep_v * (2.0 - psi_v)[:, None]


def clustering_terms(
    win_uv: jax.Array,  # (W, 2) int32
    win_valid: jax.Array,  # (W,) bool
    rep_u: jax.Array,  # (W, K) f32/bool — replicas of u_j rows
    rep_v: jax.Array,  # (W, K)
) -> tuple[jax.Array, jax.Array]:
    """Window-local clustering score CS (Eq. 6), multiset semantics.

    For window slots i, j: edge j contributes its endpoint v_j to N(u_i)∪N(v_i)
    iff u_j ∈ {u_i, v_i} (and symmetrically u_j if v_j matches). Returns
    (numerator (W,K), denominator (W,)).

    The O(W²) match matrices become two (W,W)x(W,K) matmuls — MXU food. This
    is the computation the `window_score` Pallas kernel fuses.
    """
    u = win_uv[:, 0]
    v = win_uv[:, 1]
    vj = win_valid[None, :]
    noti = ~jnp.eye(u.shape[0], dtype=bool)
    # A[i, j]: u_j matches an endpoint of edge i  -> neighbour is v_j.
    a = (u[None, :] == u[:, None]) | (u[None, :] == v[:, None])
    # B[i, j]: v_j matches an endpoint of edge i  -> neighbour is u_j.
    b = (v[None, :] == u[:, None]) | (v[None, :] == v[:, None])
    a = (a & vj & noti).astype(jnp.float32)
    b = (b & vj & noti).astype(jnp.float32)
    num = a @ rep_v.astype(jnp.float32) + b @ rep_u.astype(jnp.float32)
    den = jnp.sum(a, axis=1) + jnp.sum(b, axis=1)
    return num, den


@partial(jax.jit, static_argnames=("use_cs", "eps"))
def window_scores(
    win_uv: jax.Array,  # (W, 2)
    win_valid: jax.Array,  # (W,)
    rep_u: jax.Array,  # (W, K) bool
    rep_v: jax.Array,  # (W, K) bool
    deg_u: jax.Array,  # (W,)
    deg_v: jax.Array,  # (W,)
    max_deg: jax.Array,  # ()
    sizes: jax.Array,  # (K,)
    allowed: jax.Array,  # (K,) bool (spotlight spread / capacity mask)
    lam: jax.Array,  # ()
    *,
    use_cs: bool = True,
    eps: float = 0.01,
) -> jax.Array:
    """Full g(e,p) = λ·B(p) + R(e,p) + CS(e,p) (Eq. 7), (W, K), masked with -inf."""
    bal = balance_score(sizes, allowed, eps)
    g = lam * bal[None, :] + replication_score(rep_u, rep_v, deg_u, deg_v, max_deg)
    if use_cs:
        num, den = clustering_terms(win_uv, win_valid, rep_u, rep_v)
        g = g + num / jnp.maximum(den, 1.0)[:, None]
    g = jnp.where(win_valid[:, None], g, NEG_INF)
    g = jnp.where(allowed[None, :], g, NEG_INF)
    return g


def lambda_update(
    lam: jax.Array,
    sizes: jax.Array,
    allowed: jax.Array,
    assigned: jax.Array,
    m_total: jax.Array,
    lo: float,
    hi: float,
) -> jax.Array:
    """Adaptive balance weight (Eq. 4): λ += (ι − tolerance(α)), clipped.

    ι = (maxsize − minsize)/maxsize over allowed partitions,
    tolerance(α) = max(0, 1 − α), α = assigned/m.
    """
    mx = jnp.max(jnp.where(allowed, sizes, 0)).astype(jnp.float32)
    mn = jnp.min(jnp.where(allowed, sizes, jnp.iinfo(jnp.int32).max)).astype(jnp.float32)
    iota = jnp.where(mx > 0, (mx - mn) / jnp.maximum(mx, 1.0), 0.0)
    alpha = assigned.astype(jnp.float32) / jnp.maximum(m_total.astype(jnp.float32), 1.0)
    tol = jnp.maximum(0.0, 1.0 - alpha)
    return jnp.clip(lam + (iota - tol), lo, hi)
