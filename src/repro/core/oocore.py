"""Out-of-core partitioning driver: any registry strategy over a file reader.

`partition_file` runs a registry strategy — adwise / adwise-restream / 2ps /
hdrf / dbh / greedy / hash / grid, with or without a z>1 spotlight spread —
over an :class:`repro.graph.io.format.EdgeFileReader` while keeping resident
*edge* memory bounded by the chunk size. Assignments are written to a spill
memmap as they are produced; multi-pass re-streaming re-reads the stream from
disk each pass and reads the prior pass's placements back from its spill
(never holding a resident edge array). Output is **bit-identical** to the
in-memory path for every strategy:

* Every scan-core strategy — ADWISE, HDRF, Greedy, and 2PS(-L) phase 2 —
  runs through ONE code path: :class:`repro.core.driver.ScanDriver` over a
  :class:`repro.core.driver.FileSource` — a **device-resident ring buffer**:
  logical stream row ``s`` lives in ring slot ``s % B`` on device, each
  refill ships only the new tail rows (`jax.lax.dynamic_update_slice` into
  the donated buffer), and the scan step is the very same trace the
  in-memory path runs (``s % m`` is the identity there). Per scan call of
  ``S`` steps the cursor advances at most
  ``window_rows + S * rows_per_step`` rows (ADWISE:
  ``window_max + S * assign_batch``; the single-edge cores ``0 + S``),
  which bounds the refill — host→device traffic is O(refill) per call, not
  O(B), and is reported as ``h2d_rows`` / ``h2d_bytes`` in stats (billed by
  the latency model).
* The z>1 spotlight path batches per-instance ring buffers over
  per-instance sub-readers (`EdgeFileReader.split` — the same ceil(m/z)
  ``split_bounds`` byte ranges `EdgeStream` uses) through the same driver:
  every instance runs at GLOBAL k restricted by its ``allowed`` spread
  mask, exactly mirroring `spotlight_partition`'s batched backend (HDRF
  instances derive their tie-noise streams from ``seed + i`` inside the
  batched carry). Only the stateless hashes (hash/dbh) run a per-instance
  chunked loop — the same vectorized assignment either way.
* DBH takes a chunked degree pass then a chunked placement pass; Hash /
  Grid are stateless. The chunk-resumable numpy states
  (`repro.core.baselines.HdrfState` / ``GreedyState``) survive as the
  base-pass path for non-adwise re-streaming.
* 2PS / 2PS-L take a chunked degree pass, stream phase 1 through the
  chunk-resumable `lax.scan` clustering
  (:class:`repro.core.restream.VertexClusteringState`), and run phase 2
  through the warm-started ring scan (the ADWISE scan for 2ps, the
  :class:`repro.core.restream.TpslCore` step-core for 2ps-l).

Stats report the *measured* IO: ``io_wall_s`` (seconds inside ``read``),
``rows_read`` and ``stream_reads`` (measured full passes over the stream),
so `repro.engine.latency_model.partition_latency` bills real IO instead of
an assumed single pass.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core import baselines
from repro.core.adwise import WarmState
from repro.core.driver import FileSource, RingHandle, ScanDriver
from repro.core.restream import TpslCore, VertexClusteringState, _pack_clusters
from repro.core.spotlight import _SPOTLIGHT_INCOMPATIBLE, spread_mask
from repro.core.types import AdwiseConfig, PartitionResult
from repro.graph import metrics
from repro.graph.stream import EdgeStream
from repro.obs import resolve_tracer

__all__ = ["partition_file"]

_ADWISE_FIELDS = {f.name for f in dataclasses.fields(AdwiseConfig)} - {"k", "seed"}


# ----------------------------------------------------------------------------
# Assignment spill (disk-backed int32[m], -1 = unassigned)
# ----------------------------------------------------------------------------


class _Spill:
    """int32[m] assignment spill memmap; resident set is page cache, not heap."""

    def __init__(self, path: str, m: int):
        self.path = path
        self.m = m
        self._map = np.memmap(path, dtype=np.int32, mode="w+", shape=(max(m, 1),))
        self._map[:] = -1

    def write(self, idx: np.ndarray, vals: np.ndarray) -> None:
        self._map[idx] = vals

    def write_range(self, start: int, vals: np.ndarray) -> None:
        self._map[start : start + len(vals)] = vals

    def read(self, start: int, count: int) -> np.ndarray:
        return np.asarray(self._map[start : start + count])

    def flush_readonly(self) -> np.memmap:
        self._map.flush()
        return np.memmap(self.path, dtype=np.int32, mode="r", shape=(max(self.m, 1),))[
            : self.m
        ]

    def remove(self) -> None:
        """Drop the mapping and delete the backing file (dead pass spills)."""
        self._map = None
        try:
            os.remove(self.path)
        except OSError:
            pass


# ----------------------------------------------------------------------------
# Chunked accumulation helpers (vertex-sized state, O(chunk) edge memory)
# ----------------------------------------------------------------------------


def _chunked_degrees(reader, num_vertices: int, chunk_edges: int) -> np.ndarray:
    deg = np.zeros(num_vertices, dtype=np.int64)
    for chunk in reader.chunks(chunk_edges):
        deg += np.bincount(chunk[:, 0], minlength=num_vertices)
        deg += np.bincount(chunk[:, 1], minlength=num_vertices)
    return deg


def _pairs(reader, spill: _Spill, offset: int, chunk_edges: int):
    """Yield (edges_chunk, assign_chunk) over a sub-reader + its spill range."""
    start = 0
    for chunk in reader.chunks(chunk_edges):
        yield chunk, spill.read(offset + start, len(chunk))
        start += len(chunk)


class _PassMetrics:
    """Replica table + sizes + quality of one completed pass, accumulated in
    a SINGLE chunked read of (stream, spill) — the table feeds both the pass
    quality stats and the next pass's warm start, so re-streaming pays one
    metric read per pass, not two (`warm_from_assignment` parity: the spill
    is complete, so drop/raise policies coincide)."""

    def __init__(self, reader, spill: _Spill, offset: int, num_vertices: int,
                 k: int, chunk_edges: int):
        q = metrics.quality_from_chunks(
            _pairs(reader, spill, offset, chunk_edges), num_vertices, k
        )
        self.rep = q["replicas"]
        self.sizes = q["sizes"]
        self.rd = q["replication_degree"]
        self.imbalance = q["imbalance"]

    def warm(self, deg: np.ndarray) -> WarmState:
        return WarmState(replicas=self.rep, deg=deg, sizes=self.sizes,
                         prev_assign=None)


# ----------------------------------------------------------------------------
# The ring-buffer scan driver (z >= 1 batched, warm-chunk path, any core)
# ----------------------------------------------------------------------------


def _drive_core(
    readers: Sequence,
    num_vertices: int,
    core,  # a StepCore, or an AdwiseConfig (wrapped by the driver)
    *,
    write_assign: Callable[[int, np.ndarray, np.ndarray], None],
    chunk_edges: int,
    allowed: Optional[np.ndarray] = None,  # (z, k) bool
    warm: Optional[List[WarmState]] = None,
    prev_read: Optional[List[Callable[[int, int], np.ndarray]]] = None,
    backend: str = "auto",
    prefetch: Optional[int] = None,
    resume: Optional[RingHandle] = None,
    trace=None,
) -> tuple[List[dict], Optional[RingHandle]]:
    """Feed z instance streams through any step-core's scan in a bounded
    device-resident ring buffer — a thin caller of
    :class:`repro.core.driver.ScanDriver` over a
    :class:`~repro.core.driver.FileSource`.

    ``readers[i]`` is instance i's (locally addressed) stream;
    ``write_assign(i, local_idx, p)`` receives finished placements.
    ``prev_read[i](start, count)`` supplies the prior pass's placements for
    buffered re-streaming revocation; ``resume`` adopts the previous pass's
    ring under the cross-pass shared-buffer contract. Returns per-instance
    stats dicts plus this pass's :class:`RingHandle` for the next one.
    """
    z = len(readers)
    m_per = np.array([r.num_edges for r in readers], dtype=np.int64)
    m_max = int(m_per.max()) if z else 0
    if m_max == 0:
        return [dict(k=core.k, score_rows=0, assigned=0, unassigned=0)
                for _ in range(z)], None

    is_cfg = isinstance(core, AdwiseConfig)
    source = FileSource(
        readers, chunk_edges=chunk_edges,
        cfg=core if is_cfg else None, core=None if is_cfg else core,
        prev_read=prev_read, prefetch=prefetch, resume=resume, trace=trace,
    )
    drv = ScanDriver(source, core, num_vertices, allowed=allowed, warm=warm,
                     backend=backend, trace=trace)
    res = drv.run(on_assign=write_assign)
    stats = []
    for i in range(z):
        assert int(res.assigned[i]) == int(m_per[i]), (
            f"instance {i}: {int(res.assigned[i])} of {int(m_per[i])} assigned"
        )
        stats.append(
            dict(
                drv.stats_base(res, i),
                batched=True,
                backend=res.backend,
                n_shards=res.n_shards,
                z=z,
                instance=i,
                unassigned=0,
            )
        )
    return stats, drv.ring_handle


# ----------------------------------------------------------------------------
# Chunk-resumable baselines / 2PS over a (sub-)reader
# ----------------------------------------------------------------------------


def _run_baseline_chunks(
    strategy: str,
    reader,
    num_vertices: int,
    k: int,
    seed: int,
    chunk_edges: int,
    write_range: Callable[[int, np.ndarray], None],
    trace=None,
    **cfg,
) -> dict:
    """Stream a single-edge baseline over reader chunks (state resumes)."""
    allowed_cfg = {"hdrf": {"lam", "eps"}}.get(strategy, set())
    unknown = set(cfg) - allowed_cfg
    if unknown:
        raise TypeError(f"{strategy}: unknown config keys {sorted(unknown)}")
    m = reader.num_edges
    t0 = time.perf_counter()
    reads = 1
    if strategy == "hash":
        off = 0
        for chunk in reader.chunks(chunk_edges):
            write_range(off, baselines.hash_assign(chunk, num_vertices, k, seed=seed))
            off += len(chunk)
        stats = dict(name="hash")
    elif strategy == "grid":
        off = 0
        for chunk in reader.chunks(chunk_edges):
            write_range(off, baselines.grid_assign(chunk, k, seed=seed))
            off += len(chunk)
        stats = dict(name="grid")
    elif strategy == "dbh":
        deg = _chunked_degrees(reader, num_vertices, chunk_edges)
        off = 0
        for chunk in reader.chunks(chunk_edges):
            write_range(off, baselines.dbh_assign(chunk, deg, k, seed=seed))
            off += len(chunk)
        reads = 2
        stats = dict(name="dbh")
    elif strategy == "hdrf":
        state = baselines.HdrfState(num_vertices, k, seed=seed, **cfg)
        off = 0
        for chunk in reader.chunks(chunk_edges):
            write_range(off, state.assign_chunk(chunk))
            off += len(chunk)
        stats = dict(name="hdrf", score_count=m * k)
    elif strategy == "greedy":
        state = baselines.GreedyState(num_vertices, k)
        off = 0
        for chunk in reader.chunks(chunk_edges):
            write_range(off, state.assign_chunk(chunk))
            off += len(chunk)
        stats = dict(name="greedy")
    else:
        raise KeyError(f"no chunk-resumable core for strategy {strategy!r}")
    stats.update(k=k, wall_time_s=time.perf_counter() - t0, stream_reads=reads)
    tr = resolve_tracer(trace)
    if tr.enabled:
        tr.add_span(
            f"baseline:{strategy}", "phase", t0, time.perf_counter(),
            attrs=dict(strategy=strategy, k=k, stream_reads=reads),
        )
    return stats


def _run_two_phase_chunks(
    readers: Sequence,
    num_vertices: int,
    k: int,
    seed: int,
    chunk_edges: int,
    write_assign: Callable[[int, np.ndarray, np.ndarray], None],
    *,
    variant: str = "2ps",
    allowed: Optional[np.ndarray] = None,  # (z, k) bool
    backend: str = "auto",
    prefetch: Optional[int] = None,
    cluster_slack: float = 1.25,
    trace=None,
    **cfg,
) -> List[dict]:
    """2PS / 2PS-L over z per-instance readers: chunked degree pass →
    chunk-resumable `lax.scan` clustering → LPT packing onto each
    instance's allowed partitions → warm-started ring-buffer phase 2 (the
    ADWISE scan for 2ps, the :class:`TpslCore` step-core for 2ps-l). The
    per-instance phase 1 is bit-identical to
    :func:`repro.core.restream._phase1_warm` on the resident sub-stream."""
    z = len(readers)
    tr = resolve_tracer(trace)
    t0 = time.perf_counter()
    warms, n_clusters = [], []
    for i in range(z):
        a_i = None if allowed is None else np.asarray(allowed[i], bool)
        n_allowed = k if a_i is None else max(int(a_i.sum()), 1)
        with tr.span("degree-pass", cat="phase", instance=i):
            deg = _chunked_degrees(readers[i], num_vertices, chunk_edges)
        state = VertexClusteringState(
            num_vertices, n_allowed, readers[i].num_edges, deg,
            cluster_slack=cluster_slack, chunk_edges=chunk_edges,
        )
        with tr.span("clustering", cat="phase", instance=i):
            for chunk in readers[i].chunks(chunk_edges):
                state.update(chunk)
            cl, vols = state.finalize()
        part = (
            _pack_clusters(vols, n_allowed) if len(vols)
            else np.zeros(0, np.int32)
        )
        if a_i is not None:
            part = np.flatnonzero(a_i).astype(np.int32)[part]
        replicas = np.zeros((num_vertices, k), dtype=bool)
        clustered = np.flatnonzero(cl >= 0)
        if len(clustered):
            replicas[clustered, part[cl[clustered]]] = True
        warms.append(WarmState(
            replicas=replicas, deg=deg, sizes=np.zeros(k, dtype=np.int64),
            prev_assign=None,
        ))
        n_clusters.append(int(len(vols)))
    t_phase1 = time.perf_counter() - t0
    if tr.enabled:
        # Same endpoints that define phase1_wall_s in the returned stats.
        tr.add_span(
            "phase1", "phase", t0, t0 + t_phase1,
            attrs=dict(variant=variant, z=z, n_clusters=sum(n_clusters)),
        )

    if variant == "2ps":
        cfg.setdefault("window_max", 32)
        cfg.setdefault("window_init", max(1, min(8, cfg["window_max"])))
        core = AdwiseConfig(k=k, seed=seed, **cfg)
    else:
        core = TpslCore(
            num_vertices=int(num_vertices), k=int(k),
            lam=float(cfg.pop("lam", 1.1)), eps=float(cfg.pop("eps", 1.0)),
            cap_slack=float(cfg.pop("cap_slack", 1.15)),
        )
        assert not cfg, cfg  # partition_file validated the keys
    with tr.span("phase2", cat="phase", variant=variant):
        per_stats, _ = _drive_core(
            readers, num_vertices, core, write_assign=write_assign,
            chunk_edges=chunk_edges, allowed=allowed, warm=warms,
            backend=backend, prefetch=prefetch, trace=trace,
        )
    wall = time.perf_counter() - t0
    return [
        dict(
            st,
            name=variant,
            n_clusters=n_clusters[i],
            cluster_slack=cluster_slack,
            phase1_wall_s=t_phase1,
            # Degree pass + clustering pass + scoring pass: three measured
            # reads of the file (the in-memory path folds degree counting
            # into its resident array and bills 2).
            stream_reads=3,
            wall_time_s=wall,
        )
        for i, st in enumerate(per_stats)
    ]


# ----------------------------------------------------------------------------
# Multi-pass re-streaming from disk
# ----------------------------------------------------------------------------


def _run_restream_chunks(
    readers: Sequence,
    num_vertices: int,
    k: int,
    seed: int,
    chunk_edges: int,
    spill_dir: str,
    m_total: int,
    offsets: np.ndarray,  # (z,) global start row per instance
    final_spill: _Spill,
    *,
    allowed: Optional[np.ndarray] = None,
    passes: int = 2,
    base: str = "adwise",
    keep_best: bool = True,
    eps: Optional[float] = None,
    backend: str = "auto",
    prefetch: Optional[int] = None,
    trace=None,
    **adwise_cfg,
) -> dict:
    """n-pass re-streaming where every pass re-reads the stream from disk and
    the prior pass's placements from its spill (WarmState.prev_assign becomes
    a spill-backed range read instead of a resident array). Consecutive
    passes share the device ring through the driver's :class:`RingHandle`:
    when the geometry lets a stream sit in the ring without wrapping, pass
    j+1 ships only the 4 B/row prev placements."""
    if passes < 1:
        raise ValueError(f"passes must be >= 1, got {passes}")
    z = len(readers)
    tr = resolve_tracer(trace)
    cfg = AdwiseConfig(k=k, seed=seed, **adwise_cfg)
    m_per = np.array([r.num_edges for r in readers], dtype=np.int64)
    spills: List[_Spill] = []

    def new_spill(j: int) -> _Spill:
        s = _Spill(os.path.join(spill_dir, f"restream.pass{j}.i32"), m_total)
        spills.append(s)
        return s

    t0 = time.perf_counter()
    spill = new_spill(0)
    handle: Optional[RingHandle] = None
    if base == "adwise":
        pass_stats, handle = _drive_core(
            readers, num_vertices, cfg,
            write_assign=(
                lambda sp: lambda i, idx, p: sp.write(offsets[i] + idx, p)
            )(spill),
            chunk_edges=chunk_edges, allowed=allowed, backend=backend,
            prefetch=prefetch, trace=trace,
        )
    else:
        if z > 1:
            raise ValueError(
                "file-driven restream only batches base='adwise' under a "
                f"z>1 spotlight (got base={base!r}); run z=1 or base='adwise'"
            )
        st = _run_baseline_chunks(
            base, readers[0], num_vertices, k, seed, chunk_edges,
            lambda off, a: spill.write_range(int(offsets[0]) + off, a),
            trace=trace,
        )
        pass_stats = [st]

    def metrics_of(j_spill: _Spill) -> List[_PassMetrics]:
        # One fused read per instance: quality stats AND the next pass's
        # warm tables come out of the same chunked accumulation.
        with tr.span("metrics", cat="phase", z=z):
            return [
                _PassMetrics(readers[i], j_spill, int(offsets[i]),
                             num_vertices, k, chunk_edges)
                for i in range(z)
            ]

    def score_rows_of(stats_list) -> List[int]:
        return [
            int(s.get("score_rows", s.get("score_count", 0) // max(k, 1)))
            for s in stats_list
        ]

    def h2d_of(stats_list) -> tuple[int, int, int]:
        # The driver's h2d counters are run-level (shared by every
        # instance); pass-level totals accumulate over passes.
        s0 = stats_list[0] if stats_list else {}
        return (s0.get("h2d_rows", 0), s0.get("h2d_bytes", 0),
                s0.get("scan_calls", 0))

    def pipeline_of(stats_list) -> tuple[float, int, int, int, float]:
        s0 = stats_list[0] if stats_list else {}
        return (s0.get("h2d_wait_s", 0.0), s0.get("refill_spans", 0),
                s0.get("spans_prestaged", 0), s0.get("spans_missed", 0),
                s0.get("prestage_wall_s", 0.0))

    pm = metrics_of(spill)
    if tr.enabled:
        tr.add_span(
            "pass-1", "pass", t0, time.perf_counter(),
            track="restream-pass-1", attrs=dict(base=base, rd=pm[0].rd),
        )
    pass_rd = [[pm[i].rd] for i in range(z)]
    pass_imbalance = [[pm[i].imbalance] for i in range(z)]
    pass_score_rows = [[s] for s in score_rows_of(pass_stats)]
    h2d_rows, h2d_bytes, scan_calls = h2d_of(pass_stats)
    (h2d_wait_s, refill_spans, spans_prestaged, spans_missed,
     prestage_wall_s) = pipeline_of(pass_stats)
    prefetch_depth = pass_stats[0].get("prefetch_depth", 0)
    buffer_rows = pass_stats[0].get("buffer_rows", 0)
    best_spill = [spill] * z
    best_rd = [pass_rd[i][0] for i in range(z)]
    best_pass = [1] * z
    prev = spill

    # The degree tables are pass-invariant: one counting read per instance,
    # reused by every warm start (no re-reads inside the pass loop).
    if passes > 1:
        with tr.span("degree-pass", cat="phase", z=z):
            degs = [
                _chunked_degrees(readers[i], num_vertices, chunk_edges)
                for i in range(z)
            ]
    else:
        degs = []
    for j in range(1, passes):
        t_pass = time.perf_counter()
        warms = [pm[i].warm(degs[i]) for i in range(z)]
        prev_read = [
            (lambda pv, off: lambda start, count: pv.read(off + start, count))(
                prev, int(offsets[i])
            )
            for i in range(z)
        ]
        spill = new_spill(j)
        pass_stats, handle = _drive_core(
            readers, num_vertices, cfg,
            write_assign=(
                lambda sp: lambda i, idx, p: sp.write(offsets[i] + idx, p)
            )(spill),
            chunk_edges=chunk_edges, allowed=allowed, warm=warms,
            prev_read=prev_read, backend=backend,
            prefetch=prefetch, resume=handle, trace=trace,
        )
        pm = metrics_of(spill)
        dr, db, dc = h2d_of(pass_stats)
        h2d_rows += dr
        h2d_bytes += db
        scan_calls += dc
        dw, ds, dp, dm, dpw = pipeline_of(pass_stats)
        h2d_wait_s += dw
        refill_spans += ds
        spans_prestaged += dp
        spans_missed += dm
        prestage_wall_s += dpw
        buffer_rows = max(buffer_rows, pass_stats[0].get("buffer_rows", 0))
        improved = 0.0
        for i in range(z):
            improved = max(improved, pass_rd[i][-1] - pm[i].rd)
            pass_rd[i].append(pm[i].rd)
            pass_imbalance[i].append(pm[i].imbalance)
            pass_score_rows[i].append(score_rows_of(pass_stats)[i])
            if pm[i].rd <= best_rd[i]:
                best_spill[i], best_rd[i] = spill, pm[i].rd
                best_pass[i] = len(pass_rd[i])
        if tr.enabled:
            # Per-pass lane with the quality delta this pass bought.
            tr.add_span(
                f"pass-{j + 1}", "pass", t_pass, time.perf_counter(),
                track=f"restream-pass-{j + 1}",
                attrs=dict(rd=pm[0].rd,
                           rd_delta=pass_rd[0][-2] - pass_rd[0][-1],
                           improved=improved),
            )
        prev = spill
        if eps is not None and improved < eps:
            break

    passes_run = len(pass_rd[0])
    # Compose the final assignment from each instance's winning pass, then
    # drop the (passes x 4m-byte) intermediate spills — only the final spill
    # backs the returned memmap.
    with tr.span("compose", cat="phase", passes_run=passes_run):
        for i in range(z):
            src = best_spill[i] if keep_best else spill
            g0 = int(offsets[i])
            for start in range(0, int(m_per[i]), chunk_edges):
                c = min(chunk_edges, int(m_per[i]) - start)
                final_spill.write_range(g0 + start, src.read(g0 + start, c))
        for s in spills:
            s.remove()
    score_rows = int(sum(sum(sr) for sr in pass_score_rows))
    return dict(
        k=k,
        name="adwise-restream",
        base=base,
        passes=passes,
        passes_run=passes_run,
        stream_reads=passes_run,
        eps=eps,
        best_pass=best_pass[0] if keep_best else passes_run,
        pass_rd=pass_rd[0] if z == 1 else [list(r) for r in pass_rd],
        pass_imbalance=pass_imbalance[0] if z == 1 else None,
        pass_score_rows=pass_score_rows[0] if z == 1 else None,
        score_rows=score_rows,
        score_count=score_rows * k,
        h2d_rows=h2d_rows,
        h2d_bytes=h2d_bytes,
        h2d_wait_s=h2d_wait_s,
        prefetch_depth=prefetch_depth,
        refill_spans=refill_spans,
        spans_prestaged=spans_prestaged,
        spans_missed=spans_missed,
        prestage_wall_s=prestage_wall_s,
        scan_calls=scan_calls,
        buffer_rows=buffer_rows,
        wall_time_s=time.perf_counter() - t0,
    )


# ----------------------------------------------------------------------------
# partition_file — the public driver
# ----------------------------------------------------------------------------


def partition_file(
    reader,
    strategy: str,
    k: int,
    *,
    z: int = 1,
    spread: Optional[int] = None,
    seed: int = 0,
    chunk_edges: int = 1 << 16,
    spill_dir: Optional[str] = None,
    backend: str = "auto",
    prefetch: Optional[int] = None,
    trace=None,
    **cfg,
) -> PartitionResult:
    """Partition a file-resident edge stream with bounded edge memory.

    Args:
      reader: an :class:`repro.graph.io.format.EdgeFileReader` (or sub-reader).
      strategy: registry strategy name — 'adwise', 'adwise-restream', '2ps',
        '2ps-l', 'hdrf', 'dbh', 'greedy', 'hash', 'grid'.
      k: global partition count.
      z: spotlight parallel-loading instances; z > 1 splits the file into z
        contiguous byte ranges (``EdgeFileReader.split`` — the boundaries
        `EdgeStream.split_padded` uses) and restricts instance i to a cyclic
        ``spread``-partition block, exactly like
        :func:`repro.core.spotlight.spotlight_partition`.
      spread: partitions per instance (z > 1 only; default ``max(1, k // z)``).
      chunk_edges: the resident-edge bound. Per instance, the device-resident
        ring holds O(max(chunk_edges, window_max + assign_batch)) rows (a
        quantized multiple — see :class:`repro.core.driver.FileSource`) and
        the host heap only ever holds one in-flight refill span of at most
        ``max(chunk_edges, window_max + assign_batch)`` rows; ``stats``
        report the realized bound as ``peak_resident_edges`` and the shipped
        traffic as ``h2d_rows`` / ``h2d_bytes``.
      spill_dir: directory for assignment spill files (default: a fresh
        temp dir; the final spill backs the returned ``assign`` memmap, so
        the directory outlives the call — pass e.g. a pytest tmp_path to
        control its lifetime).
      backend: forwarded to the batched scan ('auto'/'vmap'/'shard_map').
      prefetch: ring read-ahead depth (None → ``ADWISE_PREFETCH`` env →
        default 2; 0 = synchronous refills). See
        :func:`repro.core.driver.resolve_prefetch` and the double-buffer
        protocol in :mod:`repro.core.driver`.
      trace: an optional :class:`repro.obs.Tracer`. When given, the whole
        pipeline records host-side spans into it (scan calls, refills,
        read-ahead staging, restream passes, phases) and stats carry a
        ``trace_summary`` (see :mod:`repro.obs`). ``None`` selects the
        zero-overhead null tracer.
      cfg: strategy knobs, exactly as `repro.core.registry.run_partitioner`
        takes them (AdwiseConfig fields; `passes=`/`base=`/`keep_best=`/
        `eps=` for adwise-restream; `cluster_slack=` for 2ps;
        `cluster_slack=`/`lam=`/`eps=`/`cap_slack=` for 2ps-l; `lam=` for
        hdrf, ...).

    Returns a PartitionResult whose ``assign`` is a read-only memmap over the
    final spill file (stats carry ``spill_path``) — **bit-identical** to the
    in-memory registry / spotlight path for the same inputs.
    """
    m = reader.num_edges
    n = reader.num_vertices
    if z < 1:
        raise ValueError(f"z must be >= 1, got {z}")
    if z > 1 and strategy in _SPOTLIGHT_INCOMPATIBLE:
        raise ValueError(
            f"strategy {strategy!r} does not compose with spotlight spread "
            "masking (see repro.core.spotlight)"
        )
    if spread is None:
        spread = k if z == 1 else max(1, k // z)
    if m == 0:
        # Full stats surface (no spill file is created for an empty stream).
        return PartitionResult(
            np.zeros((0,), np.int32),
            dict(k=k, name=strategy, m=0, num_vertices=n, z=z,
                 chunk_edges=chunk_edges, peak_resident_edges=0,
                 spill_path=None, wall_time_s=0.0, io_wall_s=0.0,
                 rows_read=0, stream_reads=0, stream_reads_measured=0,
                 h2d_rows=0, h2d_bytes=0, scan_calls=0, buffer_rows=0,
                 h2d_wait_s=0.0, prefetch_depth=0, refill_spans=0,
                 spans_prestaged=0, spans_missed=0, prestage_wall_s=0.0,
                 unassigned=0),
        )
    if spill_dir is None:
        spill_dir = tempfile.mkdtemp(prefix="adwise-oocore-")
    os.makedirs(spill_dir, exist_ok=True)

    tr = resolve_tracer(trace)
    rows_before = getattr(reader, "rows_read", 0)
    io_before = getattr(reader, "read_seconds", 0.0)
    final = _Spill(os.path.join(spill_dir, "assign.i32"), m)
    t0 = time.perf_counter()

    readers = list(reader.split(z)) if z > 1 else [reader]
    offsets = (
        np.asarray(EdgeStream.split_bounds(m, z)[:z])
        if z > 1
        else np.zeros((1,), np.int64)
    )
    allowed = (
        np.stack([spread_mask(k, z, i, spread) for i in range(z)])
        if z > 1
        else None
    )

    def write_core(i, idx, p):
        final.write(offsets[i] + idx, p)

    def spotlightify(stats, per_stats):
        return dict(
            stats, name=f"spotlight-{strategy}", z=z, spread=spread,
            score_count=sum(s.get("score_count", 0) for s in per_stats),
        )

    if strategy in ("adwise", "adwise-restream"):
        unknown = set(cfg) - _ADWISE_FIELDS - (
            {"passes", "base", "keep_best", "eps", "n_chunks"}
            if strategy == "adwise-restream" else set()
        )
        if unknown:
            raise TypeError(f"{strategy}: unknown config keys {sorted(unknown)}")
        cfg.pop("n_chunks", None)
        if strategy == "adwise":
            acfg = AdwiseConfig(k=k, seed=seed, **cfg)
            per_stats, _ = _drive_core(
                readers, n, acfg, write_assign=write_core,
                chunk_edges=chunk_edges, allowed=allowed, backend=backend,
                prefetch=prefetch, trace=trace,
            )
            stats = dict(per_stats[0], stream_reads=1)
            if z > 1:
                stats = spotlightify(stats, per_stats)
        else:
            stats = _run_restream_chunks(
                readers, n, k, seed, chunk_edges, spill_dir, m, offsets, final,
                allowed=allowed, backend=backend, prefetch=prefetch,
                trace=trace, **cfg,
            )
            if z > 1:
                stats.update(name="spotlight-adwise-restream", z=z, spread=spread)
    elif strategy in ("2ps", "2ps-l"):
        allowed_keys = (
            _ADWISE_FIELDS | {"cluster_slack", "n_chunks"}
            if strategy == "2ps"
            else {"cluster_slack", "lam", "eps", "cap_slack", "n_chunks"}
        )
        unknown = set(cfg) - allowed_keys
        if unknown:
            raise TypeError(f"{strategy}: unknown config keys {sorted(unknown)}")
        cfg.pop("n_chunks", None)
        per_stats = _run_two_phase_chunks(
            readers, n, k, seed, chunk_edges, write_core,
            variant=strategy, allowed=allowed, backend=backend,
            prefetch=prefetch, trace=trace, **cfg,
        )
        stats = per_stats[0]
        if z > 1:
            stats = dict(
                spotlightify(stats, per_stats),
                n_clusters=[s["n_clusters"] for s in per_stats],
            )
    elif strategy in ("hdrf", "greedy"):
        if strategy == "hdrf":
            unknown = set(cfg) - {"lam", "eps"}
            if unknown:
                raise TypeError(f"hdrf: unknown config keys {sorted(unknown)}")
            core = baselines.HdrfCore(
                num_vertices=n, k=k, lam=float(cfg.get("lam", 1.1)),
                eps=float(cfg.get("eps", 1.0)), seed=seed,
            )
        else:
            if cfg:
                raise TypeError(f"greedy: unknown config keys {sorted(cfg)}")
            core = baselines.GreedyCore(num_vertices=n, k=k)
        per_stats, _ = _drive_core(
            readers, n, core, write_assign=write_core,
            chunk_edges=chunk_edges, allowed=allowed, backend=backend,
            prefetch=prefetch, trace=trace,
        )
        stats = dict(per_stats[0], stream_reads=1)
        if z > 1:
            stats = spotlightify(stats, per_stats)
    elif strategy in ("hash", "dbh", "grid"):
        if z == 1:
            stats = _run_baseline_chunks(
                strategy, reader, n, k, seed, chunk_edges,
                lambda off, a: final.write_range(off, a), trace=trace, **cfg,
            )
        else:
            stats = _run_stateless_spotlight(
                strategy, readers, offsets, n, k, z, spread, seed,
                chunk_edges, final, cfg, trace=trace,
            )
    else:
        raise KeyError(
            f"partition_file has no out-of-core driver for strategy "
            f"{strategy!r}"
        )

    wall = time.perf_counter() - t0
    rows_read = getattr(reader, "rows_read", 0) - rows_before
    io_wall = getattr(reader, "read_seconds", 0.0) - io_before
    measured_reads = max(1, int(round(rows_read / max(m, 1))))
    # Resident-edge ceiling: per instance, the (device-resident) ring buffer
    # (or baseline chunk) plus host-side in-flight reads of at most the same
    # size. Host heap itself only ever holds one refill span (<= chunk).
    buffer_rows = int(stats.get("buffer_rows", chunk_edges) or chunk_edges)
    stats = dict(
        stats,
        k=k,
        file=getattr(reader, "path", None),
        m=m,
        num_vertices=n,
        z=z,
        chunk_edges=chunk_edges,
        peak_resident_edges=z * 2 * buffer_rows,
        spill_path=final.path,
        wall_time_s=stats.get("wall_time_s", wall),
        io_wall_s=io_wall,
        rows_read=int(rows_read),
        stream_reads=int(stats.get("stream_reads", measured_reads)),
        stream_reads_measured=measured_reads,
        unassigned=0,
    )
    # Chunked completeness check (no O(m) temporary; raises even under -O).
    with tr.span("spill-verify", cat="phase", m=m):
        neg = 0
        for start in range(0, m, chunk_edges):
            a = final.read(start, min(chunk_edges, m - start))
            neg += int((a < 0).sum())
    if neg:
        raise RuntimeError(f"partition_file left {neg} of {m} edges unassigned")
    if tr.enabled:
        tr.add_span(
            "partition_file", "phase", t0, time.perf_counter(),
            attrs=dict(strategy=strategy, k=k, z=z, m=m),
        )
        stats["trace_summary"] = tr.summary().as_dict()
    return PartitionResult(final.flush_readonly(), stats)


def _run_stateless_spotlight(
    strategy: str,
    readers: Sequence,
    offsets: np.ndarray,
    num_vertices: int,
    k: int,
    z: int,
    spread: int,
    seed: int,
    chunk_edges: int,
    final: _Spill,
    cfg: dict,
    trace=None,
) -> dict:
    """z>1 spotlight for the stateless hashes (hash/dbh): each instance runs
    the chunked assignment at its local spread-k over its byte range with
    ``seed + i``, local partition *ranks* remapped to the global ids its mask
    selects — the same rank-remap `spotlight_partition`'s batched backend
    applies to masked hashing in memory, so file == memory bit-for-bit."""
    t0 = time.perf_counter()
    walls, score_counts, reads = [], 0, 0
    for i in range(z):
        allowed = spread_mask(k, z, i, spread)
        local_to_global = np.flatnonzero(allowed).astype(np.int32)
        g0 = int(offsets[i])
        st = _run_baseline_chunks(
            strategy, readers[i], num_vertices, int(allowed.sum()),
            seed + i, chunk_edges,
            lambda off, a, g0=g0, m_=local_to_global: final.write_range(
                g0 + off, m_[a]
            ),
            trace=trace,
            **cfg,
        )
        walls.append(st.get("wall_time_s", 0.0))
        score_counts += st.get("score_count", 0)
        reads = max(reads, st.get("stream_reads", 1))
    return dict(
        k=k,
        z=z,
        spread=spread,
        name=f"spotlight-{strategy}",
        backend="loop",
        wall_time_s=max(walls) if walls else 0.0,
        wall_time_serial_s=time.perf_counter() - t0,
        score_count=score_counts,
        stream_reads=reads,
    )
