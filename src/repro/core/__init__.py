"""ADWISE — the paper's primary contribution.

Public API:
  AdwiseConfig, PartitionResult           — configuration / result types
  partition_stream                        — vectorized windowed partitioner
  partition_stream_batched                — z instance scans as ONE vmapped /
                                            shard_mapped program (device-
                                            parallel spotlight loading)
  ref_adwise_partition                    — sequential Algorithm-1 oracle
  hdrf_partition, dbh_partition, ...      — single-edge streaming baselines
  spotlight_partition, spread_mask        — §III-D parallel-loading optimization
  run_partitioner, available_strategies   — strategy registry (registry.py):
                                            all partitioners behind one
                                            (edges, n, k, seed, **cfg) API
  restream_partition, two_phase_partition — multi-pass re-streaming layer
                                            (restream.py: 'adwise-restream'
                                            and '2ps' registry entries)
  partition_file                          — out-of-core driver (oocore.py):
                                            any registry strategy over a
                                            repro.graph.io file reader with
                                            bounded resident edge memory
"""
from repro.core.types import AdwiseConfig, PartitionResult
from repro.core.adwise import WarmState, partition_stream, partition_stream_batched
from repro.core.reference import ref_adwise_partition
from repro.core.baselines import (
    hdrf_partition,
    dbh_partition,
    greedy_partition,
    hash_partition,
    grid_partition,
)
from repro.core.registry import (
    available_strategies,
    get_partitioner,
    register,
    run_partitioner,
)
from repro.core.restream import (
    restream_partition,
    restream_partition_batched,
    two_phase_partition,
    warm_from_assignment,
)
from repro.core.spotlight import spotlight_partition, spread_mask
from repro.core.oocore import partition_file

__all__ = [
    "AdwiseConfig",
    "PartitionResult",
    "WarmState",
    "partition_stream",
    "partition_stream_batched",
    "restream_partition",
    "restream_partition_batched",
    "two_phase_partition",
    "warm_from_assignment",
    "ref_adwise_partition",
    "hdrf_partition",
    "dbh_partition",
    "greedy_partition",
    "hash_partition",
    "grid_partition",
    "spotlight_partition",
    "spread_mask",
    "partition_file",
    "available_strategies",
    "get_partitioner",
    "register",
    "run_partitioner",
]
