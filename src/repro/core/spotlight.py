"""Spotlight partitioning (§III-D): reduce the *spread* of parallel partitioners.

With ``z`` parallel partitioner instances and ``k`` global partitions, each
instance ``i`` is restricted to a window ("spread") of ``s`` partitions
starting at ``i * k/z`` (cyclic). ``s = k/z`` gives fully disjoint blocks —
the configuration the paper recommends; ``s = k`` degenerates to the usual
full-spread parallel loading. Spotlight composes with *any* streaming
partitioner ("can be applied on top of any existing algorithm").

Instance-axis layout (the batched backend)
------------------------------------------
The paper's cluster runs the z instances on z machines; this module runs
them as ONE batched program. The stream is reshaped by
``EdgeStream.split_padded(z)`` into ``streams[z, per, 2]`` with a per-row
prefix mask ``valid[z, per]`` — instance ``i`` owns the contiguous global
slice ``[i*per, i*per + valid[i].sum())``. Every per-instance quantity the
ADWISE scan carries (vertex cache, window buffer, partition loads, λ,
controller state) gains a leading ``z`` axis, and
:func:`repro.core.adwise.partition_stream_batched` runs the z scans as one
``vmap`` over that instance axis — wrapped in ``shard_map`` over an
``("instances",)`` mesh axis when multiple devices are visible, so instances
land on separate devices exactly as they land on separate machines in the
paper. Instances share nothing: each keeps its own vertex cache (the
parallel loading model — no communication during partitioning). The batched
scan itself is driven by the unified :class:`repro.core.driver.ScanDriver`
(one engine for the in-memory, re-streaming, and out-of-core ring-buffer
paths), whose host→device accounting surfaces here as ``h2d_rows`` /
``h2d_bytes``.

Backends:

* ``"batched"`` (default for 'adwise' / 'adwise-restream'): one vmapped /
  shard_mapped program; ``wall_time_s`` is the measured wall of that program,
  which IS the parallel-model wall. ``"vmap"`` / ``"shard_map"`` force the
  inner execution mode.
* ``"loop"``: the sequential per-instance escape hatch — one scan per
  instance in a Python loop. Required for the masked baseline strategies
  (hdrf/dbh/greedy/hash run on the local partition subset and are remapped);
  ``wall_time_s`` then reports the parallel model ``max(instance walls)``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core import registry
from repro.core.adwise import partition_stream, partition_stream_batched
from repro.core.restream import restream_partition_batched
from repro.core.types import AdwiseConfig, PartitionResult
from repro.graph.stream import EdgeStream

__all__ = ["spread_mask", "spotlight_partition"]


def spread_mask(k: int, z: int, instance: int, spread: int) -> np.ndarray:
    """bool (k,): partitions instance ``i`` may fill — cyclic block of ``spread``."""
    assert 1 <= spread <= k
    start = (instance * k) // z
    idx = (start + np.arange(spread)) % k
    mask = np.zeros((k,), bool)
    mask[idx] = True
    return mask


# Strategies whose placement structure breaks under the small local k the
# spread mask induces: grid's floor(sqrt(k)) collapses to 1 for k < 4, making
# every instance dump its whole chunk on one partition.
_SPOTLIGHT_INCOMPATIBLE = {"grid"}

# Strategies the batched (vmapped/shard_mapped) backend supports natively.
_BATCHED_STRATEGIES = {"adwise", "adwise-restream"}

# spotlight backend -> inner partition_stream_batched backend.
_BATCHED_INNER = {"batched": "auto", "vmap": "vmap", "shard_map": "shard_map"}


def _masked_strategy(strategy, edges, num_vertices, allowed, seed, strategy_cfg=None):
    """Run a registry strategy on the allowed partition subset only.

    The strategy partitions into ``|allowed|`` local parts; local ids are then
    mapped back to the global ids the mask selects. Works for any registered
    strategy whose placement depends only on k (all the baselines)."""
    if strategy in _SPOTLIGHT_INCOMPATIBLE:
        raise ValueError(
            f"strategy {strategy!r} does not compose with spotlight spread "
            "masking (its placement structure degenerates at small local k); "
            "use hash/dbh/hdrf/greedy or adwise"
        )
    res = registry.run_partitioner(
        strategy, edges, num_vertices, int(allowed.sum()), seed=seed,
        **(strategy_cfg or {}),
    )
    local_to_global = np.flatnonzero(allowed).astype(np.int32)
    return PartitionResult(local_to_global[res.assign], res.stats)


def _spotlight_batched(
    edges, num_vertices, k, z, spread, strategy, cfg, seed, strategy_cfg,
    inner_backend,
):
    """One batched program for all z instances (adwise / adwise-restream)."""
    stream = EdgeStream(edges, num_vertices)
    streams, valid = stream.split_padded(z)
    per = streams.shape[1]
    m = stream.num_edges
    allowed = np.stack([spread_mask(k, z, i, spread) for i in range(z)])
    t0 = time.perf_counter()
    if strategy == "adwise":
        c = cfg or AdwiseConfig(k=k)
        if c.k != k:
            c = dataclasses.replace(c, k=k)
        results = partition_stream_batched(
            streams, valid, num_vertices, c,
            allowed=allowed, backend=inner_backend,
        )
    else:  # adwise-restream: per-instance WarmState batches between passes
        results = restream_partition_batched(
            streams, valid, num_vertices, k,
            allowed=allowed, seed=seed, backend=inner_backend,
            **(strategy_cfg or {}),
        )
    serial_wall = time.perf_counter() - t0
    assign = np.full((m,), -1, np.int32)
    for i, r in enumerate(results):
        assign[i * per : i * per + len(r.assign)] = r.assign
    s0 = results[0].stats if results else {}
    stats = dict(
        k=k,
        z=z,
        spread=spread,
        name=f"spotlight-{strategy}",
        backend=s0.get("backend", "vmap"),
        n_shards=s0.get("n_shards", 0),
        # One program ran every instance: its wall IS the parallel wall.
        wall_time_s=s0.get("wall_time_s", serial_wall),
        wall_time_serial_s=serial_wall,
        score_count=sum(r.stats.get("score_count", 0) for r in results),
        stream_reads=s0.get("stream_reads", 1),
        # One batched program shipped one stream upload for all instances.
        h2d_rows=s0.get("h2d_rows", 0),
        h2d_bytes=s0.get("h2d_bytes", 0),
    )
    if strategy == "adwise-restream":
        stats["passes_run"] = s0.get("passes_run", 1)
    return PartitionResult(assign, stats)


def spotlight_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    z: int,
    spread: int,
    strategy: str = "adwise",
    cfg: Optional[AdwiseConfig] = None,
    seed: int = 0,
    partitioner: Optional[Callable] = None,
    strategy_cfg: Optional[dict] = None,
    backend: str = "auto",
) -> PartitionResult:
    """Run ``z`` parallel partitioner instances with a limited spread.

    Args:
      strategy: any name in ``registry.available_strategies()`` ('adwise' and
        'adwise-restream' get the native batched allowed-mask path; baselines
        run on the local subset under the loop backend and are remapped), or
        pass ``partitioner``:
        callable (edges, num_vertices, k, allowed, seed) -> PartitionResult
        with *global* partition ids.
      cfg: AdwiseConfig for strategy='adwise' (k is overridden).
      strategy_cfg: keyword cfg forwarded to every non-'adwise' strategy
        instance (e.g. ``dict(passes=3, window_max=64)`` for
        'adwise-restream'). Under the loop backend the instance-local k is
        the spread size; under the batched backend instances run at global k
        restricted by their spread mask.
      spread: partitions per instance; k/z = disjoint spotlight blocks.
      backend: 'auto' (batched for adwise/adwise-restream, loop otherwise),
        'batched' / 'vmap' / 'shard_map' (one program for all instances —
        see the module docstring), or 'loop' (sequential per-instance
        fallback; wall_time_s reports the parallel model max(instance
        walls), matching the paper's cluster where instances run on
        separate machines).
    """
    batchable = partitioner is None and strategy in _BATCHED_STRATEGIES
    if strategy == "adwise-restream" and (strategy_cfg or {}).get(
        "base", "adwise"
    ) != "adwise":
        # A non-adwise base pass runs per-instance registry baselines, which
        # only the sequential path supports.
        batchable = False
    if backend == "auto":
        backend = "batched" if batchable else "loop"
    if backend in _BATCHED_INNER:
        if not batchable:
            raise ValueError(
                f"backend {backend!r} requires strategy in "
                f"{sorted(_BATCHED_STRATEGIES)} with an adwise base pass "
                f"(got {strategy!r}"
                f"{' with custom partitioner' if partitioner else ''}); "
                "use backend='loop'"
            )
        return _spotlight_batched(
            edges, num_vertices, k, z, spread, strategy, cfg, seed,
            strategy_cfg, _BATCHED_INNER[backend],
        )
    if backend != "loop":
        raise ValueError(
            "backend must be 'auto', 'batched', 'vmap', 'shard_map' or "
            f"'loop', got {backend!r}"
        )

    stream = EdgeStream(edges, num_vertices)
    subs = stream.split(z)
    m = stream.num_edges
    assign = np.full((m,), -1, np.int32)
    offsets = EdgeStream.split_bounds(m, z)
    walls, score_counts = [], 0
    t0 = time.perf_counter()
    for i, sub in enumerate(subs):
        allowed = spread_mask(k, z, i, spread)
        if partitioner is not None:
            res = partitioner(sub.edges, num_vertices, k, allowed, seed + i)
        elif strategy == "adwise":
            c = cfg or AdwiseConfig(k=k)
            if c.k != k:
                c = dataclasses.replace(c, k=k)
            # Per-instance latency budget: the budget is wall-clock and the
            # instances run in parallel on the cluster, so each gets L.
            res = partition_stream(sub.edges, num_vertices, c, allowed=allowed)
        else:
            res = _masked_strategy(strategy, sub.edges, num_vertices, allowed,
                                   seed + i, strategy_cfg)
        assign[offsets[i] : offsets[i + 1]] = res.assign
        walls.append(res.stats.get("wall_time_s", 0.0))
        score_counts += res.stats.get("score_count", 0)
    stats = dict(
        k=k,
        z=z,
        spread=spread,
        name=f"spotlight-{strategy}",
        backend="loop",
        wall_time_s=max(walls) if walls else 0.0,
        wall_time_serial_s=time.perf_counter() - t0,
        score_count=score_counts,
    )
    return PartitionResult(assign, stats)
