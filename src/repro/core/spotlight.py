"""Spotlight partitioning (§III-D): reduce the *spread* of parallel partitioners.

With ``z`` parallel partitioner instances and ``k`` global partitions, each
instance ``i`` is restricted to a window ("spread") of ``s`` partitions
starting at ``i * k/z`` (cyclic). ``s = k/z`` gives fully disjoint blocks —
the configuration the paper recommends; ``s = k`` degenerates to the usual
full-spread parallel loading. Spotlight composes with *any* streaming
partitioner ("can be applied on top of any existing algorithm").

Instance-axis layout (the batched backend)
------------------------------------------
The paper's cluster runs the z instances on z machines; this module runs
them as ONE batched program. The stream is reshaped by
``EdgeStream.split_padded(z)`` into ``streams[z, per, 2]`` with a per-row
prefix mask ``valid[z, per]`` — instance ``i`` owns the contiguous global
slice ``[i*per, i*per + valid[i].sum())``. Every per-instance quantity the
ADWISE scan carries (vertex cache, window buffer, partition loads, λ,
controller state) gains a leading ``z`` axis, and
:func:`repro.core.adwise.partition_stream_batched` runs the z scans as one
``vmap`` over that instance axis — wrapped in ``shard_map`` over an
``("instances",)`` mesh axis when multiple devices are visible, so instances
land on separate devices exactly as they land on separate machines in the
paper. Instances share nothing: each keeps its own vertex cache (the
parallel loading model — no communication during partitioning). The batched
scan itself is driven by the unified :class:`repro.core.driver.ScanDriver`
(one engine for the in-memory, re-streaming, and out-of-core ring-buffer
paths), whose host→device accounting surfaces here as ``h2d_rows`` /
``h2d_bytes``.

Backends:

* ``"batched"`` (the ``"auto"`` default for every registry strategy): one
  program for all z instances. The adwise-scan family (adwise,
  adwise-restream, 2ps, 2ps-l) and the step-core baselines (hdrf, greedy)
  vmap/shard_map their scan over the instance axis; the stateless hashes
  (hash, dbh) run their vectorized assignment per instance. ``wall_time_s``
  is the measured wall of the batched program, which IS the parallel-model
  wall. ``"vmap"`` / ``"shard_map"`` force the inner execution mode.
* ``"loop"``: the sequential per-instance escape hatch — one
  ``registry.run_partitioner`` call per instance at GLOBAL k with the
  instance's ``allowed`` spread mask; required only for custom
  ``partitioner=`` callables and non-adwise restream base passes.
  ``wall_time_s`` then reports the parallel model ``max(instance walls)``.
  Bit-identical to the batched backend for every registry strategy.

Per-instance seeds: the stateless hashes and HDRF's counter-based tie noise
derive instance ``i``'s stream from ``seed + i`` (loop and batched agree:
``HdrfCore.seed_instances`` plants the same ``seed + i`` per vmap lane).
The adwise-scan strategies share one trace-static ``seed`` across
instances.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core import baselines, registry
from repro.core.adwise import partition_stream, partition_stream_batched
from repro.core.restream import (
    restream_partition_batched,
    two_phase_partition_batched,
)
from repro.core.types import AdwiseConfig, PartitionResult
from repro.graph.stream import EdgeStream

__all__ = ["spread_mask", "spotlight_partition"]


def spread_mask(k: int, z: int, instance: int, spread: int) -> np.ndarray:
    """bool (k,): partitions instance ``i`` may fill — cyclic block of ``spread``."""
    assert 1 <= spread <= k
    start = (instance * k) // z
    idx = (start + np.arange(spread)) % k
    mask = np.zeros((k,), bool)
    mask[idx] = True
    return mask


# Strategies whose placement structure breaks under spread masking: grid's
# vertex-pair cells impose their own replica constraint and cannot honor an
# allowed subset.
_SPOTLIGHT_INCOMPATIBLE = {"grid"}

# Strategies whose per-instance state is an independent seed (the stateless
# hashes and HDRF's counter-based tie noise): instance i runs with seed + i
# on both backends. The adwise-scan strategies share one trace-static seed.
_PER_INSTANCE_SEED = {"hash", "dbh", "hdrf", "greedy"}

# spotlight backend -> inner partition_stream_batched backend.
_BATCHED_INNER = {"batched": "auto", "vmap": "vmap", "shard_map": "shard_map"}


def _reject_incompatible(strategy: str) -> None:
    if strategy in _SPOTLIGHT_INCOMPATIBLE:
        raise ValueError(
            f"strategy {strategy!r} does not compose with spotlight spread "
            "masking (its placement structure ignores the allowed subset); "
            "use hash/dbh/hdrf/greedy or the adwise family"
        )


def _spotlight_batched(
    edges, num_vertices, k, z, spread, strategy, cfg, seed, strategy_cfg,
    inner_backend,
):
    """One batched program for all z instances (any registry strategy)."""
    stream = EdgeStream(edges, num_vertices)
    streams, valid = stream.split_padded(z)
    per = streams.shape[1]
    m = stream.num_edges
    allowed = np.stack([spread_mask(k, z, i, spread) for i in range(z)])
    scfg = dict(strategy_cfg or {})
    t0 = time.perf_counter()
    if strategy == "adwise":
        c = cfg or AdwiseConfig(k=k)
        if c.k != k:
            c = dataclasses.replace(c, k=k)
        results = partition_stream_batched(
            streams, valid, num_vertices, c,
            allowed=allowed, backend=inner_backend,
        )
    elif strategy == "adwise-restream":
        # Per-instance WarmState batches between passes.
        results = restream_partition_batched(
            streams, valid, num_vertices, k,
            allowed=allowed, seed=seed, backend=inner_backend, **scfg,
        )
    elif strategy in ("2ps", "2ps-l"):
        results = two_phase_partition_batched(
            streams, valid, num_vertices, k, variant=strategy,
            allowed=allowed, seed=seed, backend=inner_backend, **scfg,
        )
    elif strategy in ("hdrf", "greedy"):
        if strategy == "hdrf":
            unknown = set(scfg) - {"lam", "eps"}
            if unknown:
                raise TypeError(f"hdrf: unknown config keys {sorted(unknown)}")
            core = baselines.HdrfCore(
                num_vertices=int(num_vertices), k=int(k),
                lam=float(scfg.get("lam", 1.1)), eps=float(scfg.get("eps", 1.0)),
                seed=int(seed),
            )
        else:
            if scfg:
                raise TypeError(f"greedy: unknown config keys {sorted(scfg)}")
            core = baselines.GreedyCore(num_vertices=int(num_vertices), k=int(k))
        results = partition_stream_batched(
            streams, valid, num_vertices, None, core=core,
            allowed=allowed, backend=inner_backend,
        )
    else:
        # Stateless hashes (hash/dbh) — or an unknown name, which
        # run_partitioner rejects. One vectorized assignment per instance;
        # seed + i is each instance's independent hash stream.
        m_per = valid.sum(axis=1)
        results = [
            registry.run_partitioner(
                strategy, streams[i, : m_per[i]], num_vertices, k,
                seed=seed + i, allowed=allowed[i], **scfg,
            )
            for i in range(z)
        ]
    serial_wall = time.perf_counter() - t0
    assign = np.full((m,), -1, np.int32)
    for i, r in enumerate(results):
        assign[i * per : i * per + len(r.assign)] = r.assign
    s0 = results[0].stats if results else {}
    if strategy in ("hash", "dbh"):
        # Instances ran as independent vectorized assigns — the parallel
        # model bills the slowest one.
        wall = max((r.stats.get("wall_time_s", 0.0) for r in results),
                   default=0.0)
    else:
        # One program ran every instance: its wall IS the parallel wall.
        wall = s0.get("wall_time_s", serial_wall)
    stats = dict(
        k=k,
        z=z,
        spread=spread,
        name=f"spotlight-{strategy}",
        backend=s0.get("backend", "batched"),
        n_shards=s0.get("n_shards", 0),
        wall_time_s=wall,
        wall_time_serial_s=serial_wall,
        score_count=sum(r.stats.get("score_count", 0) for r in results),
        stream_reads=s0.get("stream_reads", 1),
        # One batched program shipped one stream upload for all instances.
        h2d_rows=s0.get("h2d_rows", 0),
        h2d_bytes=s0.get("h2d_bytes", 0),
    )
    if strategy == "adwise-restream":
        stats["passes_run"] = s0.get("passes_run", 1)
    return PartitionResult(assign, stats)


def spotlight_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    z: int,
    spread: int,
    strategy: str = "adwise",
    cfg: Optional[AdwiseConfig] = None,
    seed: int = 0,
    partitioner: Optional[Callable] = None,
    strategy_cfg: Optional[dict] = None,
    backend: str = "auto",
) -> PartitionResult:
    """Run ``z`` parallel partitioner instances with a limited spread.

    Args:
      strategy: any name in ``registry.available_strategies()`` except
        'grid' — every registry strategy runs at GLOBAL k restricted by its
        instance's ``allowed`` spread mask, on either backend. Or pass
        ``partitioner``:
        callable (edges, num_vertices, k, allowed, seed) -> PartitionResult
        with *global* partition ids (loop backend only).
      cfg: AdwiseConfig for strategy='adwise' (k is overridden).
      strategy_cfg: keyword cfg forwarded to every non-'adwise' strategy
        instance (e.g. ``dict(passes=3, window_max=64)`` for
        'adwise-restream', ``dict(lam=1.5)`` for 'hdrf').
      spread: partitions per instance; k/z = disjoint spotlight blocks.
      backend: 'auto' (batched for every registry strategy, loop for custom
        partitioners), 'batched' / 'vmap' / 'shard_map' (one program for
        all instances — see the module docstring), or 'loop' (sequential
        per-instance fallback, bit-identical; wall_time_s reports the
        parallel model max(instance walls), matching the paper's cluster
        where instances run on separate machines).
    """
    if partitioner is None:
        _reject_incompatible(strategy)
    batchable = partitioner is None
    if strategy == "adwise-restream" and (strategy_cfg or {}).get(
        "base", "adwise"
    ) != "adwise":
        # A non-adwise base pass runs per-instance registry baselines, which
        # only the sequential path supports.
        batchable = False
    if backend == "auto":
        backend = "batched" if batchable else "loop"
    if backend in _BATCHED_INNER:
        if not batchable:
            raise ValueError(
                f"backend {backend!r} needs a registry strategy with an "
                f"adwise base pass (got {strategy!r}"
                f"{' with custom partitioner' if partitioner else ''}); "
                "use backend='loop'"
            )
        return _spotlight_batched(
            edges, num_vertices, k, z, spread, strategy, cfg, seed,
            strategy_cfg, _BATCHED_INNER[backend],
        )
    if backend != "loop":
        raise ValueError(
            "backend must be 'auto', 'batched', 'vmap', 'shard_map' or "
            f"'loop', got {backend!r}"
        )

    stream = EdgeStream(edges, num_vertices)
    subs = stream.split(z)
    m = stream.num_edges
    assign = np.full((m,), -1, np.int32)
    offsets = EdgeStream.split_bounds(m, z)
    walls, score_counts = [], 0
    t0 = time.perf_counter()
    for i, sub in enumerate(subs):
        allowed = spread_mask(k, z, i, spread)
        if partitioner is not None:
            res = partitioner(sub.edges, num_vertices, k, allowed, seed + i)
        elif strategy == "adwise":
            c = cfg or AdwiseConfig(k=k)
            if c.k != k:
                c = dataclasses.replace(c, k=k)
            # Per-instance latency budget: the budget is wall-clock and the
            # instances run in parallel on the cluster, so each gets L.
            res = partition_stream(sub.edges, num_vertices, c, allowed=allowed)
        else:
            res = registry.run_partitioner(
                strategy, sub.edges, num_vertices, k,
                seed=seed + i if strategy in _PER_INSTANCE_SEED else seed,
                allowed=allowed, **(strategy_cfg or {}),
            )
        assign[offsets[i] : offsets[i + 1]] = res.assign
        walls.append(res.stats.get("wall_time_s", 0.0))
        score_counts += res.stats.get("score_count", 0)
    stats = dict(
        k=k,
        z=z,
        spread=spread,
        name=f"spotlight-{strategy}",
        backend="loop",
        wall_time_s=max(walls) if walls else 0.0,
        wall_time_serial_s=time.perf_counter() - t0,
        score_count=score_counts,
    )
    return PartitionResult(assign, stats)
