"""Spotlight partitioning (§III-D): reduce the *spread* of parallel partitioners.

With ``z`` parallel partitioner instances and ``k`` global partitions, each
instance ``i`` is restricted to a window ("spread") of ``s`` partitions
starting at ``i * k/z`` (cyclic). ``s = k/z`` gives fully disjoint blocks —
the configuration the paper recommends; ``s = k`` degenerates to the usual
full-spread parallel loading. Spotlight composes with *any* streaming
partitioner ("can be applied on top of any existing algorithm").

Each instance consumes a disjoint contiguous chunk of the stream and keeps
its **own** vertex cache (the paper's parallel loading model — no
communication during partitioning).
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.core import registry
from repro.core.adwise import partition_stream
from repro.core.types import AdwiseConfig, PartitionResult
from repro.graph.stream import EdgeStream

__all__ = ["spread_mask", "spotlight_partition"]


def spread_mask(k: int, z: int, instance: int, spread: int) -> np.ndarray:
    """bool (k,): partitions instance ``i`` may fill — cyclic block of ``spread``."""
    assert 1 <= spread <= k
    start = (instance * k) // z
    idx = (start + np.arange(spread)) % k
    mask = np.zeros((k,), bool)
    mask[idx] = True
    return mask


# Strategies whose placement structure breaks under the small local k the
# spread mask induces: grid's floor(sqrt(k)) collapses to 1 for k < 4, making
# every instance dump its whole chunk on one partition.
_SPOTLIGHT_INCOMPATIBLE = {"grid"}


def _masked_strategy(strategy, edges, num_vertices, allowed, seed, strategy_cfg=None):
    """Run a registry strategy on the allowed partition subset only.

    The strategy partitions into ``|allowed|`` local parts; local ids are then
    mapped back to the global ids the mask selects. Works for any registered
    strategy whose placement depends only on k (all the baselines)."""
    if strategy in _SPOTLIGHT_INCOMPATIBLE:
        raise ValueError(
            f"strategy {strategy!r} does not compose with spotlight spread "
            "masking (its placement structure degenerates at small local k); "
            "use hash/dbh/hdrf/greedy or adwise"
        )
    res = registry.run_partitioner(
        strategy, edges, num_vertices, int(allowed.sum()), seed=seed,
        **(strategy_cfg or {}),
    )
    local_to_global = np.flatnonzero(allowed).astype(np.int32)
    return PartitionResult(local_to_global[res.assign], res.stats)


def spotlight_partition(
    edges: np.ndarray,
    num_vertices: int,
    k: int,
    z: int,
    spread: int,
    strategy: str = "adwise",
    cfg: Optional[AdwiseConfig] = None,
    seed: int = 0,
    partitioner: Optional[Callable] = None,
    strategy_cfg: Optional[dict] = None,
) -> PartitionResult:
    """Run ``z`` parallel partitioner instances with a limited spread.

    Args:
      strategy: any name in ``registry.available_strategies()`` ('adwise'
        gets its native allowed-mask path; baselines run on the local subset
        and are remapped), or pass ``partitioner``:
        callable (edges, num_vertices, k, allowed, seed) -> PartitionResult
        with *global* partition ids.
      cfg: AdwiseConfig for strategy='adwise' (k is overridden).
      strategy_cfg: keyword cfg forwarded to every non-'adwise' registry
        strategy instance (e.g. ``dict(passes=3, window_max=64)`` for
        'adwise-restream'); note the instance-local k is the spread size.
      spread: partitions per instance; k/z = disjoint spotlight blocks.

    Note: instances run sequentially here (single host); wall_time_s reports
    the *parallel* model max(instance walls), matching the paper's cluster
    setup where instances run on separate machines.
    """
    stream = EdgeStream(edges, num_vertices)
    subs = stream.split(z)
    m = stream.num_edges
    assign = np.full((m,), -1, np.int32)
    offsets = np.linspace(0, m, z + 1).astype(np.int64)
    walls, score_counts = [], 0
    t0 = time.perf_counter()
    for i, sub in enumerate(subs):
        allowed = spread_mask(k, z, i, spread)
        if partitioner is not None:
            res = partitioner(sub.edges, num_vertices, k, allowed, seed + i)
        elif strategy == "adwise":
            c = cfg or AdwiseConfig(k=k)
            if c.k != k:
                import dataclasses

                c = dataclasses.replace(c, k=k)
            # Per-instance latency budget: the budget is wall-clock and the
            # instances run in parallel on the cluster, so each gets L.
            res = partition_stream(sub.edges, num_vertices, c, allowed=allowed)
        else:
            res = _masked_strategy(strategy, sub.edges, num_vertices, allowed,
                                   seed + i, strategy_cfg)
        assign[offsets[i] : offsets[i + 1]] = res.assign
        walls.append(res.stats.get("wall_time_s", 0.0))
        score_counts += res.stats.get("score_count", 0)
    stats = dict(
        k=k,
        z=z,
        spread=spread,
        name=f"spotlight-{strategy}",
        wall_time_s=max(walls) if walls else 0.0,
        wall_time_serial_s=time.perf_counter() - t0,
        score_count=score_counts,
    )
    return PartitionResult(assign, stats)
