"""ADWISE-style adaptive balancing applied to MoE token routing (beyond-paper).

The paper's partitioner balances edge→partition assignment with an *adaptive*
weight λ(ι, α)·B(p) (Eq. 3/4) instead of a fixed balance coefficient. The
token→expert assignment in a capacity-constrained MoE is the same bipartite
streaming-assignment problem: tokens ≙ edges, experts ≙ partitions, expert
overflow (dropped tokens) ≙ imbalance cost, router score ≙ replication score.

`adwise_router_bias` maintains running expert loads across steps and returns
the additive bias λ·B(e) for the router logits:

  B(e) = (maxload − load_e) / (maxload − minload + ε)            (Eq. 3)
  λ   += (ι − tolerance(α)),  clipped to [λ_lo, λ_hi]            (Eq. 4)

with ι the current load imbalance and α the fraction of the training horizon
elapsed (early in training the balance pressure is relaxed, exactly like the
early stream phase in the paper). Benchmarked against plain top-k +
aux-loss routing in `benchmarks/bench_moe_balance.py`.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["MoeBalanceState", "init_moe_balance", "adwise_router_bias"]


class MoeBalanceState(NamedTuple):
    loads: jax.Array  # (E,) f32 — cumulative routed tokens per expert
    lam: jax.Array  # () f32


def init_moe_balance(n_experts: int, lam_init: float = 1.0) -> MoeBalanceState:
    return MoeBalanceState(
        loads=jnp.zeros((n_experts,), jnp.float32), lam=jnp.float32(lam_init)
    )


LOAD_EMA = 0.65  # responsiveness of the load estimate (distribution drift)


def adwise_router_bias(
    state: MoeBalanceState,
    progress: jax.Array,  # () f32 in [0, 1] — step / total_steps (the α analogue)
    eps: float = 0.01,
    lam_lo: float = 0.4,
    lam_hi: float = 5.0,
) -> Tuple[jax.Array, MoeBalanceState]:
    """Returns (router bias (E,), state with updated λ). Call update_loads after."""
    mx = jnp.max(state.loads)
    mn = jnp.min(state.loads)
    bal = (mx - state.loads) / (mx - mn + eps)
    iota = jnp.where(mx > 0, (mx - mn) / jnp.maximum(mx, 1.0), 0.0)
    tol = jnp.maximum(0.0, 1.0 - progress)
    lam = jnp.clip(state.lam + (iota - tol), lam_lo, lam_hi)
    return lam * bal, MoeBalanceState(loads=state.loads, lam=lam)


def update_loads(state: MoeBalanceState, expert_counts: jax.Array) -> MoeBalanceState:
    """EMA rather than a cumulative sum: the edge stream analogue is the
    *current* partition fill, and an EMA tracks it under distribution drift
    (a cumulative sum reacts ~1/steps too slowly — measured in
    benchmarks/bench_moe_balance.py)."""
    loads = LOAD_EMA * state.loads + (1.0 - LOAD_EMA) * expert_counts
    return MoeBalanceState(loads=loads, lam=state.lam)
