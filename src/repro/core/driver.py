"""Unified streaming-scan driver: ONE engine behind every ADWISE scan caller.

Before this module, the paper's core loop — adaptive window scan with
per-step latency billing (§III-A) — was implemented four times over:
``partition_stream``, ``partition_stream_batched`` (core/adwise.py), the
out-of-core ADWISE path (core/oocore.py), and the warm-started re-streaming
passes each re-derived ``r_sel``, the capacity caps, budget wiring, carry
initialization, and the chunked stepping loop. :class:`ScanDriver` owns all
of that once, over a *pluggable chunk source*:

* :class:`ResidentSource` — the whole stream is uploaded to device once
  (``streams[z, per, 2]``); scan calls index it directly with ``base=0``
  semantics. This is the in-memory path (`partition_stream`,
  `partition_stream_batched`, every re-streaming pass over a resident
  array).
* :class:`FileSource` — a **device-resident ring buffer**: a donated
  ``(z, B, 2)`` buffer lives on device across scan calls, logical stream row
  ``s`` occupies slot ``s % B``, and each refill ships ONLY the new tail
  rows through ``jax.lax.dynamic_update_slice`` — host→device traffic per
  scan call drops from O(B) (the PR-4 full re-upload) to O(refill). Rows
  are uploaded in quantized spans (multiples of ``Rq``, a power of two) so
  the update kernel compiles for a bounded set of shapes; ``B`` is a
  multiple of ``Rq`` sized so a quantized refill always covers the next
  scan call's worst-case consumption (``window_max + S * assign_batch``
  rows per S-step call — the same cursor-advance bound PR 4 proved).

Both modes run the *same* vmapped (optionally shard_mapped) step function —
the per-step math is one trace, so the file path stays bit-identical to the
in-memory path (the registry-wide parity tests in tests/test_oocore.py are
the oracle, plus the ring-specific property tests in tests/test_driver.py).

Step-cores
----------
The per-step math itself is pluggable. A **step-core** (:class:`StepCore`)
is a hashable, frozen description of one streaming strategy that the driver
jit-specializes on. A core implements:

* ``make_step(stream, m_real, allowed, cap, prev_assign) -> step`` — the
  step factory. ``step(carry, _) -> (carry, StepOut)`` is scanned by
  ``jax.lax.scan``; it must read stream rows at ``src % m_pad`` (the ring
  invariant: for a resident source the mod is the identity, for the ring it
  maps logical row ``s`` to slot ``s % B``) and must never read more than
  ``window_rows + rows_per_step`` rows ahead of ``carry.cursor`` in one
  step (the refill bound the :class:`FileSource` sizing proves).
* ``init_carry(budget)`` / ``warm_carry(budget, warm)`` — cold start and
  warm resume from a :class:`~repro.core.types.WarmState`. The carry is any
  pytree obeying the contract in :mod:`repro.core.types` (``.cursor`` and
  ``.assigned`` int32 leaves).
* ``seed_instances(carry, z, ids)`` — batched hook: derive per-instance
  state (e.g. counter-based tie-break seeds ``seed + ids[i]``) after the
  driver stacks z carries; ``ids`` are the caller's global instance
  indices so bucketed sub-batches reproduce the unbucketed streams.
* ``window_rows`` / ``rows_per_step`` — the look-ahead and per-step
  consumption bounds the driver sizes scan calls and the ring with
  (ADWISE: ``window_max`` / ``assign_batch``; single-edge baselines 0 / 1).
* ``counters(carry)`` / ``recalibrate(carry, t0, z)`` / ``set_cost`` —
  stats extraction and the optional latency-budget hooks.

``AdwiseCore`` wraps the adaptive-window math from ``repro.core.adwise``;
``repro.core.baselines`` provides ``HdrfCore`` / ``GreedyCore`` and
``repro.core.restream`` the 2PS-L phase-2 core — all four ride the very
same driver, sources, and h2d accounting.

The double-buffer refill pipeline (prefetch)
--------------------------------------------
With ``prefetch >= 1`` (the default — ``prefetch=0`` is the synchronous
bit-parity escape hatch, also reachable via the ``ADWISE_PREFETCH`` env
var), :class:`FileSource` runs a two-stage pipeline:

1. A host **read-ahead worker** (:class:`_ReadAhead`: one daemon thread +
   a bounded staging queue) reads the stream — and, on re-streaming
   passes, the prior placements — in ``Rq``-row blocks ahead of
   consumption, at most ``prefetch * max_span`` rows past what the scan
   has taken. Refill spans are always whole multiples of ``Rq`` (plus one
   ragged tail ending exactly at ``m_i``), so staged blocks align with
   span consumption exactly — the queue never splits a block.
2. After dispatching scan call k, the driver issues a **speculative
   refill** *before* syncing the ``assigned`` counter, so the
   ``_ring_write`` h2d for span k+1 is enqueued while scan k is still in
   flight. The safe cursor proxy is the guaranteed-progress lower bound
   ``lb = min(assigned_k + S, m)`` (every scan step with a non-empty
   window assigns >= 1 edge — the same bound that proves termination):
   the slots a speculative write recycles held rows ``< lb``, and the
   next scan starts at ``cursor >= assigned_{k+1} >= lb``, so it can
   never read a recycled slot. Because ``_run_scan_ring`` *donates* the
   ring, XLA orders the write after the in-flight scan — the pipeline
   only moves *when* spans are staged and shipped, never *what* they
   contain, which is why bit-parity is geometry-independent.

Cross-pass shared-buffer contract: after a completed ring pass the driver
exposes a :class:`RingHandle` (the final donated ring + upload high-water
marks). A re-streaming pass may adopt it (``FileSource(resume=...)``):
instances whose whole stream fit in the ring without wrapping
(``m_i <= B``) keep their ``uv`` rows device-resident and ship only the
4 B/row ``prev`` placements — restream h2d drops from ``8m + 12m`` bytes
per extra pass to ``8m + 4m``. Wrapped instances fall back to the full
re-ship. The in-memory analogue is :class:`StreamResidency`: re-stream
passes over a :class:`ResidentSource` reuse pass p's uploaded device
stream array and ship only the new ``prev`` table.

Host→device accounting: the driver counts every stream-buffer byte it ships
(``h2d_rows`` / ``h2d_bytes`` / ``h2d_calls``), the measured refill stall
(``h2d_wait_s``: wall spent in non-speculative refills, i.e. staging work
the device had to wait for) and the pipeline hit rate
(``spans_prestaged`` / ``spans_missed``; their sum is ``refill_spans``).
Callers surface the counters in partition stats, and
``repro.engine.latency_model.partition_latency`` bills them — against
:data:`~repro.engine.latency_model.H2D_BW_BPS` when only modeled traffic
is available, overlap-aware (``max(io, h2d, compute)``) when a prefetch
depth and measured stalls are present.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import threading
import time
from functools import partial
from typing import Any, Callable, Deque, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.adwise import Carry, _init_carry, _make_step
from repro.core.types import AdwiseConfig, WarmState
from repro.obs import resolve_tracer

__all__ = [
    "StepCore",
    "AdwiseCore",
    "ResidentSource",
    "FileSource",
    "RingBuf",
    "RingHandle",
    "StreamResidency",
    "ScanDriver",
    "DriveResult",
    "resolve_backend",
    "resolve_prefetch",
    "scan_compile_counts",
    "PREFETCH_ENV",
]

PREFETCH_ENV = "ADWISE_PREFETCH"


def resolve_prefetch(prefetch: Optional[int] = None) -> int:
    """Effective read-ahead depth: explicit argument > ``ADWISE_PREFETCH``
    env var > default 2. ``0`` selects the synchronous bit-parity path
    (no worker thread, every span read inline between scan calls)."""
    if prefetch is None:
        raw = os.environ.get(PREFETCH_ENV, "").strip()
        prefetch = int(raw) if raw else 2
    return max(0, int(prefetch))


def resolve_backend(backend: str, z: int) -> tuple[str, int]:
    """(effective backend, n_shards). 'auto' picks shard_map when multiple
    devices are visible; shard_map degrades to vmap when no device count > 1
    divides z."""
    if backend == "auto":
        backend = "shard_map" if jax.device_count() > 1 else "vmap"
    if backend == "vmap":
        return "vmap", 0
    if backend != "shard_map":
        raise ValueError(
            f"backend must be 'auto', 'vmap' or 'shard_map', got {backend!r}"
        )
    nd = min(jax.device_count(), z)
    n_shards = max((d for d in range(1, nd + 1) if z % d == 0), default=1)
    if n_shards <= 1:
        return "vmap", 0
    return "shard_map", n_shards


# ----------------------------------------------------------------------------
# The step-core interface
# ----------------------------------------------------------------------------


class StepCore:
    """Base class for streaming-strategy step-cores (see module docstring).

    Concrete cores are **frozen dataclasses** holding only hashable scalars
    (k, |V|, quantized weights, ...) — the core object is a jit static
    argument, so its identity selects the compiled trace. All per-instance
    *state* (vertex caches, seeds, cursors) lives in the carry, never in the
    core.
    """

    name: str = "core"

    # The sizing contract is read-only by design (concrete cores either
    # derive it from config or shadow it with class attributes), so the base
    # declares properties rather than writable attributes.
    @property
    def window_rows(self) -> int:
        """Look-ahead rows the step may read beyond the last assignment
        (ring sizing adds this to the per-call consumption bound)."""
        return 0

    @property
    def rows_per_step(self) -> int:
        """Max stream rows consumed (and assignments emitted) per step."""
        return 1

    @property
    def r_sel(self) -> int:
        """Lazy-traversal rescore budget (diagnostics; ADWISE-specific)."""
        return 0

    @property
    def has_budget(self) -> bool:
        return False

    # -- required hooks ----------------------------------------------------
    def make_step(
        self, stream: Any, m_real: Any, allowed: Any, cap: Any, prev_assign: Any
    ) -> Callable[[Any, Any], Any]:
        raise NotImplementedError

    def init_carry(self, budget: float) -> Any:
        raise NotImplementedError

    def warm_carry(self, budget: float, warm: WarmState) -> Any:
        raise NotImplementedError(f"{self.name} does not support warm starts")

    # -- optional hooks ----------------------------------------------------
    def cap_value(self, m: int, n_allowed: int) -> int:
        """Hard per-partition capacity for an instance streaming m edges."""
        return int(np.iinfo(np.int32).max)

    def seed_instances(
        self, carry: Any, z: int, ids: Optional[np.ndarray] = None
    ) -> Any:
        """Derive per-instance carry state after batching (default: none).

        ``ids`` are the caller's *global* instance indices for the z batch
        positions (defaults to ``arange(z)``). Seed-deriving cores must key
        on ``ids`` — never on the batch position — so length-bucketed
        batching, which permutes instances across sub-batches, reproduces
        the exact per-instance streams of the unbucketed layout.
        """
        return carry

    def set_cost(self, carry: Any, cost_per_score: float, z: int) -> Any:
        raise ValueError(f"{self.name} core does not model per-score cost")

    def recalibrate(self, carry: Any, t0: float, z: int) -> Any:
        """Between-chunks budget recalibration (no-op unless has_budget)."""
        return carry

    def counters(self, carry: Any) -> dict:
        """Final per-instance counters for :class:`DriveResult` (each (z,))."""
        assigned = np.asarray(carry.assigned)
        z = assigned.shape[0]
        return dict(
            score_rows=assigned.astype(np.int64),
            final_w=np.ones((z,), np.int64),
            lam=np.zeros((z,), np.float32),
            cost_per_score=np.zeros((z,), np.float32),
        )


@dataclasses.dataclass(frozen=True)
class AdwiseCore(StepCore):
    """ADWISE adaptive-window scan as a step-core (math in core/adwise.py)."""

    cfg: AdwiseConfig
    num_vertices: int
    update_deg: bool = True  # False on warm passes: degrees already final

    name = "adwise"

    @property
    def k(self) -> int:
        return self.cfg.k

    @property
    def window_rows(self) -> int:
        return self.cfg.window_max

    @property
    def rows_per_step(self) -> int:
        return self.cfg.assign_batch

    @property
    def r_sel(self) -> int:
        return self.cfg.resolve_r_sel()

    @property
    def has_budget(self) -> bool:
        return self.cfg.latency_budget is not None

    def cap_value(self, m: int, n_allowed: int) -> int:
        return self.cfg.cap_value(m, n_allowed)

    def make_step(
        self, stream: Any, m_real: Any, allowed: Any, cap: Any, prev_assign: Any
    ) -> Callable[[Any, Any], Any]:
        return _make_step(
            self.cfg, self.num_vertices, self.r_sel, stream, m_real, allowed,
            cap, self.has_budget, prev_assign, self.update_deg,
        )

    def init_carry(self, budget: float) -> Carry:
        return _init_carry(self.cfg, self.num_vertices, budget)

    def warm_carry(self, budget: float, warm: WarmState) -> Carry:
        return Carry.warm_start(
            self.cfg, self.num_vertices, budget,
            replicas=warm.replicas, deg=warm.deg, sizes=warm.sizes,
        )

    def set_cost(self, carry: Any, cost_per_score: float, z: int) -> Any:
        return carry._replace(
            cost_per_score=jnp.full((z,), cost_per_score, jnp.float32)
        )

    def recalibrate(self, carry: Any, t0: float, z: int) -> Any:
        budget = self.cfg.latency_budget
        assert budget is not None  # only called when has_budget
        # Recalibrate the modeled cost against measured wall between scan
        # calls: one program runs all instances, so the shared per-row cost
        # comes from the batched wall over the total row count.
        # staticcheck: disable=SC003 budget recalibration MEASURES wall clock — the sync is the measurement (§III-B latency budget)
        jax.block_until_ready(carry.score_rows)
        wall = time.perf_counter() - t0
        # staticcheck: disable=SC003 score_rows drives the measured cost; already synced by the block above
        rows = max(int(np.asarray(carry.score_rows).sum()), 1)
        return carry._replace(
            cost_per_score=jnp.full(
                (z,), wall / (rows * self.cfg.k), jnp.float32
            ),
            budget_left=jnp.full((z,), budget - wall, jnp.float32),
        )

    def counters(self, carry: Any) -> dict:
        return dict(
            score_rows=np.asarray(carry.score_rows),
            final_w=np.asarray(carry.w_cap),
            lam=np.asarray(carry.lam),
            cost_per_score=np.asarray(carry.cost_per_score),
        )


# ----------------------------------------------------------------------------
# Scan executors: one vmapped program for all z instances, resident or ring
# ----------------------------------------------------------------------------


class RingBuf(NamedTuple):
    """Device-resident stream ring: slot ``s % B`` holds logical row ``s``.

    Threaded through every ring-mode scan call as part of the donated carry,
    so XLA aliases it in place — only the refill spans ever cross the
    host→device boundary.
    """

    uv: jax.Array  # (B, 2) int32 per instance (batched: (z, B, 2))
    prev: jax.Array  # (B,) int32 prior-pass assignment, -1 = none


def _shard_over_instances(
    fn: Callable[..., Any], n_shards: int, n_args: int
) -> Callable[..., Any]:
    mesh = compat.make_mesh(
        (n_shards,), ("instances",),
        devices=np.array(jax.devices()[:n_shards]),
    )
    return compat.shard_map(
        fn,
        mesh=mesh,
        in_specs=(P("instances"),) * n_args,
        out_specs=P("instances"),
        check_replication=False,
    )


@partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=("core", "n_steps", "n_shards"),
)
def _run_scan_resident(
    carry: Any,  # core carry; every leaf carries a leading (z,) instance axis
    streams: jax.Array,  # (z, per, 2) int32
    m_real: jax.Array,  # (z,) int32
    allowed: jax.Array,  # (z, K) bool
    cap: jax.Array,  # (z,) int32
    prev_assign: jax.Array,  # (z, per) int32
    *,
    core: StepCore,
    n_steps: int,
    n_shards: int = 0,
) -> Any:
    """All z instance scans as ONE program over a fully resident stream."""

    def one(
        carry: Any, stream: Any, m_real: Any, allowed: Any, cap: Any, prev: Any
    ) -> Any:
        step = core.make_step(stream, m_real, allowed, cap, prev)
        return jax.lax.scan(step, carry, None, length=n_steps)

    batched = jax.vmap(one)
    if n_shards > 1:
        batched = _shard_over_instances(batched, n_shards, 6)
    return batched(carry, streams, m_real, allowed, cap, prev_assign)


@partial(
    jax.jit,
    donate_argnums=(0,),
    static_argnames=("core", "n_steps", "n_shards"),
)
def _run_scan_ring(
    carry_buf: tuple,  # (carry, RingBuf), each leaf with a leading (z,) axis
    m_real: jax.Array,  # (z,) int32
    allowed: jax.Array,  # (z, K) bool
    cap: jax.Array,  # (z,) int32
    *,
    core: StepCore,
    n_steps: int,
    n_shards: int = 0,
) -> Any:
    """Ring-mode scan: the stream buffer rides in the donated carry and is
    returned untouched, so XLA aliases it across calls (zero copies, zero
    re-upload)."""

    def one(carry_buf: Any, m_real: Any, allowed: Any, cap: Any) -> Any:
        carry, buf = carry_buf
        step = core.make_step(buf.uv, m_real, allowed, cap, buf.prev)
        carry, outs = jax.lax.scan(step, carry, None, length=n_steps)
        return (carry, buf), outs

    batched = jax.vmap(one)
    if n_shards > 1:
        batched = _shard_over_instances(batched, n_shards, 4)
    return batched(carry_buf, m_real, allowed, cap)


@partial(
    jax.jit, donate_argnums=(0,), static_argnames=("with_uv", "with_prev")
)
def _ring_write(
    buf: RingBuf,
    uv_rows: jax.Array,  # (c, 2) int32 — the ONLY stream bytes shipped h2d
    prev_rows: jax.Array,  # (c,) int32 (dummy empty when with_prev=False)
    instance: jax.Array,  # () int32
    slot: jax.Array,  # () int32 — c never wraps past B (spans pre-split)
    *,
    with_prev: bool,
    with_uv: bool = True,  # False on cross-pass resumed instances: uv rows
    # are already device-resident, only prev ships (dummy empty uv_rows)
) -> RingBuf:
    if with_uv:
        uv = jax.lax.dynamic_update_slice(
            buf.uv, uv_rows[None], (instance, slot, jnp.int32(0))
        )
    else:
        uv = buf.uv
    if with_prev:
        prev = jax.lax.dynamic_update_slice(
            buf.prev, prev_rows[None], (instance, slot)
        )
    else:
        prev = buf.prev
    return RingBuf(uv, prev)


def scan_compile_counts() -> dict:
    """Live jit-cache sizes of the three driver kernels — the retrace
    budget the pow2-``Rq`` quantization exists to bound.

    ``_run_scan_resident`` / ``_run_scan_ring`` compile once per distinct
    (core static config, n_steps, carry/stream shapes); ``_ring_write``
    once per distinct refill-span shape, which quantization keeps to the
    multiples of ``Rq`` up to ``max_span`` plus at most one ragged
    final-tail span per instance. tests/test_compile_budget.py asserts the
    bound over random geometries; benchmarks/run.py emits the counts into
    ``BENCH_<n>.json`` so retrace regressions show up in the perf
    trajectory. Returns zeros if the jax version hides ``_cache_size``.
    """
    return {
        name: int(getattr(fn, "_cache_size", lambda: 0)())
        for name, fn in (
            ("run_scan_resident", _run_scan_resident),
            ("run_scan_ring", _run_scan_ring),
            ("ring_write", _ring_write),
        )
    }


# ----------------------------------------------------------------------------
# Chunk sources
# ----------------------------------------------------------------------------


class RingHandle(NamedTuple):
    """Cross-pass hand-off of a completed ring pass (file mode).

    Produced by :class:`ScanDriver` after a ring drive finishes; a
    re-streaming pass with identical geometry may adopt it via
    ``FileSource(resume=...)`` so instances whose whole stream fit in the
    ring without wrapping keep their uv rows device-resident and ship only
    prev placements. The handle is single-use: the adopting pass donates
    the buffer back into its own scan calls.
    """

    buf: RingBuf  # final donated ring (valid until the next pass donates it)
    hi: np.ndarray  # (z,) per-instance upload high-water marks at pass end
    B: int  # ring rows per instance
    z: int
    m_per: np.ndarray  # (z,) real stream lengths the pass ran over


class StreamResidency:
    """Cross-pass device residency for resident (in-memory) sources.

    A re-streaming caller creates one holder and threads it through every
    pass; pass p publishes its uploaded ``(z, per, 2)`` device stream
    array(s) here and pass p+1 reuses them, shipping only the new ``prev``
    table. Length-bucketed batching (`partition_stream_batched`) uploads one
    array per pow2 bucket, so the holder keys residency by shape — every
    bucket of the next pass finds its own resident array. Caller contract:
    every pass must stream the SAME edge content in the same instance
    layout — only the shape is cheap to verify, so the holder must never be
    shared across different streams.
    """

    __slots__ = ("_by_shape",)

    def __init__(self) -> None:
        self._by_shape: dict[Tuple[int, ...], jax.Array] = {}

    def publish(self, streams: jax.Array, shape: Tuple[int, ...]) -> None:
        self._by_shape[tuple(shape)] = streams

    def lookup(self, shape: Tuple[int, ...]) -> Optional[jax.Array]:
        return self._by_shape.get(tuple(shape))


# One staged block: (start_row, row_count, uv rows or None, prev rows or
# None). uv is None for cross-pass resumed instances (prev-only refills).
_Block = Tuple[int, int, Optional[np.ndarray], Optional[np.ndarray]]


class _ReadAhead:
    """Host read-ahead worker: stage stream/prev rows while the scan runs.

    One daemon thread services all z instances round-robin, reading
    ``Rq``-row blocks (final ragged tail ends exactly at ``m_i``) into a
    bounded per-instance staging deque, at most ``depth_rows`` rows past
    what :meth:`take` has consumed. Every refill span is a whole number of
    Rq blocks (or ends exactly at ``m_i`` — see the FileSource sizing), so
    ``take`` always pops whole blocks and never splits one.

    Disk reads happen OUTSIDE the lock (the lock only guards the deques and
    the progress counters); worker exceptions are captured and re-raised in
    the consumer's next ``take``. ``close`` is idempotent and joins the
    thread — safe on every exception path.
    """

    def __init__(self, source: "FileSource", depth_rows: int) -> None:
        self._src = source
        self._depth = int(depth_rows)
        self._cv = threading.Condition()
        z = source.z
        self._staged: List[Deque[_Block]] = [
            collections.deque() for _ in range(z)
        ]
        # Worker-side read position and consumer-side pop position per
        # instance; both only ever advance.
        self._next = np.zeros((z,), np.int64)
        self._taken = np.zeros((z,), np.int64)
        self._exc: Optional[BaseException] = None
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, name="adwise-readahead", daemon=True
        )
        self._thread.start()

    # -- worker side -------------------------------------------------------
    def _pick(self) -> Optional[int]:
        """Least-staged eligible instance, or None (caller holds the lock)."""
        src = self._src
        best, best_lag = None, 0
        for i in range(src.z):
            if self._next[i] >= src.m_per[i]:
                continue  # instance fully staged
            lag = int(self._next[i] - self._taken[i])
            if lag >= self._depth:
                continue  # at the bound: wait for the consumer
            if best is None or lag < best_lag:
                best, best_lag = i, lag
        return best

    def _loop(self) -> None:
        src = self._src
        try:
            while True:
                with self._cv:
                    while True:
                        if self._stop:
                            return
                        i = self._pick()
                        if i is not None:
                            break
                        if (self._next >= src.m_per).all():
                            return  # everything staged; worker retires
                        self._cv.wait()
                    start = int(self._next[i])
                    c = min(src.Rq, int(src.m_per[i]) - start)
                # Reads outside the lock: the consumer keeps popping while
                # the worker is on disk.
                trace = src.trace
                t_stage = time.perf_counter()
                uv: Optional[np.ndarray] = None
                if not src.uv_resident[i]:
                    uv = np.ascontiguousarray(
                        src.readers[i].read(start, c), np.int32
                    )
                    assert len(uv) == c, (
                        f"instance {i}: reader returned {len(uv)} of {c} "
                        f"rows at offset {start}"
                    )
                prev: Optional[np.ndarray] = None
                if src.prev_read is not None:
                    prev = np.ascontiguousarray(
                        src.prev_read[i](start, c), np.int32
                    )
                    assert len(prev) == c, (
                        f"instance {i}: prev_read returned {len(prev)} of "
                        f"{c} rows at offset {start}"
                    )
                t_staged = time.perf_counter()
                if trace.enabled:
                    # Recorded from the worker thread, so the span lands on
                    # the `adwise-readahead` track.
                    trace.add_span(
                        "stage", "stage", t_stage, t_staged,
                        attrs=dict(instance=i, start=start, rows=c,
                                   prev=prev is not None),
                    )
                with self._cv:
                    # Worker-side staging wall: the blind spot h2d_wait_s
                    # (blocking refills only) cannot see. Accumulated even
                    # when untraced so overlap_efficiency is always measured.
                    src.prestage_wall_s += t_staged - t_stage
                    self._staged[i].append((start, c, uv, prev))
                    self._next[i] = start + c
                    if trace.enabled:
                        depth = int((self._next - self._taken).sum())
                    self._cv.notify_all()
                if trace.enabled:
                    trace.gauge("readahead_staged_rows", depth)
        except BaseException as e:  # surfaced via take(); thread must not die silently
            with self._cv:
                self._exc = e
                self._cv.notify_all()

    # -- consumer side -----------------------------------------------------
    def take(
        self, i: int, start: int, count: int
    ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray], bool]:
        """Pop ``count`` staged rows of instance i beginning at ``start``.

        Returns ``(uv_rows, prev_rows, waited)`` — ``waited`` is True when
        the consumer had to block on the worker (a pipeline miss).
        """
        end = start + count
        uv_parts: List[np.ndarray] = []
        prev_parts: List[np.ndarray] = []
        waited = False
        with self._cv:
            assert start == int(self._taken[i]), (
                f"instance {i}: take at {start}, staged position is "
                f"{int(self._taken[i])}"
            )
            while self._taken[i] < end:
                if self._exc is not None:
                    raise RuntimeError(
                        "read-ahead worker failed"
                    ) from self._exc
                if self._staged[i]:
                    b_start, c, uv, prev = self._staged[i].popleft()
                    assert b_start == int(self._taken[i])
                    assert b_start + c <= end, (
                        f"instance {i}: staged block [{b_start}, "
                        f"{b_start + c}) straddles take end {end} — "
                        "span/block alignment broken"
                    )
                    if uv is not None:
                        uv_parts.append(uv)
                    if prev is not None:
                        prev_parts.append(prev)
                    self._taken[i] = b_start + c
                    self._cv.notify_all()  # freed depth: wake the worker
                else:
                    waited = True
                    self._cv.wait()
        uv_all = (
            uv_parts[0] if len(uv_parts) == 1
            else np.concatenate(uv_parts) if uv_parts else None
        )
        prev_all = (
            prev_parts[0] if len(prev_parts) == 1
            else np.concatenate(prev_parts) if prev_parts else None
        )
        return uv_all, prev_all, waited

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=30.0)


class ResidentSource:
    """Whole stream resident on device: ONE upload for the entire run.

    ``streams`` is the (z, per, 2) padded instance layout
    (:meth:`repro.graph.stream.EdgeStream.split_padded`); ``m_per[i]`` is the
    real (un-padded) length of instance i's stream. z == 1 wraps a plain
    (m, 2) stream as (1, m, 2).

    ``residency`` (optional :class:`StreamResidency`) lets re-streaming
    passes over the same stream reuse the previous pass's uploaded device
    array: when the holder already has a matching-shape array, the driver
    skips the stream upload and ships only the new ``prev`` table.
    """

    resident = True

    def __init__(
        self,
        streams: np.ndarray,
        m_per: np.ndarray,
        *,
        residency: Optional[StreamResidency] = None,
    ) -> None:
        streams = np.ascontiguousarray(streams, np.int32)
        assert streams.ndim == 3 and streams.shape[2] == 2, streams.shape
        self.z, self.per = int(streams.shape[0]), int(streams.shape[1])
        self.m_per = np.asarray(m_per, np.int64)
        assert self.m_per.shape == (self.z,)
        assert (self.m_per <= self.per).all()
        self.streams = streams
        self.residency = residency

    @property
    def upload_rows(self) -> int:
        return self.z * self.per


class FileSource:
    """Bounded device-resident ring buffer over per-instance stream readers.

    ``readers[i]`` is instance i's locally addressed stream (an
    ``EdgeFileReader`` / sub-reader, or anything with ``num_edges`` and
    ``read(start, count)``); ``prev_read[i](start, count)`` optionally
    supplies the prior pass's placements for buffered re-streaming
    revocation.

    Sizing (strategy-agnostic, driven by the step-core's look-ahead and
    consumption bounds ``W = core.window_rows``, ``b = core.rows_per_step``
    — ADWISE: ``window_max`` / ``assign_batch``, single-edge baselines
    0 / 1): ``S = (B0 - W) // b`` scan steps per call consume at most
    ``F = W + S · b`` rows (look-ahead refill ceiling + per-step
    assignments — the PR-4 cursor-advance bound), where
    ``B0 = max(chunk_edges, W + b)``. Refills are quantized to spans that
    are multiples of ``Rq`` (a power of two, so the `dynamic_update_slice`
    kernel compiles for a bounded shape set); the ring holds
    ``B = (⌈F/Rq⌉ + 2) · Rq`` rows, so a quantized refill always leaves
    ≥ F uploaded-but-unread rows ahead of the cursor while never
    overwriting a live slot (row ``s`` may land in slot ``s % B`` only once
    row ``s − B`` is behind the cursor).

    Invariants (checked): ``cursor ≤ hi ≤ cursor + B`` and ``hi`` advances
    monotonically — every stream row is read from disk and shipped to the
    device exactly once per pass.

    ``prefetch >= 1`` enables the double-buffer pipeline (module docstring):
    a :class:`_ReadAhead` worker stages up to ``prefetch * max_span`` rows
    ahead of consumption, and the driver issues a speculative refill before
    its per-call counter sync. ``prefetch=0`` is the synchronous bit-parity
    path. ``resume`` adopts a previous pass's :class:`RingHandle` —
    matching-geometry instances that never wrapped ship prev-only spans
    (4 B/row instead of 12 B/row).
    """

    resident = False

    def __init__(
        self,
        readers: Sequence,
        *,
        chunk_edges: int,
        cfg: Optional[AdwiseConfig] = None,
        core: Optional[StepCore] = None,
        prev_read: Optional[List[Callable[[int, int], np.ndarray]]] = None,
        prefetch: Optional[int] = None,
        resume: Optional[RingHandle] = None,
        trace: Any = None,
    ) -> None:
        self.trace = resolve_tracer(trace)
        self.readers = list(readers)
        self.z = len(self.readers)
        self.m_per = np.array([r.num_edges for r in self.readers], np.int64)
        self.prev_read = prev_read
        if core is not None:
            w_max, b = core.window_rows, core.rows_per_step
        else:
            assert cfg is not None, "FileSource needs a cfg or a step-core"
            w_max, b = cfg.window_max, cfg.assign_batch
        b0 = int(max(chunk_edges, w_max + b))
        self.scan_steps = max(1, (b0 - w_max) // b)
        f = w_max + self.scan_steps * b  # worst-case rows consumed per call
        self.Rq = 1 << max(2, (max(f // 8, 1)).bit_length())
        self.B = (-(-f // self.Rq) + 2) * self.Rq
        # Single disk reads (and update-kernel spans) stay within the
        # b0 = max(chunk_edges, window_max + assign_batch) bound even though
        # the ring is slightly larger; kept a multiple of Rq so span shapes
        # stay quantized.
        self.max_span = max(self.Rq, (b0 // self.Rq) * self.Rq)
        # Host-side high-water mark: rows [0, hi) are on device.
        self.hi = np.zeros((self.z,), np.int64)
        self.h2d_rows = 0
        self.h2d_bytes = 0
        self.h2d_calls = 0
        self.h2d_wait_s = 0.0
        self.prestage_wall_s = 0.0
        self.refill_spans = 0
        self.spans_prestaged = 0
        self.spans_missed = 0
        self.prefetch = resolve_prefetch(prefetch)
        # uv_resident[i]: instance i's uv rows survive from the adopted
        # previous-pass ring — refills ship prev-only spans.
        self.uv_resident = np.zeros((self.z,), bool)
        self._resume_buf: Optional[RingBuf] = None
        if resume is not None:
            self._adopt(resume)
        self._worker: Optional[_ReadAhead] = None
        self._worker_started = False

    def _adopt(self, resume: RingHandle) -> None:
        """Adopt a previous pass's ring under the cross-pass contract:
        same geometry (B, z, per-instance m), and only instances whose
        whole stream fit without wrapping (``m_i <= B`` and the pass
        uploaded all of it) keep uv residency."""
        assert self.prev_read is not None, (
            "resuming a ring without prev_read would re-run the same pass; "
            "cross-pass adoption is for re-streaming revocation only"
        )
        if (
            resume.B != self.B
            or resume.z != self.z
            or not (np.asarray(resume.m_per) == self.m_per).all()
        ):
            return  # geometry changed (re-chunked): full re-ship fallback
        fits = (self.m_per <= resume.B) & (np.asarray(resume.hi) >= self.m_per)
        if fits.any():
            self.uv_resident = fits
            self._resume_buf = resume.buf
            if self.trace.enabled:
                self.trace.instant(
                    "ring-adopt", "refill",
                    resident_instances=int(fits.sum()), z=self.z, B=self.B,
                )

    def alloc(self) -> RingBuf:
        """Device ring for this pass: the adopted previous-pass buffer when
        resuming (single-use — it is donated back into this pass's scan
        calls), else a fresh one: uv zeros, prev all -1 (= no prior
        placement — 0 would be a real partition id and would trigger false
        revocation). Stale prev rows in an adopted ring are harmless: hi
        restarts at 0, so every row's prev is re-shipped before the cursor
        can reach it."""
        if self._resume_buf is not None:
            buf = self._resume_buf
            self._resume_buf = None
            return buf
        return RingBuf(
            uv=jnp.zeros((self.z, self.B, 2), jnp.int32),
            prev=jnp.full((self.z, self.B), -1, jnp.int32),
        )

    def _fetch(
        self, i: int, start: int, c: int
    ) -> Tuple[Optional[np.ndarray], Optional[np.ndarray], bool]:
        """One span's host rows: from the staging queue when pipelined,
        read inline otherwise. Lazily starts the worker so sizing-only
        FileSource uses never spawn a thread."""
        if self.prefetch > 0 and not self._worker_started:
            self._worker_started = True
            self._worker = _ReadAhead(
                self, max(1, self.prefetch) * self.max_span
            )
        if self._worker is not None:
            return self._worker.take(i, start, c)
        uv: Optional[np.ndarray] = None
        if not self.uv_resident[i]:
            uv = np.ascontiguousarray(self.readers[i].read(start, c), np.int32)
            assert len(uv) == c, (
                f"instance {i}: reader returned {len(uv)} of {c} rows "
                f"at offset {start}"
            )
        prev: Optional[np.ndarray] = None
        if self.prev_read is not None:
            prev = np.ascontiguousarray(self.prev_read[i](start, c), np.int32)
        # The synchronous path stalls on every span by construction.
        return uv, prev, True

    def refill(
        self, buf: RingBuf, cursors: np.ndarray, *, speculative: bool = False
    ) -> RingBuf:
        """Ship the new tail rows for every instance; returns the new ring.

        ``cursors[i]`` is instance i's scan cursor — rows behind it are dead
        and their slots are free to overwrite. A ``speculative`` refill
        passes the guaranteed-progress lower bound instead of the true
        cursor (see the module docstring) and is excluded from the measured
        ``h2d_wait_s`` stall: its staging work overlaps the in-flight scan.
        """
        self.h2d_calls += 1
        trace = self.trace
        traced = trace.enabled
        t_start = time.perf_counter() if (traced or not speculative) else 0.0
        shipped_rows = 0
        call_spans = 0
        call_missed = 0
        with_prev = self.prev_read is not None
        dummy_uv = np.zeros((0, 2), np.int32)
        dummy_prev = np.zeros((0,), np.int32)
        for i in range(self.z):
            cur = int(cursors[i])
            m_i = int(self.m_per[i])
            hi = int(self.hi[i])
            assert cur <= hi, (
                f"instance {i}: scan cursor {cur} overran the uploaded "
                f"high-water mark {hi} — ring refill bound violated"
            )
            target = min(cur + self.B, m_i)
            if target <= hi:
                continue
            span_total = target - hi
            if target < m_i:
                # Quantize to Rq blocks (bounded kernel-shape set); the
                # remainder is covered because B ≥ F + 2·Rq keeps ≥ F rows
                # ahead of the cursor even after flooring.
                span_total -= span_total % self.Rq
            end = hi + span_total
            ship_uv = not bool(self.uv_resident[i])
            while hi < end:
                slot = hi % self.B
                # Never wrap inside a write; never exceed the chunk bound.
                c = min(end - hi, self.B - slot, self.max_span)
                if traced:
                    t_fetch = time.perf_counter()
                rows, prows, waited = self._fetch(i, hi, c)
                if traced:
                    trace.add_span(
                        "fetch", "fetch", t_fetch, time.perf_counter(),
                        attrs=dict(instance=i, start=hi, rows=c,
                                   prestaged=not waited),
                    )
                self.refill_spans += 1
                call_spans += 1
                if waited:
                    self.spans_missed += 1
                    call_missed += 1
                else:
                    self.spans_prestaged += 1
                buf = _ring_write(
                    buf,
                    rows if rows is not None else dummy_uv,
                    prows if prows is not None else dummy_prev,
                    np.int32(i),
                    np.int32(slot),
                    with_prev=with_prev,
                    with_uv=ship_uv,
                )
                if ship_uv:
                    self.h2d_rows += c
                    self.h2d_bytes += c * 8
                if with_prev:
                    self.h2d_bytes += c * 4
                shipped_rows += c
                hi += c
            self.hi[i] = hi
        if not speculative:
            t_end = time.perf_counter()
            self.h2d_wait_s += t_end - t_start
            if traced:
                # Same (t_start, t_end) floats that fed h2d_wait_s: the
                # `refill` category total reconciles with it exactly.
                trace.add_span(
                    "refill", "refill", t_start, t_end,
                    attrs=dict(rows=shipped_rows, spans=call_spans,
                               missed=call_missed, Rq=self.Rq),
                )
        elif traced and call_spans:
            trace.add_span(
                "refill-spec", "refill-spec", t_start, time.perf_counter(),
                attrs=dict(rows=shipped_rows, spans=call_spans,
                           missed=call_missed, Rq=self.Rq),
            )
        return buf

    def close(self) -> None:
        """Join the read-ahead worker (idempotent; safe on exception paths).
        After close, further refills fall back to synchronous reads."""
        if self._worker is not None:
            self._worker.close()
            self._worker = None

    def __enter__(self) -> "FileSource":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


# ----------------------------------------------------------------------------
# The driver
# ----------------------------------------------------------------------------


class DriveResult(NamedTuple):
    """Raw outcome of one driven scan; callers assemble their stats shapes."""

    # Per-instance step outputs, concatenated over every scan call — only
    # collected in resident mode (the file path streams them to `on_assign`
    # to stay O(chunk)): (z, T·b) / (z, T·b) / (z, T).
    sidx: Optional[np.ndarray]
    p: Optional[np.ndarray]
    w_trace: Optional[np.ndarray]
    # Final carry counters, one row per instance.
    assigned: np.ndarray  # (z,) int
    score_rows: np.ndarray  # (z,) int
    final_w: np.ndarray  # (z,) int
    lam: np.ndarray  # (z,) f32
    cost_per_score: np.ndarray  # (z,) f32
    # Run-level accounting.
    wall_time_s: float
    r_sel: int
    backend: str
    n_shards: int
    scan_calls: int
    h2d_rows: int
    h2d_bytes: int
    buffer_rows: int  # ring B (file mode) / per (resident mode)
    scan_steps_per_call: int
    # Refill-pipeline accounting (file mode; zeros for resident sources).
    h2d_wait_s: float = 0.0  # wall spent in non-speculative (blocking) refills
    prefetch_depth: int = 0
    refill_spans: int = 0
    spans_prestaged: int = 0
    spans_missed: int = 0
    # Worker-side staging wall (read-ahead thread): the time spent reading
    # and preparing spans the blocking h2d_wait_s stall cannot see.
    prestage_wall_s: float = 0.0


class ScanDriver:
    """One streaming-scan engine for every step-core strategy.

    Owns carry initialization (cold or warm-started from per-instance
    :class:`~repro.core.types.WarmState`), capacity-cap resolution,
    latency-budget wiring (including the between-chunks wall-clock
    recalibration of the modeled cost), backend/shard resolution, and the
    chunked stepping loop over the given source. Callers stay thin: they
    build a source and a step-core (or pass an :class:`AdwiseConfig`, which
    wraps into an :class:`AdwiseCore`), run the driver, and format stats.
    """

    def __init__(
        self,
        source: Any,  # a ResidentSource or FileSource (anything source-shaped)
        core: Any,  # a StepCore, or an AdwiseConfig (compat: wraps AdwiseCore)
        num_vertices: Optional[int] = None,
        *,
        allowed: Optional[np.ndarray] = None,  # (z, k) bool
        warm: Optional[Sequence[WarmState]] = None,
        cost_per_score: Optional[float] = None,
        backend: str = "vmap",
        trace: Any = None,
        instance_ids: Optional[np.ndarray] = None,
    ) -> None:
        self.trace = resolve_tracer(trace)
        # A traced driver over an untraced FileSource adopts the driver's
        # tracer, so refill/stage spans land in the same timeline without
        # every caller having to thread trace= twice.
        src_trace = getattr(source, "trace", None)
        if self.trace.enabled and src_trace is not None and not src_trace.enabled:
            source.trace = self.trace
        self.source = source
        if isinstance(core, AdwiseConfig):
            assert num_vertices is not None, "AdwiseConfig path needs |V|"
            self.cfg: Optional[AdwiseConfig] = core
            core = AdwiseCore(
                cfg=core, num_vertices=num_vertices, update_deg=warm is None
            )
        else:
            self.cfg = getattr(core, "cfg", None)
        self.core = core
        self.num_vertices = num_vertices
        z, k = source.z, core.k
        self.z = z
        self.m_per = source.m_per
        self.r_sel = core.r_sel

        if allowed is None:
            allowed_np = np.ones((z, k), bool)
        else:
            allowed_np = np.asarray(allowed, bool)
            assert allowed_np.shape == (z, k), (allowed_np.shape, (z, k))
        caps = np.array(
            [
                core.cap_value(int(self.m_per[i]), max(int(allowed_np[i].sum()), 1))
                for i in range(z)
            ],
            np.int32,
        )

        self.has_budget = bool(core.has_budget)
        budget = 0.0
        if self.has_budget and self.cfg is not None:
            budget = self.cfg.latency_budget or 0.0
        self.warm = warm is not None
        per = int(getattr(source, "per", 0))
        prev_np: Optional[np.ndarray] = (
            np.full((z, per), -1, np.int32) if source.resident else None
        )
        if warm is None:
            base = core.init_carry(budget)
            carry = jax.tree.map(lambda x: jnp.broadcast_to(x, (z,) + x.shape), base)
        else:
            assert len(warm) == z, f"need one WarmState per instance, got {len(warm)}"
            has_prev = [w.prev_assign is not None for w in warm]
            assert all(has_prev) or not any(has_prev), (
                "all instances must agree on whether prev_assign is provided"
            )
            # File mode feeds prior placements through the source's
            # prev_read range reads, never through resident prev arrays —
            # silently dropping them would skip revocation.
            assert source.resident or not any(has_prev), (
                "file-mode warm states must not carry prev_assign; pass "
                "prev_read to the FileSource instead"
            )
            carries = [core.warm_carry(budget, w) for w in warm]
            carry = jax.tree.map(lambda *xs: jnp.stack(xs), *carries)
            if prev_np is not None and all(has_prev):
                for i, w in enumerate(warm):
                    assert w.prev_assign is not None  # all(has_prev) above
                    pa = np.asarray(w.prev_assign, np.int32)
                    assert pa.shape == (int(self.m_per[i]),), (
                        f"instance {i}: prev_assign must align with its stream"
                    )
                    prev_np[i, : len(pa)] = pa
        if instance_ids is None:
            ids = np.arange(z)
        else:
            ids = np.asarray(instance_ids)
            assert ids.shape == (z,), (ids.shape, z)
        carry = core.seed_instances(carry, z, ids)
        self.fixed_cost = cost_per_score is not None
        if cost_per_score is not None:
            carry = core.set_cost(carry, cost_per_score, z)
        self.carry = carry
        self.backend, self.n_shards = resolve_backend(backend, z)
        self._m_real_j = jnp.asarray(self.m_per.astype(np.int32))
        self._allowed_j = jnp.asarray(allowed_np)
        self._caps_j = jnp.asarray(caps)
        self._prev_np = prev_np
        # Set after a completed ring drive: the cross-pass hand-off a
        # re-streaming pass may adopt (FileSource(resume=...)).
        self.ring_handle: Optional[RingHandle] = None

    # -- budget recalibration (shared by both modes) -----------------------
    def _recalibrate(self, carry: Any, t0: float) -> Any:
        if not (self.has_budget and not self.fixed_cost):
            return carry
        return self.core.recalibrate(carry, t0, self.z)

    # -- resident mode -----------------------------------------------------
    def _run_resident(self, n_chunks: int) -> DriveResult:
        src, core = self.source, self.core
        z, b = self.z, core.rows_per_step
        m_max = int(self.m_per.max())
        # Scan-step provisioning sized by the largest instance (smaller ones
        # idle); the drain below covers top-b pick stalls (star graphs with
        # rows_per_step > 1 assign one edge per step, not b — each step with
        # a non-empty window assigns >= 1 edge, so ceil(m/chunk_steps) extra
        # chunks always finish).
        steps_total = -(-m_max // b) + -(-core.window_rows // b) + 2
        n_chunks = max(1, min(n_chunks, steps_total))
        chunk_steps = -(-steps_total // n_chunks)
        n_chunks = -(-steps_total // chunk_steps)

        prev_np = self._prev_np
        assert prev_np is not None  # resident mode always builds prev table
        residency: Optional[StreamResidency] = getattr(src, "residency", None)
        resident_streams = (
            residency.lookup(src.streams.shape) if residency is not None
            else None
        )
        if resident_streams is not None:
            # Cross-pass residency: the stream array is already on device
            # from the previous pass — only the new prev table ships.
            streams_j = resident_streams
            h2d_rows = 0
            h2d_bytes = prev_np.size * 4
        else:
            streams_j = jnp.asarray(src.streams)
            h2d_rows = src.upload_rows
            h2d_bytes = src.upload_rows * 8 + prev_np.size * 4
        if residency is not None:
            residency.publish(streams_j, src.streams.shape)
        prev_j = jnp.asarray(prev_np)
        carry = self.carry

        def run_chunk(carry: Any) -> Any:
            return _run_scan_resident(
                carry, streams_j, self._m_real_j, self._allowed_j,
                self._caps_j, prev_j,
                core=core, n_steps=chunk_steps, n_shards=self.n_shards,
            )

        trace = self.trace
        traced = trace.enabled
        outs = []
        calls = 0
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            if traced:
                t_call = time.perf_counter()
                cc0 = scan_compile_counts()["run_scan_resident"]
            carry, out = run_chunk(carry)
            calls += 1
            # Device handles only — materializing here would sync the host
            # to every chunk and serialize dispatch (SC003); the transfer
            # happens once, after the stepping loop.
            outs.append(out)
            if traced:
                # Dispatch-only span: the provisioned loop never syncs, so
                # this measures trace/compile/enqueue time, not device wall.
                trace.add_span(
                    "scan-call", "scan", t_call, time.perf_counter(),
                    attrs=dict(call=calls, steps=chunk_steps, mode="dispatch",
                               compiled=scan_compile_counts()[
                                   "run_scan_resident"] > cc0),
                )
            carry = self._recalibrate(carry, t0)
        drain_left = -(-m_max // chunk_steps) + 2
        # staticcheck: disable=SC003 drain termination must observe `assigned`; one sync per extra call, none in the provisioned loop
        while (np.asarray(carry.assigned) < self.m_per).any() and drain_left > 0:
            if traced:
                t_call = time.perf_counter()
            carry, out = run_chunk(carry)
            calls += 1
            outs.append(out)
            if traced:
                trace.add_span(
                    "scan-call", "scan", t_call, time.perf_counter(),
                    attrs=dict(call=calls, steps=chunk_steps, mode="drain"),
                )
            drain_left -= 1
        if traced:
            t_mat = time.perf_counter()
        outs = [jax.tree.map(np.asarray, o) for o in outs]
        if traced:
            trace.add_span(
                "materialize", "host", t_mat, time.perf_counter(),
                attrs=dict(calls=calls),
            )
        wall = time.perf_counter() - t0
        self.carry = carry
        return self._result(
            carry, wall,
            sidx=np.concatenate([o.sidx.reshape(z, -1) for o in outs], axis=1),
            p=np.concatenate([o.p.reshape(z, -1) for o in outs], axis=1),
            w_trace=np.concatenate([o.w_cap.reshape(z, -1) for o in outs], axis=1),
            scan_calls=calls, h2d_rows=h2d_rows, h2d_bytes=h2d_bytes,
            buffer_rows=src.per, steps_per_call=chunk_steps,
        )

    # -- ring (file) mode --------------------------------------------------
    def _run_ring(
        self, on_assign: Callable[[int, np.ndarray, np.ndarray], None]
    ) -> DriveResult:
        src, core = self.source, self.core
        z = self.z
        m_max = int(self.m_per.max())
        S = src.scan_steps
        pipelined = src.prefetch > 0
        carry = self.carry
        iters = 0
        # Every step with a non-empty window assigns >= 1 edge per instance
        # (capacity caps sum to > m, so an allowed partition below cap always
        # exists), so total steps are bounded by m_max plus the window
        # build-up.
        max_iters = -(-(m_max + core.window_rows) // S) + 8
        # Host mirrors of the synced counters, one sync per scan call. The
        # loop body is ordered for the pipeline: top-up refill (true cursor)
        # -> dispatch scan k -> SPECULATIVE refill for call k+1 (the
        # guaranteed-progress lower bound, enqueued before the sync so the
        # h2d overlaps scan k) -> the one assigned/cursor sync -> emit.
        # At prefetch=0 the speculative refill is skipped and the sequence
        # of refills/scans is identical to the classic synchronous loop.
        assigned = np.zeros((z,), np.int64)
        cursors = np.zeros((z,), np.int64)
        trace = self.trace
        traced = trace.enabled
        done_before = 0
        try:
            buf = src.alloc()
            t0 = time.perf_counter()
            while not (assigned >= self.m_per).all():
                iters += 1
                assert iters <= max_iters, (
                    f"streaming scan failed to converge: {assigned} of "
                    f"{self.m_per} assigned after {iters} calls"
                )
                buf = src.refill(buf, cursors)
                if traced:
                    t_call = time.perf_counter()
                    cc0 = scan_compile_counts()["run_scan_ring"]
                (carry, buf), out = _run_scan_ring(
                    (carry, buf), self._m_real_j, self._allowed_j,
                    self._caps_j,
                    core=core, n_steps=S, n_shards=self.n_shards,
                )
                if pipelined:
                    # Safe without syncing: the in-flight call advances
                    # every unfinished instance by >= S assignments, so rows
                    # below lb are dead for every future scan; the donated
                    # ring orders this write after the in-flight scan.
                    lb = np.minimum(assigned + S, self.m_per)
                    buf = src.refill(buf, lb, speculative=True)
                # staticcheck: disable=SC003 ring-mode termination: ONE assigned-counter sync per scan call, amortized over S steps
                assigned = np.asarray(carry.assigned).astype(np.int64)
                # staticcheck: disable=SC003 next refill needs the host cursor to size disk reads; same single sync point per call
                cursors = np.asarray(carry.cursor).astype(np.int64)
                # staticcheck: disable=SC003 file mode streams placements to on_assign to stay O(chunk) — per-call materialization is the design
                sidx = np.asarray(out.sidx).reshape(z, -1)
                # staticcheck: disable=SC003 same spill materialization as sidx above
                pout = np.asarray(out.p).reshape(z, -1)
                for i in range(z):
                    live = sidx[i] >= 0
                    if live.any():
                        on_assign(
                            i, sidx[i][live].astype(np.int64), pout[i][live]
                        )
                if traced:
                    # Dispatch -> speculative refill -> the per-call sync ->
                    # emit: the whole host wait for scan call k. `rows` stays
                    # an np scalar (no int() on synced mirrors on this hot
                    # path); the exporter unwraps it.
                    done = assigned.sum()
                    trace.add_span(
                        "scan-call", "scan", t_call, time.perf_counter(),
                        attrs=dict(call=iters, steps=S,
                                   rows=done - done_before,
                                   compiled=scan_compile_counts()[
                                       "run_scan_ring"] > cc0),
                    )
                    done_before = done
                carry = self._recalibrate(carry, t0)
            assert (cursors <= src.hi).all(), (
                f"scan cursors {cursors} overran uploaded rows {src.hi}"
            )
            wall = time.perf_counter() - t0
        finally:
            src.close()
        self.carry = carry
        self.ring_handle = RingHandle(
            buf=buf, hi=src.hi.copy(), B=src.B, z=z, m_per=self.m_per.copy()
        )
        return self._result(
            carry, wall, sidx=None, p=None, w_trace=None,
            scan_calls=iters, h2d_rows=src.h2d_rows, h2d_bytes=src.h2d_bytes,
            buffer_rows=src.B, steps_per_call=S,
            h2d_wait_s=src.h2d_wait_s, prefetch_depth=src.prefetch,
            refill_spans=src.refill_spans,
            spans_prestaged=src.spans_prestaged,
            spans_missed=src.spans_missed,
            prestage_wall_s=src.prestage_wall_s,
        )

    def _result(
        self,
        carry: Any,
        wall: float,
        *,
        sidx: Optional[np.ndarray],
        p: Optional[np.ndarray],
        w_trace: Optional[np.ndarray],
        scan_calls: int,
        h2d_rows: int,
        h2d_bytes: int,
        buffer_rows: int,
        steps_per_call: int,
        h2d_wait_s: float = 0.0,
        prefetch_depth: int = 0,
        refill_spans: int = 0,
        spans_prestaged: int = 0,
        spans_missed: int = 0,
        prestage_wall_s: float = 0.0,
    ) -> DriveResult:
        cnt = self.core.counters(carry)
        return DriveResult(
            sidx=sidx,
            p=p,
            w_trace=w_trace,
            assigned=np.asarray(carry.assigned),
            score_rows=np.asarray(cnt["score_rows"]),
            final_w=np.asarray(cnt["final_w"]),
            lam=np.asarray(cnt["lam"]),
            cost_per_score=np.asarray(cnt["cost_per_score"]),
            wall_time_s=wall,
            r_sel=self.r_sel,
            backend=self.backend,
            n_shards=self.n_shards,
            scan_calls=scan_calls,
            h2d_rows=int(h2d_rows),
            h2d_bytes=int(h2d_bytes),
            buffer_rows=int(buffer_rows),
            scan_steps_per_call=int(steps_per_call),
            h2d_wait_s=float(h2d_wait_s),
            prefetch_depth=int(prefetch_depth),
            refill_spans=int(refill_spans),
            spans_prestaged=int(spans_prestaged),
            spans_missed=int(spans_missed),
            prestage_wall_s=float(prestage_wall_s),
        )

    def run(
        self,
        *,
        n_chunks: int = 8,
        on_assign: Optional[Callable[[int, np.ndarray, np.ndarray], None]] = None,
    ) -> DriveResult:
        """Drive the scan to completion.

        Resident sources step through ``n_chunks`` provisioned scan calls
        (+ drain) and return the collected step outputs; file sources loop
        refill→scan until every instance has assigned its stream, emitting
        finished placements through ``on_assign(i, local_idx, p)`` (required
        — the file path never holds O(m) outputs).
        """
        if self.source.resident:
            return self._run_resident(n_chunks)
        assert on_assign is not None, "file-mode driving requires on_assign"
        return self._run_ring(on_assign)

    def stats_base(self, res: DriveResult, instance: int) -> dict:
        """The shared per-instance stat fields every caller reports."""
        return dict(
            k=self.core.k,
            name=self.core.name,
            wall_time_s=res.wall_time_s,
            score_rows=int(res.score_rows[instance]),
            score_count=int(res.score_rows[instance]) * self.core.k,
            final_w=int(res.final_w[instance]),
            lam_final=float(res.lam[instance]),
            assigned=int(res.assigned[instance]),
            warm=self.warm,
            r_sel=res.r_sel,
            modeled_cost_per_score=float(res.cost_per_score[instance]),
            scan_calls=res.scan_calls,
            h2d_rows=res.h2d_rows,
            h2d_bytes=res.h2d_bytes,
            buffer_rows=res.buffer_rows,
            scan_steps_per_call=res.scan_steps_per_call,
            h2d_wait_s=res.h2d_wait_s,
            prefetch_depth=res.prefetch_depth,
            refill_spans=res.refill_spans,
            spans_prestaged=res.spans_prestaged,
            spans_missed=res.spans_missed,
            prestage_wall_s=res.prestage_wall_s,
        )
