"""Gradient compression for data-parallel reduction (top-k + error feedback).

On bandwidth-bound DP meshes the gradient all-reduce dominates step time.
`topk_compress_allreduce` keeps the top ρ fraction of gradient magnitudes per
leaf, all-reduces only those (as a dense masked tensor under GSPMD — the
sparsity is what a ring implementation would exploit; the *selection* math
and error-feedback residual are the real algorithm), and accumulates the
rest into a residual carried in optimizer state (error feedback, Karimireddy
et al. 2019 — prevents compression bias).

Exposed as `--grad-compress ρ` in `launch/train.py`; OFF by default (exact
reduction). Tests verify error feedback recovers the exact gradient sum over
steps in expectation.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["topk_compress_allreduce"]


def _topk_mask(x: jax.Array, k: int) -> jax.Array:
    flat = jnp.abs(x.reshape(-1))
    if k >= flat.size:
        return jnp.ones_like(flat, bool).reshape(x.shape)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh)


def topk_compress_allreduce(
    grads: Any,
    residual: Any,
    axis_name: str | None,
    ratio: float = 0.05,
) -> Tuple[Any, Any]:
    """Returns (reduced_grads, new_residual).

    Inside shard_map/pmap pass `axis_name` of the DP axis; with `None` the
    reduction is assumed implicit (pjit) and only selection+residual run.
    """

    def one(g, r):
        g = g.astype(jnp.float32) + r
        k = max(1, int(ratio * g.size))
        mask = _topk_mask(g, k)
        sel = jnp.where(mask, g, 0.0)
        new_r = g - sel
        if axis_name is not None:
            sel = jax.lax.pmean(sel, axis_name)
        return sel, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        treedef.unflatten([o[0] for o in outs]),
        treedef.unflatten([o[1] for o in outs]),
    )
