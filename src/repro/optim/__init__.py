"""Optimizer substrate: AdamW + schedules + gradient compression."""
from repro.optim.adamw import adamw_init, adamw_update, cosine_schedule, global_norm
from repro.optim.compress import topk_compress_allreduce

__all__ = [
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "global_norm",
    "topk_compress_allreduce",
]
