"""AdamW with decoupled weight decay, grad clipping and cosine schedule.

State layout mirrors the parameter pytree so `launch.sharding` can assign the
moments the same (fully sharded) PartitionSpecs — the ZeRO-style sharded
optimizer that makes the 100B+ assigned configs fit 16 GB/chip.
Moments are fp32 regardless of param dtype (bf16 training stability).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "cosine_schedule", "global_norm"]


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        m=jax.tree.map(f32, params),
        v=jax.tree.map(f32, params),
        step=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)

    return lr


def adamw_update(
    grads,
    state,
    params,
    lr: jax.Array,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step; returns (new_params, new_state)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, dict(m=new_m, v=new_v, step=step)
