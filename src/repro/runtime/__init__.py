"""Distributed runtime: failure handling, elastic re-mesh, stragglers."""
from repro.runtime.fault import FaultTolerantLoop, StepFailure
from repro.runtime.elastic import plan_mesh, replan_after_failure
from repro.runtime.straggler import StragglerMonitor

__all__ = [
    "FaultTolerantLoop",
    "StepFailure",
    "plan_mesh",
    "replan_after_failure",
    "StragglerMonitor",
]
