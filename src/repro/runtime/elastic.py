"""Elastic mesh planning.

`plan_mesh(n_chips)` picks the best (pod, data, model) factorization for an
arbitrary healthy-chip count; `replan_after_failure` shrinks the data axis
(keeping TP intact — TP shards hold non-replicated parameter state, so losing
a TP group member means that whole group's replica is lost anyway) and
reports the gradient-accumulation factor that keeps the global batch
constant. Sharding rules in `launch.sharding` are mesh-shape-agnostic, so a
re-mesh only requires re-jitting the step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["MeshPlan", "plan_mesh", "replan_after_failure"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    pod: int
    data: int
    model: int
    grad_accum: int = 1

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def axes(self):
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    @property
    def shape(self):
        return (
            (self.pod, self.data, self.model)
            if self.pod > 1
            else (self.data, self.model)
        )


def plan_mesh(n_chips: int, model_parallel: int = 16, pods: int = 1) -> MeshPlan:
    """Largest usable mesh: data = floor(chips / (pods·model))."""
    per_pod = n_chips // pods
    data = per_pod // model_parallel
    assert data >= 1, f"{n_chips} chips cannot host model_parallel={model_parallel}"
    return MeshPlan(pod=pods, data=data, model=model_parallel)


def replan_after_failure(
    plan: MeshPlan, lost_chips: int, global_batch: int
) -> Optional[MeshPlan]:
    """Shrink the data axis to survive `lost_chips` failures.

    A lost chip removes its whole TP group (model_parallel chips) from
    service. Keeps global batch via gradient accumulation. Returns None if
    no viable mesh remains.
    """
    lost_groups = -(-lost_chips // plan.model)
    total_groups = plan.pod * plan.data - lost_groups
    if total_groups < 1:
        return None
    # Prefer keeping pods balanced; fold odd groups into a single-pod mesh.
    if plan.pod > 1 and total_groups % plan.pod == 0:
        pod, data = plan.pod, total_groups // plan.pod
    else:
        pod, data = 1, total_groups
    dp_old = plan.pod * plan.data * plan.grad_accum
    accum = -(-dp_old // (pod * data))
    # Global batch must stay divisible across the new data-parallel width.
    while global_batch % (pod * data) != 0 and data > 1:
        data -= 1
        accum = -(-dp_old // (pod * data))
    return MeshPlan(pod=pod, data=data, model=plan.model, grad_accum=accum)
