"""Straggler detection and mitigation.

Per-step wall times are tracked as an EMA (mean + variance); a step slower
than mean + `sigma`·std AND `ratio`× the mean flags a straggler event. The
mitigation policy at scale:

  1. persistent straggler host → rebalance: shift one gradient-accumulation
     microbatch from the slow host to the fastest (returned as a new
     microbatch allocation vector),
  2. chronic (≥ `evict_after` flags) → recommend eviction, which the caller
     turns into an elastic re-mesh (runtime.elastic).

On a single-host container the monitor sees per-step times only; the
allocation logic is exercised in tests with synthetic timing traces.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = ["StragglerMonitor"]


@dataclasses.dataclass
class StragglerDecision:
    flagged_host: Optional[int]
    evict: bool
    microbatch_alloc: np.ndarray  # (hosts,) microbatches per host


class StragglerMonitor:
    def __init__(
        self,
        hosts: int,
        microbatches_per_host: int = 1,
        sigma: float = 3.0,
        ratio: float = 1.3,
        evict_after: int = 5,
        alpha: float = 0.1,
    ):
        self.hosts = hosts
        self.sigma, self.ratio, self.evict_after, self.alpha = (
            sigma, ratio, evict_after, alpha,
        )
        self.alloc = np.full(hosts, microbatches_per_host, np.int64)
        self.mean = np.zeros(hosts)
        self.var = np.zeros(hosts)
        self.flags = np.zeros(hosts, np.int64)
        self.n = 0

    def observe(self, per_host_step_s: np.ndarray) -> StragglerDecision:
        """Feed one step's per-host wall times; get the mitigation decision."""
        t = np.asarray(per_host_step_s, float)
        # Normalize by workload (time per microbatch) so rebalanced hosts are
        # judged fairly.
        t = t / np.maximum(self.alloc, 1)
        if self.n == 0:
            self.mean, self.var = t.copy(), np.zeros_like(t)
        else:
            d = t - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1
        fleet_mean = float(self.mean.mean())
        std = float(np.sqrt(self.var.mean()) + 1e-12)
        slow = (self.mean > fleet_mean + self.sigma * std) & (
            self.mean > self.ratio * fleet_mean
        )
        flagged = int(np.argmax(self.mean)) if slow.any() else None
        evict = False
        if flagged is not None:
            self.flags[flagged] += 1
            evict = bool(self.flags[flagged] >= self.evict_after)
            fastest = int(np.argmin(self.mean + (self.alloc == 0) * 1e9))
            if self.alloc[flagged] > 1 and fastest != flagged:
                self.alloc[flagged] -= 1
                self.alloc[fastest] += 1
        return StragglerDecision(flagged, evict, self.alloc.copy())
