"""Fault-tolerant training loop wrapper.

At thousand-node scale the failure model is: a step either (a) raises on this
host (XLA error, NaN loss, collective timeout surfaced as an exception), or
(b) a peer disappears (surfaced by the coordinator — here simulated through
an injectable failure hook). The loop's contract:

  1. every step runs under a watchdog; classified failures increment a
     budget-limited retry counter,
  2. TRANSIENT failures (timeout, injected flake) retry the same step from
     live state,
  3. FATAL/TOPOLOGY failures restore the last checkpoint and, on topology
     change, ask `runtime.elastic.replan_after_failure` for a smaller mesh
     before resuming (the caller rebuilds the jitted step for the new mesh),
  4. NaN/inf loss restores the checkpoint and skips the offending data step.

The loop is deliberately framework-level (no jax internals): it is exercised
in tests with injected failures and used by `launch/train.py`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

__all__ = ["StepFailure", "FaultTolerantLoop"]


class StepFailure(Exception):
    """A classified step failure. kind: 'transient' | 'fatal' | 'topology'."""

    def __init__(self, kind: str, msg: str = ""):
        super().__init__(f"[{kind}] {msg}")
        self.kind = kind


@dataclasses.dataclass
class LoopStats:
    steps_done: int = 0
    retries: int = 0
    restores: int = 0
    remesh_events: int = 0
    skipped_data_steps: int = 0


class FaultTolerantLoop:
    """Drives `step_fn(state, batch) -> (state, metrics)` with recovery.

    Args:
      step_fn: jitted train step.
      save_fn: (step, state) -> None — checkpoint write.
      restore_fn: () -> (state, step) — restore latest checkpoint.
      remesh_fn: optional (lost_nodes) -> new step_fn after an elastic replan.
      ckpt_every: checkpoint cadence in steps.
      max_retries: transient-retry budget per step.
    """

    def __init__(
        self,
        step_fn: Callable,
        save_fn: Callable,
        restore_fn: Callable,
        remesh_fn: Optional[Callable] = None,
        ckpt_every: int = 50,
        max_retries: int = 3,
        failure_hook: Optional[Callable] = None,
    ):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.remesh_fn = remesh_fn
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.failure_hook = failure_hook  # (step) -> None; may raise StepFailure
        self.stats = LoopStats()

    def run(self, state: Any, batches: Callable, start_step: int, num_steps: int):
        """batches: step -> batch. Returns (state, metrics_history)."""
        history = []
        step = start_step
        while step < start_step + num_steps:
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                t0 = time.perf_counter()
                state_new, metrics = self.step_fn(state, batches(step))
                loss = float(metrics.get("loss", 0.0))
                if not np.isfinite(loss):
                    raise StepFailure("nan", f"loss={loss} at step {step}")
                state = state_new
                metrics = dict(metrics)
                metrics["step_time_s"] = time.perf_counter() - t0
                history.append((step, metrics))
                self.stats.steps_done += 1
                step += 1
                if step % self.ckpt_every == 0:
                    self.save_fn(step, state)
            except StepFailure as e:
                if e.kind == "transient" and self.stats.retries < self.max_retries:
                    self.stats.retries += 1
                    continue  # retry same step, live state
                if e.kind == "topology" and self.remesh_fn is not None:
                    self.stats.remesh_events += 1
                    self.step_fn = self.remesh_fn(e)
                state, step = self.restore_fn()
                self.stats.restores += 1
                if e.kind == "nan":
                    self.stats.skipped_data_steps += 1
                    step += 1  # skip the poisoned batch
        self.save_fn(step, state)
        return state, history
