"""repro: ADWISE streaming edge partitioning + multi-pod JAX LM framework.

Layout landmarks:
  repro.compat        — JAX version-portability layer (shard_map location +
                        replication-check kwarg, make_mesh fallback, Pallas
                        availability probe). All engine/kernel/launch code
                        reaches JAX's moving surfaces through it.
  repro.core.registry — partitioner strategy registry: adwise and every
                        baseline behind one (edges, n, k, seed, **cfg) ->
                        PartitionResult signature, resolved by name.
"""
__version__ = "0.1.0"
