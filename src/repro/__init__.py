"""repro: ADWISE streaming edge partitioning + multi-pod JAX LM framework."""
__version__ = "0.1.0"
