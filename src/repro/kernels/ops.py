"""Jit'd public wrappers for the Pallas kernels.

Each op takes `impl` ∈ {'auto', 'pallas', 'ref'}:
  * 'pallas' — pl.pallas_call; on CPU this runs interpret=True (the container
    has no TPU), on TPU it lowers for real.
  * 'ref'    — the pure-jnp oracle (XLA). This is the default inside model /
    partitioner code paths that must `.lower().compile()` on CPU host devices
    (the multi-pod dry-run), where a TPU Pallas kernel cannot compile.
  * 'auto'   — 'pallas' on TPU backends, 'ref' elsewhere.

Pallas availability is probed through `repro.compat`: on installs without
`jax.experimental.pallas`, 'auto' *and* 'pallas' both degrade to the XLA
reference so callers never crash on import or dispatch.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import EB, SB, csr_block_layout, segment_sum_pallas
from repro.kernels.window_score import window_score_pallas

__all__ = ["window_score", "segment_sum_sorted", "flash_attention", "resolve_impl"]


_WARNED_DOWNGRADES: set[str] = set()


def _downgrade(op: str, reason: str) -> str:
    """Explicit 'pallas' request that cannot run: degrade loudly to 'ref'."""
    if op not in _WARNED_DOWNGRADES:
        _WARNED_DOWNGRADES.add(op)
        warnings.warn(
            f"{op}: impl='pallas' requested but {reason}; running the XLA "
            "reference instead — reported timings are NOT pallas timings",
            RuntimeWarning,
            stacklevel=3,
        )
    return "ref"


def resolve_impl(
    impl: str,
    *,
    require_tpu_support: bool = False,
    require_prefetch_grid: bool = False,
    op: str = "op",
) -> str:
    """Resolve 'auto'/'pallas' to what can actually run on this install.

    ``require_tpu_support``: the op needs `jax.experimental.pallas.tpu`
    (e.g. VMEM scratch spaces), not just base pallas.
    ``require_prefetch_grid``: the op additionally needs the (deprecated
    upstream) `PrefetchScalarGridSpec`. An explicit 'pallas' request that
    cannot be honoured degrades to 'ref' with a RuntimeWarning so benchmark
    columns are never silently mislabeled.
    """
    available = compat.has_pallas(require_tpu_support)
    if require_prefetch_grid:
        available = available and compat.HAS_PREFETCH_GRID
    if impl == "pallas":
        if available:
            return impl
        return _downgrade(op, "this install lacks the pallas support it needs")
    if impl != "auto":
        return impl
    if jax.default_backend() == "tpu" and available:
        return "pallas"
    return "ref"


def _interpret() -> bool:
    return compat.pallas_interpret()


def window_score(
    win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed, lam, max_deg,
    *, use_cs: bool = True, impl: str = "auto",
):
    impl = resolve_impl(impl, op="window_score")
    if impl == "pallas":
        return window_score_pallas(
            win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed,
            jnp.asarray(lam), jnp.asarray(max_deg),
            use_cs=use_cs, interpret=_interpret(),
        )
    return _ref.window_score_ref(
        win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed,
        jnp.asarray(lam), jnp.asarray(max_deg), use_cs=use_cs,
    )


def segment_sum_sorted(
    data: jax.Array,  # (E, D) — messages sorted by seg id
    seg_ids: np.ndarray,  # (E,) sorted, HOST array (static layout per graph)
    num_segments: int,
    *, impl: str = "auto",
):
    """Segment sum where the segment layout is static (known per graph).

    'pallas' without `PrefetchScalarGridSpec` no longer downgrades to 'ref':
    the blocked entry point itself falls back to its `jax.ops.segment_sum`
    fast path over the same layout (with a RuntimeWarning), so the blocked
    code path stays exercised on installs where the grid cannot be built.
    """
    impl = resolve_impl(impl, require_tpu_support=True, op="segment_sum_sorted")
    if impl == "pallas":
        perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(
            np.asarray(seg_ids), num_segments, data.shape[1]
        )
        gather = jnp.where(perm[:, None] >= 0, data[jnp.maximum(perm, 0)], 0.0)
        return segment_sum_pallas(
            gather.astype(jnp.float32),
            jnp.asarray(loc),
            jnp.asarray(chunk_ptr),
            jnp.asarray(nchunks),
            num_segments,
            max_chunks=int(nchunks.max()),
            interpret=_interpret(),
        )
    return _ref.segment_sum_ref(data, jnp.asarray(seg_ids), num_segments)


def flash_attention(q, k, v, *, causal: bool = True, scale=None, impl: str = "auto"):
    impl = resolve_impl(impl, require_tpu_support=True, op="flash_attention")
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, interpret=_interpret()
        )
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
