"""Tier-dispatched public wrappers for the Pallas kernels.

Every op dispatches through one :func:`resolve_tier` ladder instead of the
old 'auto'/'pallas'/'ref' impl switch:

  * ``pallas-tpu`` — `pl.pallas_call` lowered for real on a TPU backend.
  * ``pallas-cpu`` — `pl.pallas_call` lowered through JAX's CPU Pallas
    lowering path, on installs whose JAX supports it (probed once in
    `repro.compat.has_pallas_cpu_lowering`). Never interpret mode.
  * ``xla``        — the XLA fallbacks (`segment_sum_xla` / the pure-jnp
    oracles in `kernels/ref.py`). Always available.
  * ``interpret``  — Pallas interpret mode. This is an explicit DEBUG flag
    (``tier='interpret'`` or ``$ADWISE_KERNEL_TIER=interpret``); the
    resolver never lands on it by itself, so the default path is never
    pure-Python emulation on any backend.

When more than one lowered tier is available for an op, the winner is picked
by a one-shot microbenchmark cached per (op, shape-bucket, backend, jax
version) in a small on-disk autotune table (see :func:`autotune_cache_path`;
``$ADWISE_AUTOTUNE_CACHE`` relocates it). ``$ADWISE_KERNEL_TIER`` is the
override/escape hatch: force ``xla`` for bit-stable CI runs, ``interpret``
to step through a kernel.

Pallas availability is probed through `repro.compat`: on installs without
`jax.experimental.pallas` the pallas tiers are simply absent and every op
runs its XLA tier — callers never crash on import or dispatch.
"""
from __future__ import annotations

import json
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import EB, SB, csr_block_layout, segment_sum_pallas
from repro.kernels.window_score import window_score_pallas

__all__ = [
    "window_score",
    "segment_sum_sorted",
    "flash_attention",
    "KERNEL_TIER_ENV",
    "TIERS",
    "INTERPRET_TIER",
    "available_tiers",
    "resolve_tier",
    "autotune_cache_path",
    "autotune_record",
    "measured_score_cost_s",
    "clear_tier_cache",
]

KERNEL_TIER_ENV = "ADWISE_KERNEL_TIER"
AUTOTUNE_CACHE_ENV = "ADWISE_AUTOTUNE_CACHE"

# Resolvable tiers in preference order (used when timing is unavailable).
TIERS = ("pallas-tpu", "pallas-cpu", "xla")
# Debug-only pseudo-tier: must be requested explicitly, never resolved to.
INTERPRET_TIER = "interpret"

_OPS = ("window_score", "segment_sum", "flash_attention")
# Ops whose pallas kernels need jax.experimental.pallas.tpu surfaces (VMEM
# scratch shapes / PrefetchScalarGridSpec) — those cannot take the CPU
# lowering path even where base pallas_call can.
_NEEDS_TPU_SUPPORT = ("segment_sum", "flash_attention")

_WARNED_DOWNGRADES: set[str] = set()
# In-process tier memo: (op, bucket, backend) -> {"tier": str, "walls_s": {}}.
_TIER_MEMO: dict[tuple, dict] = {}


def _downgrade(op: str, requested: str, actual: str, reason: str) -> str:
    """Requested tier cannot run: degrade loudly so benchmark columns are
    never silently mislabeled."""
    key = f"{op}:{requested}"
    if key not in _WARNED_DOWNGRADES:
        _WARNED_DOWNGRADES.add(key)
        warnings.warn(
            f"{op}: tier='{requested}' requested but {reason}; running "
            f"'{actual}' instead — reported timings are NOT {requested} "
            "timings",
            RuntimeWarning,
            stacklevel=4,
        )
    return actual


def available_tiers(op: str) -> tuple[str, ...]:
    """Tiers this install/backend can genuinely run for ``op`` (no
    interpret), best first. ``xla`` is always present."""
    if op not in _OPS:
        raise ValueError(f"unknown op {op!r}; known: {_OPS}")
    tiers: list[str] = []
    needs_tpu = op in _NEEDS_TPU_SUPPORT
    needs_prefetch = op == "segment_sum"
    if (
        jax.default_backend() == "tpu"
        and compat.has_pallas(needs_tpu)
        and (not needs_prefetch or compat.HAS_PREFETCH_GRID)
    ):
        tiers.append("pallas-tpu")
    if (
        jax.default_backend() != "tpu"
        and not needs_tpu
        and compat.has_pallas()
        and compat.has_pallas_cpu_lowering()
    ):
        tiers.append("pallas-cpu")
    tiers.append("xla")
    return tuple(tiers)


# ----------------------------------------------------------------------------
# On-disk autotune table
# ----------------------------------------------------------------------------

def autotune_cache_path() -> str:
    """Location of the on-disk autotune table (JSON).

    ``$ADWISE_AUTOTUNE_CACHE`` overrides; default is
    ``~/.cache/adwise/kernel_tiers.json`` (XDG_CACHE_HOME respected).
    """
    env = os.environ.get(AUTOTUNE_CACHE_ENV)
    if env:
        return env
    base = os.environ.get("XDG_CACHE_HOME") or os.path.expanduser("~/.cache")
    return os.path.join(base, "adwise", "kernel_tiers.json")


def _table_key(op: str, bucket: str, backend: str) -> str:
    return f"{op}|{bucket}|{backend}|jax{jax.__version__}"


def _load_table() -> dict:
    try:
        with open(autotune_cache_path()) as f:
            doc = json.load(f)
        if isinstance(doc, dict) and doc.get("version") == 1:
            return doc.get("entries", {})
    except (OSError, ValueError):
        pass
    return {}


def _store_entry(key: str, entry: dict) -> None:
    """Best-effort persist: autotuning must never fail an op call."""
    path = autotune_cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        entries = _load_table()
        entries[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": entries}, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        pass


def clear_tier_cache(*, disk: bool = False) -> None:
    """Drop the in-process tier memo (tests; env-var changes mid-process).
    ``disk=True`` also removes the on-disk table."""
    _TIER_MEMO.clear()
    _WARNED_DOWNGRADES.clear()
    if disk:
        try:
            os.remove(autotune_cache_path())
        except OSError:
            pass


def _pow2_bucket(*dims: int) -> str:
    """Shape bucket: each dim rounded up to a power of two, so nearby shapes
    share one autotune entry (same discipline as the ring's pow2 Rq)."""
    out = []
    for d in dims:
        d = max(int(d), 1)
        out.append(str(1 << (d - 1).bit_length()))
    return "x".join(out)


def _time_call(fn, n: int = 3) -> float:
    jax.block_until_ready(fn())  # warm: compile outside the timed region
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def autotune_record(op: str, bucket: str, candidates: dict) -> dict:
    """Time each candidate tier's thunk once, pick the fastest, memoise in
    process and on disk. ``candidates`` maps tier name -> zero-arg callable.

    Returns the table entry ``{"tier": str, "walls_s": {tier: seconds}}``.
    Exposed so `benchmarks/bench_kernels.py` can seed the table from its
    (larger) timed shapes.
    """
    walls: dict[str, float] = {}
    for tier, thunk in candidates.items():
        try:
            walls[tier] = _time_call(thunk)
        except Exception as e:  # a candidate that errors just loses
            warnings.warn(
                f"{op}: tier '{tier}' failed during autotune ({e!r}); "
                "excluded from selection",
                RuntimeWarning,
                stacklevel=2,
            )
    if not walls:
        raise RuntimeError(f"{op}: no autotune candidate ran")
    best = min(walls, key=lambda t: walls[t])
    entry = {"tier": best, "walls_s": walls}
    key = _table_key(op, bucket, jax.default_backend())
    _TIER_MEMO[(op, bucket, jax.default_backend())] = entry
    _store_entry(key, entry)
    return entry


def _lookup_entry(op: str, bucket: str) -> dict | None:
    memo_key = (op, bucket, jax.default_backend())
    if memo_key in _TIER_MEMO:
        return _TIER_MEMO[memo_key]
    entry = _load_table().get(_table_key(op, bucket, jax.default_backend()))
    if entry is not None:
        _TIER_MEMO[memo_key] = entry
    return entry


def resolve_tier(
    op: str,
    tier: str = "auto",
    *,
    bucket: str = "",
    candidates: dict | None = None,
) -> str:
    """Resolve a requested tier to what actually runs on this install.

    ``'auto'`` (the default everywhere) consults, in order: the
    ``$ADWISE_KERNEL_TIER`` override, the autotune table entry for
    (op, bucket, backend) — microbenchmarking the ``candidates`` thunks once
    and caching the verdict when more than one lowered tier is available —
    and finally the static preference order :data:`TIERS`. ``'interpret'``
    is honoured only as an explicit request (debug); an explicit tier that
    cannot run on this install degrades loudly to the best available one.
    ``'ref'`` is accepted as a legacy alias of ``'xla'``.
    """
    if tier == "ref":  # legacy alias from the impl= era
        tier = "xla"
    avail = available_tiers(op)
    if tier == "auto":
        env = os.environ.get(KERNEL_TIER_ENV, "").strip()
        if env and env != "auto":
            tier = env
    if tier != "auto":
        if tier == INTERPRET_TIER:
            if compat.has_pallas(op in _NEEDS_TPU_SUPPORT):
                return INTERPRET_TIER
            return _downgrade(
                op, tier, "xla", "this install has no pallas to interpret"
            )
        if tier not in TIERS:
            raise ValueError(
                f"{op}: unknown kernel tier {tier!r}; expected one of "
                f"{TIERS + (INTERPRET_TIER, 'auto')}"
            )
        if tier in avail:
            return tier
        return _downgrade(
            op, tier, avail[0], "this install cannot lower it"
        )
    if len(avail) == 1:
        return avail[0]
    entry = _lookup_entry(op, bucket)
    if entry is not None and entry.get("tier") in avail:
        return entry["tier"]
    if candidates:
        usable = {t: f for t, f in candidates.items() if t in avail}
        if len(usable) > 1:
            return autotune_record(op, bucket, usable)["tier"]
    return avail[0]


def measured_score_cost_s() -> float | None:
    """Per-(edge, partition) window-score cost at the *measured* tier.

    Scans the autotune walls recorded for ``window_score`` on the current
    backend and returns the median chosen-tier wall divided by the bucket's
    w·k score count — the constant `engine/latency_model.py` bills compute
    with when a measurement exists. Returns None when nothing has been
    measured on this backend (the model then falls back to its calibrated
    paper constant). Never triggers a microbenchmark itself.
    """
    backend = jax.default_backend()
    prefix = "window_score|"
    suffix = f"|{backend}|jax{jax.__version__}"
    costs: list[float] = []
    entries = dict(_load_table())
    for (op, bucket, be), entry in _TIER_MEMO.items():
        if op == "window_score" and be == backend:
            entries[f"{op}|{bucket}{suffix}"] = entry
    for key, entry in entries.items():
        if not (key.startswith(prefix) and key.endswith(suffix)):
            continue
        bucket = key[len(prefix) : -len(suffix)]
        try:
            w, k = (int(x) for x in bucket.split("x")[:2])
            wall = float(entry["walls_s"][entry["tier"]])
        except (KeyError, TypeError, ValueError):
            continue
        if w * k > 0 and wall > 0:
            costs.append(wall / (w * k))
    if not costs:
        return None
    return float(np.median(costs))


# ----------------------------------------------------------------------------
# Ops
# ----------------------------------------------------------------------------

def window_score(
    win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed, lam, max_deg,
    *, use_cs: bool = True, tier: str = "auto",
):
    w, k = rep_u.shape
    args = (
        win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed,
        jnp.asarray(lam), jnp.asarray(max_deg),
    )

    def _pallas(interpret: bool):
        return window_score_pallas(*args, use_cs=use_cs, interpret=interpret)

    def _xla():
        return _ref.window_score_ref(*args, use_cs=use_cs)

    resolved = resolve_tier(
        "window_score", tier, bucket=_pow2_bucket(w, k),
        candidates={
            "pallas-tpu": lambda: _pallas(False),
            "pallas-cpu": lambda: _pallas(False),
            "xla": _xla,
        },
    )
    if resolved == INTERPRET_TIER:
        return _pallas(True)
    if resolved in ("pallas-tpu", "pallas-cpu"):
        return _pallas(False)
    return _xla()


def segment_sum_sorted(
    data: jax.Array,  # (E, D) — messages sorted by seg id
    seg_ids: np.ndarray,  # (E,) sorted, HOST array (static layout per graph)
    num_segments: int,
    *, tier: str = "auto",
):
    """Segment sum where the segment layout is static (known per graph).

    The pallas tiers run the blocked-CSR kernel over the
    `csr_block_layout` padding; the ``xla`` tier is the plain
    `jax.ops.segment_sum` reference over the raw sorted ids (no layout
    cost). A pallas request on an install without `PrefetchScalarGridSpec`
    still routes through the blocked entry point, which falls back to its
    `segment_sum_xla` fast path with a RuntimeWarning.

    Every tier accumulates and returns fp32 regardless of input dtype (the
    blocked kernel's MXU-style mixed precision) — switching tiers never
    changes numeric semantics, only speed.
    """
    e, d = data.shape

    def _pallas(interpret: bool):
        perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(
            np.asarray(seg_ids), num_segments, d
        )
        gather = jnp.where(perm[:, None] >= 0, data[jnp.maximum(perm, 0)], 0.0)
        return segment_sum_pallas(
            gather.astype(jnp.float32),
            jnp.asarray(loc),
            jnp.asarray(chunk_ptr),
            jnp.asarray(nchunks),
            num_segments,
            max_chunks=int(nchunks.max()) if len(nchunks) else 1,
            interpret=interpret,
        )

    def _xla():
        return _ref.segment_sum_ref(
            data.astype(jnp.float32), jnp.asarray(seg_ids), num_segments
        )

    resolved = resolve_tier(
        "segment_sum", tier, bucket=_pow2_bucket(e, d, num_segments),
        candidates={"pallas-tpu": lambda: _pallas(False), "xla": _xla},
    )
    if resolved == INTERPRET_TIER:
        return _pallas(True)
    if resolved == "pallas-tpu":
        return _pallas(False)
    return _xla()


def flash_attention(q, k, v, *, causal: bool = True, scale=None, tier: str = "auto"):
    def _pallas(interpret: bool):
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, interpret=interpret
        )

    def _xla():
        return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)

    resolved = resolve_tier(
        "flash_attention", tier,
        bucket=_pow2_bucket(q.shape[0] * q.shape[1], q.shape[2], q.shape[3]),
        candidates={"pallas-tpu": lambda: _pallas(False), "xla": _xla},
    )
    if resolved == INTERPRET_TIER:
        return _pallas(True)
    if resolved == "pallas-tpu":
        return _pallas(False)
    return _xla()
