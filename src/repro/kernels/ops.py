"""Jit'd public wrappers for the Pallas kernels.

Each op takes `impl` ∈ {'auto', 'pallas', 'ref'}:
  * 'pallas' — pl.pallas_call; on CPU this runs interpret=True (the container
    has no TPU), on TPU it lowers for real.
  * 'ref'    — the pure-jnp oracle (XLA). This is the default inside model /
    partitioner code paths that must `.lower().compile()` on CPU host devices
    (the multi-pod dry-run), where a TPU Pallas kernel cannot compile.
  * 'auto'   — 'pallas' on TPU backends, 'ref' elsewhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.segment_sum import EB, SB, csr_block_layout, segment_sum_pallas
from repro.kernels.window_score import window_score_pallas

__all__ = ["window_score", "segment_sum_sorted", "flash_attention", "resolve_impl"]


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def window_score(
    win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed, lam, max_deg,
    *, use_cs: bool = True, impl: str = "auto",
):
    impl = resolve_impl(impl)
    if impl == "pallas":
        return window_score_pallas(
            win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed,
            jnp.asarray(lam), jnp.asarray(max_deg),
            use_cs=use_cs, interpret=_interpret(),
        )
    return _ref.window_score_ref(
        win_uv, win_valid, rep_u, rep_v, deg_u, deg_v, bal, allowed,
        jnp.asarray(lam), jnp.asarray(max_deg), use_cs=use_cs,
    )


def segment_sum_sorted(
    data: jax.Array,  # (E, D) — messages sorted by seg id
    seg_ids: np.ndarray,  # (E,) sorted, HOST array (static layout per graph)
    num_segments: int,
    *, impl: str = "auto",
):
    """Segment sum where the segment layout is static (known per graph)."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(
            np.asarray(seg_ids), num_segments, data.shape[1]
        )
        gather = jnp.where(perm[:, None] >= 0, data[jnp.maximum(perm, 0)], 0.0)
        return segment_sum_pallas(
            gather.astype(jnp.float32),
            jnp.asarray(loc),
            jnp.asarray(chunk_ptr),
            jnp.asarray(nchunks),
            num_segments,
            max_chunks=int(nchunks.max()),
            interpret=_interpret(),
        )
    return _ref.segment_sum_ref(data, jnp.asarray(seg_ids), num_segments)


def flash_attention(q, k, v, *, causal: bool = True, scale=None, impl: str = "auto"):
    impl = resolve_impl(impl)
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale, interpret=_interpret()
        )
    return _ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
