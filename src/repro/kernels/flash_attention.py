"""Pallas TPU kernel: blocked causal GQA flash attention (forward).

Used by the LM serving path (prefill) of the assigned architectures. Online-
softmax over KV blocks with VMEM scratch accumulators; GQA is handled by
steering the K/V BlockSpec index map with `q_head // group`.

  grid = (B, Hq, Tq/BQ, Tk/BK)   — KV innermost so the scratch accumulators
                                    carry across the KV loop for a fixed
                                    (batch, head, q-block).

Causality is aligned to the *end* of the KV sequence (q position offset
Tk - Tq), so the same kernel serves full prefill (Tq == Tk) and chunked
prefill / decode append (Tq < Tk). Out-of-causal-range KV blocks are skipped
with @pl.when — the same work-skipping the roofline analysis credits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# TPU scratch memory spaces are unused under interpret=True on CPU; both are
# None on installs without pallas (ops.py then routes to the XLA reference).
from repro.compat import pallas as pl, pallas_tpu as pltpu

NEG_INF = -1e30
BQ = 128
BK = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal, tq, tk):
    iq = pl.program_id(2)
    jk = pl.program_id(3)
    nk = pl.num_programs(3)
    offset = tk - tq  # causal alignment: q row r has absolute position offset+iq*BQ+r

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_end = offset + (iq + 1) * BQ - 1
    k_start = jk * BK
    live = (q_end >= k_start) if causal else True

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (BQ, Dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (BK, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (BQ, BK)
        if causal:
            rows = offset + iq * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]  # (BQ, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(jk == nk - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, Tq, Dh)
    k: jax.Array,  # (B, Hkv, Tk, Dh)
    v: jax.Array,  # (B, Hkv, Tk, Dh)
    *,
    causal: bool = True,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, hq, tq, dh = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    tq_pad = -(-tq // BQ) * BQ
    tk_pad = -(-tk // BK) * BK
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, tq_pad - tq), (0, 0)))
    # Padded KV must not contribute: causal masking handles the tail when
    # rows < cols; for safety with non-causal, pad K with NEG-biasing zeros and
    # rely on explicit masking below via length check.
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tk_pad - tk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tk_pad - tk), (0, 0)))
    # With causal masking against the TRUE (tq, tk) offsets, padded K columns
    # sit at positions ≥ tk which no real query row ever attends; padded Q
    # rows are sliced off the output. Non-causal callers must be BK-aligned.
    if tk_pad != tk:
        assert causal, "non-causal flash requires Tk divisible by BK"

    grid = (b, hq, tq_pad // BQ, tk_pad // BK)
    if pltpu is None:  # pragma: no cover
        raise RuntimeError("pallas TPU scratch unavailable")
    scratch_shapes = [
        pltpu.VMEM((BQ, dh), jnp.float32),
        pltpu.VMEM((BQ, 1), jnp.float32),
        pltpu.VMEM((BQ, 1), jnp.float32),
    ]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, tq=tq, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, BQ, dh), lambda bb, h, iq, jk: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, BK, dh), lambda bb, h, iq, jk: (bb, h // group, jk, 0)),
            pl.BlockSpec((1, 1, BK, dh), lambda bb, h, iq, jk: (bb, h // group, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BQ, dh), lambda bb, h, iq, jk: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq_pad, dh), q.dtype),
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :tq, :]
