"""Pallas TPU kernel for the ADWISE window scoring hot loop.

The partitioner's inner loop evaluates g(e,p) = λ·B(p) + R(e,p) + CS(e,p)
for every (window edge, partition) pair — w × k scores per assignment. The
paper's whole latency knob is this computation (§III-A/B), so it is the
kernel-worthy hot spot.

TPU adaptation (see DESIGN.md §3/§5): the clustering score's window-local
neighbourhood test is an O(W²) endpoint-match which we phrase as two
(BW, W) × (W, K) matmuls — MXU work — fused with the VPU-friendly R and
λ·B terms, one pass over VMEM-resident window state:

  grid  = (W / BW,)                       one program per row tile
  VMEM  = u,v,deg,valid (1, W) rows; replica tables (W, K);
          balance/allowed (1, K); out tile (BW, K)

W and K are padded to multiples of (BW=128, 128) so matmul operands are
MXU-aligned. Padded rows/columns carry valid=0 / allowed=0 and are masked to
NEG_INF, exactly like the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import pallas as pl  # None when pallas is unavailable

NEG_INF = -1e30
BW = 128  # row-tile size (MXU sublane-aligned)
LANE = 128  # lane padding for K


def _kernel(
    u_ref,  # (1, W) int32
    v_ref,  # (1, W) int32
    valid_ref,  # (1, W) int32 (0/1)
    degu_ref,  # (1, W) int32
    degv_ref,  # (1, W) int32
    repu_ref,  # (W, K) f32   replica rows of u_j
    repv_ref,  # (W, K) f32
    bal_ref,  # (1, K) f32   λ·B(p) already folded in host wrapper? no — raw B(p)
    allowed_ref,  # (1, K) int32
    scal_ref,  # (1, 2) f32   [lam, max_deg]
    out_ref,  # (BW, K) f32
    *,
    use_cs: bool,
):
    i = pl.program_id(0)
    w = u_ref.shape[1]
    u = u_ref[0, :]
    v = v_ref[0, :]
    valid = valid_ref[0, :]
    lam = scal_ref[0, 0]
    max_deg = scal_ref[0, 1]

    # Row tile of this program.
    start = i * BW
    u_i = jax.lax.dynamic_slice(u, (start,), (BW,))
    v_i = jax.lax.dynamic_slice(v, (start,), (BW,))
    valid_i = jax.lax.dynamic_slice(valid, (start,), (BW,))
    deg_u = jax.lax.dynamic_slice(degu_ref[0, :], (start,), (BW,))
    deg_v = jax.lax.dynamic_slice(degv_ref[0, :], (start,), (BW,))
    repu_i = jax.lax.dynamic_slice(repu_ref[...], (start, 0), (BW, repu_ref.shape[1]))
    repv_i = jax.lax.dynamic_slice(repv_ref[...], (start, 0), (BW, repv_ref.shape[1]))

    # Degree-aware replication score R (Eq. 5), Ψ_x = deg(x)/(2·maxDeg).
    denom = 2.0 * jnp.maximum(max_deg, 1.0)
    psi_u = deg_u.astype(jnp.float32) / denom
    psi_v = deg_v.astype(jnp.float32) / denom
    g = repu_i * (2.0 - psi_u)[:, None] + repv_i * (2.0 - psi_v)[:, None]

    if use_cs:
        # Window-local neighbourhood match (CS, Eq. 6) as MXU matmuls.
        col = jax.lax.broadcasted_iota(jnp.int32, (BW, w), 1)
        row_gid = jax.lax.broadcasted_iota(jnp.int32, (BW, w), 0) + start
        keep = (valid[None, :] > 0) & (col != row_gid)
        a = ((u[None, :] == u_i[:, None]) | (u[None, :] == v_i[:, None])) & keep
        b = ((v[None, :] == u_i[:, None]) | (v[None, :] == v_i[:, None])) & keep
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        num = jax.lax.dot(af, repv_ref[...], preferred_element_type=jnp.float32)
        num += jax.lax.dot(bf, repu_ref[...], preferred_element_type=jnp.float32)
        den = af.sum(axis=1) + bf.sum(axis=1)
        g = g + num / jnp.maximum(den, 1.0)[:, None]

    # Adaptive balance term + validity masking.
    g = g + lam * bal_ref[0, :][None, :]
    ok = (valid_i[:, None] > 0) & (allowed_ref[0, :][None, :] > 0)
    out_ref[...] = jnp.where(ok, g, NEG_INF)


def window_score_pallas(
    win_uv: jax.Array,  # (W, 2) int32
    win_valid: jax.Array,  # (W,) bool
    rep_u: jax.Array,  # (W, K) bool/f32
    rep_v: jax.Array,  # (W, K)
    deg_u: jax.Array,  # (W,) int32
    deg_v: jax.Array,  # (W,) int32
    bal: jax.Array,  # (K,) f32
    allowed: jax.Array,  # (K,) bool
    lam: jax.Array,  # () f32
    max_deg: jax.Array,  # () int32
    *,
    use_cs: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Padded pallas_call wrapper; returns (W, K) f32 score matrix.

    ``interpret=True`` is a debug flag (pure-Python emulation); the default
    lowers for real and raises where the backend cannot (dispatch belongs in
    ``ops.window_score``, which resolves a runnable tier first).
    """
    if pl is None:
        raise RuntimeError(
            "jax.experimental.pallas unavailable — use ops.window_score"
            " (impl='ref'/'auto'), which falls back to the XLA oracle"
        )
    w, k = rep_u.shape
    w_pad = -(-w // BW) * BW
    k_pad = -(-k // LANE) * LANE

    def pad2(x, rows, cols, fill=0):
        return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])), constant_values=fill)

    def pad_row(x, cols, fill=0):
        return jnp.pad(x, (0, cols - x.shape[0]), constant_values=fill)[None, :]

    u = pad_row(win_uv[:, 0].astype(jnp.int32), w_pad, fill=-1)
    v = pad_row(win_uv[:, 1].astype(jnp.int32), w_pad, fill=-2)
    valid = pad_row(win_valid.astype(jnp.int32), w_pad)
    dgu = pad_row(deg_u.astype(jnp.int32), w_pad)
    dgv = pad_row(deg_v.astype(jnp.int32), w_pad)
    ru = pad2(rep_u.astype(jnp.float32), w_pad, k_pad)
    rv = pad2(rep_v.astype(jnp.float32), w_pad, k_pad)
    bl = pad_row(bal.astype(jnp.float32), k_pad)
    al = pad_row(allowed.astype(jnp.int32), k_pad)
    scal = jnp.stack([lam.astype(jnp.float32), max_deg.astype(jnp.float32)])[None, :]

    full_row = lambda i: (0, 0)
    grid = (w_pad // BW,)
    out = pl.pallas_call(
        functools.partial(_kernel, use_cs=use_cs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w_pad), full_row),  # u
            pl.BlockSpec((1, w_pad), full_row),  # v
            pl.BlockSpec((1, w_pad), full_row),  # valid
            pl.BlockSpec((1, w_pad), full_row),  # deg_u
            pl.BlockSpec((1, w_pad), full_row),  # deg_v
            pl.BlockSpec((w_pad, k_pad), full_row),  # rep_u
            pl.BlockSpec((w_pad, k_pad), full_row),  # rep_v
            pl.BlockSpec((1, k_pad), full_row),  # bal
            pl.BlockSpec((1, k_pad), full_row),  # allowed
            pl.BlockSpec((1, 2), full_row),  # scalars
        ],
        out_specs=pl.BlockSpec((BW, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w_pad, k_pad), jnp.float32),
        interpret=interpret,
    )(u, v, valid, dgu, dgv, ru, rv, bl, al, scal)
    return out[:w, :k]
