"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated against
(interpret=True on CPU, real lowering on TPU). They are also the fallback
implementation used by the models / partitioner when `use_pallas=False`
(the default on CPU, where Pallas TPU kernels cannot lower).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

__all__ = ["window_score_ref", "segment_sum_ref", "flash_attention_ref"]


def window_score_ref(
    win_uv: jax.Array,  # (W, 2) int32
    win_valid: jax.Array,  # (W,) bool
    rep_u: jax.Array,  # (W, K) bool/f32 — replica rows for u_i
    rep_v: jax.Array,  # (W, K)
    deg_u: jax.Array,  # (W,) int32
    deg_v: jax.Array,  # (W,) int32
    bal: jax.Array,  # (K,) f32 — precomputed balance scores B(p)
    allowed: jax.Array,  # (K,) bool
    lam: jax.Array,  # () f32
    max_deg: jax.Array,  # () int32
    *,
    use_cs: bool = True,
) -> jax.Array:
    """ADWISE g(e,p) = λ·B(p) + R(e,p) + CS(e,p) over the full (W, K) grid.

    Multiset window-local CS semantics (DESIGN.md §3). Invalid rows/partitions
    masked to NEG_INF. This mirrors `repro.core.scoring.window_scores` but
    takes B(p) precomputed so kernel and oracle share the exact same inputs.
    """
    w = win_uv.shape[0]
    u, v = win_uv[:, 0], win_uv[:, 1]
    denom = 2.0 * jnp.maximum(max_deg, 1).astype(jnp.float32)
    psi_u = deg_u.astype(jnp.float32) / denom
    psi_v = deg_v.astype(jnp.float32) / denom
    repu_f = rep_u.astype(jnp.float32)
    repv_f = rep_v.astype(jnp.float32)
    g = repu_f * (2.0 - psi_u)[:, None] + repv_f * (2.0 - psi_v)[:, None]
    if use_cs:
        vj = win_valid[None, :]
        noti = ~jnp.eye(w, dtype=bool)
        a = ((u[None, :] == u[:, None]) | (u[None, :] == v[:, None])) & vj & noti
        b = ((v[None, :] == u[:, None]) | (v[None, :] == v[:, None])) & vj & noti
        af, bf = a.astype(jnp.float32), b.astype(jnp.float32)
        num = af @ repv_f + bf @ repu_f
        den = af.sum(axis=1) + bf.sum(axis=1)
        g = g + num / jnp.maximum(den, 1.0)[:, None]
    g = g + lam * bal[None, :]
    g = jnp.where(win_valid[:, None] & allowed[None, :], g, NEG_INF)
    return g


def segment_sum_ref(
    data: jax.Array,  # (E, D) f32 — per-edge messages, sorted by segment
    seg_ids: jax.Array,  # (E,) int32 — destination segment per row (sorted)
    num_segments: int,
) -> jax.Array:
    """(S, D) segment sum — the engine's edge→vertex accumulation."""
    return jax.ops.segment_sum(data, seg_ids, num_segments=num_segments)


def flash_attention_ref(
    q: jax.Array,  # (B, Hq, Tq, Dh)
    k: jax.Array,  # (B, Hkv, Tk, Dh)
    v: jax.Array,  # (B, Hkv, Tk, Dh)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """GQA softmax attention oracle (fp32 accumulation)."""
    b, hq, tq, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(b, hkv, group, tq, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    if causal:
        tk = k.shape[2]
        # Align causality to the *end* of the KV sequence (decode-friendly).
        qpos = jnp.arange(tq) + (tk - tq)
        mask = qpos[:, None] >= jnp.arange(tk)[None, :]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(b, hq, tq, dh).astype(q.dtype)
