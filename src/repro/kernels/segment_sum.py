"""Pallas TPU kernel: blocked segment-sum (edge→vertex accumulation).

The vertex-cut processing engine's dominant op is accumulating per-edge
messages into destination vertices (gather-apply-scatter). On TPU a raw
scatter is VPU-serial; the TPU-native phrasing is a *blocked CSR* one-hot
matmul:

  * edges are pre-sorted by destination segment (static per graph),
  * each segment block (SB=128 rows of the output) owns a contiguous,
    EB-aligned run of edge chunks (host-side padding aligns the runs),
  * grid = (num_segment_blocks, max_chunks_per_block); the kernel builds a
    local (EB, SB) one-hot from the in-chunk destination ids and accumulates
    `one_hotᵀ @ data` (MXU) into the output tile resident in VMEM.

Chunk ranges are passed as scalar-prefetch operands so BlockSpec index maps
can steer each program to its chunk (PrefetchScalarGridSpec) — the standard
ragged-block pattern.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import pallas as pl, pallas_tpu as pltpu  # None when absent

SB = 128  # segment (output row) block
EB = 512  # edge chunk


def csr_block_layout(seg_ids: np.ndarray, num_segments: int, d: int):
    """Host-side preprocessing: pad the sorted edge list into EB-aligned runs.

    Returns (perm, loc, chunk_ptr, nchunks, e_pad) where
      perm: int64 (E_pad,) — index into the original edge array (-1 = padding),
      loc:  int32 (E_pad,) — destination id *local to its segment block*,
      chunk_ptr: int32 (n_sblocks,) — first chunk index of each block,
      nchunks:   int32 (n_sblocks,) — number of chunks of each block.

    Invalid layouts are rejected up front with a ValueError naming the
    offending position — unsorted or out-of-range ids would otherwise
    surface as index garbage deep in the padding math. Degenerate inputs are
    legal: ``m=0`` yields an all-padding layout and a single segment block
    still gets its one (padded) chunk run.
    """
    seg_ids = np.asarray(seg_ids)
    if seg_ids.ndim != 1:
        raise ValueError(
            f"csr_block_layout: seg_ids must be 1-D, got shape {seg_ids.shape}"
        )
    if num_segments < 1:
        raise ValueError(
            f"csr_block_layout: num_segments must be >= 1, got {num_segments}"
        )
    if seg_ids.size:
        drop = np.diff(seg_ids) < 0
        if drop.any():
            i = int(np.argmax(drop))
            raise ValueError(
                "csr_block_layout: segment ids must be sorted ascending; "
                f"seg_ids[{i}]={int(seg_ids[i])} > "
                f"seg_ids[{i + 1}]={int(seg_ids[i + 1])}"
            )
        bad = (seg_ids < 0) | (seg_ids >= num_segments)
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                "csr_block_layout: segment ids must lie in "
                f"[0, {num_segments}); seg_ids[{i}]={int(seg_ids[i])}"
            )
    n_sblocks = -(-num_segments // SB)
    # Edge range per segment block.
    lo = np.searchsorted(seg_ids, np.arange(n_sblocks) * SB)
    hi = np.searchsorted(seg_ids, np.minimum((np.arange(n_sblocks) + 1) * SB, num_segments))
    counts = hi - lo
    nchunks = np.maximum(-(-counts // EB), 1).astype(np.int32)
    chunk_ptr = np.concatenate([[0], np.cumsum(nchunks)[:-1]]).astype(np.int32)
    e_pad = int(nchunks.sum()) * EB
    perm = np.full(e_pad, -1, dtype=np.int64)
    loc = np.zeros(e_pad, dtype=np.int32)
    for b in range(n_sblocks):
        n = counts[b]
        dst = chunk_ptr[b] * EB
        perm[dst : dst + n] = np.arange(lo[b], hi[b])
        loc[dst : dst + n] = seg_ids[lo[b] : hi[b]] - b * SB
    return perm, loc, chunk_ptr, nchunks, e_pad


def segment_sum_xla(
    data_padded: jax.Array,  # (E_pad, D) f32 — permuted by csr_block_layout
    loc: jax.Array,  # (E_pad,) int32 — block-local destination ids
    chunk_ptr: jax.Array,  # (n_sblocks,) int32
    num_segments: int,
) -> jax.Array:
    """`jax.ops.segment_sum` fast path over the same blocked CSR layout.

    Used when pallas-TPU's (deprecated-upstream) `PrefetchScalarGridSpec` is
    absent: global destination ids are reconstructed from the layout
    (block-of-chunk × SB + local id) and handed to XLA's segment sum, so
    callers of the blocked kernel keep working — and fast — on installs
    where the Pallas grid cannot be built. Padding rows carry zero data, so
    they contribute nothing wherever their reconstructed id lands.
    """
    e_pad, _ = data_padded.shape
    n_sblocks = chunk_ptr.shape[0]
    n_total_chunks = e_pad // EB
    chunk_ids = jnp.arange(n_total_chunks, dtype=chunk_ptr.dtype)
    block_of_chunk = jnp.searchsorted(chunk_ptr, chunk_ids, side="right") - 1
    seg = jnp.repeat(block_of_chunk.astype(jnp.int32), EB) * SB + loc
    s_pad = n_sblocks * SB
    assert num_segments <= s_pad, (
        f"num_segments={num_segments} exceeds the layout's {s_pad} padded rows"
    )
    out = jax.ops.segment_sum(
        data_padded.astype(jnp.float32), seg, num_segments=s_pad
    )
    return out[:num_segments]


def _kernel(chunk_ptr_ref, nchunks_ref, loc_ref, data_ref, out_ref):
    b = pl.program_id(0)
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(c < nchunks_ref[b])
    def _acc():
        loc = loc_ref[0, :]  # (EB,) int32 local ids; padding rows have data==0
        onehot = (loc[:, None] == jax.lax.broadcasted_iota(jnp.int32, (EB, SB), 1)).astype(
            jnp.float32
        )
        contrib = jax.lax.dot(
            onehot.T, data_ref[...], preferred_element_type=jnp.float32
        )
        out_ref[...] += contrib


def segment_sum_pallas(
    data_padded: jax.Array,  # (E_pad, D) f32 — permuted by csr_block_layout, pad rows zero
    loc: jax.Array,  # (E_pad,) int32
    chunk_ptr: jax.Array,  # (n_sblocks,) int32
    nchunks: jax.Array,  # (n_sblocks,) int32
    num_segments: int,
    *,
    max_chunks: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """(S_pad, D) blocked segment sum; rows ≥ num_segments are zero padding.

    ``interpret=True`` is a debug flag only — tier dispatch (including the
    decision to run this kernel at all) lives in ``ops.segment_sum_sorted``.
    """
    if pl is None or pltpu is None or not hasattr(pltpu, "PrefetchScalarGridSpec"):
        # Fast path (ROADMAP item): no Pallas prefetch grid on this install —
        # compute the same blocked layout through jax.ops.segment_sum. Loud so
        # a benchmark column labeled 'pallas' is never silently XLA numbers.
        warnings.warn(
            "segment_sum_pallas: PrefetchScalarGridSpec unavailable — running "
            "the jax.ops.segment_sum fast path over the blocked layout; "
            "reported timings are NOT pallas timings",
            RuntimeWarning,
            stacklevel=2,
        )
        return segment_sum_xla(data_padded, loc, chunk_ptr, num_segments)
    e_pad, d = data_padded.shape
    n_sblocks = chunk_ptr.shape[0]
    n_total_chunks = e_pad // EB
    if max_chunks is None:
        max_chunks = n_total_chunks  # safe upper bound for the chunk grid dim
    s_pad = n_sblocks * SB

    def data_index(b, c, ptr, nch):
        return (jnp.minimum(ptr[b] + c, n_total_chunks - 1), 0)

    def loc_index(b, c, ptr, nch):
        return (jnp.minimum(ptr[b] + c, n_total_chunks - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_sblocks, max_chunks),
        in_specs=[
            pl.BlockSpec((1, EB), loc_index),
            pl.BlockSpec((EB, d), data_index),
        ],
        out_specs=pl.BlockSpec((SB, d), lambda b, c, ptr, nch: (b, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_pad, d), jnp.float32),
        interpret=interpret,
    )(chunk_ptr, nchunks, loc.reshape(n_total_chunks, EB), data_padded)
    return out[:num_segments] if num_segments <= s_pad else out
