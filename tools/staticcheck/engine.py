"""Checker engine: findings, suppressions, baseline diffing, file walking.

Rule implementations live in :mod:`tools.staticcheck.rules`; this module is
the rule-agnostic plumbing:

* :class:`Finding` — one diagnostic (rule id, severity, location, message,
  fix-it hint, suppression state, and a line-content fingerprint that stays
  stable across unrelated edits for baseline diffing).
* Inline suppressions — ``# staticcheck: disable=SC003 <reason>`` on the
  offending line or on a comment line directly above it. The reason is
  MANDATORY: a reasonless suppression does not suppress and is itself
  reported as an ``SC000`` finding, so "shut it up" without a recorded
  justification can never pass CI.
* Baseline — a JSON set of fingerprints of known findings; only findings
  *not* in the baseline count as new. The repo policy (ISSUE 7) is an empty
  baseline: intentional violations get inline suppressions with reasons.
* Self-test — every fixture under ``fixtures/`` declares the rule ids it
  must trigger (``# staticcheck-fixture-expect: SC001,...``); the checker
  validates itself against them so a silently-broken rule fails CI.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*staticcheck:\s*disable=((?:SC\d{3})(?:\s*,\s*SC\d{3})*)"
    r"(?:[ \t]+(?P<reason>\S.*?))?\s*$"
)
FIXTURE_EXPECT_RE = re.compile(
    r"#\s*staticcheck-fixture-expect:\s*((?:SC\d{3})(?:\s*,\s*SC\d{3})*)?\s*$",
    re.MULTILINE,
)
# Fixture files are deliberate violations — never scanned in a normal run.
_EXCLUDED_DIR = "fixtures"


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str  # "error" | "warning"
    path: str
    line: int
    col: int
    message: str
    hint: str = ""
    suppressed: bool = False
    suppress_reason: str = ""
    fingerprint: str = ""

    def render(self) -> str:
        sup = f"  [suppressed: {self.suppress_reason}]" if self.suppressed else ""
        hint = f"\n    hint: {self.hint}" if self.hint and not self.suppressed else ""
        return (
            f"{self.path}:{self.line}:{self.col}: {self.rule} "
            f"[{self.severity}] {self.message}{sup}{hint}"
        )

    def as_json(self) -> dict:
        return dataclasses.asdict(self)


def _fingerprint(rule: str, path: str, source_line: str, dup: int) -> str:
    """Stable id for baseline diffing: rule + path + the stripped source
    line (not the line *number*, so unrelated edits above don't churn the
    baseline) + a duplicate counter for repeated identical lines."""
    key = f"{rule}|{path}|{source_line.strip()}|{dup}"
    return hashlib.sha1(key.encode()).hexdigest()[:16]


def parse_suppressions(
    lines: Sequence[str],
) -> Tuple[Dict[int, Dict[str, str]], List[Finding]]:
    """Map line -> {rule_id: reason} plus SC000 findings for reasonless
    suppressions. A suppression on a comment-only line also covers the next
    line (so long statements can carry the justification above them)."""
    by_line: Dict[int, Dict[str, str]] = {}
    bad: List[Finding] = []
    for i, raw in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(raw)
        if not m:
            continue
        rules = [r.strip() for r in m.group(1).split(",")]
        reason = (m.group("reason") or "").strip()
        if not reason:
            bad.append(
                Finding(
                    rule="SC000",
                    severity="error",
                    path="",
                    line=i,
                    col=raw.index("#"),
                    message=(
                        "suppression without justification: "
                        "'# staticcheck: disable=...' requires a reason "
                        "after the rule list (the finding is NOT suppressed)"
                    ),
                    hint="write `# staticcheck: disable=SCnnn <why this is intentional>`",
                )
            )
            continue
        targets = [i]
        if raw.strip().startswith("#"):
            targets.append(i + 1)
        for ln in targets:
            by_line.setdefault(ln, {}).update({r: reason for r in rules})
    return by_line, bad


def check_source(
    text: str, path: str, rules: Optional[Sequence] = None
) -> List[Finding]:
    """Run every applicable rule over one file's source. ``path`` is the
    path findings are reported (and path-filtered rules matched) under —
    callers may pass a virtual path (the self-test does)."""
    if rules is None:
        from tools.staticcheck.rules import RULES as rules  # lazy, no cycle

    norm = path.replace("\\", "/")
    lines = text.splitlines()
    try:
        tree = ast.parse(text, filename=path)
    except SyntaxError as e:
        return [
            Finding(
                rule="SC000",
                severity="error",
                path=norm,
                line=int(e.lineno or 1),
                col=int(e.offset or 0),
                message=f"file does not parse: {e.msg}",
            )
        ]

    suppress, bad_sup = parse_suppressions(lines)
    findings: List[Finding] = [
        dataclasses.replace(f, path=norm) for f in bad_sup
    ]
    seen = set()
    dup_count: Dict[str, int] = {}
    for rule in rules:
        if not rule.applies_to(norm):
            continue
        for raw in rule.check(tree, norm, lines):
            key = (raw.rule, raw.line, raw.col, raw.message)
            if key in seen:  # nested-scope walks may visit a node twice
                continue
            seen.add(key)
            src_line = lines[raw.line - 1] if 0 < raw.line <= len(lines) else ""
            fkey = f"{raw.rule}|{src_line.strip()}"
            dup = dup_count.get(fkey, 0)
            dup_count[fkey] = dup + 1
            reason = suppress.get(raw.line, {}).get(raw.rule, "")
            findings.append(
                dataclasses.replace(
                    raw,
                    path=norm,
                    suppressed=bool(reason),
                    suppress_reason=reason,
                    fingerprint=_fingerprint(raw.rule, norm, src_line, dup),
                )
            )
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def iter_py_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        root = Path(p)
        if root.is_file():
            out.append(root)
            continue
        for f in sorted(root.rglob("*.py")):
            if _EXCLUDED_DIR in f.parts and "staticcheck" in f.parts:
                continue
            out.append(f)
    return out


def check_paths(
    paths: Iterable[str], rules: Optional[Sequence] = None
) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(check_source(f.read_text(), str(f), rules))
    return findings


# -- baseline ----------------------------------------------------------------


def load_baseline(path: Optional[str]) -> set:
    if not path:
        return set()
    doc = json.loads(Path(path).read_text())
    return {e["fingerprint"] for e in doc.get("findings", [])}


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    doc = {
        "version": 1,
        "findings": [
            {"rule": f.rule, "path": f.path, "fingerprint": f.fingerprint}
            for f in findings
            if not f.suppressed
        ],
    }
    Path(path).write_text(json.dumps(doc, indent=1) + "\n")


def new_findings(
    findings: Sequence[Finding], baseline: set
) -> List[Finding]:
    """Unsuppressed findings not already recorded in the baseline."""
    return [
        f
        for f in findings
        if not f.suppressed and f.fingerprint not in baseline
    ]


# -- self-test over the bundled fixtures -------------------------------------


def run_selftest(fixtures_dir: Optional[str] = None) -> Tuple[bool, List[str]]:
    """Every fixture must trigger exactly the rule ids it declares (clean
    fixtures declare none and must stay finding-free). Returns (ok, report
    lines). Fixtures are checked under a virtual ``src/repro/core/`` path so
    path-filtered rules (SC004) apply."""
    fdir = Path(fixtures_dir or Path(__file__).parent / "fixtures")
    lines_out: List[str] = []
    ok = True
    files = sorted(fdir.glob("*.py"))
    if not files:
        return False, [f"selftest: no fixtures found in {fdir}"]
    for f in files:
        text = f.read_text()
        m = FIXTURE_EXPECT_RE.search(text)
        if not m:
            ok = False
            lines_out.append(
                f"selftest FAIL {f.name}: missing "
                "'# staticcheck-fixture-expect:' header"
            )
            continue
        expected = set()
        if m.group(1):
            expected = {r.strip() for r in m.group(1).split(",")}
        found = check_source(text, f"src/repro/core/{f.name}")
        got = {x.rule for x in found if not x.suppressed}
        missing = expected - got
        unexpected = got - expected
        if missing or unexpected:
            ok = False
            lines_out.append(
                f"selftest FAIL {f.name}: expected {sorted(expected)}, "
                f"got {sorted(got)}"
                + (f" (missing {sorted(missing)})" if missing else "")
            )
            for x in found:
                lines_out.append(f"    {x.render()}")
        else:
            lines_out.append(
                f"selftest ok   {f.name}: {sorted(got) or 'clean'}"
            )
    return ok, lines_out
