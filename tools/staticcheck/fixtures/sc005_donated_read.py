# staticcheck-fixture-expect: SC005
"""SC005 fixture: reading a buffer after donating it to a jitted call."""
from functools import partial

import jax


@partial(jax.jit, donate_argnums=(0,))
def run_chunk(carry, xs):
    return carry + xs, xs


def drive(carry, xs):
    new_carry, out = run_chunk(carry, xs)
    leak = carry + 1  # SC005: carry's buffer was donated to run_chunk
    return new_carry, leak


def drive_loop(carry, chunks):
    for xs in chunks:
        total = carry.sum()  # SC005 (2nd iteration): donated last iteration
        state, _ = run_chunk(carry, xs)
    return state, total


def drive_ok(carry, xs):
    carry, out = run_chunk(carry, xs)  # rebinding the name is the idiom
    return carry + 1, out
