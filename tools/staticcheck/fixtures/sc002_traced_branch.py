# staticcheck-fixture-expect: SC002
"""SC002 fixture: Python control flow on traced values in step closures."""
import jax
import jax.numpy as jnp


def make_step(stream, cap):
    def step(carry, _):
        row = stream[carry % stream.shape[0]]
        if row[0] > cap:  # SC002: Python if on a traced value
            carry = carry + 1
        while carry > 0:  # SC002: Python while on a traced value
            carry = carry - 1
        assert carry >= 0  # SC002: assert concretizes the tracer
        flag = bool(carry)  # SC002: bool() coercion
        out = row if carry > 0 else -row  # SC002: ternary on traced test
        return carry, (out, flag)

    return step


def body(i, acc):
    derived = acc + i
    if derived > 0:  # SC002: body is passed to fori_loop below
        derived = -derived
    return derived


def run(n, acc):
    return jax.lax.fori_loop(0, n, body, acc)


def scanned(xs):
    def inner(carry, x):  # passed to lax.scan below
        if x > carry:  # SC002
            carry = x
        return carry, x

    return jax.lax.scan(inner, jnp.int32(0), xs)
