# staticcheck-fixture-expect:
"""Clean fixture: the contract-conformant shapes of everything the other
fixtures violate. Must produce zero findings."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


class StepCore:  # stand-in base; exempt by name
    pass


@dataclasses.dataclass(frozen=True)
class GoodCore(StepCore):
    k: int = 2
    gamma: float = 1.5
    seed: int = 0

    def make_step(self, stream, m_real, allowed, cap, prev_assign):
        def step(carry, _):
            row = stream[carry % m_real]
            nxt = jnp.where(row[0] > cap, carry, carry + 1)
            return nxt, row

        return step


@partial(jax.jit, donate_argnums=(0,))
def run_chunk(carry, xs):
    return carry + xs, xs


class ScanDriver:
    def _run_ring(self, m_per, chunks):
        carry = jnp.int32(0)
        outs = []
        for xs in chunks:
            carry, out = run_chunk(carry, xs)
            outs.append(out)  # device handles only; no per-call sync
        return carry, [np.asarray(o) for o in outs]


def seeded(seed, m):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, size=m)
