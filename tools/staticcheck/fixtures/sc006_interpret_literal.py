# staticcheck-fixture-expect: SC006
"""SC006 fixture: literal interpret=True at a kernel call site (checked
under a virtual src/repro/core/ path — i.e. NOT one of the kernel modules
that own the debug flag)."""


def window_score_pallas(*args, interpret=False):
    return args, interpret


def score_window(args):
    # SC006: hardwired debug emulator, bypasses the tier ladder
    return window_score_pallas(*args, interpret=True)


def score_window_ok(args, debug):
    # fine: forwarding a variable keeps the decision with the dispatcher
    return window_score_pallas(*args, interpret=debug)


def score_window_default(args):
    # fine: explicit False is the non-debug default
    return window_score_pallas(*args, interpret=False)
