# staticcheck-fixture-expect: SC001
"""SC001 fixture: step-cores that are not frozen hashable dataclasses.

Never imported — parsed only. Each class below violates the core contract
in a distinct way the rule must catch.
"""
import dataclasses

import numpy as np


class StepCore:  # stand-in base; exempt by name
    pass


class MutableCore(StepCore):  # SC001: not a dataclass at all
    deg: np.ndarray = None  # SC001: ndarray-typed field

    def make_step(self, stream, m_real, allowed, cap, prev_assign):
        return None


@dataclasses.dataclass
class UnfrozenCore(StepCore):  # SC001: dataclass but frozen=False
    weights: list = dataclasses.field(default_factory=list)  # SC001: list field


@dataclasses.dataclass(frozen=True)
class OrphanCore:  # SC001: defines make_step without subclassing StepCore
    k: int = 2

    def make_step(self, stream, m_real, allowed, cap, prev_assign):
        return None


@dataclasses.dataclass(frozen=True)
class CacheCore(StepCore):
    k: int = 2
    scratch: dict = None  # SC001: dict-typed field poisons the jit cache
