# staticcheck-fixture-expect: SC003
"""SC003 fixture: host syncs inside stepping loops / refill / closures."""
import jax
import numpy as np


class ScanDriver:  # name puts its methods on the stepping surface
    def _run_ring(self, run_chunk, m_per):
        carry = self.carry
        calls = 0
        while calls < 64:
            carry, out = run_chunk(carry)
            calls += 1
            done = float(carry.assigned)  # SC003: float() on device value
            host = np.asarray(out.p)  # SC003: per-call materialization
            stall = carry.budget.item()  # SC003: .item() round-trip
            jax.block_until_ready(carry)  # SC003: full-pipeline sync
            if done >= m_per and host.size and stall >= 0:
                break
        return carry


class FileSource:
    def refill(self, buf, cursors):
        for i in range(4):
            rows = int(buf.hi[i])  # SC003: int() on the device ring
            buf = self._write(buf, rows)
        return buf


class _ReadAhead:  # the refill pipeline's staging worker is also surface
    def _loop(self, src):
        while not self._stop:
            block = src.readers[0].read(0, 64)
            jax.block_until_ready(block)  # SC003: sync in the staging loop
            self._staged.append(block)

    def take(self, buf, start, count):
        while self._taken < start + count:
            rows = np.asarray(buf.uv)  # SC003: materializes the donated ring
            self._taken += len(rows)
        return rows


def _run_pipeline(src, cursors):
    ring = src.alloc()
    while True:
        ring = src.refill(ring, cursors)  # refill returns the device ring
        depth = int(ring.hi[0])  # SC003: int() on the refill result
        if depth > 64:
            return ring


def make_step(stream):
    def step(carry, _):
        probe = np.asarray(carry)  # SC003: sync inside the traced step
        return carry, probe

    return step


def make_step_traced_tracer(trace):
    def make_step(stream):
        def step(carry, _):
            # SC003: tracer call inside the jit-traced step closure — it
            # would record trace/compile time, not per-call run time.
            with trace.span("step", cat="scan"):
                carry = carry + 1
            trace.add_span("tick", "scan", 0.0, 1.0)  # SC003 too
            return carry, carry

        return step

    return make_step
