# staticcheck-fixture-expect: SC004
"""SC004 fixture: legacy global-state RNG (checked under a virtual
src/repro/core/ path by the self-test)."""
import random

import numpy as np
from numpy.random import randint  # SC004: legacy import

np.random.seed(0)  # SC004: hidden global seed


def tie_noise(m):
    noise = np.random.rand(m)  # SC004: stateful draw -> geometry-dependent
    jitter = random.random()  # SC004: stdlib global RNG
    return noise + jitter + randint(0, 2)


def seeded_ok(seed, m):
    rng = np.random.default_rng(seed)  # fine: explicit seeded Generator
    return rng.integers(0, 2, size=m)
