"""Trace-contract checker: AST static analysis for scan-core hazards.

The streaming-scan architecture rests on contracts nothing in Python
enforces at runtime: step-cores must be frozen hashable dataclasses (they
are jit *static* arguments), traced step bodies must never branch in Python
or sync to host, tie noise must be counter-hashed rather than drawn from
stateful RNG, and donated buffers die at the donating call. This package
turns those contracts into CI-gated rules (see README.md for the catalog).

    python -m tools.staticcheck src/ --baseline tools/staticcheck/baseline.json
    python -m tools.staticcheck --selftest

Pure stdlib (``ast``) — no repro/jax import, safe in any environment.
"""
from tools.staticcheck.engine import (  # noqa: F401
    Finding,
    check_paths,
    check_source,
    load_baseline,
    run_selftest,
)
from tools.staticcheck.rules import RULES  # noqa: F401
