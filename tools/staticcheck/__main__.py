"""CLI for the trace-contract checker.

    python -m tools.staticcheck src/ --baseline tools/staticcheck/baseline.json
    python -m tools.staticcheck src/ tools/ --json
    python -m tools.staticcheck --selftest

Exit codes: 0 = clean (no new unsuppressed findings), 1 = findings (or a
failed self-test), 2 = usage error. Pure stdlib — runs anywhere.
"""
from __future__ import annotations

import argparse
import json
import sys

from tools.staticcheck.engine import (
    check_paths,
    load_baseline,
    new_findings,
    run_selftest,
    write_baseline,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.staticcheck",
        description="AST trace-contract checker (rules SC001-SC005)",
    )
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to scan (default: src)")
    ap.add_argument("--baseline", default=None,
                    help="JSON baseline; only findings absent from it fail")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline with the current findings")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    ap.add_argument("--selftest", action="store_true",
                    help="verify every fixture triggers its declared rules")
    args = ap.parse_args(argv)

    if args.selftest:
        ok, lines = run_selftest()
        print("\n".join(lines))
        print("staticcheck selftest:", "OK" if ok else "FAILED")
        return 0 if ok else 1

    paths = args.paths or ["src"]
    findings = check_paths(paths)
    baseline = load_baseline(args.baseline)
    if args.write_baseline:
        if not args.baseline:
            ap.error("--write-baseline requires --baseline")
        write_baseline(args.baseline, findings)
        baseline = load_baseline(args.baseline)
    new = new_findings(findings, baseline)
    suppressed = [f for f in findings if f.suppressed]

    if args.json:
        print(json.dumps({
            "new": [f.as_json() for f in new],
            "suppressed": [f.as_json() for f in suppressed],
            "baseline_matched": len(findings) - len(new) - len(suppressed),
            "files_scanned": paths,
            "ok": not new,
        }, indent=1))
    else:
        for f in new:
            print(f.render())
        for f in suppressed:
            print(f.render())
        print(
            f"staticcheck: {len(new)} new finding(s), "
            f"{len(suppressed)} suppressed, "
            f"{len(findings) - len(new) - len(suppressed)} baselined"
        )
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
