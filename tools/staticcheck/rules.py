"""The trace-contract rules (SC001–SC005). See README.md for the catalog.

Each rule is an object with ``id`` / ``severity`` / ``hint`` /
``applies_to(path)`` and ``check(tree, path, lines) -> [Finding]``. The
shared analyses below are deliberately simple forward passes — conservative
taint propagation and a dataflow-lite donated-liveness walk — tuned so the
current repo has zero false positives while every fixture violation fires.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from tools.staticcheck.engine import Finding

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def dotted(node: Optional[ast.AST]) -> Optional[str]:
    """'jax.lax.scan' for nested Attribute/Name chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def last_part(name: Optional[str]) -> str:
    return name.rsplit(".", 1)[-1] if name else ""


def names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def root_name(node: ast.AST) -> Optional[str]:
    """carry.assigned[i] -> 'carry'."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _target_names(target: ast.AST) -> Set[str]:
    """Names actually *bound* by an assignment target — Store context only,
    so `self.carry = x` binds nothing by name (not `self`)."""
    return {
        n.id
        for n in ast.walk(target)
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)
    }


def functions_in(tree: ast.AST) -> List[ast.FunctionDef]:
    return [
        n
        for n in ast.walk(tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]


def param_names(fn: ast.FunctionDef) -> Set[str]:
    a = fn.args
    params = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    if a.vararg:
        params.append(a.vararg)
    if a.kwarg:
        params.append(a.kwarg)
    return {p.arg for p in params} - {"self", "cls"}


def propagate(fn: ast.AST, seed: Set[str]) -> Set[str]:
    """Forward-close a taint set over assignments until fixpoint: any
    target assigned from an expression mentioning a tainted name becomes
    tainted. Conservative (ignores control flow, descends into nested
    defs) — fine, because only specific *uses* of tainted names are
    flagged."""
    tainted = set(seed)
    for _ in range(16):
        grew = False
        for node in ast.walk(fn):
            value = None
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.AugAssign):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.NamedExpr):
                value, targets = node.value, [node.target]
            elif isinstance(node, ast.For):
                value, targets = node.iter, [node.target]
            if value is None or not (names_in(value) & tainted):
                continue
            new = set().union(*(_target_names(t) for t in targets)) - tainted
            if new:
                tainted |= new
                grew = True
        if not grew:
            break
    return tainted


# -- step-closure discovery (shared by SC002 / SC003) ------------------------

# jax transforms whose function arguments run traced. Index = which
# positional args are traced callables (None = all Name args).
_TRACED_CALLEE_ARGS: Dict[str, Tuple[int, ...]] = {
    "scan": (0,),
    "fori_loop": (2,),
    "while_loop": (0, 1),
    "cond": (1, 2),
    "switch": (1,),
    "vmap": (0,),
    "pmap": (0,),
    "shard_map": (0,),
    "checkpoint": (0,),
    "remat": (0,),
}
_MAKE_STEP_NAMES = {"make_step", "_make_step"}


def step_closures(tree: ast.AST) -> Dict[ast.FunctionDef, str]:
    """FunctionDefs whose bodies run under jax tracing: functions returned
    by ``make_step``/``_make_step`` factories, and functions passed by name
    to ``lax.scan`` / ``fori_loop`` / ``while_loop`` / ``cond`` / ``vmap``
    / ``shard_map`` (and friends). Maps node -> why it is a closure."""
    by_name: Dict[str, List[ast.FunctionDef]] = {}
    for f in functions_in(tree):
        by_name.setdefault(f.name, []).append(f)

    closures: Dict[ast.FunctionDef, str] = {}
    for factory in functions_in(tree):
        if factory.name not in _MAKE_STEP_NAMES:
            continue
        returned = {
            dotted(r.value)
            for r in ast.walk(factory)
            if isinstance(r, ast.Return) and r.value is not None
        }
        for g in functions_in(factory):
            if g is not factory and g.name in returned:
                closures[g] = f"returned by {factory.name}"

    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        callee = last_part(dotted(call.func))
        if callee not in _TRACED_CALLEE_ARGS:
            continue
        for idx in _TRACED_CALLEE_ARGS[callee]:
            if idx >= len(call.args):
                continue
            arg_name = dotted(call.args[idx])
            for g in by_name.get(arg_name or "", []):
                closures.setdefault(g, f"passed to {callee}")
    return closures


# ---------------------------------------------------------------------------
# SC001 — step-cores are frozen hashable dataclasses
# ---------------------------------------------------------------------------

_UNHASHABLE_TYPE_NAMES = {
    "list", "dict", "set", "bytearray",
    "List", "Dict", "Set", "DefaultDict", "Counter", "OrderedDict",
    "MutableMapping", "MutableSequence", "MutableSet",
    "ndarray", "Array", "DeviceArray",
}
_MUTABLE_FACTORIES = {
    "list", "dict", "set", "bytearray",
    "array", "zeros", "ones", "empty", "full", "arange",
}


class SC001:
    id = "SC001"
    severity = "error"
    hint = (
        "cores are jit STATIC arguments: make the class "
        "`@dataclasses.dataclass(frozen=True)`, subclass StepCore, and keep "
        "every field a hashable scalar — per-instance arrays belong in the "
        "carry, not the core"
    )

    def applies_to(self, path: str) -> bool:
        return True

    def check(
        self, tree: ast.AST, path: str, lines: Sequence[str]
    ) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, ast.ClassDef) or node.name == "StepCore":
                continue
            bases = [last_part(dotted(b)) for b in node.bases]
            is_sub = "StepCore" in bases
            has_make_step = any(
                isinstance(x, (ast.FunctionDef, ast.AsyncFunctionDef))
                and x.name == "make_step"
                for x in node.body
            )
            if not (is_sub or has_make_step):
                continue
            if has_make_step and not is_sub:
                yield self._f(
                    node,
                    f"class {node.name} defines make_step but does not "
                    "subclass StepCore — it evades the step-core contract "
                    "(and this rule's field checks)",
                )
            is_dc = frozen = False
            for dec in node.decorator_list:
                name = last_part(
                    dotted(dec.func if isinstance(dec, ast.Call) else dec)
                )
                if name != "dataclass":
                    continue
                is_dc = True
                if isinstance(dec, ast.Call):
                    for kw in dec.keywords:
                        if (
                            kw.arg == "frozen"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value is True
                        ):
                            frozen = True
            if not is_dc:
                yield self._f(
                    node,
                    f"step-core {node.name} is not a dataclass — it must be "
                    "@dataclass(frozen=True) so instances hash by value as "
                    "jit cache keys",
                )
            elif not frozen:
                yield self._f(
                    node,
                    f"step-core {node.name} is a dataclass but not "
                    "frozen=True — mutable cores break hashing and poison "
                    "the jit-static cache",
                )
            yield from self._check_fields(node)

    def _check_fields(self, cls: ast.ClassDef) -> Iterator[Finding]:
        for stmt in cls.body:
            ann = value = None
            name = "?"
            if isinstance(stmt, ast.AnnAssign) and isinstance(
                stmt.target, ast.Name
            ):
                ann, value, name = stmt.annotation, stmt.value, stmt.target.id
            elif isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and (
                isinstance(stmt.targets[0], ast.Name)
            ):
                value, name = stmt.value, stmt.targets[0].id
            else:
                continue
            if ann is not None:
                bad = self._bad_annotation_names(ann)
                if bad:
                    yield self._f(
                        stmt,
                        f"field {cls.name}.{name} is annotated with "
                        f"unhashable type {sorted(bad)} — core fields must "
                        "be hashable scalars (arrays/containers go in the "
                        "carry)",
                    )
            if value is not None:
                why = self._mutable_default(value)
                if why:
                    yield self._f(
                        stmt,
                        f"field {cls.name}.{name} has a mutable default "
                        f"({why}) — this makes the core unhashable and "
                        "aliases state across instances",
                    )

    def _bad_annotation_names(self, ann: ast.AST) -> Set[str]:
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            try:
                ann = ast.parse(ann.value, mode="eval").body
            except SyntaxError:
                return set()
        found = set()
        for n in ast.walk(ann):
            nm = None
            if isinstance(n, ast.Name):
                nm = n.id
            elif isinstance(n, ast.Attribute):
                nm = n.attr
            if nm in _UNHASHABLE_TYPE_NAMES:
                found.add(nm)
        return found

    def _mutable_default(self, value: ast.AST) -> Optional[str]:
        if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
            return "container literal"
        if isinstance(value, ast.Call):
            callee = dotted(value.func)
            tail = last_part(callee)
            if tail == "field":
                for kw in value.keywords:
                    if kw.arg == "default_factory" and last_part(
                        dotted(kw.value)
                    ) in _MUTABLE_FACTORIES:
                        return f"field(default_factory={dotted(kw.value)})"
                    if kw.arg == "default" and self._mutable_default(kw.value):
                        return "field(default=<mutable>)"
                return None
            if tail in _MUTABLE_FACTORIES:
                return f"{callee}(...)"
        return None

    def _f(self, node: ast.AST, msg: str) -> Finding:
        return Finding(
            rule=self.id, severity=self.severity, path="",
            line=node.lineno, col=node.col_offset, message=msg,
            hint=self.hint,
        )


# ---------------------------------------------------------------------------
# SC002 — no Python control flow on traced values in step closures
# ---------------------------------------------------------------------------


class SC002:
    id = "SC002"
    severity = "error"
    hint = (
        "traced values have no concrete truth value inside jit — use "
        "jnp.where / lax.select / lax.cond on the traced operand instead "
        "of Python `if`/`while`/`assert`/bool()"
    )

    def applies_to(self, path: str) -> bool:
        return True

    def check(
        self, tree: ast.AST, path: str, lines: Sequence[str]
    ) -> Iterator[Finding]:
        for fn, why in step_closures(tree).items():
            tainted = propagate(fn, param_names(fn))
            for node in ast.walk(fn):
                test = None
                kind = ""
                if isinstance(node, (ast.If, ast.While)):
                    test, kind = node.test, type(node).__name__.lower()
                elif isinstance(node, ast.IfExp):
                    test, kind = node.test, "conditional expression"
                elif isinstance(node, ast.Assert):
                    test, kind = node.test, "assert"
                elif isinstance(node, ast.Call) and dotted(node.func) == "bool":
                    if any(names_in(a) & tainted for a in node.args):
                        yield self._f(
                            node, fn, why,
                            "bool() coercion of a traced value",
                        )
                    continue
                if test is None:
                    continue
                hit = names_in(test) & tainted
                if hit:
                    yield self._f(
                        node, fn, why,
                        f"Python `{kind}` on traced value(s) "
                        f"{sorted(hit)}",
                    )

    def _f(self, node, fn, why, what) -> Finding:
        return Finding(
            rule=self.id, severity=self.severity, path="",
            line=node.lineno, col=node.col_offset,
            message=(
                f"{what} inside step closure `{fn.name}` ({why}) — "
                "concretizes a tracer (errors under jit, or silently bakes "
                "one branch into the trace)"
            ),
            hint=self.hint,
        )


# ---------------------------------------------------------------------------
# SC003 — no host syncs in stepping loops / step closures / refill paths
# ---------------------------------------------------------------------------

_STEP_SURFACE_CLASSES = {"ScanDriver", "FileSource", "_ReadAhead"}
_STEP_SURFACE_FN = re.compile(r"^(_run_\w*|refill|recalibrate|take|_loop|_fetch)$")
_SYNC_ON_TAINTED = {
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "np.ascontiguousarray", "numpy.ascontiguousarray",
    "int", "float",
}
_SYNC_ALWAYS = {
    "jax.device_get", "device_get",
    "jax.block_until_ready", "block_until_ready",
}
_DEVICE_PRODUCERS = re.compile(r"^(_run_scan\w*|run_chunk|_ring_write|refill)$")
_DEVICE_NAME_SEEDS = {"carry", "buf", "carry_buf"}
# repro.obs recording API. Tracing is HOST-side only: a tracer call inside a
# jit-traced step closure reads the host clock at *trace* time (the span is
# baked into the compiled program, not measured per call) and mutates host
# state from a traced context — both silently wrong.
_TRACER_API = {"span", "add_span", "instant", "gauge", "counter", "event"}


def _tracer_base(node: ast.AST) -> bool:
    """True when a dotted receiver names a tracer: any component is `tr` or
    contains `trace` (`trace.span`, `self.trace`, `self._trace`, `tracer`)."""
    base = dotted(node)
    if not base:
        return False
    return any(p == "tr" or "trace" in p for p in base.lower().split("."))


class SC003:
    id = "SC003"
    severity = "error"
    hint = (
        "each host sync serializes dispatch and stalls the device — keep "
        "the stepping loop async (materialize outputs after the loop) or "
        "suppress with a justification if the sync is the design (e.g. a "
        "termination check that must read `assigned`)"
    )

    def applies_to(self, path: str) -> bool:
        return True

    def check(
        self, tree: ast.AST, path: str, lines: Sequence[str]
    ) -> Iterator[Finding]:
        closures = step_closures(tree)
        for fn, why in closures.items():
            tainted = propagate(fn, param_names(fn))
            yield from self._scan_region(fn, tainted, f"step closure ({why})")
            yield from self._scan_tracer(fn, f"step closure ({why})")
        for fn, region, owner in self._stepping_regions(tree):
            if fn in closures:
                continue
            tainted = self._device_taint(fn)
            yield from self._scan_region(region, tainted, owner)

    # -- scope discovery ----------------------------------------------------
    def _stepping_regions(self, tree) -> Iterator[Tuple[ast.AST, ast.AST, str]]:
        """(function, region-node, description) for every stepping-surface
        region: loop bodies (tests included) of driver/source methods and
        `_run_*`/`refill` functions, and whole `recalibrate` bodies."""
        method_owner: Dict[ast.AST, str] = {}
        for cls in ast.walk(tree):
            if isinstance(cls, ast.ClassDef):
                for item in cls.body:
                    if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        method_owner[item] = cls.name
        for fn in functions_in(tree):
            owner = method_owner.get(fn, "")
            surface = bool(_STEP_SURFACE_FN.match(fn.name)) or (
                owner in _STEP_SURFACE_CLASSES
            )
            if not surface:
                continue
            where = f"{owner + '.' if owner else ''}{fn.name}"
            if fn.name == "recalibrate":
                yield fn, fn, f"budget recalibration `{where}`"
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.For, ast.While)):
                    yield fn, node, f"stepping loop in `{where}`"

    def _device_taint(self, fn: ast.AST) -> Set[str]:
        seeds: Set[str] = set()
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            seeds |= param_names(fn) & _DEVICE_NAME_SEEDS
            if fn.name == "recalibrate":
                seeds |= param_names(fn)
        for node in ast.walk(fn):
            value = None
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                value, targets = node.value, node.targets
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                value, targets = node.value, [node.target]
            if value is None:
                continue
            names = set().union(*(_target_names(t) for t in targets))
            if names & _DEVICE_NAME_SEEDS:
                seeds |= names & _DEVICE_NAME_SEEDS
            producer = False
            for call in ast.walk(value):
                if isinstance(call, ast.Call) and _DEVICE_PRODUCERS.match(
                    last_part(dotted(call.func)) or ""
                ):
                    producer = True
            vname = dotted(value)
            if vname and last_part(vname) == "carry":
                producer = True  # e.g. `carry = self.carry`
            if producer:
                seeds |= names
        return propagate(fn, seeds)

    # -- sync detection -----------------------------------------------------
    def _scan_region(
        self, region: ast.AST, tainted: Set[str], where: str
    ) -> Iterator[Finding]:
        for node in ast.walk(region):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted(node.func)
            # x.item() on a device value
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"
                and not node.args
            ):
                if (root_name(node.func.value) or "") in tainted:
                    yield self._f(node, where, ".item() host round-trip")
                continue
            if callee in _SYNC_ALWAYS:
                yield self._f(
                    node, where, f"{callee}() forces a host sync"
                )
                continue
            if callee in _SYNC_ON_TAINTED:
                hit = set().union(*(names_in(a) for a in node.args)) & tainted
                if hit:
                    yield self._f(
                        node, where,
                        f"{callee}() on device value(s) {sorted(hit)}",
                    )
                continue
            # jax.tree.map(np.asarray, device_tree) and tree_map variants
            if last_part(callee) in {"map", "tree_map"} and node.args:
                f0 = dotted(node.args[0])
                if f0 in _SYNC_ON_TAINTED:
                    hit = set().union(
                        *(names_in(a) for a in node.args[1:])
                    ) & tainted
                    if hit:
                        yield self._f(
                            node, where,
                            f"{callee}({f0}, ...) materializes device "
                            f"tree(s) {sorted(hit)}",
                        )

    def _scan_tracer(self, fn: ast.AST, where: str) -> Iterator[Finding]:
        """Tracer calls inside jit-traced step closures. Stepping *loops*
        may trace (they run on the host); step *closures* must not — the
        call would record compile-time, not run-time."""
        for node in ast.walk(fn):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _TRACER_API
            ):
                continue
            if _tracer_base(node.func.value):
                yield self._f(
                    node, where,
                    f"tracer call `.{node.func.attr}()` — tracing is "
                    "host-side only; inside a traced closure it records "
                    "trace/compile time (not run time) and mutates host "
                    "state from a traced context",
                )

    def _f(self, node, where, what) -> Finding:
        return Finding(
            rule=self.id, severity=self.severity, path="",
            line=node.lineno, col=node.col_offset,
            message=f"host sync in {where}: {what}",
            hint=self.hint,
        )


# ---------------------------------------------------------------------------
# SC004 — no legacy global RNG in src/repro/core/
# ---------------------------------------------------------------------------

_RNG_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "MT19937", "Philox", "SFC64",
}
_STDLIB_RANDOM_LEGACY = {
    "random", "randint", "randrange", "uniform", "normalvariate", "gauss",
    "choice", "choices", "sample", "shuffle", "seed", "betavariate",
    "expovariate", "getrandbits", "random_sample",
}


class SC004:
    id = "SC004"
    severity = "error"
    hint = (
        "tie noise and sampling must be reproducible and geometry-"
        "independent: use a seeded np.random.default_rng(seed) Generator, "
        "or (for per-row tie noise) the stateless counter hash "
        "(baselines.tie_break_hash) so chunking cannot change assignments"
    )

    def applies_to(self, path: str) -> bool:
        return "repro/core/" in path

    def check(
        self, tree: ast.AST, path: str, lines: Sequence[str]
    ) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute):
                base = dotted(node.value)
                if base in {"np.random", "numpy.random"} and (
                    node.attr not in _RNG_OK
                ):
                    yield self._f(
                        node,
                        f"legacy global-state RNG {base}.{node.attr} — "
                        "hidden global state makes runs irreproducible and "
                        "chunk-geometry-dependent",
                    )
                elif base == "random" and node.attr in _STDLIB_RANDOM_LEGACY:
                    yield self._f(
                        node,
                        f"stdlib global RNG random.{node.attr} — same "
                        "hidden-global-state hazard as np.random.*",
                    )
            elif isinstance(node, ast.ImportFrom):
                if node.module == "numpy.random":
                    for alias in node.names:
                        if alias.name not in _RNG_OK:
                            yield self._f(
                                node,
                                "importing legacy RNG "
                                f"numpy.random.{alias.name}",
                            )

    def _f(self, node, msg) -> Finding:
        return Finding(
            rule=self.id, severity=self.severity, path="",
            line=node.lineno, col=node.col_offset, message=msg,
            hint=self.hint,
        )


# ---------------------------------------------------------------------------
# SC005 — no read of a donated buffer after the donating call
# ---------------------------------------------------------------------------


class SC005:
    id = "SC005"
    severity = "error"
    hint = (
        "donate_argnums invalidates the argument buffer at the call — "
        "rebind the result to the same name (`carry, out = f(carry)`), or "
        "copy before donating if the old value is still needed"
    )

    def applies_to(self, path: str) -> bool:
        return True

    def check(
        self, tree: ast.AST, path: str, lines: Sequence[str]
    ) -> Iterator[Finding]:
        donators = self._donating_functions(tree)
        if not donators:
            return
        for fn in functions_in(tree):
            if fn.name in donators:
                continue  # inside the jitted fn itself everything is traced
            findings: List[Finding] = []
            self._exec_block(fn.body, {}, donators, findings)
            yield from findings

    def _donating_functions(self, tree) -> Dict[str, Tuple[int, ...]]:
        out: Dict[str, Tuple[int, ...]] = {}
        for fn in functions_in(tree):
            for dec in fn.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                d = last_part(dotted(dec.func))
                is_jit = False
                if d == "partial" and dec.args and last_part(
                    dotted(dec.args[0])
                ) == "jit":
                    is_jit = True
                elif d == "jit":
                    is_jit = True
                if not is_jit:
                    continue
                for kw in dec.keywords:
                    if kw.arg not in ("donate_argnums", "donate_argnames"):
                        continue
                    idxs = []
                    for c in ast.walk(kw.value):
                        if isinstance(c, ast.Constant) and isinstance(
                            c.value, int
                        ):
                            idxs.append(c.value)
                    if idxs:
                        out[fn.name] = tuple(idxs)
        return out

    # -- dataflow-lite ------------------------------------------------------
    def _exec_block(self, stmts, dead, donators, findings) -> None:
        for st in stmts:
            self._exec_stmt(st, dead, donators, findings)

    def _exec_stmt(self, st, dead, donators, findings) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            return  # separate frame; analyzed on its own
        if isinstance(st, ast.If):
            self._check_loads(st.test, dead, findings)
            d1, d2 = dict(dead), dict(dead)
            self._exec_block(st.body, d1, donators, findings)
            self._exec_block(st.orelse, d2, donators, findings)
            dead.clear()
            dead.update(d1)
            dead.update(d2)
            return
        if isinstance(st, (ast.For, ast.While)):
            # Two passes so a donation late in the body kills a read at the
            # top of the next iteration.
            seen: Set[Tuple[int, int, str]] = set()
            for _ in range(2):
                if isinstance(st, ast.While):
                    self._check_loads(st.test, dead, findings, seen)
                else:
                    self._check_loads(st.iter, dead, findings, seen)
                    for n in _target_names(st.target):
                        dead.pop(n, None)
                for s in st.body:
                    self._exec_pass(s, dead, donators, findings, seen)
            self._exec_block(st.orelse, dead, donators, findings)
            return
        if isinstance(st, (ast.With,)):
            for item in st.items:
                self._check_loads(item.context_expr, dead, findings)
            self._exec_block(st.body, dead, donators, findings)
            return
        if isinstance(st, ast.Try):
            self._exec_block(st.body, dead, donators, findings)
            for h in st.handlers:
                self._exec_block(h.body, dict(dead), donators, findings)
            self._exec_block(st.finalbody, dead, donators, findings)
            return
        # simple statement: loads happen before the call donates, then the
        # assignment targets revive.
        self._check_loads(st, dead, findings)
        for call in ast.walk(st):
            if not isinstance(call, ast.Call):
                continue
            fname = last_part(dotted(call.func))
            if fname not in donators:
                continue
            for idx in donators[fname]:
                if idx < len(call.args):
                    for n in names_in(call.args[idx]):
                        dead[n] = fname
        targets: List[ast.AST] = []
        if isinstance(st, ast.Assign):
            targets = st.targets
        elif isinstance(st, (ast.AnnAssign, ast.AugAssign)):
            targets = [st.target]
        for t in targets:
            for n in _target_names(t):
                dead.pop(n, None)

    def _exec_pass(self, st, dead, donators, findings, seen) -> None:
        before = len(findings)
        self._exec_stmt(st, dead, donators, findings)
        kept = []
        for f in findings[before:]:
            key = (f.line, f.col, f.message)
            if key not in seen:
                seen.add(key)
                kept.append(f)
        findings[before:] = kept

    def _check_loads(self, node, dead, findings, seen=None) -> None:
        if not dead:
            return
        for n in ast.walk(node):
            if (
                isinstance(n, ast.Name)
                and isinstance(n.ctx, ast.Load)
                and n.id in dead
            ):
                f = Finding(
                    rule=self.id, severity=self.severity, path="",
                    line=n.lineno, col=n.col_offset,
                    message=(
                        f"`{n.id}` is read after being donated to "
                        f"`{dead[n.id]}` (donate_argnums) — the buffer is "
                        "invalidated at the call; reading it is undefined"
                    ),
                    hint=self.hint,
                )
                if seen is not None:
                    key = (f.line, f.col, f.message)
                    if key in seen:
                        continue
                    seen.add(key)
                findings.append(f)


# ---------------------------------------------------------------------------
# SC006 — no interpret=True literals outside the kernels' debug entry points
# ---------------------------------------------------------------------------

# The Pallas kernel modules own `interpret` as an explicit debug parameter
# (default False, forwarded to pallas_call); every other call site must go
# through the repro.kernels.ops tier ladder.
_INTERPRET_ENTRY_RE = re.compile(
    r"repro/kernels/(window_score|segment_sum|flash_attention)\.py$"
)


class SC006:
    id = "SC006"
    severity = "error"
    hint = (
        "interpret mode is a debug tier, never the dispatch default: "
        "request it explicitly through repro.kernels.ops "
        "(tier='interpret', or $ADWISE_KERNEL_TIER=interpret at run time) "
        "so the resolved tier ladder stays in charge — a literal "
        "interpret=True pins pure-Python kernel emulation at the call site"
    )

    def applies_to(self, path: str) -> bool:
        return not _INTERPRET_ENTRY_RE.search(path.replace("\\", "/"))

    def check(
        self, tree: ast.AST, path: str, lines: Sequence[str]
    ) -> Iterator[Finding]:
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if (
                    kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                ):
                    callee = dotted(node.func) or "<call>"
                    yield Finding(
                        rule=self.id, severity=self.severity, path="",
                        line=kw.value.lineno, col=kw.value.col_offset,
                        message=(
                            f"literal interpret=True passed to {callee} — "
                            "hardwires the Pallas debug emulator and "
                            "bypasses the kernel tier ladder"
                        ),
                        hint=self.hint,
                    )


RULES = (SC001(), SC002(), SC003(), SC004(), SC005(), SC006())
