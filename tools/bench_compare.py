"""Bench regression gate: diff the two newest ``BENCH_<n>.json`` summaries.

    PYTHONPATH=src python -m tools.bench_compare bench_logs/
    PYTHONPATH=src python -m tools.bench_compare bench_logs/ --threshold 0.25

Reads the two highest-numbered ``BENCH_<n>.json`` files a kept
``--json-dir`` accumulated (see benchmarks/run.py), prints per-row deltas
for the headline walls (partition file/sync/memory walls, h2d stall,
prestage wall) and the jit compile counts, and exits non-zero when any
tracked wall regressed by more than ``--threshold`` (default 25%).

tools/ci.sh runs it warn-only (`|| echo warn`): a single CI box's bench
walls are noisy, so the gate flags rather than blocks there; a perf-CI
runner with pinned hardware can drop the `||` and make it binding.

Fewer than two summaries (fresh checkout, first run) exits 0 — there is
nothing to compare yet, which is not a regression.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

# Wall-clock keys gated by --threshold (from BENCH summary; lower = better).
_WALL_KEYS = (
    "partition_file_wall_s",
    "partition_file_sync_wall_s",
    "partition_memory_wall_s",
    "h2d_wait_s",
    "prestage_wall_s",
    "window_score_wall_s",
    "segment_sum_wall_s",
)
# Context keys printed but never gated (counts / ratios / throughputs).
_INFO_KEYS = (
    "overlap_efficiency",
    "ingest_mb_s",
    "read_mb_s",
    "h2d_bytes",
    "kernel_tier",
)


def _bench_files(json_dir: str):
    """(n, path) pairs for every BENCH_<n>.json in json_dir, sorted by n."""
    if not os.path.isdir(json_dir):
        return []
    pairs = [
        (int(m.group(1)), os.path.join(json_dir, f))
        for f in os.listdir(json_dir)
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", f))
    ]
    return sorted(pairs)


def _fmt(v):
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _delta(old, new):
    """Relative change new vs old; None when either side is missing/zero."""
    if not isinstance(old, (int, float)) or not isinstance(new, (int, float)):
        return None
    if old <= 0:
        return None
    return (new - old) / old


def compare(old_doc: dict, new_doc: dict, threshold: float):
    """(lines, regressions) — report lines plus the walls over threshold."""
    old_s, new_s = old_doc.get("summary") or {}, new_doc.get("summary") or {}
    lines = [f"{'key':32s} {'old':>12s} {'new':>12s} {'delta':>8s}"]
    regressions = []
    for key in _WALL_KEYS:
        old_v, new_v = old_s.get(key), new_s.get(key)
        d = _delta(old_v, new_v)
        mark = ""
        if d is not None and d > threshold:
            regressions.append((key, old_v, new_v, d))
            mark = "  << REGRESSION"
        ds = f"{d:+.0%}" if d is not None else "-"
        lines.append(f"{key:32s} {_fmt(old_v):>12s} {_fmt(new_v):>12s} "
                     f"{ds:>8s}{mark}")
    for key in _INFO_KEYS:
        old_v, new_v = old_s.get(key), new_s.get(key)
        if old_v is None and new_v is None:
            continue
        d = _delta(old_v, new_v)
        ds = f"{d:+.0%}" if d is not None else "-"
        lines.append(f"{key:32s} {_fmt(old_v):>12s} {_fmt(new_v):>12s} "
                     f"{ds:>8s}")
    # Compile budget: any growth without a geometry change is suspect — the
    # pow2-Rq contract (tests/test_compile_budget.py) bounds this per run.
    old_c = old_doc.get("jit_scan_compiles") or {}
    new_c = new_doc.get("jit_scan_compiles") or {}
    for key in sorted(set(old_c) | set(new_c)):
        lines.append(f"{'compiles.' + key:32s} {_fmt(old_c.get(key)):>12s} "
                     f"{_fmt(new_c.get(key)):>12s} {'':>8s}")
    return lines, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("json_dir", help="directory of BENCH_<n>.json summaries "
                                     "(benchmarks/run.py --json-dir)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative wall regression that fails the gate "
                         "(default 0.25 = 25%%)")
    args = ap.parse_args(argv)

    files = _bench_files(args.json_dir)
    if len(files) < 2:
        print(f"bench_compare: {len(files)} summary file(s) in "
              f"{args.json_dir} — need 2 to compare; nothing to gate")
        return 0
    (old_n, old_path), (new_n, new_path) = files[-2], files[-1]
    with open(old_path) as f:
        old_doc = json.load(f)
    with open(new_path) as f:
        new_doc = json.load(f)
    print(f"bench_compare: BENCH_{old_n} ({old_doc.get('mode')}) -> "
          f"BENCH_{new_n} ({new_doc.get('mode')}), "
          f"threshold {args.threshold:.0%}")
    if old_doc.get("mode") != new_doc.get("mode"):
        print("bench_compare: modes differ — walls are not comparable; "
              "reporting without gating")
        for line in compare(old_doc, new_doc, threshold=float("inf"))[0]:
            print(line)
        return 0
    lines, regressions = compare(old_doc, new_doc, args.threshold)
    for line in lines:
        print(line)
    if regressions:
        for key, old_v, new_v, d in regressions:
            print(f"bench_compare: {key} regressed {d:+.0%} "
                  f"({_fmt(old_v)}s -> {_fmt(new_v)}s)", file=sys.stderr)
        return 1
    print("bench_compare: no wall regression over threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
