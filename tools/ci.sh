#!/usr/bin/env bash
# CI gate: static trace-contract checks, type check, tier-1 quick test
# profile, and the smoke pass over every benchmark entrypoint (proves each
# bench still *runs*; regressions in launch/bench wiring fail here, not in
# a nightly).
#
#   tools/ci.sh          # what the workflow runs
#   tools/ci.sh --full   # also run the slow-marked tests
#
# Runs under `set -euo pipefail` end-to-end: every step below must succeed
# or the script dies there — no failing checker/bench can be masked by a
# later successful command (note the nullglob arrays for BENCH counting:
# `ls ... | wc -l` would abort the script on an empty dir under pipefail).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

MARK='not slow'
if [[ "${1:-}" == "--full" ]]; then
  MARK=''
fi

# Trace-contract checker. Self-test FIRST: every fixture must trip its
# rule, so a silently-broken checker fails CI before it can wave the repo
# through. Then the repo gate: zero unsuppressed findings over src/ and
# tools/ (fixtures excluded by the engine; the shipped baseline is empty —
# intentional violations carry inline justifications instead).
python -m tools.staticcheck --selftest
python -m tools.staticcheck src tools --baseline tools/staticcheck/baseline.json

# Strict type check on the trace-contract surface (core/types.py +
# core/driver.py, per mypy.ini). The workflow installs mypy; bare
# containers without it skip rather than mask the rest of the gate.
if python -c "import mypy" >/dev/null 2>&1; then
  python -m mypy --config-file mypy.ini
else
  echo "mypy not installed: skipping type check (workflow installs it)"
fi

if [[ -n "$MARK" ]]; then
  python -m pytest -x -q -m "$MARK"
else
  python -m pytest -x -q
fi

# Refill-pipeline matrix: the driver/ring suite must hold bit-parity and
# its h2d accounting at BOTH ends of the prefetch knob — 0 (synchronous
# escape hatch) and 2 (the double-buffered default) — whatever the
# environment's ADWISE_PREFETCH happens to be.
ADWISE_PREFETCH=0 python -m pytest -x -q tests/test_driver.py
ADWISE_PREFETCH=2 python -m pytest -x -q tests/test_driver.py

# Kernel-tier matrix: the kernel suite must hold numeric parity at BOTH a
# pinned xla tier (the env override escape hatch, bit-stable everywhere)
# and the autotuned default this host resolves — whichever tier that is,
# it is never interpret (asserted inside the suite).
ADWISE_KERNEL_TIER=xla python -m pytest -x -q tests/test_kernels.py
python -m pytest -x -q tests/test_kernels.py

# The smoke pass also writes a machine-readable BENCH_<n>.json into
# bench_logs/ (kept / uploaded as a CI artifact), so the perf trajectory —
# partition walls, h2d stream traffic, ingest MB/s, scan-core speedups,
# supersteps/s, jit compile counts — is tracked run over run instead of
# scrolling away in logs.
shopt -s nullglob
BENCH_BEFORE=(bench_logs/BENCH_*.json)
python -m benchmarks.run --smoke --json-dir bench_logs
BENCH_AFTER=(bench_logs/BENCH_*.json)
shopt -u nullglob
if (( ${#BENCH_AFTER[@]} <= ${#BENCH_BEFORE[@]} )); then
  echo "FATAL: benchmarks.run --json-dir bench_logs produced no new" \
       "BENCH_<n>.json (before=${#BENCH_BEFORE[@]} after=${#BENCH_AFTER[@]})" >&2
  exit 1
fi

# Bench regression gate: diff the two newest BENCH summaries. Warn-only on
# this shared box — smoke-scale walls are noisy — but the report lands in
# the log and a pinned perf runner can make it binding by dropping the ||.
python -m tools.bench_compare bench_logs \
  || echo "WARN: bench_compare flagged a wall regression (warn-only here)"

# Multi-device path: batched spotlight (shard_map over instances) + padded
# engine mesh on 2 fake CPU devices, every run.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
  python -m benchmarks.bench_scaling --smoke --in-process

# Ring-buffer smoke: text ingest (bytes vs python parser parity) -> binary
# -> file-driven partitioning in a tmpdir. Asserted inside: bit-parity with
# the in-memory path, h2d_rows == m (each stream row ships to the device
# once), and per-scan-call h2d below a full ring re-upload.
python -m benchmarks.bench_io --smoke

# Step-core spotlight smoke on 2 fake CPU devices: hdrf z=4 through the
# file-driven ring buffer (one batched program over the instances), asserted
# bit-identical to the in-memory spotlight — mirrors the bench_scaling
# spotlight smoke for the baseline step-cores.
XLA_FLAGS="--xla_force_host_platform_device_count=2" python - <<'PY'
import os, tempfile
import numpy as np
import jax
assert jax.device_count() >= 2, jax.devices()
from repro.core import partition_file
from repro.core.spotlight import spotlight_partition
from repro.graph import rmat
from repro.graph.io import EdgeFileReader, write_edge_file

edges, n = rmat(10, 4000, seed=0)
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "g.adw")
    write_edge_file(path, edges, n)
    with EdgeFileReader(path) as r:
        res = partition_file(r, "hdrf", 8, z=4, spread=2, seed=0,
                             chunk_edges=1024, spill_dir=td)
    ref = spotlight_partition(edges, n, 8, z=4, spread=2, seed=0,
                              strategy="hdrf")
    assert (np.asarray(res.assign) == ref.assign).all(), (
        "2-device file-driven hdrf spotlight diverged from in-memory")
    print("2-device hdrf z=4 partition_file smoke OK "
          f"({res.stats['name']}, backend={res.stats.get('backend')}, "
          f"devices={jax.device_count()})")

# Slab-balanced engine placement: k=7 on 2 devices pads to 8 slabs, and
# make_superstep spreads the pad so per-device REAL slab counts differ by
# at most one ((4, 3), not tail-padded (4, 4-with-1-pad-heavy)).
from repro.engine import build_partitioned_graph
from repro.engine.gas import engine_mesh, make_superstep
g = build_partitioned_graph(edges, ref.assign % 7, n, 7)
step = make_superstep(g, lambda xu, xv, du, dv: (xu, xv),
                      lambda s, a, d: s, engine_mesh(k=7))
occ = step.slab_occupancy
assert sum(occ) == 7 and max(occ) - min(occ) <= 1, occ
print(f"2-device slab placement OK: occupancy={occ}")
PY

# Traced pipeline smoke: drive the real launcher CLI with --trace over a
# file-driven hdrf z=2 run, then validate the emitted Chrome trace-event
# JSON (schema + globally monotonic ts) and the contract that makes the
# timeline trustworthy: scan-span count == scan_calls, and both the main
# stepping track and the adwise-readahead worker track are present. The
# trace is kept in bench_logs/ and uploaded as a CI artifact next to the
# BENCH summaries.
python - <<'PY'
import json, os, tempfile
import numpy as np
from repro.graph import rmat
from repro.graph.io import write_edge_file
from repro.launch import partition as launch
from repro.obs import validate_chrome_trace

os.makedirs("bench_logs", exist_ok=True)
trace_path = "bench_logs/trace_smoke.json"
edges, n = rmat(10, 4000, seed=0)
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "g.adw")
    write_edge_file(path, edges, n)
    out = launch.main([
        "--graph", path, "--strategy", "hdrf", "--k", "8",
        "--z", "2", "--spread", "4", "--chunk-edges", "1024",
        "--prefetch", "2", "--workload", "none",
        "--trace", trace_path,
    ])
doc = json.load(open(trace_path))
errs = validate_chrome_trace(doc)
assert not errs, f"invalid chrome trace: {errs[:5]}"
scan_spans = [e for e in doc["traceEvents"]
              if e.get("ph") == "X" and e.get("cat") == "scan"]
scan_calls = int(out["stats"].get("scan_calls", 0))
assert scan_calls and len(scan_spans) == scan_calls, (
    f"scan span count {len(scan_spans)} != scan_calls {scan_calls}")
tracks = {e["args"]["name"] for e in doc["traceEvents"]
          if e.get("ph") == "M" and e.get("name") == "thread_name"}
assert "main" in tracks and "adwise-readahead" in tracks, tracks
print(f"traced smoke OK: {len(doc['traceEvents'])} events, "
      f"{scan_calls} scan spans, tracks={sorted(tracks)} -> {trace_path}")
PY

echo "bench summaries kept:"
ls -l bench_logs/ 2>/dev/null || true
