#!/usr/bin/env bash
# CI gate: tier-1 quick test profile + the smoke pass over every benchmark
# entrypoint (proves each bench still *runs*; regressions in launch/bench
# wiring fail here, not in a nightly).
#
#   tools/ci.sh          # what the workflow runs
#   tools/ci.sh --full   # also run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

MARK='not slow'
if [[ "${1:-}" == "--full" ]]; then
  MARK=''
fi

if [[ -n "$MARK" ]]; then
  python -m pytest -x -q -m "$MARK"
else
  python -m pytest -x -q
fi

# The smoke pass also writes a machine-readable BENCH_<n>.json into
# bench_logs/ (kept / uploaded as a CI artifact), so the perf trajectory —
# partition walls, h2d stream traffic, ingest MB/s, scan-core speedups,
# supersteps/s — is tracked run over run instead of scrolling away in logs.
BENCH_COUNT_BEFORE=$(ls bench_logs/BENCH_*.json 2>/dev/null | wc -l)
python -m benchmarks.run --smoke --json-dir bench_logs
BENCH_COUNT_AFTER=$(ls bench_logs/BENCH_*.json 2>/dev/null | wc -l)
if [[ "$BENCH_COUNT_AFTER" -le "$BENCH_COUNT_BEFORE" ]]; then
  echo "FATAL: benchmarks.run --json-dir bench_logs produced no new" \
       "BENCH_<n>.json (before=$BENCH_COUNT_BEFORE after=$BENCH_COUNT_AFTER)" >&2
  exit 1
fi

# Multi-device path: batched spotlight (shard_map over instances) + padded
# engine mesh on 2 fake CPU devices, every run.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
  python -m benchmarks.bench_scaling --smoke --in-process

# Ring-buffer smoke: text ingest (bytes vs python parser parity) -> binary
# -> file-driven partitioning in a tmpdir. Asserted inside: bit-parity with
# the in-memory path, h2d_rows == m (each stream row ships to the device
# once), and per-scan-call h2d below a full ring re-upload.
python -m benchmarks.bench_io --smoke

# Step-core spotlight smoke on 2 fake CPU devices: hdrf z=4 through the
# file-driven ring buffer (one batched program over the instances), asserted
# bit-identical to the in-memory spotlight — mirrors the bench_scaling
# spotlight smoke for the baseline step-cores.
XLA_FLAGS="--xla_force_host_platform_device_count=2" python - <<'PY'
import os, tempfile
import numpy as np
import jax
assert jax.device_count() >= 2, jax.devices()
from repro.core import partition_file
from repro.core.spotlight import spotlight_partition
from repro.graph import rmat
from repro.graph.io import EdgeFileReader, write_edge_file

edges, n = rmat(10, 4000, seed=0)
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "g.adw")
    write_edge_file(path, edges, n)
    with EdgeFileReader(path) as r:
        res = partition_file(r, "hdrf", 8, z=4, spread=2, seed=0,
                             chunk_edges=1024, spill_dir=td)
    ref = spotlight_partition(edges, n, 8, z=4, spread=2, seed=0,
                              strategy="hdrf")
    assert (np.asarray(res.assign) == ref.assign).all(), (
        "2-device file-driven hdrf spotlight diverged from in-memory")
    print("2-device hdrf z=4 partition_file smoke OK "
          f"({res.stats['name']}, backend={res.stats.get('backend')}, "
          f"devices={jax.device_count()})")
PY

echo "bench summaries kept:"
ls -l bench_logs/ 2>/dev/null || true
