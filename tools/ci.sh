#!/usr/bin/env bash
# CI gate: tier-1 quick test profile + the smoke pass over every benchmark
# entrypoint (proves each bench still *runs*; regressions in launch/bench
# wiring fail here, not in a nightly).
#
#   tools/ci.sh          # what the workflow runs
#   tools/ci.sh --full   # also run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

MARK='not slow'
if [[ "${1:-}" == "--full" ]]; then
  MARK=''
fi

if [[ -n "$MARK" ]]; then
  python -m pytest -x -q -m "$MARK"
else
  python -m pytest -x -q
fi

# The smoke pass also writes a machine-readable BENCH_<n>.json into
# bench_logs/ (kept / uploaded as a CI artifact), so the perf trajectory —
# partition walls, h2d stream traffic, ingest MB/s, supersteps/s — is
# tracked run over run instead of scrolling away in logs.
python -m benchmarks.run --smoke --json-dir bench_logs

# Multi-device path: batched spotlight (shard_map over instances) + padded
# engine mesh on 2 fake CPU devices, every run.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
  python -m benchmarks.bench_scaling --smoke --in-process

# Ring-buffer smoke: text ingest (bytes vs python parser parity) -> binary
# -> file-driven partitioning in a tmpdir. Asserted inside: bit-parity with
# the in-memory path, h2d_rows == m (each stream row ships to the device
# once), and per-scan-call h2d below a full ring re-upload.
python -m benchmarks.bench_io --smoke

echo "bench summaries kept:"
ls -l bench_logs/ 2>/dev/null || true
