#!/usr/bin/env bash
# CI gate: tier-1 quick test profile + the smoke pass over every benchmark
# entrypoint (proves each bench still *runs*; regressions in launch/bench
# wiring fail here, not in a nightly).
#
#   tools/ci.sh          # what the workflow runs
#   tools/ci.sh --full   # also run the slow-marked tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

MARK='not slow'
if [[ "${1:-}" == "--full" ]]; then
  MARK=''
fi

if [[ -n "$MARK" ]]; then
  python -m pytest -x -q -m "$MARK"
else
  python -m pytest -x -q
fi

python -m benchmarks.run --smoke

# Multi-device path: batched spotlight (shard_map over instances) + padded
# engine mesh on 2 fake CPU devices, every run.
XLA_FLAGS="--xla_force_host_platform_device_count=2" \
  python -m benchmarks.bench_scaling --smoke --in-process

# Out-of-core path: text ingest -> binary -> file-driven partitioning in a
# tmpdir, with bit-parity against the in-memory path asserted inside.
python -m benchmarks.bench_io --smoke
