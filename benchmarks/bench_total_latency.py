"""Fig. 7a-f analogue: total latency (partition + processing) vs latency
preference L, per graph × workload, ADWISE vs HDRF vs DBH.

    PYTHONPATH=src python -m benchmarks.bench_total_latency --scale 0.08 \
        --baselines dbh hdrf greedy

Baselines may be any names from the partitioner registry
(`repro.core.available_strategies()`); ADWISE rows sweep the window sizes
given by --windows (Fig. 7's invested-latency x-axis), and
--restream-passes adds adwise-restream rows sweeping the *pass count* at
each window — the second invested-latency knob (re-streaming invests more
partitioning time for lower replication, next to window_max).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import run_strategy
from repro.core import available_strategies, run_partitioner
from repro.engine import PAPER_CLUSTER, build_partitioned_graph, partition_latency, process_latency
from repro.graph import make_graph

# (workload, supersteps, msg_width): PageRank-like light & SI/clique-like heavy.
WORKLOADS = {
    "pagerank_300": (300, 1),
    "coloring_300": (300, 65),
    "heavy_si": (40, 128),  # wide messages, few rounds (paper's SI analogue)
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--graphs", nargs="+",
                    default=["brain_like", "web_like", "orkut_like"])
    ap.add_argument("--baselines", nargs="+", default=["dbh", "hdrf"],
                    choices=[s for s in available_strategies() if s != "adwise"],
                    help="single-edge strategies to compare ADWISE against")
    ap.add_argument("--windows", nargs="+", type=int, default=[16, 64, 256],
                    help="ADWISE window sizes (increasing invested latency)")
    ap.add_argument("--restream-passes", nargs="+", type=int, default=[2],
                    help="adwise-restream pass counts swept at each window "
                         "(the second invested-latency knob); 0 disables")
    ap.add_argument("--scan-oracle", nargs="*",
                    default=["hdrf", "greedy", "2ps-l"],
                    help="strategies timed as step-core scan vs numpy "
                         "oracle per graph (parity asserted, rows kept in "
                         "the json); pass none to skip")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    rows = []
    print("graph,workload,strategy,L,partition_s,process_s,total_s,RD")
    for preset in args.graphs:
        edges, n = make_graph(preset, seed=0, scale=args.scale)
        use_cs = preset != "orkut_like"  # paper switches CS off on Orkut
        # Partition ONCE per (strategy, window) and reuse across workloads.
        parts = []
        # Increasing windows = increasing invested partitioning latency
        # (Fig. 7 x-axis; paper guideline ≈ 2-4x single-edge).
        sweep = [(s, [None], None) for s in args.baselines]
        sweep.append(("adwise", args.windows, None))
        for p in args.restream_passes:
            if p > 0:
                sweep.append((f"adwise-restream[{p}p]", args.windows, p))
        for label, budgets, passes in sweep:
            strategy = label.split("[")[0]
            for L in budgets:
                res, rd = run_strategy(edges, n, args.k, strategy, budget=L,
                                       use_cs=use_cs, passes=passes)
                g = build_partitioned_graph(edges, res.assign, n, args.k)
                # Multi-pass strategies report stats['stream_reads'] (2PS: 2,
                # restream: passes_run) — partition_latency bills IO per read.
                t_part = partition_latency(res.stats, len(edges), args.k)
                parts.append((label, L, res, rd, g, t_part))
        # Step-core scan vs numpy-oracle partition wall (the per-edge loops
        # every core replaced stay as parity references — timed side by side
        # so the perf trajectory tracks the scan's advantage per graph).
        for strat in args.scan_oracle:
            t0 = time.perf_counter()
            res_s = run_partitioner(strat, edges, n, args.k, seed=0, scan=True)
            t_scan = time.perf_counter() - t0
            t0 = time.perf_counter()
            res_o = run_partitioner(strat, edges, n, args.k, seed=0,
                                    scan=False)
            t_oracle = time.perf_counter() - t0
            assert (np.asarray(res_s.assign) == res_o.assign).all(), (
                f"{strat}: scan core diverged from numpy oracle"
            )
            rows.append(dict(
                graph=preset, kind="scan_vs_oracle", strategy=strat,
                t_scan_s=t_scan, t_oracle_s=t_oracle,
                speedup=t_oracle / max(t_scan, 1e-9),
            ))
            print(f"{preset},scan_vs_oracle,{strat},,"
                  f"{t_scan:.3f},{t_oracle:.3f},"
                  f"{t_oracle / max(t_scan, 1e-9):.2f}x,")
        for wname, (iters, width) in WORKLOADS.items():
            for strategy, L, res, rd, g, t_part in parts:
                model = process_latency(g, iters, width, PAPER_CLUSTER)
                r = dict(graph=preset, workload=wname, strategy=strategy,
                         budget=L, replication_degree=rd,
                         t_partition_s=t_part,
                         t_partition_wall_s=res.stats.get("wall_time_s", 0.0),
                         t_process_s=model["t_total_s"],
                         t_total_s=t_part + model["t_total_s"],
                         sync_bytes=model["sync_bytes_per_step"])
                rows.append(r)
                print(f"{preset},{wname},{strategy},{L if L else ''},"
                      f"{r['t_partition_s']:.3f},{r['t_process_s']:.3f},"
                      f"{r['t_total_s']:.3f},{r['replication_degree']:.3f}")
    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    main()
