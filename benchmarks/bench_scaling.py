"""Multi-device scaling harness: batched spotlight partitioning wall and
engine supersteps/s vs device count.

The container has one physical CPU, so device scaling is measured against
XLA's fake host devices: for each N the harness spawns a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag is read at
process startup, so it cannot be flipped in-process) and measures, inside it:

  * batched spotlight partitioning (z instances as ONE vmapped/shard_mapped
    program — instances land on separate devices when N > 1),
  * the sequential ``backend="loop"`` path on the same host (the z× cost the
    batched scan removes),
  * engine supersteps/s for PageRank on the partitioned graph (the `parts`
    mesh axis is padded inside `make_superstep`, so every N is valid for
    every k).

    PYTHONPATH=src python -m benchmarks.bench_scaling                 # N = 1,2,4,8
    PYTHONPATH=src python -m benchmarks.bench_scaling --smoke         # CI-size
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        PYTHONPATH=src python -m benchmarks.bench_scaling --in-process
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_JSON_MARK = "BENCH_SCALING_ROW:"


def _measure(args) -> dict:
    """Measure on THIS process's devices (call under the right XLA_FLAGS)."""
    import jax
    import numpy as np

    from repro.core import AdwiseConfig, spotlight_partition
    from repro.engine import build_partitioned_graph, pagerank
    from repro.graph import make_graph

    edges, n = make_graph(args.graph, seed=0, scale=args.scale)
    k, z = args.k, args.z
    spread = args.spread if args.spread else max(k // z, 1)
    cfg = AdwiseConfig(k=k, window_max=args.window,
                       window_init=max(1, args.window // 4))

    def run(backend):
        return spotlight_partition(edges, n, k, z=z, spread=spread,
                                   strategy="adwise", cfg=cfg, backend=backend)

    # Warm both paths (compile), then time a second run of each.
    res_b = run("batched")
    res_b = run("batched")
    t_batched = res_b.stats["wall_time_s"]  # measured batched-program wall
    res_l = run("loop")
    res_l = run("loop")
    t_loop = res_l.stats["wall_time_serial_s"]  # real serial host wall
    assert (res_b.assign >= 0).all() and (res_l.assign >= 0).all()

    g = build_partitioned_graph(edges, res_b.assign, n, k)
    iters = args.iters
    pagerank(g, iters=2)  # compile
    t0 = time.perf_counter()
    pr, _ = pagerank(g, iters=iters)
    t_engine = time.perf_counter() - t0
    assert np.isfinite(pr).all()

    return dict(
        devices=jax.device_count(),
        m=len(edges),
        k=k,
        z=z,
        spread=spread,
        backend=res_b.stats["backend"],
        n_shards=res_b.stats["n_shards"],
        t_partition_batched_s=round(t_batched, 4),
        t_partition_loop_s=round(t_loop, 4),
        partition_speedup=round(t_loop / max(t_batched, 1e-9), 2),
        supersteps_per_s=round(iters / max(t_engine, 1e-9), 2),
    )


def _spawn(n_devices: int, args) -> dict:
    """Run `--in-process` in a subprocess pinned to n_devices fake devices."""
    cmd = [
        sys.executable, "-m", "benchmarks.bench_scaling", "--in-process",
        "--graph", args.graph, "--scale", str(args.scale),
        "--k", str(args.k), "--z", str(args.z), "--spread", str(args.spread),
        "--window", str(args.window), "--iters", str(args.iters),
    ]
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.abspath("src"), env.get("PYTHONPATH")] if p
    )
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(
            f"bench_scaling child (N={n_devices}) failed:\n{out.stderr[-2000:]}"
        )
    for line in out.stdout.splitlines():
        if line.startswith(_JSON_MARK):
            return json.loads(line[len(_JSON_MARK):])
    raise RuntimeError(f"child (N={n_devices}) printed no row:\n{out.stdout}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="brain_like")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--z", type=int, default=4, help="partitioner instances")
    ap.add_argument("--spread", type=int, default=0,
                    help="partitions per instance (0 = k/z disjoint blocks)")
    ap.add_argument("--window", type=int, default=64)
    ap.add_argument("--iters", type=int, default=10, help="engine supersteps")
    ap.add_argument("--devices", default="1,2,4,8",
                    help="comma-separated fake-device counts to sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size: tiny graph, N in {1,2}")
    ap.add_argument("--in-process", action="store_true",
                    help="measure at THIS process's device count (set "
                         "XLA_FLAGS yourself) instead of spawning the sweep")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale, args.k, args.z, args.window, args.iters = 0.008, 8, 4, 16, 4
        if args.devices == "1,2,4,8":
            args.devices = "1,2"

    if args.in_process:
        row = _measure(args)
        print(f"{_JSON_MARK}{json.dumps(row)}")
        rows = [row]
    else:
        rows = []
        print("devices,backend,n_shards,t_partition_batched_s,"
              "t_partition_loop_s,partition_speedup,supersteps_per_s")
        for n_dev in [int(x) for x in args.devices.split(",") if x]:
            r = _spawn(n_dev, args)
            rows.append(r)
            print(f"{r['devices']},{r['backend']},{r['n_shards']},"
                  f"{r['t_partition_batched_s']},{r['t_partition_loop_s']},"
                  f"{r['partition_speedup']},{r['supersteps_per_s']}")
    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    main()
