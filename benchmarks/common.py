"""Shared benchmark helpers.

Strategies are resolved through `repro.core.registry`, so every registered
partitioner (adwise / hdrf / dbh / greedy / hash / grid / future entries)
can be benchmarked by name with no bench-side dispatch code.
"""
from __future__ import annotations

import numpy as np

from repro.core import run_partitioner
from repro.engine import (
    PAPER_CLUSTER,
    build_partitioned_graph,
    partition_latency,
    process_latency,
)
from repro.graph import make_graph, replica_sets_from_assignment, replication_degree


def run_strategy(edges, n, k, strategy, budget=None, window_max=256, use_cs=True,
                 seed=0, passes=None):
    """Returns (PartitionResult, replication_degree).

    For ADWISE (and its restreamed variant), `budget` (when set) is
    interpreted as a fixed window size — benchmark rows are labeled by the
    resulting MODELED partitioning latency, which is Fig. 7's x-axis
    semantics ("latency invested"). `passes` sets the re-streaming pass
    count for 'adwise-restream' (the second invested-latency knob).
    """
    cfg = {}
    if strategy in ("adwise", "adwise-restream"):
        wm = window_max if budget is None else int(budget)
        cfg = dict(window_max=wm, window_init=max(1, wm // 4),
                   use_clustering=use_cs)
        if strategy == "adwise-restream":
            cfg["passes"] = 2 if passes is None else int(passes)
    elif strategy == "2ps":
        cfg = dict(use_clustering=use_cs)
    res = run_partitioner(strategy, edges, n, k, seed=seed, **cfg)
    rd = replication_degree(replica_sets_from_assignment(edges, res.assign, n, k))
    return res, rd


def total_latency_row(edges, n, k, strategy, workload_iters, msg_width=1,
                      budget=None, window_max=256, use_cs=True, passes=None):
    """One (strategy, L) experiment → dict of latencies (Fig. 7 data point)."""
    res, rd = run_strategy(edges, n, k, strategy, budget, window_max, use_cs,
                           passes=passes)
    g = build_partitioned_graph(edges, res.assign, n, k)
    # Both terms in the SAME modeled cluster units (measured 1-core CPU wall
    # kept alongside for reference — DESIGN.md §3). Multi-pass strategies
    # report stats['stream_reads']; partition_latency bills the IO term per
    # read, so m here is always the plain stream length.
    t_part = partition_latency(res.stats, len(edges), k)
    model = process_latency(g, workload_iters, msg_width, PAPER_CLUSTER)
    return dict(
        strategy=strategy,
        budget=budget,
        replication_degree=rd,
        t_partition_s=t_part,
        t_partition_wall_s=res.stats.get("wall_time_s", 0.0),
        t_process_s=model["t_total_s"],
        t_total_s=t_part + model["t_total_s"],
        sync_bytes=model["sync_bytes_per_step"],
    )
