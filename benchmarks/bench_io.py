"""Out-of-core I/O benchmark: ingest throughput + file-driven partitioning.

    PYTHONPATH=src python -m benchmarks.bench_io --scale 0.05
    PYTHONPATH=src python -m benchmarks.bench_io --smoke   # CI wiring check

Everything runs in a tmpdir on an R-MAT graph:

  1. text ingest MB/s — the vectorized bytes-level parser against the
     per-line reference parser (same binary asserted, speedup reported),
  2. binary read-through MB/s (bounded-chunk reader) and external shuffle
     wall (hard O(chunk) bucket bound reported from the ShuffleReport),
  3. file-driven vs in-memory partitioning wall for a set of strategies —
     `partition_file` (bounded resident edge memory, spill to disk) against
     the resident-array registry path, with the parity of the two assignments
     asserted (the file path is bit-identical by construction; the bench
     fails loudly if that ever regresses). Each strategy runs at prefetch=0
     (synchronous refills) AND prefetch=2 (the double-buffered read-ahead
     pipeline) so the overlap win shows up as a wall column; the span
     accounting invariant (prestaged + missed == refills) is asserted at
     both settings. For the ring-buffer scan path the bench also asserts
     the host→device traffic contract: each stream row ships once
     (h2d_rows == m), per-scan-call traffic is the refill size, NOT a full
     (z, B, 2) buffer re-upload — and (3b) a ring-resident re-streaming
     run ships exactly 8m + 4m*(passes-1) bytes: pass 2+ adopts pass 1's
     donated device ring and re-ships only the prev table.
  4. step-core scan vs numpy-oracle wall (hdrf / greedy / 2ps-l): the
     device-resident `lax.scan` cores against the per-edge python loops they
     replaced, parity asserted, rows kept in the BENCH_<n>.json summary.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from repro.core import partition_file, run_partitioner
from repro.graph import rmat
from repro.graph.io import (
    EdgeFileReader,
    ingest_text,
    read_edge_file,
    shuffle_file,
    write_edge_file,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05,
                    help="R-MAT edge count = scale * 4e6")
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--chunk-edges", type=int, default=1 << 14)
    ap.add_argument("--strategies", nargs="+",
                    default=["hdrf", "dbh", "adwise"])
    ap.add_argument("--scan-oracle", nargs="*",
                    default=["hdrf", "greedy", "2ps-l"],
                    help="strategies timed scan-core vs numpy-oracle "
                         "(in-memory, parity asserted); pass none to skip")
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph, k=8, fastest pass (CI)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        args.scale = 0.002
        args.k = 8
        args.chunk_edges = 2048
        args.strategies = ["dbh", "hdrf", "adwise"]
        args.window = 8

    m = max(1000, int(4e6 * args.scale))
    n_log2 = max(10, int(np.log2(m)) - 3)
    edges, n = rmat(n_log2, m, seed=0)
    m = len(edges)
    out = dict(m=m, n=n, k=args.k, chunk_edges=args.chunk_edges, rows=[])

    with tempfile.TemporaryDirectory() as td:
        # --- 1) text ingest MB/s: vectorized vs reference parser ---------
        txt = os.path.join(td, "g.txt")
        with open(txt, "w") as f:
            f.write("# bench graph\n")
            np.savetxt(f, edges, fmt="%d")
        binary = os.path.join(td, "g.adw")
        rep_py = ingest_text(txt, os.path.join(td, "g_py.adw"),
                             parser="python")
        rep = ingest_text(txt, binary, parser="bytes")
        ref_bin, ref_n = read_edge_file(os.path.join(td, "g_py.adw"))
        fast_bin, fast_n = read_edge_file(binary)
        assert (ref_bin == fast_bin).all() and ref_n == fast_n, (
            "bytes ingester diverged from the per-line reference parser"
        )
        mbs_py = rep_py.bytes_read / 1e6 / max(rep_py.wall_s, 1e-9)
        mbs = rep.bytes_read / 1e6 / max(rep.wall_s, 1e-9)
        print(f"ingest: {m} edges, {rep.bytes_read/1e6:.1f} MB text — "
              f"bytes parser {mbs:.1f} MB/s vs python parser "
              f"{mbs_py:.1f} MB/s ({mbs / max(mbs_py, 1e-9):.1f}x, "
              f"parity asserted)")
        out["ingest_mb_s"] = mbs
        out["ingest_python_mb_s"] = mbs_py
        out["ingest_speedup"] = mbs / max(mbs_py, 1e-9)

        # --- 2) binary read-through + external shuffle -------------------
        with EdgeFileReader(binary) as r:
            t0 = time.perf_counter()
            for _ in r.chunks(args.chunk_edges):
                pass
            t_read = time.perf_counter() - t0
        read_mbs = m * 8 / 1e6 / max(t_read, 1e-9)
        print(f"binary read-through: {read_mbs:.0f} MB/s "
              f"({args.chunk_edges}-row chunks)")
        out["read_mb_s"] = read_mbs
        shuf = os.path.join(td, "g_shuf.adw")
        t0 = time.perf_counter()
        shrep = shuffle_file(binary, shuf, seed=1,
                             chunk_edges=args.chunk_edges)
        t_shuf = time.perf_counter() - t0
        assert shrep.max_loaded_rows <= shrep.bound_rows
        print(f"external shuffle: {t_shuf:.2f}s "
              f"({m * 8 / 1e6 / max(t_shuf, 1e-9):.0f} MB/s effective, "
              f"max bucket {shrep.max_loaded_rows} <= hard bound "
              f"{shrep.bound_rows} rows, depth {shrep.depth})")
        out["shuffle_s"] = t_shuf
        out["shuffle_max_bucket_rows"] = shrep.max_loaded_rows

        # --- 3) file-driven vs in-memory partitioning wall ---------------
        # Rebuild the binary from the in-memory array so both paths see the
        # exact same stream (ingest already guarantees it; belt and braces).
        write_edge_file(binary, edges, n)
        # Each strategy runs the file path twice: prefetch=0 (synchronous
        # refills) and prefetch=2 (the double-buffered default), parity
        # asserted for both. Wall improvement is printed, not asserted —
        # tiny smoke graphs are dominated by dispatch noise.
        print("strategy,in_memory_s,file_sync_s,file_pipe_s,file_io_s,"
              "overhead,h2d_rows_per_call,overlap,parity")
        for strat in args.strategies:
            cfg = dict(window_max=args.window) if strat == "adwise" else {}
            t0 = time.perf_counter()
            ref = run_partitioner(strat, edges, n, args.k, seed=0, **cfg)
            t_mem = time.perf_counter() - t0
            walls = {}
            res = None
            for pf in (0, 2):
                with EdgeFileReader(binary) as r:
                    t0 = time.perf_counter()
                    res = partition_file(
                        r, strat, args.k, seed=0,
                        chunk_edges=args.chunk_edges, prefetch=pf,
                        spill_dir=os.path.join(td, f"spill_{strat}_{pf}"),
                        **cfg,
                    )
                    walls[pf] = time.perf_counter() - t0
                parity = bool((np.asarray(res.assign) == ref.assign).all())
                assert parity, (
                    f"file-driven {strat} (prefetch={pf}) diverged from "
                    "in-memory"
                )
                spans = int(res.stats.get("refill_spans", 0))
                assert (int(res.stats.get("spans_prestaged", 0))
                        + int(res.stats.get("spans_missed", 0)) == spans), (
                    f"{strat} prefetch={pf}: span accounting broken"
                )
            t_file = walls[2]
            h2d_rows = res.stats.get("h2d_rows", 0)
            calls = res.stats.get("scan_calls", 0)
            ring_rows = res.stats.get("buffer_rows", 0)
            spans = int(res.stats.get("refill_spans", 0))
            prestaged = int(res.stats.get("spans_prestaged", 0))
            h2d_wait = float(res.stats.get("h2d_wait_s", 0.0))
            prestage_wall = float(res.stats.get("prestage_wall_s", 0.0))
            # Measured overlap efficiency: fraction of the read-ahead
            # worker's staging wall hidden from the driver critical path
            # (1 - stall/staging). The span-hit ratio stays as a secondary
            # key — it counts spans, not seconds.
            overlap = (max(0.0, 1.0 - h2d_wait / prestage_wall)
                       if prestage_wall > 0 else 0.0)
            span_hit = prestaged / spans if spans else 0.0
            h2d_per_call = h2d_rows / calls if calls else 0.0
            if strat == "adwise":
                # The device-resident ring's contract: every stream row
                # ships exactly once, and per-scan-call traffic is the
                # refill (bounded by max_span), not a (z, B, 2) re-upload.
                assert h2d_rows == m, (h2d_rows, m)
                if calls >= 2:
                    assert h2d_per_call < ring_rows, (
                        f"h2d per call {h2d_per_call:.0f} should be below "
                        f"the full ring ({ring_rows} rows) — refill-only "
                        "uploads regressed"
                    )
            row = dict(strategy=strat, t_memory_s=t_mem, t_file_s=t_file,
                       t_file_sync_s=walls[0],
                       io_wall_s=res.stats["io_wall_s"],
                       overhead=t_file / max(t_mem, 1e-9), parity=parity,
                       h2d_rows=int(h2d_rows), scan_calls=int(calls),
                       ring_rows=int(ring_rows),
                       h2d_bytes=int(res.stats.get("h2d_bytes", 0)),
                       h2d_wait_s=h2d_wait,
                       prestage_wall_s=prestage_wall,
                       prefetch_depth=int(res.stats.get("prefetch_depth", 0)),
                       refill_spans=spans, spans_prestaged=prestaged,
                       spans_missed=int(res.stats.get("spans_missed", 0)),
                       overlap_efficiency=overlap,
                       span_hit_ratio=span_hit)
            out["rows"].append(row)
            print(f"{strat},{t_mem:.3f},{walls[0]:.3f},{t_file:.3f},"
                  f"{res.stats['io_wall_s']:.3f},{row['overhead']:.2f}x,"
                  f"{h2d_per_call:.0f}/{ring_rows},{overlap:.0%},{parity}")

        # --- 3b) restream cross-pass shared-buffer contract ---------------
        # With chunk_edges >= m the whole stream stays ring-resident, so
        # pass 2+ adopts pass 1's donated device ring (RingHandle) and
        # ships ONLY the 4 B/row prev table: total file-restream h2d must
        # be exactly 8m + 4m*(passes-1) bytes.
        passes = 2
        cfg_rs = dict(window_max=args.window, passes=passes)
        ref_rs = run_partitioner("adwise-restream", edges, n, args.k,
                                 seed=0, **cfg_rs)
        with EdgeFileReader(binary) as r:
            t0 = time.perf_counter()
            res_rs = partition_file(
                r, "adwise-restream", args.k, seed=0,
                chunk_edges=max(args.chunk_edges, m),
                spill_dir=os.path.join(td, "spill_restream"), **cfg_rs,
            )
            t_rs = time.perf_counter() - t0
        assert (np.asarray(res_rs.assign) == ref_rs.assign).all(), (
            "file-driven restream diverged from in-memory"
        )
        want = m * 8 + m * 4 * (passes - 1)
        got = int(res_rs.stats["h2d_bytes"])
        assert got == want, (
            f"restream cross-pass h2d contract broken: shipped {got} B, "
            f"expected {want} B (= 8m + 4m*(passes-1); pass 2+ must reuse "
            "the resident uv ring and ship prev only)"
        )
        assert int(res_rs.stats["h2d_rows"]) == m
        print(f"restream x{passes} (chunk>=m): wall={t_rs:.3f}s, "
              f"h2d={got/1e6:.2f} MB == 8m + 4m*(passes-1) "
              "(pass-2 ships prev only; contract asserted)")
        out["restream_passes"] = passes
        out["restream_h2d_bytes"] = got
        out["restream_wall_s"] = t_rs

        # --- 4) step-core scan vs numpy-oracle wall ----------------------
        out["scan_vs_oracle"] = []
        if args.scan_oracle:
            print("strategy,scan_s,oracle_s,oracle/scan,parity")
        for strat in args.scan_oracle:
            t0 = time.perf_counter()
            res_s = run_partitioner(strat, edges, n, args.k, seed=0,
                                    scan=True)
            t_scan = time.perf_counter() - t0
            t0 = time.perf_counter()
            res_o = run_partitioner(strat, edges, n, args.k, seed=0,
                                    scan=False)
            t_oracle = time.perf_counter() - t0
            parity = bool((res_s.assign == res_o.assign).all())
            assert parity, f"{strat}: scan core diverged from numpy oracle"
            row = dict(strategy=strat, t_scan_s=t_scan, t_oracle_s=t_oracle,
                       speedup=t_oracle / max(t_scan, 1e-9), parity=parity)
            out["scan_vs_oracle"].append(row)
            print(f"{strat},{t_scan:.3f},{t_oracle:.3f},"
                  f"{row['speedup']:.2f}x,{parity}")

    if args.json:
        json.dump(out, open(args.json, "w"), indent=1)
    return out


if __name__ == "__main__":
    main()
