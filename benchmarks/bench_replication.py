"""Fig. 7g-i analogue: replication degree per strategy and latency preference.

    PYTHONPATH=src python -m benchmarks.bench_replication --scale 0.08
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import run_strategy
from repro.graph import make_graph, partition_balance


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.08)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--graphs", nargs="+",
                    default=["brain_like", "web_like", "orkut_like"])
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    rows = []
    print("graph,strategy,L,partition_s,RD,imbalance")
    for preset in args.graphs:
        edges, n = make_graph(preset, seed=0, scale=args.scale)
        use_cs = preset != "orkut_like"
        runs = [("dbh", None), ("hdrf", None),
                ("adwise", 16), ("adwise", 64), ("adwise", 256)]
        for strategy, L in runs:
            res, rd = run_strategy(edges, n, args.k, strategy, budget=L,
                                   use_cs=use_cs)
            imb = partition_balance(res.assign, args.k)
            rows.append(dict(graph=preset, strategy=strategy, budget=L,
                             replication_degree=rd, imbalance=imb,
                             t_partition_s=res.stats["wall_time_s"]))
            print(f"{preset},{strategy},{L if L else ''},"
                  f"{res.stats['wall_time_s']:.3f},{rd:.3f},{imb:.4f}")
            # Paper reports balanced partitions (<5%) at 100M+ edge scale;
            # hashing partitioners are noisier at proxy scale — flag, don't die.
            if imb > 0.3:
                print(f"#  note: {strategy} imbalance {imb:.2f} at proxy scale")
    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    main()
