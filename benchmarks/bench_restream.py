"""Re-streaming sweep: replication degree / balance / latency vs pass count.

    PYTHONPATH=src python -m benchmarks.bench_restream --scale 0.02 --passes 3

One row per (graph, pass): `adwise-restream` is run once with the maximum
pass count and its per-pass stats are unrolled, so the table shows the
quality bought by each extra pass over the same stream. A `2ps` row and a
single-edge `hdrf` row anchor the two ends (two-phase vs one-pass).
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import run_strategy
from repro.core import run_partitioner
from repro.engine import partition_latency
from repro.graph import make_graph, partition_balance


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--graphs", nargs="+",
                    default=["brain_like", "web_like"])
    ap.add_argument("--passes", type=int, default=3,
                    help="max re-streaming pass count")
    ap.add_argument("--window", type=int, default=64,
                    help="window_max for every ADWISE pass")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    rows = []
    print("graph,strategy,passes,RD,imbalance,partition_model_s,partition_wall_s")

    def emit(graph, strategy, passes, rd, imb, t_model, t_wall):
        rows.append(dict(graph=graph, strategy=strategy, passes=passes,
                         replication_degree=rd, imbalance=imb,
                         t_partition_s=t_model, t_partition_wall_s=t_wall))
        print(f"{graph},{strategy},{passes},{rd:.3f},{imb:.4f},"
              f"{t_model:.3f},{t_wall:.3f}")

    for preset in args.graphs:
        edges, n = make_graph(preset, seed=0, scale=args.scale)
        res = run_partitioner(
            "adwise-restream", edges, n, args.k, passes=args.passes,
            keep_best=False, window_max=args.window,
            window_init=max(1, args.window // 4),
        )
        # Unroll per-pass quality; the modeled latency at pass p is the
        # cumulative score work of passes 1..p (invested latency is additive).
        cum_rows, cum_wall = 0, 0.0
        for p in range(1, args.passes + 1):
            cum_rows += res.stats["pass_score_rows"][p - 1]
            cum_wall += res.stats["pass_wall_s"][p - 1]
            t_model = partition_latency(
                dict(score_rows=cum_rows, stream_reads=p), len(edges), args.k)
            emit(preset, "adwise-restream", p, res.stats["pass_rd"][p - 1],
                 res.stats["pass_imbalance"][p - 1], t_model, cum_wall)

        res2, rd2 = run_strategy(edges, n, args.k, "2ps")
        # 2PS stats carry stream_reads=2 (clustering pass + scoring pass).
        emit(preset, "2ps", 2, rd2, partition_balance(res2.assign, args.k),
             partition_latency(res2.stats, len(edges), args.k),
             res2.stats.get("wall_time_s", 0.0))

        resh, rdh = run_strategy(edges, n, args.k, "hdrf")
        emit(preset, "hdrf", 1, rdh, partition_balance(resh.assign, args.k),
             partition_latency(resh.stats, len(edges), args.k),
             resh.stats.get("wall_time_s", 0.0))

    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    main()
