"""§III-A/B/C ablations: window size, lazy traversal, adaptive λ, clustering.

    PYTHONPATH=src python -m benchmarks.bench_window --scale 0.04
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.core import AdwiseConfig, partition_stream
from repro.graph import make_graph, partition_balance, replica_sets_from_assignment, replication_degree


def _run(edges, n, cfg):
    res = partition_stream(edges, n, cfg)
    rd = replication_degree(replica_sets_from_assignment(edges, res.assign, n, cfg.k))
    return res, rd


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.04)
    ap.add_argument("--graph", default="brain_like")
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    edges, n = make_graph(args.graph, seed=0, scale=args.scale)
    rows = []
    print("experiment,variant,RD,score_rows,wall_s,imbalance")

    # 1) Window size sweep (fixed w, no adaptation): quality vs w (Fig. 1 gap).
    for w in (1, 8, 32, 128, 512):
        cfg = AdwiseConfig(k=args.k, window_max=w, window_init=w, adapt=False)
        res, rd = _run(edges, n, cfg)
        rows.append(dict(experiment="window_sweep", variant=str(w),
                         rd=rd, score_rows=res.stats["score_rows"],
                         wall_s=res.stats["wall_time_s"]))
        print(f"window_sweep,w={w},{rd:.3f},{res.stats['score_rows']},"
              f"{res.stats['wall_time_s']:.2f},"
              f"{partition_balance(res.assign, args.k):.4f}")

    # 2) Lazy traversal: score computations saved at bounded quality cost.
    base = AdwiseConfig(k=args.k, window_max=128, window_init=128, adapt=False)
    for lazy in (False, True):
        cfg = dataclasses.replace(base, lazy=lazy)
        res, rd = _run(edges, n, cfg)
        rows.append(dict(experiment="lazy", variant=str(lazy), rd=rd,
                         score_rows=res.stats["score_rows"],
                         wall_s=res.stats["wall_time_s"]))
        print(f"lazy,lazy={lazy},{rd:.3f},{res.stats['score_rows']},"
              f"{res.stats['wall_time_s']:.2f},")

    # 3) Clustering score on/off (paper: off for low-clustering graphs).
    for cs in (False, True):
        cfg = dataclasses.replace(base, use_clustering=cs)
        res, rd = _run(edges, n, cfg)
        rows.append(dict(experiment="clustering", variant=str(cs), rd=rd))
        print(f"clustering,cs={cs},{rd:.3f},,,")

    # 4) Adaptive λ vs fixed λ (clipped to the fixed point of Eq. 4 extremes).
    for lam, adapt_note in ((1.1, "fixed-1.1"), (None, "adaptive")):
        if lam is None:
            cfg = base
        else:
            cfg = dataclasses.replace(base, lam_init=lam, lam_lo=lam, lam_hi=lam)
        res, rd = _run(edges, n, cfg)
        imb = partition_balance(res.assign, args.k)
        rows.append(dict(experiment="lambda", variant=adapt_note, rd=rd,
                         imbalance=imb))
        print(f"lambda,{adapt_note},{rd:.3f},,,{imb:.4f}")

    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    main()
