"""Beyond-paper: ADWISE adaptive balancing applied to MoE routing.

Compares expert-load imbalance and token-drop rate of plain top-k routing vs
top-k + the paper's adaptive λ·B(e) bias (core/moe_balance) over a stream of
batches with a drifting token distribution (the hard case for static
aux-loss-only balancing).

    PYTHONPATH=src python -m benchmarks.bench_moe_balance
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moe_balance import adwise_router_bias, init_moe_balance, update_loads
from repro.models.layers import init_moe, moe_ffn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=16)
    ap.add_argument("--topk", type=int, default=2)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    e, k, d, t = args.experts, args.topk, args.d, args.tokens
    params = init_moe(jax.random.PRNGKey(0), d, 2 * d, e, jnp.float32)
    rng = np.random.default_rng(0)
    cap_f = 1.25

    def stream(step):
        # Slowly drifting distribution (topic changes every 8 steps): a
        # "topic" direction concentrates router mass on a few experts —
        # static routing overloads them; the load-feedback bias adapts.
        topic = np.zeros(d)
        topic[(step // 8) % d] = 3.0
        return jnp.asarray(
            (rng.normal(size=(1, t, d)) + topic).astype(np.float32))

    results = {}
    for mode in ("plain", "adwise"):
        st = init_moe_balance(e)
        drops, imbs = [], []
        for step in range(args.steps):
            x = stream(step)
            bias = None
            if mode == "adwise":
                bias, st = adwise_router_bias(
                    st, jnp.float32(step / args.steps))
            out, aux, counts = moe_ffn(
                params, x, n_experts=e, top_k=k, capacity_factor=cap_f,
                router_bias=bias)
            counts = np.asarray(counts)
            st = update_loads(st, jnp.asarray(counts))
            cap = max(8, -(-int(cap_f * t * k / e) // 8) * 8)
            dropped = np.maximum(counts - cap, 0).sum() / (t * k)
            imb = (counts.max() - counts.min()) / max(counts.max(), 1)
            drops.append(dropped)
            imbs.append(imb)
        results[mode] = dict(
            drop_rate=float(np.mean(drops)), imbalance=float(np.mean(imbs)))
        print(f"{mode}: mean_drop_rate={np.mean(drops):.4f} "
              f"mean_imbalance={np.mean(imbs):.4f}")
    gain = (1 - results["adwise"]["drop_rate"] /
            max(results["plain"]["drop_rate"], 1e-9)) * 100
    print(f"adwise-balance reduces token drops by {gain:.0f}%")
    if args.json:
        json.dump(results, open(args.json, "w"), indent=1)
    return results


if __name__ == "__main__":
    main()
