"""Run every benchmark at smoke scale. One section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run --smoke   # every entrypoint, seconds
    PYTHONPATH=src python -m benchmarks.run           # smoke scale (CI)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale proxies

--smoke exists so CI (and the test suite) can prove every bench entrypoint
still *runs* — tiny graphs, k=8, minimal steps — without paying benchmark
wall-clock.

--json-dir DIR additionally writes a machine-readable ``BENCH_<n>.json``
summary (n auto-increments over the files already in DIR, so a kept
directory accumulates the perf trajectory run over run): partition walls,
host→device stream traffic, ingest MB/s, engine supersteps/s, and the raw
per-bench rows. tools/ci.sh passes ``bench_logs/`` and keeps the file.

``--trace out.json`` wraps every bench section in a ``bench``-category span
(repro.obs) and writes a Perfetto-loadable Chrome trace-event timeline.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import re
import sys
import time


def _next_bench_path(json_dir: str) -> str:
    os.makedirs(json_dir, exist_ok=True)
    taken = [
        int(m.group(1))
        for f in os.listdir(json_dir)
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", f))
    ]
    return os.path.join(json_dir, f"BENCH_{max(taken, default=-1) + 1}.json")


def _summarize(results: dict) -> dict:
    """The headline numbers the perf trajectory tracks, pulled from the raw
    bench returns (absent benches simply contribute nothing)."""
    head: dict = {}
    io = results.get("io") or {}
    if io:
        head["ingest_mb_s"] = io.get("ingest_mb_s")
        head["ingest_python_mb_s"] = io.get("ingest_python_mb_s")
        head["ingest_speedup"] = io.get("ingest_speedup")
        head["read_mb_s"] = io.get("read_mb_s")
        for row in io.get("rows", []):
            if row.get("strategy") == "adwise":
                head["partition_file_wall_s"] = row.get("t_file_s")
                head["partition_memory_wall_s"] = row.get("t_memory_s")
                head["h2d_bytes"] = row.get("h2d_bytes")
                head["h2d_rows_per_call"] = (
                    row["h2d_rows"] / row["scan_calls"]
                    if row.get("scan_calls") else None
                )
                head["ring_rows"] = row.get("ring_rows")
                head["partition_file_sync_wall_s"] = row.get("t_file_sync_s")
                head["h2d_wait_s"] = row.get("h2d_wait_s")
                head["prestage_wall_s"] = row.get("prestage_wall_s")
                head["prefetch_depth"] = row.get("prefetch_depth")
                head["overlap_efficiency"] = row.get("overlap_efficiency")
        head["restream_h2d_bytes"] = io.get("restream_h2d_bytes")
    for row in io.get("scan_vs_oracle", []):
        head.setdefault("scan_core_speedup", {})[row["strategy"]] = (
            row.get("speedup")
        )
    for row in results.get("scaling") or []:
        head.setdefault("supersteps_per_s", {})[str(row.get("devices"))] = (
            row.get("supersteps_per_s")
        )
        head.setdefault("partition_batched_s", {})[str(row.get("devices"))] = (
            row.get("t_partition_batched_s")
        )
    kernels = results.get("kernels") or {}
    if kernels:
        # Chosen dispatch tier + hot-kernel walls at that tier (the tier
        # ladder replaced unconditional interpret mode; a flip back to a
        # slower tier shows up here and in bench_compare).
        head["kernel_tier"] = kernels.get("kernel_tier")
        head["window_score_wall_s"] = kernels.get("window_score_wall_s")
        head["segment_sum_wall_s"] = kernels.get("segment_sum_wall_s")
    return head


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fastest possible pass over every bench entrypoint")
    ap.add_argument("--json-dir", default=None,
                    help="write a BENCH_<n>.json machine-readable summary "
                         "into this directory (auto-incrementing n)")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record a section-level span timeline (repro.obs) "
                         "and write Chrome trace-event JSON here (open in "
                         "https://ui.perfetto.dev)")
    args = ap.parse_args(argv)
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    scale = 0.08 if args.full else 0.012

    from repro.obs import Tracer, resolve_tracer

    tr = resolve_tracer(Tracer() if args.trace else None)

    def sec(title, name, fn):
        """One bench section: banner + a `bench`-category span around it."""
        print(title)
        with tr.span(name, cat="bench"):
            return fn()

    t0 = time.time()

    from benchmarks import (
        bench_io,
        bench_kernels,
        bench_moe_balance,
        bench_replication,
        bench_restream,
        bench_scaling,
        bench_spotlight,
        bench_total_latency,
        bench_window,
        roofline,
    )

    results: dict = {}
    if args.smoke:
        k = ["--k", "8"]
        results["total_latency"] = sec(
            "=== Fig.7a-f: total latency (smoke) ===", "total_latency",
            lambda: bench_total_latency.main(
                ["--scale", "0.006", *k, "--graphs", "brain_like",
                 "--windows", "8", "--baselines", "dbh"]))
        sec("\n=== Fig.7g-i: replication degree (smoke) ===", "replication",
            lambda: bench_replication.main(
                ["--scale", "0.006", *k, "--graphs", "brain_like"]))
        sec("\n=== re-streaming pass sweep (smoke) ===", "restream",
            lambda: bench_restream.main(
                ["--scale", "0.006", *k, "--graphs", "brain_like",
                 "--passes", "2", "--window", "8"]))
        sec("\n=== Fig.8: spotlight spread sweep (smoke) ===", "spotlight",
            lambda: bench_spotlight.main(["--scale", "0.01", *k, "--z", "4"]))
        results["scaling"] = sec(
            "\n=== multi-device scaling (smoke: N in {1,2}) ===", "scaling",
            lambda: bench_scaling.main(["--smoke"]))
        results["io"] = sec(
            "\n=== out-of-core I/O: ingest + ring-buffer partitioning (smoke) ===",
            "io", lambda: bench_io.main(["--smoke"]))
        sec("\n=== §III ablations (smoke) ===", "window",
            lambda: bench_window.main(["--scale", "0.004", *k]))
        sec("\n=== ADWISE-balance MoE routing (smoke) ===", "moe_balance",
            lambda: bench_moe_balance.main(
                ["--steps", "3", "--tokens", "128", "--d", "16"]))
        results["kernels"] = sec(
            "\n=== kernels (smoke) ===", "kernels",
            lambda: bench_kernels.main(["--quick"]))
        sec("\n=== roofline table ===", "roofline", lambda: roofline.main([]))
        print(f"\nsmoke pass over all bench entrypoints done in {time.time()-t0:.0f}s")
    else:
        results["total_latency"] = sec(
            "=== Fig.7a-f: total latency (partition + modeled processing) ===",
            "total_latency",
            lambda: bench_total_latency.main(["--scale", str(scale)]))
        sec("\n=== Fig.7g-i: replication degree per strategy and L ===",
            "replication",
            lambda: bench_replication.main(["--scale", str(scale)]))
        sec("\n=== re-streaming: RD vs pass count (adwise-restream / 2ps) ===",
            "restream",
            lambda: bench_restream.main(["--scale", str(scale / 2)]))
        sec("\n=== Fig.8: spotlight spread sweep ===", "spotlight",
            lambda: bench_spotlight.main(["--scale", str(scale * 1.5)]))
        results["scaling"] = sec(
            "\n=== multi-device scaling: batched spotlight + engine vs N ===",
            "scaling",
            lambda: bench_scaling.main(
                ["--scale", str(scale / 2), "--devices", "1,2,4,8"]))
        results["io"] = sec(
            "\n=== out-of-core I/O: ingest MB/s + file vs in-memory wall ===",
            "io", lambda: bench_io.main(["--scale", str(scale)]))
        sec("\n=== §III ablations: window / lazy / clustering / lambda ===",
            "window", lambda: bench_window.main(["--scale", str(scale / 2)]))
        sec("\n=== beyond-paper: ADWISE-balance MoE routing ===", "moe_balance",
            lambda: bench_moe_balance.main(
                ["--steps", "12" if not args.full else "40"]))
        results["kernels"] = sec(
            "\n=== kernels (per-tier wall times, CPU-indicative) ===",
            "kernels",
            lambda: bench_kernels.main(["--quick"] if not args.full else []))
        sec("\n=== roofline table (from dry-run artifact, if present) ===",
            "roofline", lambda: roofline.main([]))
        print(f"\nall benchmarks done in {time.time()-t0:.0f}s")

    if args.trace:
        n_events = tr.export(args.trace)
        print(f"trace: {n_events} events -> {args.trace}")

    if args.json_dir:
        path = _next_bench_path(args.json_dir)
        # Retrace budget over the whole bench pass: how many distinct
        # programs the driver kernels compiled. A jump here without a
        # geometry change is a recompilation regression (the pow2-Rq
        # contract tests/test_compile_budget.py enforces per-run).
        from repro.core.driver import scan_compile_counts

        compiles = scan_compile_counts()
        doc = dict(
            mode="full" if args.full else ("smoke" if args.smoke else "default"),
            wall_s=round(time.time() - t0, 2),
            platform=platform.platform(),
            python=platform.python_version(),
            summary=dict(_summarize(results), jit_scan_compiles=compiles),
            jit_scan_compiles=compiles,
            io=results.get("io"),
            kernels=results.get("kernels"),
            scaling=results.get("scaling"),
            total_latency=results.get("total_latency"),
        )
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, default=str)
        print(f"bench summary -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
