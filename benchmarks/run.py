"""Run every benchmark at smoke scale. One section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run --smoke   # every entrypoint, seconds
    PYTHONPATH=src python -m benchmarks.run           # smoke scale (CI)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale proxies

--smoke exists so CI (and the test suite) can prove every bench entrypoint
still *runs* — tiny graphs, k=8, minimal steps — without paying benchmark
wall-clock.

--json-dir DIR additionally writes a machine-readable ``BENCH_<n>.json``
summary (n auto-increments over the files already in DIR, so a kept
directory accumulates the perf trajectory run over run): partition walls,
host→device stream traffic, ingest MB/s, engine supersteps/s, and the raw
per-bench rows. tools/ci.sh passes ``bench_logs/`` and keeps the file.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import re
import sys
import time


def _next_bench_path(json_dir: str) -> str:
    os.makedirs(json_dir, exist_ok=True)
    taken = [
        int(m.group(1))
        for f in os.listdir(json_dir)
        if (m := re.fullmatch(r"BENCH_(\d+)\.json", f))
    ]
    return os.path.join(json_dir, f"BENCH_{max(taken, default=-1) + 1}.json")


def _summarize(results: dict) -> dict:
    """The headline numbers the perf trajectory tracks, pulled from the raw
    bench returns (absent benches simply contribute nothing)."""
    head: dict = {}
    io = results.get("io") or {}
    if io:
        head["ingest_mb_s"] = io.get("ingest_mb_s")
        head["ingest_python_mb_s"] = io.get("ingest_python_mb_s")
        head["ingest_speedup"] = io.get("ingest_speedup")
        head["read_mb_s"] = io.get("read_mb_s")
        for row in io.get("rows", []):
            if row.get("strategy") == "adwise":
                head["partition_file_wall_s"] = row.get("t_file_s")
                head["partition_memory_wall_s"] = row.get("t_memory_s")
                head["h2d_bytes"] = row.get("h2d_bytes")
                head["h2d_rows_per_call"] = (
                    row["h2d_rows"] / row["scan_calls"]
                    if row.get("scan_calls") else None
                )
                head["ring_rows"] = row.get("ring_rows")
                head["partition_file_sync_wall_s"] = row.get("t_file_sync_s")
                head["h2d_wait_s"] = row.get("h2d_wait_s")
                head["prefetch_depth"] = row.get("prefetch_depth")
                head["overlap_efficiency"] = row.get("overlap_efficiency")
        head["restream_h2d_bytes"] = io.get("restream_h2d_bytes")
    for row in io.get("scan_vs_oracle", []):
        head.setdefault("scan_core_speedup", {})[row["strategy"]] = (
            row.get("speedup")
        )
    for row in results.get("scaling") or []:
        head.setdefault("supersteps_per_s", {})[str(row.get("devices"))] = (
            row.get("supersteps_per_s")
        )
        head.setdefault("partition_batched_s", {})[str(row.get("devices"))] = (
            row.get("t_partition_batched_s")
        )
    return head


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fastest possible pass over every bench entrypoint")
    ap.add_argument("--json-dir", default=None,
                    help="write a BENCH_<n>.json machine-readable summary "
                         "into this directory (auto-incrementing n)")
    args = ap.parse_args(argv)
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    scale = 0.08 if args.full else 0.012
    t0 = time.time()

    from benchmarks import (
        bench_io,
        bench_kernels,
        bench_moe_balance,
        bench_replication,
        bench_restream,
        bench_scaling,
        bench_spotlight,
        bench_total_latency,
        bench_window,
        roofline,
    )

    results: dict = {}
    if args.smoke:
        k = ["--k", "8"]
        print("=== Fig.7a-f: total latency (smoke) ===")
        results["total_latency"] = bench_total_latency.main(
            ["--scale", "0.006", *k, "--graphs", "brain_like",
             "--windows", "8", "--baselines", "dbh"])
        print("\n=== Fig.7g-i: replication degree (smoke) ===")
        bench_replication.main(["--scale", "0.006", *k, "--graphs", "brain_like"])
        print("\n=== re-streaming pass sweep (smoke) ===")
        bench_restream.main(["--scale", "0.006", *k, "--graphs", "brain_like",
                             "--passes", "2", "--window", "8"])
        print("\n=== Fig.8: spotlight spread sweep (smoke) ===")
        bench_spotlight.main(["--scale", "0.01", *k, "--z", "4"])
        print("\n=== multi-device scaling (smoke: N in {1,2}) ===")
        results["scaling"] = bench_scaling.main(["--smoke"])
        print("\n=== out-of-core I/O: ingest + ring-buffer partitioning (smoke) ===")
        results["io"] = bench_io.main(["--smoke"])
        print("\n=== §III ablations (smoke) ===")
        bench_window.main(["--scale", "0.004", *k])
        print("\n=== ADWISE-balance MoE routing (smoke) ===")
        bench_moe_balance.main(["--steps", "3", "--tokens", "128", "--d", "16"])
        print("\n=== kernels (smoke) ===")
        bench_kernels.main(["--quick"])
        print("\n=== roofline table ===")
        roofline.main([])
        print(f"\nsmoke pass over all bench entrypoints done in {time.time()-t0:.0f}s")
    else:
        print("=== Fig.7a-f: total latency (partition + modeled processing) ===")
        results["total_latency"] = bench_total_latency.main(["--scale", str(scale)])
        print("\n=== Fig.7g-i: replication degree per strategy and L ===")
        bench_replication.main(["--scale", str(scale)])
        print("\n=== re-streaming: RD vs pass count (adwise-restream / 2ps) ===")
        bench_restream.main(["--scale", str(scale / 2)])
        print("\n=== Fig.8: spotlight spread sweep ===")
        bench_spotlight.main(["--scale", str(scale * 1.5)])
        print("\n=== multi-device scaling: batched spotlight + engine vs N ===")
        results["scaling"] = bench_scaling.main(
            ["--scale", str(scale / 2), "--devices", "1,2,4,8"])
        print("\n=== out-of-core I/O: ingest MB/s + file vs in-memory wall ===")
        results["io"] = bench_io.main(["--scale", str(scale)])
        print("\n=== §III ablations: window / lazy / clustering / lambda ===")
        bench_window.main(["--scale", str(scale / 2)])
        print("\n=== beyond-paper: ADWISE-balance MoE routing ===")
        bench_moe_balance.main(["--steps", "12" if not args.full else "40"])
        print("\n=== kernels (interpret-mode wall times, CPU-indicative) ===")
        bench_kernels.main(["--quick"] if not args.full else [])
        print("\n=== roofline table (from dry-run artifact, if present) ===")
        roofline.main([])
        print(f"\nall benchmarks done in {time.time()-t0:.0f}s")

    if args.json_dir:
        path = _next_bench_path(args.json_dir)
        # Retrace budget over the whole bench pass: how many distinct
        # programs the driver kernels compiled. A jump here without a
        # geometry change is a recompilation regression (the pow2-Rq
        # contract tests/test_compile_budget.py enforces per-run).
        from repro.core.driver import scan_compile_counts

        compiles = scan_compile_counts()
        doc = dict(
            mode="full" if args.full else ("smoke" if args.smoke else "default"),
            wall_s=round(time.time() - t0, 2),
            platform=platform.platform(),
            python=platform.python_version(),
            summary=dict(_summarize(results), jit_scan_compiles=compiles),
            jit_scan_compiles=compiles,
            io=results.get("io"),
            scaling=results.get("scaling"),
            total_latency=results.get("total_latency"),
        )
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, default=str)
        print(f"bench summary -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
