"""Run every benchmark at smoke scale. One section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run --smoke   # every entrypoint, seconds
    PYTHONPATH=src python -m benchmarks.run           # smoke scale (CI)
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale proxies

--smoke exists so CI (and the test suite) can prove every bench entrypoint
still *runs* — tiny graphs, k=8, minimal steps — without paying benchmark
wall-clock.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fastest possible pass over every bench entrypoint")
    args = ap.parse_args(argv)
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")
    scale = 0.08 if args.full else 0.012
    t0 = time.time()

    from benchmarks import (
        bench_io,
        bench_kernels,
        bench_moe_balance,
        bench_replication,
        bench_restream,
        bench_scaling,
        bench_spotlight,
        bench_total_latency,
        bench_window,
        roofline,
    )

    if args.smoke:
        k = ["--k", "8"]
        print("=== Fig.7a-f: total latency (smoke) ===")
        bench_total_latency.main(["--scale", "0.006", *k,
                                  "--graphs", "brain_like",
                                  "--windows", "8", "--baselines", "dbh"])
        print("\n=== Fig.7g-i: replication degree (smoke) ===")
        bench_replication.main(["--scale", "0.006", *k, "--graphs", "brain_like"])
        print("\n=== re-streaming pass sweep (smoke) ===")
        bench_restream.main(["--scale", "0.006", *k, "--graphs", "brain_like",
                             "--passes", "2", "--window", "8"])
        print("\n=== Fig.8: spotlight spread sweep (smoke) ===")
        bench_spotlight.main(["--scale", "0.01", *k, "--z", "4"])
        print("\n=== multi-device scaling (smoke: N in {1,2}) ===")
        bench_scaling.main(["--smoke"])
        print("\n=== out-of-core I/O: ingest + file-driven partitioning (smoke) ===")
        bench_io.main(["--smoke"])
        print("\n=== §III ablations (smoke) ===")
        bench_window.main(["--scale", "0.004", *k])
        print("\n=== ADWISE-balance MoE routing (smoke) ===")
        bench_moe_balance.main(["--steps", "3", "--tokens", "128", "--d", "16"])
        print("\n=== kernels (smoke) ===")
        bench_kernels.main(["--quick"])
        print("\n=== roofline table ===")
        roofline.main([])
        print(f"\nsmoke pass over all bench entrypoints done in {time.time()-t0:.0f}s")
        return 0

    print("=== Fig.7a-f: total latency (partition + modeled processing) ===")
    bench_total_latency.main(["--scale", str(scale)])
    print("\n=== Fig.7g-i: replication degree per strategy and L ===")
    bench_replication.main(["--scale", str(scale)])
    print("\n=== re-streaming: RD vs pass count (adwise-restream / 2ps) ===")
    bench_restream.main(["--scale", str(scale / 2)])
    print("\n=== Fig.8: spotlight spread sweep ===")
    bench_spotlight.main(["--scale", str(scale * 1.5)])
    print("\n=== multi-device scaling: batched spotlight + engine vs N ===")
    bench_scaling.main(["--scale", str(scale / 2), "--devices", "1,2,4,8"])
    print("\n=== out-of-core I/O: ingest MB/s + file vs in-memory wall ===")
    bench_io.main(["--scale", str(scale)])
    print("\n=== §III ablations: window / lazy / clustering / lambda ===")
    bench_window.main(["--scale", str(scale / 2)])
    print("\n=== beyond-paper: ADWISE-balance MoE routing ===")
    bench_moe_balance.main(["--steps", "12" if not args.full else "40"])
    print("\n=== kernels (interpret-mode wall times, CPU-indicative) ===")
    bench_kernels.main(["--quick"] if not args.full else [])
    print("\n=== roofline table (from dry-run artifact, if present) ===")
    roofline.main([])
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
