"""§Roofline: render the per-(arch × shape × mesh) roofline table from the
dry-run artifact (results/dryrun.json).

    PYTHONPATH=src python -m benchmarks.roofline [--md] [--json results/dryrun.json]

Terms (single-pod cells, exact unrolled cost analysis):
  t_compute   = per-device HLO FLOPs / 197e12
  t_mem_hlo   = per-device HLO bytes-accessed / 819e9  (CPU-HLO pessimistic:
                counts every un-fused intermediate XLA:TPU would fuse)
  t_mem_min   = (2·temp + args + outputs) / 819e9      (buffer-assignment
                floor: every live buffer written+read once)
  t_collective= per-device collective bytes / 50e9
  dominant    = argmax(compute, mem_min, collective)   (TPU-realistic)
  useful      = MODEL_FLOPS / global HLO FLOPs
  roofline_frac = model-FLOP-time / max(term)          (perfect overlap)

Multi-pod rows prove the pod axis shards (scan-only compile): memory columns
only — their cost analysis is not trip-count-exact.
"""
from __future__ import annotations

import argparse
import json
import os

HDR = ("arch", "shape", "mesh", "t_compute_s", "t_mem_hlo_s", "t_mem_min_s",
       "t_collective_s", "dominant", "useful", "roofline_frac",
       "arg_GB/dev", "temp_GB/dev")


def derive(r):
    """Recompute roofline terms from the RAW per-device counters."""
    bpd = r["bytes_per_device"]
    mem_min = (2 * bpd["temp"] + bpd["argument"] + bpd["output"]) / 819e9
    if not r.get("cost_exact", True):
        return dict(mem_min=mem_min, exact=False)
    t = dict(
        compute=r["hlo_flops"] / 197e12,
        mem_hlo=r["hlo_bytes"] / 819e9,
        mem_min=mem_min,
        collective=r["collective_bytes"]["total"] / 50e9,
    )
    dom_terms = dict(compute=t["compute"], memory=t["mem_min"],
                     collective=t["collective"])
    dominant = max(dom_terms, key=dom_terms.get)
    t_star = max(dom_terms.values())
    t_model = r["model_flops"] / (r["n_chips"] * 197e12)
    return dict(
        **t, exact=True, dominant=dominant,
        useful=r["model_flops"] / (r["hlo_flops"] * r["n_chips"]),
        frac=t_model / t_star if t_star else 0.0,
    )


def rows_from(results):
    out = []
    for r in results:
        mesh = "2pod" if r["multi_pod"] else "1pod"
        if r.get("status") != "ok":
            out.append((r["arch"], r["shape"], mesh, "-", "-", "-", "-",
                        r.get("status"),
                        r.get("reason", r.get("error", ""))[:40], "-", "-", "-")[:12])
            continue
        d = derive(r)
        bpd = r["bytes_per_device"]
        if not d["exact"]:
            out.append((r["arch"], r["shape"], mesh, "-", "-",
                        f"{d['mem_min']:.2e}", "-", "compiles-ok", "-", "-",
                        f"{bpd['argument']/1e9:.2f}", f"{bpd['temp']/1e9:.2f}"))
            continue
        out.append((
            r["arch"], r["shape"], mesh,
            f"{d['compute']:.2e}", f"{d['mem_hlo']:.2e}", f"{d['mem_min']:.2e}",
            f"{d['collective']:.2e}", d["dominant"], f"{d['useful']:.2f}",
            f"{d['frac']:.3f}",
            f"{bpd['argument']/1e9:.2f}", f"{bpd['temp']/1e9:.2f}",
        ))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="results/dryrun.json")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    if not os.path.exists(args.json):
        print(f"no dry-run artifact at {args.json}; run repro.launch.dryrun first")
        return []
    results = json.load(open(args.json))
    rows = rows_from(results)
    if args.md:
        print("| " + " | ".join(HDR) + " |")
        print("|" + "---|" * len(HDR))
        for row in rows:
            print("| " + " | ".join(str(x) for x in row) + " |")
    else:
        print(",".join(HDR))
        for row in rows:
            print(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    main()
