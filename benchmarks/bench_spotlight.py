"""Fig. 8 analogue: spotlight spread sweep for all strategies.

    PYTHONPATH=src python -m benchmarks.bench_spotlight --scale 0.12
"""
from __future__ import annotations

import argparse
import json

from repro.core import AdwiseConfig, spotlight_partition
from repro.graph import make_graph, replica_sets_from_assignment, replication_degree


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.12)
    ap.add_argument("--graph", default="brain_like")
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--z", type=int, default=8)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    edges, n = make_graph(args.graph, seed=0, scale=args.scale)
    rows = []
    print("strategy,spread,RD,improvement_vs_full")
    for strategy in ("dbh", "hdrf", "adwise"):
        full_rd = None
        for spread in (args.k, args.k // 2, args.k // 4, args.k // args.z):
            cfg = AdwiseConfig(k=args.k, window_max=128) if strategy == "adwise" else None
            res = spotlight_partition(edges, n, args.k, z=args.z, spread=spread,
                                      strategy=strategy, cfg=cfg)
            rd = replication_degree(
                replica_sets_from_assignment(edges, res.assign, n, args.k))
            full_rd = full_rd or rd
            impr = 100 * (1 - rd / full_rd)
            rows.append(dict(strategy=strategy, spread=spread,
                             replication_degree=rd, improvement_pct=impr))
            print(f"{strategy},{spread},{rd:.3f},{impr:.1f}%")
    if args.json:
        json.dump(rows, open(args.json, "w"), indent=1)
    return rows


if __name__ == "__main__":
    main()
