"""Kernel micro-benchmarks: wall time per dispatch tier, per op.

Each op is timed at every tier runnable on this host (`xla` everywhere,
`pallas-tpu` / `pallas-cpu` where the backend lowers them) plus explicit
`interpret` where Pallas is importable — interpret is a *debug* tier, timed
here only so the chosen-tier speedup over it stays visible in the perf
trajectory. The `chosen` column is what `repro.kernels.ops.resolve_tier`
picks for the op on this host (autotuned; `$ADWISE_KERNEL_TIER` overrides),
and is never interpret.

CPU wall-times are indicative only (TPU is the target); the structural
metric that transfers is the op count / fusion shape, so we also report the
kernel's VMEM working set per tile.

    PYTHONPATH=src python -m benchmarks.bench_kernels
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.kernels import ops
from repro.kernels.window_score import BW, LANE


def _time(fn, *a, n=3, **kw):
    fn(*a, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*a, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _bench_tiers(op: str) -> list[str]:
    """Every runnable tier, plus explicit interpret where Pallas exists."""
    tiers = list(ops.available_tiers(op))
    if compat.has_pallas(op in ("segment_sum", "flash_attention")):
        if op != "segment_sum" or compat.HAS_PREFETCH_GRID:
            tiers.append(ops.INTERPRET_TIER)
    return tiers


def _row(op: str, shape: str, fn, args, vmem_kb: float) -> dict:
    chosen = ops.resolve_tier(op)
    walls_ms = {t: _time(fn, *args, tier=t) * 1e3 for t in _bench_tiers(op)}
    cols = " ".join(f"{t}={ms:.2f}" for t, ms in walls_ms.items())
    print(f"{op},{shape},chosen={chosen},{cols},vmem_tile_KB={vmem_kb:.0f}")
    return dict(kernel=op, shape=shape, chosen_tier=chosen,
                walls_ms=walls_ms, vmem_tile_kb=round(vmem_kb, 1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rng = np.random.default_rng(0)
    print("kernel,shape,chosen,per-tier ms,vmem_tile_KB")
    rows = []

    shapes = [(256, 32), (512, 32)] if args.quick else [(256, 32), (512, 32), (1024, 64)]
    for w, k in shapes:
        uv = rng.integers(0, 10_000, (w, 2)).astype(np.int32)
        valid = np.ones(w, bool)
        repu = rng.random((w, k)) < 0.2
        repv = rng.random((w, k)) < 0.2
        degu = rng.integers(1, 50, w).astype(np.int32)
        degv = rng.integers(1, 50, w).astype(np.int32)
        bal = rng.random(k).astype(np.float32)
        allowed = np.ones(k, bool)
        a = (uv, valid, repu, repv, degu, degv, bal, allowed,
             jnp.float32(1.0), jnp.int32(50))
        w_pad = -(-w // BW) * BW
        k_pad = -(-k // LANE) * LANE
        vmem = (5 * w_pad * 4 + 2 * w_pad * k_pad * 4 + BW * k_pad * 4) / 1024
        rows.append(_row("window_score", f"W{w}xK{k}", ops.window_score, a, vmem))

    for e, d, s in ([(2048, 32, 256)] if args.quick else [(2048, 32, 256), (8192, 64, 1024)]):
        seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
        data = jnp.asarray(rng.normal(size=(e, d)).astype(np.float32))
        rows.append(_row("segment_sum", f"E{e}xD{d}xS{s}",
                         ops.segment_sum_sorted, (data, seg, s),
                         (512 * d * 4 + 128 * d * 4) / 1024))

    for b, hq, hkv, t, dh in ([(1, 4, 2, 256, 64)] if args.quick
                              else [(1, 4, 2, 256, 64), (2, 8, 4, 512, 64)]):
        q = jnp.asarray(rng.normal(size=(b, hq, t, dh)).astype(np.float32))
        kk = jnp.asarray(rng.normal(size=(b, hkv, t, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, hkv, t, dh)).astype(np.float32))
        rows.append(_row("flash_attention", f"B{b}H{hq}T{t}D{dh}",
                         ops.flash_attention, (q, kk, v),
                         (128 * dh * 4 * 3 + 128 * 128 * 4) / 1024))

    # Headline numbers for the BENCH summary: the largest shape of each hot
    # op, billed at its chosen (non-interpret) tier.
    def _head(op: str):
        last = [r for r in rows if r["kernel"] == op][-1]
        return last["chosen_tier"], last["walls_ms"][last["chosen_tier"]] / 1e3

    ws_tier, ws_wall = _head("window_score")
    _, ss_wall = _head("segment_sum")
    return dict(rows=rows, kernel_tier=ws_tier,
                window_score_wall_s=ws_wall, segment_sum_wall_s=ss_wall)


if __name__ == "__main__":
    main()
