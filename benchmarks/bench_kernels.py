"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall-times.

CPU wall-times are indicative only (TPU is the target); the structural
metric that transfers is the op count / fusion shape, so we also report the
kernel's VMEM working set per tile.

    PYTHONPATH=src python -m benchmarks.bench_kernels
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.window_score import BW, LANE


def _time(fn, *a, n=3, **kw):
    fn(*a, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*a, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    rng = np.random.default_rng(0)
    print("kernel,shape,ref_ms,pallas_interp_ms,vmem_tile_KB")

    shapes = [(256, 32), (512, 32)] if args.quick else [(256, 32), (512, 32), (1024, 64)]
    for w, k in shapes:
        uv = rng.integers(0, 10_000, (w, 2)).astype(np.int32)
        valid = np.ones(w, bool)
        repu = rng.random((w, k)) < 0.2
        repv = rng.random((w, k)) < 0.2
        degu = rng.integers(1, 50, w).astype(np.int32)
        degv = rng.integers(1, 50, w).astype(np.int32)
        bal = rng.random(k).astype(np.float32)
        allowed = np.ones(k, bool)
        a = (uv, valid, repu, repv, degu, degv, bal, allowed,
             jnp.float32(1.0), jnp.int32(50))
        t_ref = _time(ops.window_score, *a, impl="ref")
        t_pl = _time(ops.window_score, *a, impl="pallas")
        w_pad = -(-w // BW) * BW
        k_pad = -(-k // LANE) * LANE
        vmem = (5 * w_pad * 4 + 2 * w_pad * k_pad * 4 + BW * k_pad * 4) / 1024
        print(f"window_score,W{w}xK{k},{t_ref*1e3:.2f},{t_pl*1e3:.2f},{vmem:.0f}")

    for e, d, s in ([(2048, 32, 256)] if args.quick else [(2048, 32, 256), (8192, 64, 1024)]):
        seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
        data = jnp.asarray(rng.normal(size=(e, d)).astype(np.float32))
        t_ref = _time(ops.segment_sum_sorted, data, seg, s, impl="ref")
        t_pl = _time(ops.segment_sum_sorted, data, seg, s, impl="pallas")
        print(f"segment_sum,E{e}xD{d}xS{s},{t_ref*1e3:.2f},{t_pl*1e3:.2f},"
              f"{(512*d*4 + 128*d*4)//1024}")

    for b, hq, hkv, t, dh in ([(1, 4, 2, 256, 64)] if args.quick
                              else [(1, 4, 2, 256, 64), (2, 8, 4, 512, 64)]):
        q = jnp.asarray(rng.normal(size=(b, hq, t, dh)).astype(np.float32))
        kk = jnp.asarray(rng.normal(size=(b, hkv, t, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, hkv, t, dh)).astype(np.float32))
        t_ref = _time(ops.flash_attention, q, kk, v, impl="ref")
        t_pl = _time(ops.flash_attention, q, kk, v, impl="pallas")
        print(f"flash_attention,B{b}H{hq}T{t}D{dh},{t_ref*1e3:.2f},{t_pl*1e3:.2f},"
              f"{(128*dh*4*3 + 128*128*4)//1024}")


if __name__ == "__main__":
    main()
