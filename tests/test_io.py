"""Out-of-core I/O subsystem: binary format, text ingest, external shuffle,
EdgeStream bridges, chunked metric accumulation."""
import os
import struct

import numpy as np
import pytest

from repro.graph import (
    EdgeStream,
    make_graph,
    partition_balance,
    quality_from_chunks,
    replica_sets_from_assignment,
    replica_sets_from_chunks,
    replication_degree,
    rmat,
)
from repro.graph.io import (
    HEADER_BYTES,
    MAGIC,
    EdgeFileReader,
    EdgeFileWriter,
    ingest_text,
    read_edge_file,
    shuffle_file,
    write_edge_file,
)

from conftest import random_edges


@pytest.fixture(scope="module")
def graph_file(tmp_path_factory):
    edges, n = make_graph("tiny_social", seed=4)
    path = str(tmp_path_factory.mktemp("io") / "g.adw")
    write_edge_file(path, edges, n)
    return path, edges, n


# ----------------------------------------------------------------------------
# Binary format
# ----------------------------------------------------------------------------

def test_binary_roundtrip(graph_file):
    path, edges, n = graph_file
    with EdgeFileReader(path) as r:
        assert r.num_edges == len(edges)
        assert r.num_vertices == n
        assert (r.read_all() == edges).all()
        # Bounded-chunk iteration reconstructs the stream.
        cat = np.concatenate(list(r.chunks(251)))
        assert (cat == edges).all()
        # Random-access row ranges, clipped at both ends.
        assert (r.read(100, 37) == edges[100:137]).all()
        assert r.read(len(edges) - 3, 100).shape == (3, 2)
        assert r.read(len(edges) + 5, 10).shape == (0, 2)


def test_reader_mmap_mode(graph_file):
    path, edges, _ = graph_file
    with EdgeFileReader(path, mmap=True) as r:
        assert (r.read_all() == edges).all()
        assert (r.read(7, 9) == edges[7:16]).all()


def test_sub_readers_match_split_bounds(graph_file):
    path, edges, n = graph_file
    m = len(edges)
    for z in (1, 3, 7):
        bounds = EdgeStream.split_bounds(m, z)
        with EdgeFileReader(path) as r:
            subs = r.split(z)
            assert len(subs) == z
            for i, s in enumerate(subs):
                lo, hi = int(bounds[i]), int(bounds[i + 1])
                assert s.num_edges == hi - lo
                assert (s.read_all() == edges[lo:hi]).all()
                # Nested sub-ranges address locally.
                if s.num_edges >= 2:
                    assert (s.sub(1, s.num_edges).read_all() == edges[lo + 1 : hi]).all()


def test_reader_io_accounting(graph_file):
    path, edges, _ = graph_file
    with EdgeFileReader(path) as r:
        subs = r.split(2)
        for s in subs:
            for _ in s.chunks(100):
                pass
        # Sub-reader IO flows to the root counters.
        assert r.rows_read == len(edges)
        assert r.read_seconds >= 0.0


def test_writer_streams_and_infers_n(tmp_path):
    path = str(tmp_path / "w.adw")
    rng = np.random.default_rng(0)
    chunks = [random_edges(rng, 50, 40) for _ in range(5)]
    with EdgeFileWriter(path) as w:
        for c in chunks:
            w.append(c)
    all_edges = np.concatenate(chunks)
    got, n = read_edge_file(path)
    assert (got == all_edges).all()
    assert n == int(all_edges.max()) + 1


def test_version_and_magic_rejection(tmp_path):
    header_fmt = "<8sIIQQQ"
    bad_version = str(tmp_path / "v99.adw")
    with open(bad_version, "wb") as f:
        f.write(struct.pack(header_fmt, MAGIC, 99, 1, 0, 0, 0).ljust(HEADER_BYTES, b"\0"))
    with pytest.raises(ValueError, match="version 99"):
        EdgeFileReader(bad_version)

    bad_magic = str(tmp_path / "magic.adw")
    with open(bad_magic, "wb") as f:
        f.write(struct.pack(header_fmt, b"NOTADWSE", 1, 1, 0, 0, 0).ljust(HEADER_BYTES, b"\0"))
    with pytest.raises(ValueError, match="not an ADWISE"):
        EdgeFileReader(bad_magic)

    bad_dtype = str(tmp_path / "dtype.adw")
    with open(bad_dtype, "wb") as f:
        f.write(struct.pack(header_fmt, MAGIC, 1, 7, 0, 0, 0).ljust(HEADER_BYTES, b"\0"))
    with pytest.raises(ValueError, match="dtype"):
        EdgeFileReader(bad_dtype)

    truncated = str(tmp_path / "trunc.adw")
    with open(truncated, "wb") as f:
        f.write(struct.pack(header_fmt, MAGIC, 1, 1, 1000, 10, 0).ljust(HEADER_BYTES, b"\0"))
        f.write(b"\0" * 16)  # 2 rows of payload, header claims 1000
    with pytest.raises(ValueError, match="truncated"):
        EdgeFileReader(truncated)

    short = str(tmp_path / "short.adw")
    with open(short, "wb") as f:
        f.write(b"ADW")
    with pytest.raises(ValueError, match="truncated header"):
        EdgeFileReader(short)


# ----------------------------------------------------------------------------
# Text ingest
# ----------------------------------------------------------------------------

_ADVERSARIAL = """# SNAP-style comment
% matrix-market-style comment
// c-style comment

5\t7
  7   5
3 3
5 7 99 extra fields ignored

\t
9\t2
"""


def test_ingest_adversarial(tmp_path):
    src = str(tmp_path / "adv.txt")
    dst = str(tmp_path / "adv.adw")
    with open(src, "w") as f:
        f.write(_ADVERSARIAL)
    rep = ingest_text(src, dst)
    edges, n = read_edge_file(dst)
    # Self-loop and the duplicate (5,7) are preserved: the file IS the stream.
    expect = np.array([[5, 7], [7, 5], [3, 3], [5, 7], [9, 2]], np.int32)
    assert (edges == expect).all()
    assert n == 10  # max id + 1 inferred
    assert rep.comment_lines == 3
    assert rep.blank_lines == 3  # empty line, whitespace-only line, trailing
    assert rep.num_edges == 5


def test_ingest_relabel_dense_first_appearance(tmp_path):
    src = str(tmp_path / "sparse.txt")
    dst = str(tmp_path / "sparse.adw")
    with open(src, "w") as f:
        f.write("1000000 42\n42 -3\n1000000 7\n")
    with pytest.raises(ValueError, match="negative"):
        ingest_text(src, dst)
    rep = ingest_text(src, dst, relabel=True)
    edges, n = read_edge_file(dst)
    # Dense ids in first-appearance order: 1000000->0, 42->1, -3->2, 7->3.
    assert (edges == np.array([[0, 1], [1, 2], [0, 3]])).all()
    assert n == 4 and rep.num_vertices == 4


def test_ingest_malformed_line_reports_position(tmp_path):
    src = str(tmp_path / "bad.txt")
    dst = str(tmp_path / "bad.adw")
    with open(src, "w") as f:
        f.write("1 2\n# ok\nonly_one_field\n")
    with pytest.raises(ValueError, match=r"bad\.txt:3"):
        ingest_text(src, dst)
    # A failed ingest must not leave a valid-looking truncated binary behind.
    assert not os.path.exists(dst)
    with open(src, "w") as f:
        f.write("1 2\n3 notanint\n")
    with pytest.raises(ValueError, match=r"bad\.txt:2"):
        ingest_text(src, dst)
    assert not os.path.exists(dst)


def test_writer_abort_on_exception(tmp_path):
    path = str(tmp_path / "partial.adw")
    with pytest.raises(RuntimeError):
        with EdgeFileWriter(path) as w:
            w.append(np.array([[0, 1]], np.int32))
            raise RuntimeError("body failed")
    assert not os.path.exists(path)


def test_ingest_chunking_invariance(tmp_path):
    """The chunk_lines bound never changes the output stream."""
    rng = np.random.default_rng(5)
    edges = random_edges(rng, 40, 200)
    src = str(tmp_path / "c.txt")
    with open(src, "w") as f:
        for i, (u, v) in enumerate(edges):
            if i % 17 == 0:
                f.write("# interleaved comment\n")
            f.write(f"{u} {v}\n")
    outs = []
    for chunk_lines in (3, 64, 10_000):
        dst = str(tmp_path / f"c{chunk_lines}.adw")
        ingest_text(src, dst, chunk_lines=chunk_lines)
        outs.append(read_edge_file(dst))
    for got, n in outs:
        assert (got == edges).all()
        assert n == outs[0][1]
    # Relabeled: the incremental id table must give the same global
    # first-appearance mapping for every chunking.
    relabeled = []
    for chunk_lines in (3, 10_000):
        dst = str(tmp_path / f"r{chunk_lines}.adw")
        ingest_text(src, dst, relabel=True, chunk_lines=chunk_lines)
        relabeled.append(read_edge_file(dst))
    assert (relabeled[0][0] == relabeled[1][0]).all()
    assert relabeled[0][1] == relabeled[1][1]
    # And the mapping is first-appearance order: sequential dense ids.
    flat = relabeled[0][0].reshape(-1)
    first_seen = flat[np.sort(np.unique(flat, return_index=True)[1])]
    assert (first_seen == np.arange(relabeled[0][1])).all()


def test_ingest_pinned_num_vertices(tmp_path):
    src = str(tmp_path / "p.txt")
    dst = str(tmp_path / "p.adw")
    with open(src, "w") as f:
        f.write("0 1\n1 2\n")
    ingest_text(src, dst, num_vertices=500)
    _, n = read_edge_file(dst)
    assert n == 500
    # Ids beyond a pinned n fail at ingest time, not at partition time.
    with pytest.raises(ValueError, match="pinned num_vertices"):
        ingest_text(src, dst, num_vertices=2)


# ----------------------------------------------------------------------------
# External shuffle
# ----------------------------------------------------------------------------

def test_shuffle_is_permutation_and_deterministic(graph_file, tmp_path):
    path, edges, n = graph_file
    a = str(tmp_path / "a.adw")
    b = str(tmp_path / "b.adw")
    shuffle_file(path, a, seed=3, chunk_edges=300)
    shuffle_file(path, b, seed=3, chunk_edges=300)
    got_a, n_a = read_edge_file(a)
    got_b, _ = read_edge_file(b)
    assert n_a == n
    assert (got_a == got_b).all(), "same seed must give the same permutation"
    assert got_a.shape == edges.shape
    assert not (got_a == edges).all(), "shuffle must not be the identity"
    order = lambda e: e[np.lexsort((e[:, 1], e[:, 0]))]
    assert (order(got_a) == order(edges)).all(), "rows must be a permutation"
    c = str(tmp_path / "c.adw")
    shuffle_file(path, c, seed=4, chunk_edges=300)
    got_c, _ = read_edge_file(c)
    assert not (got_c == got_a).all(), "different seeds, different permutation"


def test_shuffle_recursive_buckets(graph_file, tmp_path, monkeypatch):
    """With the open-file cap forced to 2, buckets overflow the chunk budget
    and must be re-scattered recursively — still a uniform permutation."""
    import repro.graph.io.shuffle as sh

    monkeypatch.setattr(sh, "_MAX_OPEN", 2)
    path, edges, _ = graph_file
    out = str(tmp_path / "rec.adw")
    shuffle_file(path, out, seed=9, chunk_edges=150)
    got, _ = read_edge_file(out)
    order = lambda e: e[np.lexsort((e[:, 1], e[:, 0]))]
    assert (order(got) == order(edges)).all()
    assert not (got == edges).all()


# ----------------------------------------------------------------------------
# EdgeStream bridges + the NpzFile leak fix
# ----------------------------------------------------------------------------

def test_edgestream_file_bridges(tmp_path, tiny_social):
    edges, n = tiny_social
    stream = EdgeStream(edges, n)
    p = str(tmp_path / "bridge.adw")
    stream.to_file(p)
    back = EdgeStream.from_file(p)
    assert back.num_vertices == n and (back.edges == stream.edges).all()


def test_edgestream_load_owns_arrays(tmp_path, tiny_social):
    """`load` copies out of the NpzFile under a context manager: the handle
    is closed and the returned arrays are owned (mutable, no lazy backing)."""
    edges, n = tiny_social
    p = str(tmp_path / "s.npz")
    EdgeStream(edges, n).save(p)
    loaded = EdgeStream.load(p)
    assert (loaded.edges == EdgeStream(edges, n).edges).all()
    # Owned data: mutating must not raise and must not touch the file.
    loaded.edges[0, 0] = 123
    again = EdgeStream.load(p)
    assert again.edges[0, 0] != 123 or edges[0, 0] == 123


# ----------------------------------------------------------------------------
# Chunked metric accumulation
# ----------------------------------------------------------------------------

def test_chunked_metrics_match_in_memory(graph_file):
    path, edges, n = graph_file
    k = 8
    rng = np.random.default_rng(0)
    assign = rng.integers(0, k, len(edges)).astype(np.int32)
    ref_rep = replica_sets_from_assignment(edges, assign, n, k)
    with EdgeFileReader(path) as r:
        pairs = (
            (chunk, assign[s : s + len(chunk)])
            for s, chunk in zip(range(0, len(edges), 301), r.chunks(301))
        )
        rep = replica_sets_from_chunks(pairs, n, k)
    assert (rep == ref_rep).all()

    with EdgeFileReader(path) as r:
        pairs = (
            (chunk, assign[s : s + len(chunk)])
            for s, chunk in zip(range(0, len(edges), 301), r.chunks(301))
        )
        q = quality_from_chunks(pairs, n, k)
    assert q["replication_degree"] == replication_degree(ref_rep)
    assert q["imbalance"] == partition_balance(assign, k)
    assert q["unassigned"] == 0


def test_chunked_metrics_unassigned_policies(graph_file):
    path, edges, n = graph_file
    k = 4
    assign = np.zeros(len(edges), np.int32)
    assign[::5] = -1
    with EdgeFileReader(path) as r:
        pairs = ((c, assign[s : s + len(c)])
                 for s, c in zip(range(0, len(edges), 200), r.chunks(200)))
        with pytest.raises(ValueError, match="unassigned"):
            replica_sets_from_chunks(pairs, n, k)
    with EdgeFileReader(path) as r:
        pairs = ((c, assign[s : s + len(c)])
                 for s, c in zip(range(0, len(edges), 200), r.chunks(200)))
        q = quality_from_chunks(pairs, n, k, unassigned="drop")
    assert q["unassigned"] == int((assign < 0).sum())


def test_rmat_roundtrip_property():
    """Random R-MAT graphs survive the write→read round trip bit-for-bit."""
    for seed in range(3):
        import tempfile

        edges, n = rmat(8, 500, seed=seed)
        with tempfile.TemporaryDirectory() as td:
            p = os.path.join(td, "r.adw")
            write_edge_file(p, edges, n)
            got, n2 = read_edge_file(p)
            assert n2 == n and (got == edges).all()
