"""Out-of-core I/O subsystem: binary format, text ingest, external shuffle,
EdgeStream bridges, chunked metric accumulation."""
import os
import struct

import numpy as np
import pytest

from repro.graph import (
    EdgeStream,
    make_graph,
    partition_balance,
    quality_from_chunks,
    replica_sets_from_assignment,
    replica_sets_from_chunks,
    replication_degree,
    rmat,
)
from repro.graph.io import (
    HEADER_BYTES,
    MAGIC,
    EdgeFileReader,
    EdgeFileWriter,
    ingest_text,
    read_edge_file,
    shuffle_file,
    write_edge_file,
)

from conftest import random_edges


@pytest.fixture(scope="module")
def graph_file(tmp_path_factory):
    edges, n = make_graph("tiny_social", seed=4)
    path = str(tmp_path_factory.mktemp("io") / "g.adw")
    write_edge_file(path, edges, n)
    return path, edges, n


# ----------------------------------------------------------------------------
# Binary format
# ----------------------------------------------------------------------------

def test_binary_roundtrip(graph_file):
    path, edges, n = graph_file
    with EdgeFileReader(path) as r:
        assert r.num_edges == len(edges)
        assert r.num_vertices == n
        assert (r.read_all() == edges).all()
        # Bounded-chunk iteration reconstructs the stream.
        cat = np.concatenate(list(r.chunks(251)))
        assert (cat == edges).all()
        # Random-access row ranges, clipped at both ends.
        assert (r.read(100, 37) == edges[100:137]).all()
        assert r.read(len(edges) - 3, 100).shape == (3, 2)
        assert r.read(len(edges) + 5, 10).shape == (0, 2)


def test_reader_mmap_mode(graph_file):
    path, edges, _ = graph_file
    with EdgeFileReader(path, mmap=True) as r:
        assert (r.read_all() == edges).all()
        assert (r.read(7, 9) == edges[7:16]).all()


def test_sub_readers_match_split_bounds(graph_file):
    path, edges, n = graph_file
    m = len(edges)
    for z in (1, 3, 7):
        bounds = EdgeStream.split_bounds(m, z)
        with EdgeFileReader(path) as r:
            subs = r.split(z)
            assert len(subs) == z
            for i, s in enumerate(subs):
                lo, hi = int(bounds[i]), int(bounds[i + 1])
                assert s.num_edges == hi - lo
                assert (s.read_all() == edges[lo:hi]).all()
                # Nested sub-ranges address locally.
                if s.num_edges >= 2:
                    assert (s.sub(1, s.num_edges).read_all() == edges[lo + 1 : hi]).all()


def test_reader_io_accounting(graph_file):
    path, edges, _ = graph_file
    with EdgeFileReader(path) as r:
        subs = r.split(2)
        for s in subs:
            for _ in s.chunks(100):
                pass
        # Sub-reader IO flows to the root counters.
        assert r.rows_read == len(edges)
        assert r.read_seconds >= 0.0


def test_writer_streams_and_infers_n(tmp_path):
    path = str(tmp_path / "w.adw")
    rng = np.random.default_rng(0)
    chunks = [random_edges(rng, 50, 40) for _ in range(5)]
    with EdgeFileWriter(path) as w:
        for c in chunks:
            w.append(c)
    all_edges = np.concatenate(chunks)
    got, n = read_edge_file(path)
    assert (got == all_edges).all()
    assert n == int(all_edges.max()) + 1


def test_version_and_magic_rejection(tmp_path):
    header_fmt = "<8sIIQQQ"
    bad_version = str(tmp_path / "v99.adw")
    with open(bad_version, "wb") as f:
        f.write(struct.pack(header_fmt, MAGIC, 99, 1, 0, 0, 0).ljust(HEADER_BYTES, b"\0"))
    with pytest.raises(ValueError, match="version 99"):
        EdgeFileReader(bad_version)

    bad_magic = str(tmp_path / "magic.adw")
    with open(bad_magic, "wb") as f:
        f.write(struct.pack(header_fmt, b"NOTADWSE", 1, 1, 0, 0, 0).ljust(HEADER_BYTES, b"\0"))
    with pytest.raises(ValueError, match="not an ADWISE"):
        EdgeFileReader(bad_magic)

    bad_dtype = str(tmp_path / "dtype.adw")
    with open(bad_dtype, "wb") as f:
        f.write(struct.pack(header_fmt, MAGIC, 1, 7, 0, 0, 0).ljust(HEADER_BYTES, b"\0"))
    with pytest.raises(ValueError, match="dtype"):
        EdgeFileReader(bad_dtype)

    truncated = str(tmp_path / "trunc.adw")
    with open(truncated, "wb") as f:
        f.write(struct.pack(header_fmt, MAGIC, 1, 1, 1000, 10, 0).ljust(HEADER_BYTES, b"\0"))
        f.write(b"\0" * 16)  # 2 rows of payload, header claims 1000
    with pytest.raises(ValueError, match="truncated"):
        EdgeFileReader(truncated)

    short = str(tmp_path / "short.adw")
    with open(short, "wb") as f:
        f.write(b"ADW")
    with pytest.raises(ValueError, match="truncated header"):
        EdgeFileReader(short)


# ----------------------------------------------------------------------------
# Text ingest
# ----------------------------------------------------------------------------

_ADVERSARIAL = """# SNAP-style comment
% matrix-market-style comment
// c-style comment

5\t7
  7   5
3 3
5 7 99 extra fields ignored

\t
9\t2
"""


def test_ingest_adversarial(tmp_path):
    src = str(tmp_path / "adv.txt")
    dst = str(tmp_path / "adv.adw")
    with open(src, "w") as f:
        f.write(_ADVERSARIAL)
    rep = ingest_text(src, dst)
    edges, n = read_edge_file(dst)
    # Self-loop and the duplicate (5,7) are preserved: the file IS the stream.
    expect = np.array([[5, 7], [7, 5], [3, 3], [5, 7], [9, 2]], np.int32)
    assert (edges == expect).all()
    assert n == 10  # max id + 1 inferred
    assert rep.comment_lines == 3
    assert rep.blank_lines == 3  # empty line, whitespace-only line, trailing
    assert rep.num_edges == 5


def test_ingest_relabel_dense_first_appearance(tmp_path):
    src = str(tmp_path / "sparse.txt")
    dst = str(tmp_path / "sparse.adw")
    with open(src, "w") as f:
        f.write("1000000 42\n42 -3\n1000000 7\n")
    with pytest.raises(ValueError, match="negative"):
        ingest_text(src, dst)
    rep = ingest_text(src, dst, relabel=True)
    edges, n = read_edge_file(dst)
    # Dense ids in first-appearance order: 1000000->0, 42->1, -3->2, 7->3.
    assert (edges == np.array([[0, 1], [1, 2], [0, 3]])).all()
    assert n == 4 and rep.num_vertices == 4


def test_ingest_malformed_line_reports_position(tmp_path):
    src = str(tmp_path / "bad.txt")
    dst = str(tmp_path / "bad.adw")
    with open(src, "w") as f:
        f.write("1 2\n# ok\nonly_one_field\n")
    with pytest.raises(ValueError, match=r"bad\.txt:3"):
        ingest_text(src, dst)
    # A failed ingest must not leave a valid-looking truncated binary behind.
    assert not os.path.exists(dst)
    with open(src, "w") as f:
        f.write("1 2\n3 notanint\n")
    with pytest.raises(ValueError, match=r"bad\.txt:2"):
        ingest_text(src, dst)
    assert not os.path.exists(dst)


def test_writer_abort_on_exception(tmp_path):
    path = str(tmp_path / "partial.adw")
    with pytest.raises(RuntimeError):
        with EdgeFileWriter(path) as w:
            w.append(np.array([[0, 1]], np.int32))
            raise RuntimeError("body failed")
    assert not os.path.exists(path)


def test_ingest_chunking_invariance(tmp_path):
    """The chunk_lines bound never changes the output stream."""
    rng = np.random.default_rng(5)
    edges = random_edges(rng, 40, 200)
    src = str(tmp_path / "c.txt")
    with open(src, "w") as f:
        for i, (u, v) in enumerate(edges):
            if i % 17 == 0:
                f.write("# interleaved comment\n")
            f.write(f"{u} {v}\n")
    outs = []
    for chunk_lines in (3, 64, 10_000):
        dst = str(tmp_path / f"c{chunk_lines}.adw")
        ingest_text(src, dst, chunk_lines=chunk_lines)
        outs.append(read_edge_file(dst))
    for got, n in outs:
        assert (got == edges).all()
        assert n == outs[0][1]
    # Relabeled: the incremental id table must give the same global
    # first-appearance mapping for every chunking.
    relabeled = []
    for chunk_lines in (3, 10_000):
        dst = str(tmp_path / f"r{chunk_lines}.adw")
        ingest_text(src, dst, relabel=True, chunk_lines=chunk_lines)
        relabeled.append(read_edge_file(dst))
    assert (relabeled[0][0] == relabeled[1][0]).all()
    assert relabeled[0][1] == relabeled[1][1]
    # And the mapping is first-appearance order: sequential dense ids.
    flat = relabeled[0][0].reshape(-1)
    first_seen = flat[np.sort(np.unique(flat, return_index=True)[1])]
    assert (first_seen == np.arange(relabeled[0][1])).all()


def test_ingest_pinned_num_vertices(tmp_path):
    src = str(tmp_path / "p.txt")
    dst = str(tmp_path / "p.adw")
    with open(src, "w") as f:
        f.write("0 1\n1 2\n")
    ingest_text(src, dst, num_vertices=500)
    _, n = read_edge_file(dst)
    assert n == 500
    # Ids beyond a pinned n fail at ingest time, not at partition time.
    with pytest.raises(ValueError, match="pinned num_vertices"):
        ingest_text(src, dst, num_vertices=2)


# ----------------------------------------------------------------------------
# External shuffle
# ----------------------------------------------------------------------------

def test_shuffle_is_permutation_and_deterministic(graph_file, tmp_path):
    path, edges, n = graph_file
    a = str(tmp_path / "a.adw")
    b = str(tmp_path / "b.adw")
    shuffle_file(path, a, seed=3, chunk_edges=300)
    shuffle_file(path, b, seed=3, chunk_edges=300)
    got_a, n_a = read_edge_file(a)
    got_b, _ = read_edge_file(b)
    assert n_a == n
    assert (got_a == got_b).all(), "same seed must give the same permutation"
    assert got_a.shape == edges.shape
    assert not (got_a == edges).all(), "shuffle must not be the identity"
    order = lambda e: e[np.lexsort((e[:, 1], e[:, 0]))]
    assert (order(got_a) == order(edges)).all(), "rows must be a permutation"
    c = str(tmp_path / "c.adw")
    shuffle_file(path, c, seed=4, chunk_edges=300)
    got_c, _ = read_edge_file(c)
    assert not (got_c == got_a).all(), "different seeds, different permutation"


def test_shuffle_recursive_buckets(graph_file, tmp_path, monkeypatch):
    """With the open-file cap forced to 2, buckets overflow the chunk budget
    and must be re-scattered recursively — still a uniform permutation."""
    import repro.graph.io.shuffle as sh

    monkeypatch.setattr(sh, "_MAX_OPEN", 2)
    path, edges, _ = graph_file
    out = str(tmp_path / "rec.adw")
    shuffle_file(path, out, seed=9, chunk_edges=150)
    got, _ = read_edge_file(out)
    order = lambda e: e[np.lexsort((e[:, 1], e[:, 0]))]
    assert (order(got) == order(edges)).all()
    assert not (got == edges).all()


# ----------------------------------------------------------------------------
# EdgeStream bridges + the NpzFile leak fix
# ----------------------------------------------------------------------------

def test_edgestream_file_bridges(tmp_path, tiny_social):
    edges, n = tiny_social
    stream = EdgeStream(edges, n)
    p = str(tmp_path / "bridge.adw")
    stream.to_file(p)
    back = EdgeStream.from_file(p)
    assert back.num_vertices == n and (back.edges == stream.edges).all()


def test_edgestream_load_owns_arrays(tmp_path, tiny_social):
    """`load` copies out of the NpzFile under a context manager: the handle
    is closed and the returned arrays are owned (mutable, no lazy backing)."""
    edges, n = tiny_social
    p = str(tmp_path / "s.npz")
    EdgeStream(edges, n).save(p)
    loaded = EdgeStream.load(p)
    assert (loaded.edges == EdgeStream(edges, n).edges).all()
    # Owned data: mutating must not raise and must not touch the file.
    loaded.edges[0, 0] = 123
    again = EdgeStream.load(p)
    assert again.edges[0, 0] != 123 or edges[0, 0] == 123


# ----------------------------------------------------------------------------
# Chunked metric accumulation
# ----------------------------------------------------------------------------

def test_chunked_metrics_match_in_memory(graph_file):
    path, edges, n = graph_file
    k = 8
    rng = np.random.default_rng(0)
    assign = rng.integers(0, k, len(edges)).astype(np.int32)
    ref_rep = replica_sets_from_assignment(edges, assign, n, k)
    with EdgeFileReader(path) as r:
        pairs = (
            (chunk, assign[s : s + len(chunk)])
            for s, chunk in zip(range(0, len(edges), 301), r.chunks(301))
        )
        rep = replica_sets_from_chunks(pairs, n, k)
    assert (rep == ref_rep).all()

    with EdgeFileReader(path) as r:
        pairs = (
            (chunk, assign[s : s + len(chunk)])
            for s, chunk in zip(range(0, len(edges), 301), r.chunks(301))
        )
        q = quality_from_chunks(pairs, n, k)
    assert q["replication_degree"] == replication_degree(ref_rep)
    assert q["imbalance"] == partition_balance(assign, k)
    assert q["unassigned"] == 0


def test_chunked_metrics_unassigned_policies(graph_file):
    path, edges, n = graph_file
    k = 4
    assign = np.zeros(len(edges), np.int32)
    assign[::5] = -1
    with EdgeFileReader(path) as r:
        pairs = ((c, assign[s : s + len(c)])
                 for s, c in zip(range(0, len(edges), 200), r.chunks(200)))
        with pytest.raises(ValueError, match="unassigned"):
            replica_sets_from_chunks(pairs, n, k)
    with EdgeFileReader(path) as r:
        pairs = ((c, assign[s : s + len(c)])
                 for s, c in zip(range(0, len(edges), 200), r.chunks(200)))
        q = quality_from_chunks(pairs, n, k, unassigned="drop")
    assert q["unassigned"] == int((assign < 0).sum())


def test_rmat_roundtrip_property():
    """Random R-MAT graphs survive the write→read round trip bit-for-bit."""
    for seed in range(3):
        import tempfile

        edges, n = rmat(8, 500, seed=seed)
        with tempfile.TemporaryDirectory() as td:
            p = os.path.join(td, "r.adw")
            write_edge_file(p, edges, n)
            got, n2 = read_edge_file(p)
            assert n2 == n and (got == edges).all()


# ----------------------------------------------------------------------------
# Vectorized bytes-level ingester vs the per-line parity oracle
# ----------------------------------------------------------------------------


def _ingest_both(tmp_path, content, name="p", newline="", **kw):
    """Run both parsers over the same text; assert identical outcome."""
    src = str(tmp_path / f"{name}.txt")
    with open(src, "w", newline=newline) as f:
        f.write(content)
    outcomes = []
    for parser in ("python", "bytes"):
        dst = str(tmp_path / f"{name}.{parser}.adw")
        try:
            rep = ingest_text(src, dst, parser=parser, **kw)
            outcomes.append(("ok", rep, read_edge_file(dst)))
        except ValueError as e:
            outcomes.append(("err", str(e).replace(src, "SRC"), None))
    (k1, a1, d1), (k2, a2, d2) = outcomes
    assert k1 == k2, f"{content!r}: python={k1} bytes={k2} ({a1} / {a2})"
    if k1 == "err":
        assert a1 == a2, f"{content!r}: error messages diverged"
        return None
    (e1, n1), (e2, n2) = d1, d2
    assert (e1 == e2).all() and n1 == n2, f"{content!r}: binaries diverged"
    for field in ("num_edges", "num_vertices", "lines", "comment_lines",
                  "blank_lines", "bytes_read", "relabeled"):
        assert getattr(a1, field) == getattr(a2, field), (content, field)
    return a2


def test_ingest_bytes_parser_parity(tmp_path):
    """The vectorized parser reproduces the reference parser bit-for-bit on
    every supported shape: comments (all three prefixes, interleaved),
    blanks, tabs/multi-space, trailing fields, CRLF, a missing final
    newline, and negative ids under relabel."""
    rng = np.random.default_rng(11)
    body = []
    for i, (u, v) in enumerate(random_edges(rng, 300, 900)):
        sep = ["\t", " ", "  ", " \t "][i % 4]
        trail = " 7 0" if i % 5 == 0 else ""
        body.append(f"{u}{sep}{v}{trail}")
        if i % 97 == 0:
            body.append("")
        if i % 131 == 0:
            body.append(["# note", "% note", "// note"][i % 3])
    content = "# header\n% header2\n// header3\n" + "\n".join(body) + "\n"
    rep = _ingest_both(tmp_path, content, name="mixed")
    assert rep.comment_lines >= 3 and rep.blank_lines > 0
    # Pure-clean body (tier-0 C tokenizer end to end).
    clean = "\n".join(f"{u} {v}" for u, v in random_edges(rng, 99, 500))
    _ingest_both(tmp_path, clean + "\n", name="clean")
    # CRLF and a file without a trailing newline.
    _ingest_both(tmp_path, "1 2\r\n3 4\r\n5 6", name="crlf")
    # Lone-\r terminators (classic-Mac; text mode treats them as newlines).
    _ingest_both(tmp_path, "1 2\r3 4\r# c\r5 6", name="mac")
    # Signed / exotic-but-int()-valid tokens ride the python fallback.
    _ingest_both(tmp_path, "+1 2\n3 +4\n", name="plus")
    _ingest_both(tmp_path, "-3 -9\n-9 -3\n", name="neg", relabel=True)
    # Empty and comment-only files.
    _ingest_both(tmp_path, "", name="empty")
    _ingest_both(tmp_path, "# a\n\n% b\n", name="comments_only")
    # Valid non-ASCII text (accented comment, unicode NBSP separator —
    # str.split() treats it as whitespace) parses identically.
    _ingest_both(tmp_path, "# café\n1 2\n3 4\n", name="unicode")


def test_ingest_bytes_parser_rejects_invalid_utf8(tmp_path):
    """The text-mode reference decodes the whole file; the bytes parser
    must fail on undecodable bytes exactly like it (not silently ingest)."""
    src = str(tmp_path / "latin1.txt")
    with open(src, "wb") as f:
        f.write(b"# caf\xe9 header\n1 2\n3 4\n")
    for parser in ("python", "bytes"):
        with pytest.raises(UnicodeDecodeError):
            ingest_text(src, str(tmp_path / f"{parser}.adw"), parser=parser)


def test_ingest_bytes_parser_error_parity(tmp_path):
    """Malformed inputs raise the exact reference error from every tier."""
    for i, content in enumerate([
        "1 2\n3\n",                      # too few fields
        "1 2\nx y\n",                    # non-integer
        "1 2\n3 4.5\n",                  # float id
        "-1 5\n",                        # negative without relabel
        "99999999999999999999 1\n",      # > int64 (overflow both parsers)
        "1 2\n- 3\n",                    # lone dash
        "1 2\r3 4\n5 6\nx y\n",          # lone-\r line before the bad line:
                                         # the reported line number must
                                         # count it (universal newlines)
    ]):
        assert _ingest_both(tmp_path, content, name=f"bad{i}") is None


def test_ingest_id_policy_errors_report_exact_line(tmp_path):
    """Negative-id / pinned-n violations point at the offending line itself
    (not a batch or block start), identically for both parsers and any
    batching."""
    lines = [f"{i} {i + 1}" for i in range(100)]
    lines[86] = "5 -7"  # line 87 (1-based)
    src = str(tmp_path / "neg.txt")
    with open(src, "w") as f:
        f.write("\n".join(lines) + "\n")
    for parser, kw in [("python", dict(chunk_lines=30)),
                       ("python", {}),
                       ("bytes", dict(chunk_bytes=256)),
                       ("bytes", {})]:
        with pytest.raises(ValueError, match="near line 87"):
            ingest_text(src, str(tmp_path / "o.adw"), parser=parser, **kw)
    # Multiple violations of different types/magnitudes: the FIRST one in
    # stream order wins, for every parser and every batch/block granularity
    # (argmin/argmax would pick the most extreme value instead, which
    # diverges once the violations straddle a batch boundary).
    lines2 = [f"{i} {i + 1}" for i in range(60)]
    lines2[9] = "5 -1"    # first violation (line 10)
    lines2[44] = "-99 5"  # more extreme, later
    src3 = str(tmp_path / "two.txt")
    with open(src3, "w") as f:
        f.write("\n".join(lines2) + "\n")
    for parser, kw in [("python", dict(chunk_lines=30)), ("python", {}),
                       ("bytes", dict(chunk_bytes=128)), ("bytes", {})]:
        with pytest.raises(ValueError, match="id -1 near line 10"):
            ingest_text(src3, str(tmp_path / "o3.adw"), parser=parser, **kw)
    # Pinned-n violation, with comments/blanks shifting the data-row index.
    content = "# head\n\n10 11\n999 1\n"
    src2 = str(tmp_path / "pin.txt")
    with open(src2, "w") as f:
        f.write(content)
    for parser in ("python", "bytes"):
        with pytest.raises(ValueError, match="near line 4"):
            ingest_text(src2, str(tmp_path / "o2.adw"), parser=parser,
                        num_vertices=100)


def test_ingest_bytes_chunking_invariance(tmp_path):
    """Block boundaries never change the fast parser's output."""
    rng = np.random.default_rng(3)
    edges = random_edges(rng, 50, 400)
    content = "# head\n" + "\n".join(f"{u} {v}" for u, v in edges) + "\n"
    src = str(tmp_path / "blk.txt")
    with open(src, "w") as f:
        f.write(content)
    outs = []
    for cb in (16, 301, 1 << 20):
        dst = str(tmp_path / f"blk{cb}.adw")
        ingest_text(src, dst, parser="bytes", chunk_bytes=cb)
        outs.append(read_edge_file(dst))
    for got, n in outs:
        assert (got == edges).all() and n == outs[0][1]


# ----------------------------------------------------------------------------
# External shuffle: the hard O(chunk) bucket bound
# ----------------------------------------------------------------------------


def test_shuffle_hard_bound_adversarial(tmp_path):
    """An adversarially skewed stream (one dominant edge, sorted tail) with
    a tiny open-file budget must recurse — and every in-memory bucket load
    stays within the hard 2x-chunk bound, proven by the returned report."""
    m, chunk = 6000, 64
    skew = np.zeros((m // 2, 2), np.int32)          # one repeated edge
    tail = np.stack([np.arange(m - m // 2), np.arange(m - m // 2)], 1)
    edges = np.concatenate([skew, tail.astype(np.int32)])
    src = str(tmp_path / "skew.adw")
    write_edge_file(src, edges, int(edges.max()) + 1)
    dst = str(tmp_path / "skew_shuf.adw")
    rep = shuffle_file(src, dst, seed=5, chunk_edges=chunk, max_open=2)
    assert rep.depth >= 2, "tiny max_open must force recursive re-splits"
    assert rep.max_loaded_rows <= rep.bound_rows == 2 * chunk
    got, _ = read_edge_file(dst)
    order = lambda e: e[np.lexsort((e[:, 1], e[:, 0]))]
    assert (order(got) == order(edges)).all()
    assert not (got == edges).all()
    # Deterministic in seed.
    dst2 = str(tmp_path / "skew_shuf2.adw")
    rep2 = shuffle_file(src, dst2, seed=5, chunk_edges=chunk, max_open=2)
    got2, _ = read_edge_file(dst2)
    assert (got == got2).all()
    assert rep2.max_loaded_rows == rep.max_loaded_rows


def test_shuffle_rejects_degenerate_fanout(tmp_path):
    src = str(tmp_path / "x.adw")
    write_edge_file(src, np.zeros((10, 2), np.int32), 1)
    with pytest.raises(ValueError, match="max_open"):
        shuffle_file(src, str(tmp_path / "y.adw"), max_open=1)


def test_shuffle_report_default_path(graph_file, tmp_path):
    path, edges, _ = graph_file
    rep = shuffle_file(path, str(tmp_path / "s.adw"), seed=1, chunk_edges=300)
    assert rep.num_edges == len(edges)
    assert 0 < rep.max_loaded_rows <= rep.bound_rows
    assert rep.buckets >= 1 and rep.depth >= 0
