"""ADWISE core: invariants (property-based), oracle agreement, adaptivity."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdwiseConfig,
    dbh_partition,
    greedy_partition,
    grid_partition,
    hash_partition,
    hdrf_partition,
    partition_stream,
    ref_adwise_partition,
    spotlight_partition,
    spread_mask,
)
from repro.graph import (
    make_graph,
    partition_balance,
    replica_sets_from_assignment,
    replication_degree,
)

from conftest import random_edges


def _rd(edges, assign, n, k):
    return replication_degree(replica_sets_from_assignment(edges, assign, n, k))


# ----------------------------------------------------------------------------
# Property tests: every streaming partitioner's hard invariants
# ----------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n=st.integers(8, 200),
    m=st.integers(1, 400),
    k=st.sampled_from([2, 4, 7, 16]),
)
def test_invariants_adwise_scan(seed, n, m, k):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, n, m)
    if len(edges) == 0:
        return
    cfg = AdwiseConfig(k=k, window_max=16, lazy=True, adapt=True)
    res = partition_stream(edges, n, cfg)
    # Every edge assigned exactly once, to a valid partition.
    assert res.assign.shape == (len(edges),)
    assert (res.assign >= 0).all() and (res.assign < k).all()
    # Hard capacity cap (Eq. 2 guarantee) honoured.
    sizes = np.bincount(res.assign, minlength=k)
    cap = int(np.ceil(cfg.cap_slack * len(edges) / k)) + 1
    assert sizes.max() <= cap
    # Replica sets consistent: every vertex of an edge is in R_v of that part.
    rep = replica_sets_from_assignment(edges, res.assign, n, k)
    for (u, v), p in zip(edges[:50], res.assign[:50]):
        assert rep[u, p] and rep[v, p]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([2, 4, 8]))
def test_invariants_oracle(seed, k):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, 60, 150)
    if len(edges) == 0:
        return
    cfg = AdwiseConfig(k=k, window_max=8)
    res = ref_adwise_partition(edges, 60, cfg)
    assert (res.assign >= 0).all() and (res.assign < k).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_invariants_baselines(seed):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, 100, 300)
    if len(edges) == 0:
        return
    n, k = 100, 8
    for fn in (hdrf_partition, dbh_partition, hash_partition, grid_partition,
               greedy_partition):
        res = fn(edges, n, k)
        assert (res.assign >= 0).all() and (res.assign < k).all()
        assert res.assign.shape == (len(edges),)


# ----------------------------------------------------------------------------
# Quality / semantics
# ----------------------------------------------------------------------------

def test_adwise_beats_single_edge_on_clustered(tiny_graph):
    edges, n = tiny_graph
    k = 8
    cfg = AdwiseConfig(k=k, window_max=64)
    rd_adwise = _rd(edges, partition_stream(edges, n, cfg).assign, n, k)
    rd_hdrf = _rd(edges, hdrf_partition(edges, n, k).assign, n, k)
    rd_dbh = _rd(edges, dbh_partition(edges, n, k).assign, n, k)
    # Paper's headline quality ordering (Fig. 7g-i).
    assert rd_adwise < rd_hdrf < rd_dbh


def test_scan_matches_oracle_quality(tiny_graph):
    """Vectorized scan and sequential Algorithm-1 oracle produce partitionings
    of equivalent quality (identical argmax semantics up to fp tie-breaks)."""
    edges, n = tiny_graph
    edges = edges[:1200]
    cfg = AdwiseConfig(k=4, window_max=16, lazy=False, adapt=False, window_init=16)
    rd_scan = _rd(edges, partition_stream(edges, n, cfg).assign, n, 4)
    rd_ref = _rd(edges, ref_adwise_partition(edges, n, cfg).assign, n, 4)
    assert abs(rd_scan - rd_ref) / rd_ref < 0.03


def test_window_one_is_single_edge_streaming(tiny_graph):
    """w=1, no adaptation ⇒ degenerates to single-edge streaming (≈HDRF-like
    quality, much worse than windowed)."""
    edges, n = tiny_graph
    edges = edges[:2000]
    k = 8
    w1 = AdwiseConfig(k=k, window_max=1, window_init=1, adapt=False,
                      use_clustering=False)
    w64 = AdwiseConfig(k=k, window_max=64, window_init=64, adapt=False)
    rd1 = _rd(edges, partition_stream(edges, n, w1).assign, n, k)
    rd64 = _rd(edges, partition_stream(edges, n, w64).assign, n, k)
    assert rd64 < rd1


def test_larger_window_improves_quality(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:2000]
    k = 8
    rds = []
    for w in (1, 16, 128):
        cfg = AdwiseConfig(k=k, window_max=w, window_init=w, adapt=False)
        rds.append(_rd(edges, partition_stream(edges, n, cfg).assign, n, k))
    assert rds[2] < rds[0]
    assert rds[1] <= rds[0] + 1e-9


def test_adaptive_window_grows_without_budget(tiny_graph):
    edges, n = tiny_graph
    cfg = AdwiseConfig(k=4, window_max=64, window_init=1, adapt=True)
    res = partition_stream(edges[:1500], n, cfg)
    assert res.stats["final_w"] > 1  # (C1)/(C2) grew the window


def test_tight_budget_shrinks_window_to_one():
    """Paper: 'if the latency preference is too tight the algorithm decreases
    w until w=1 — single-edge streaming'. Deterministic via cost model."""
    edges, n = make_graph("tiny_social", seed=3)
    cfg = AdwiseConfig(k=4, window_max=64, window_init=64,
                       latency_budget=1e-9, adapt=True)
    res = partition_stream(edges, n, cfg, cost_per_score=1.0)
    assert res.stats["final_w"] == 1


def test_lazy_traversal_reduces_score_computations(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:1500]
    lazy = AdwiseConfig(k=4, window_max=64, window_init=64, adapt=False, lazy=True)
    full = dataclasses.replace(lazy, lazy=False)
    r_lazy = partition_stream(edges, n, lazy)
    r_full = partition_stream(edges, n, full)
    assert r_lazy.stats["score_rows"] < 0.5 * r_full.stats["score_rows"]
    # ...at a bounded quality cost.
    rd_l = _rd(edges, r_lazy.assign, n, 4)
    rd_f = _rd(edges, r_full.assign, n, 4)
    assert rd_l < rd_f * 1.25


# ----------------------------------------------------------------------------
# Spotlight (§III-D)
# ----------------------------------------------------------------------------

def test_spread_mask_partition_of_partitions():
    k, z = 32, 8
    masks = [spread_mask(k, z, i, k // z) for i in range(z)]
    stacked = np.stack(masks)
    assert (stacked.sum(axis=0) == 1).all()  # disjoint cover


def test_spotlight_respects_spread(tiny_graph):
    edges, n = tiny_graph
    k, z, spread = 16, 4, 4
    res = spotlight_partition(edges, n, k, z=z, spread=spread, strategy="hdrf")
    m = len(edges)
    from repro.graph.stream import EdgeStream
    bounds = EdgeStream.split_bounds(m, z)
    for i in range(z):
        allowed = np.flatnonzero(spread_mask(k, z, i, spread))
        got = np.unique(res.assign[bounds[i]:bounds[i + 1]])
        assert set(got) <= set(allowed)


@pytest.mark.parametrize("strategy", ["hdrf", "dbh"])
def test_spotlight_improves_replication(tiny_graph, strategy):
    """Paper Fig. 8: smaller spread ⇒ lower replication degree, any strategy."""
    edges, n = tiny_graph
    k, z = 32, 8
    rd_full = _rd(edges, spotlight_partition(
        edges, n, k, z=z, spread=k, strategy=strategy).assign, n, k)
    rd_spot = _rd(edges, spotlight_partition(
        edges, n, k, z=z, spread=k // z, strategy=strategy).assign, n, k)
    assert rd_spot < rd_full
    # Balance is preserved under equal chunks.
    res = spotlight_partition(edges, n, k, z=z, spread=k // z, strategy=strategy)
    assert partition_balance(res.assign, k) < 0.5
