"""repro.compat (JAX portability) and the partitioner registry."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import available_strategies, get_partitioner, run_partitioner
from repro.core.registry import register
from repro.engine.gas import engine_mesh
from repro.kernels import ops

from conftest import random_edges

ALL_STRATEGIES = ["2ps", "2ps-l", "adwise", "adwise-restream", "dbh",
                  "greedy", "grid", "hash", "hdrf"]


# ----------------------------------------------------------------------------
# shard_map resolution / kwarg adaptation
# ----------------------------------------------------------------------------

def test_shard_map_resolves_on_installed_jax():
    """Exactly one of the two homes exists and compat found it."""
    if hasattr(jax, "shard_map"):
        assert compat.SHARD_MAP_ORIGIN == "jax.shard_map"
    else:
        assert compat.SHARD_MAP_ORIGIN == "jax.experimental.shard_map.shard_map"
    assert compat.REP_CHECK_KWARG in ("check_vma", "check_rep", None)


def test_shard_map_runs_psum():
    mesh = engine_mesh(n_devices=1)
    f = compat.shard_map(
        lambda x: jax.lax.psum(x.sum(keepdims=True), "parts"),
        mesh=mesh, in_specs=P("parts"), out_specs=P(),
        check_replication=False,
    )
    out = f(jnp.arange(4, dtype=jnp.float32))
    assert float(out[0]) == 6.0


def test_shard_map_rejects_wrong_rep_kwarg_directly():
    """The raw shard_map really does NOT accept the other version's kwarg —
    i.e. the adaptation compat performs is load-bearing, not decorative."""
    if compat.REP_CHECK_KWARG is None:
        pytest.skip("installed shard_map exposes no replication-check kwarg")
    wrong = "check_rep" if compat.REP_CHECK_KWARG == "check_vma" else "check_vma"
    mesh = engine_mesh(n_devices=1)
    with pytest.raises(TypeError):
        compat._SHARD_MAP_RAW(
            lambda x: x, mesh=mesh, in_specs=P(), out_specs=P(), **{wrong: False}
        )


# ----------------------------------------------------------------------------
# make_mesh / engine_mesh
# ----------------------------------------------------------------------------

def test_make_mesh_fallback_without_jax_make_mesh(monkeypatch):
    monkeypatch.delattr(jax, "make_mesh", raising=False)
    mesh = compat.make_mesh((1,), ("parts",))
    assert mesh.axis_names == ("parts",)
    assert mesh.devices.shape == (1,)


def test_engine_mesh_single_device():
    mesh = engine_mesh(n_devices=1)
    assert mesh.axis_names == ("parts",)
    assert mesh.devices.size == 1


def test_engine_mesh_k_exceeding_devices():
    """engine_mesh keeps every device for any k (make_superstep pads the
    parts axis); it only caps the mesh at k when devices outnumber parts."""
    import jax as _jax

    n_dev = _jax.device_count()
    for k in (3, 7, 8, 16):
        mesh = engine_mesh(k=k)
        assert mesh.devices.size == min(n_dev, k)
    assert engine_mesh(k=1).devices.size == 1


@pytest.mark.slow
def test_engine_multi_device_cpu_mesh():
    """Full engine correctness on a forced 6-device CPU host (subprocess so
    the device count does not leak into this process)."""
    prog = textwrap.dedent("""
        import numpy as np, jax
        assert jax.device_count() == 6, jax.device_count()
        from repro.engine.gas import engine_mesh
        from repro.engine import build_partitioned_graph, pagerank
        from repro.core import run_partitioner
        # All devices stay in the mesh; non-divisible k pads inside
        # make_superstep (k=9 on 6 devices -> parts axis pads 9 -> 12).
        assert engine_mesh(k=9).devices.size == 6
        assert engine_mesh(k=6).devices.size == 6
        assert engine_mesh(k=4).devices.size == 4  # capped at k
        rng = np.random.default_rng(0)
        u, v = rng.integers(0, 40, 300), rng.integers(0, 40, 300)
        keep = u != v
        edges = np.stack([u[keep], v[keep]], 1).astype(np.int32)
        n, k = 40, 9
        res = run_partitioner("hdrf", edges, n, k)
        g = build_partitioned_graph(edges, res.assign, n, k)
        pr, _ = pagerank(g, iters=5)
        deg = np.zeros(n)
        np.add.at(deg, edges[:, 0], 1); np.add.at(deg, edges[:, 1], 1)
        x = np.full(n, 1.0 / n)
        for _ in range(5):
            acc = np.zeros(n)
            np.add.at(acc, edges[:, 1], x[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
            np.add.at(acc, edges[:, 0], x[edges[:, 1]] / np.maximum(deg[edges[:, 1]], 1))
            x = 0.15 / n + 0.85 * acc
        np.testing.assert_allclose(pr, x, rtol=1e-4, atol=1e-7)
        print("MULTIDEV_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.abspath("src"), env.get("PYTHONPATH")] if p
    )
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "MULTIDEV_OK" in out.stdout


# ----------------------------------------------------------------------------
# Pallas probe
# ----------------------------------------------------------------------------

def test_pallas_probe_consistent_with_resolver(monkeypatch):
    monkeypatch.delenv(ops.KERNEL_TIER_ENV, raising=False)
    for op in ("window_score", "segment_sum", "flash_attention"):
        tiers = ops.available_tiers(op)
        assert tiers[-1] == "xla"
        resolved = ops.resolve_tier(op)
        assert resolved in tiers  # in particular: never 'interpret'
    if jax.default_backend() != "tpu":
        assert compat.pallas_interpret()
        assert "pallas-tpu" not in ops.available_tiers("window_score")
        # pallas-cpu exists only where JAX can genuinely lower on CPU.
        if not compat.has_pallas_cpu_lowering():
            assert ops.available_tiers("window_score") == ("xla",)
            assert ops.resolve_tier("window_score") == "xla"
    # Legacy alias from the impl= era still resolves.
    assert ops.resolve_tier("window_score", "ref") == "xla"


def test_pallas_cpu_lowering_probe_is_cached_and_boolean():
    first = compat.has_pallas_cpu_lowering()
    assert isinstance(first, bool)
    assert compat.has_pallas_cpu_lowering() is first
    if not compat.HAS_PALLAS:
        assert first is False


# ----------------------------------------------------------------------------
# Partitioner registry
# ----------------------------------------------------------------------------

def test_registry_lists_all_builtin_strategies():
    assert available_strategies() == ALL_STRATEGIES


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_registry_round_trip(strategy):
    rng = np.random.default_rng(7)
    edges = random_edges(rng, 60, 250)
    n, k = 60, 5
    cfg = (dict(window_max=16)
           if strategy in ("adwise", "adwise-restream", "2ps") else {})
    res = run_partitioner(strategy, edges, n, k, seed=3, **cfg)
    assert res.assign.shape == (len(edges),)
    assert res.assign.dtype == np.int32
    assert (res.assign >= 0).all() and (res.assign < k).all()
    assert res.stats.get("k") == k
    # Same name through get_partitioner is the same callable result.
    res2 = get_partitioner(strategy)(edges, n, k, seed=3, **cfg)
    np.testing.assert_array_equal(res.assign, res2.assign)


def test_registry_unknown_strategy_names_available():
    with pytest.raises(KeyError, match="hdrf"):
        get_partitioner("metis")


def test_registry_rejects_unknown_adwise_cfg():
    edges = np.array([[0, 1]], np.int32)
    with pytest.raises(TypeError, match="window_maxx"):
        run_partitioner("adwise", edges, 2, 2, window_maxx=8)


def test_registry_rejects_duplicate_name():
    with pytest.raises(ValueError, match="already registered"):
        register("hdrf")(lambda *a, **kw: None)


def test_partition_cli_accepts_every_registry_strategy():
    from repro.launch.partition import main

    for strategy in available_strategies():
        out = main(["--graph", "tiny_clustered", "--strategy", strategy,
                    "--k", "4", "--workload", "none", "--window-max", "16"])
        assert out["strategy"] == strategy
        assert out["replication_degree"] >= 1.0
