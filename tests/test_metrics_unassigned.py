"""Unassigned-edge (-1) handling in graph/metrics: raise or report, never
silently mis-count.

Historical corruption this pins down: `np.bincount` raises on negatives
(so `partition_sizes` crashed on any in-flight assignment), while bool
fancy-indexing with -1 *wraps* to the last column (so
`replica_sets_from_assignment` silently attributed unassigned edges to
partition k-1, skewing replication degree and balance).
"""
import numpy as np
import pytest

from repro.graph import (
    partition_balance,
    partition_sizes,
    replica_sets_from_assignment,
    replication_degree,
    unassigned_count,
)

EDGES = np.array([[0, 1], [1, 2], [2, 3], [3, 0]], dtype=np.int32)
K = 4


def test_unassigned_count():
    assert unassigned_count(np.array([0, 1, -1, 2, -1])) == 2
    assert unassigned_count(np.array([], dtype=np.int32)) == 0
    assert unassigned_count(np.array([0, 1, 2])) == 0


def test_partition_sizes_raises_on_unassigned():
    assign = np.array([0, 1, -1, 2], dtype=np.int32)
    with pytest.raises(ValueError, match="unassigned"):
        partition_sizes(assign, K)


def test_partition_sizes_drop_counts_assigned_only():
    assign = np.array([0, 1, -1, 1], dtype=np.int32)
    sizes = partition_sizes(assign, K, unassigned="drop")
    assert sizes.tolist() == [1, 2, 0, 0]
    assert sizes.sum() == len(assign) - unassigned_count(assign)


def test_replica_sets_raises_on_unassigned():
    assign = np.array([0, 1, -1, 2], dtype=np.int32)
    with pytest.raises(ValueError, match="unassigned"):
        replica_sets_from_assignment(EDGES, assign, 4, K)


def test_replica_sets_drop_does_not_wrap_into_last_partition():
    # Edge (2, 3) is unassigned; previously its endpoints were silently
    # replicated onto partition K-1 via -1 fancy-index wraparound.
    assign = np.array([0, 0, -1, 0], dtype=np.int32)
    rep = replica_sets_from_assignment(EDGES, assign, 4, K, unassigned="drop")
    assert not rep[:, K - 1].any()
    # The assigned edges still produce their replicas.
    assert rep[0, 0] and rep[1, 0] and rep[2, 0] and rep[3, 0]
    # Full replication degree reflects only assigned edges (1 replica each).
    assert replication_degree(rep) == 1.0


def test_partition_balance_policies():
    assign = np.array([0, 0, 1, -1], dtype=np.int32)
    with pytest.raises(ValueError, match="unassigned"):
        partition_balance(assign, 2)
    # Over the assigned subset: sizes (2, 1) -> (2-1)/2.
    assert partition_balance(assign, 2, unassigned="drop") == pytest.approx(0.5)


def test_out_of_range_partition_id_raises():
    assign = np.array([0, 1, K, 0], dtype=np.int32)
    with pytest.raises(ValueError, match=">= k"):
        partition_sizes(assign, K)
    with pytest.raises(ValueError, match=">= k"):
        replica_sets_from_assignment(EDGES, assign, 4, K)


def test_bad_policy_rejected():
    with pytest.raises(ValueError, match="policy"):
        partition_sizes(np.array([0]), K, unassigned="ignore")


def test_all_unassigned_drop_is_empty_not_corrupt():
    assign = np.full(4, -1, dtype=np.int32)
    assert partition_sizes(assign, K, unassigned="drop").sum() == 0
    rep = replica_sets_from_assignment(EDGES, assign, 4, K, unassigned="drop")
    assert not rep.any()
    assert replication_degree(rep) == 0.0
    assert partition_balance(assign, K, unassigned="drop") == 0.0
