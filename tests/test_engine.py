"""Vertex-cut engine: algorithms vs oracles; latency model properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hdrf_partition, hash_partition
from repro.engine import (
    PAPER_CLUSTER,
    build_partitioned_graph,
    coloring,
    label_propagation,
    pagerank,
    process_latency,
    triangle_count,
)
from repro.graph import make_graph, replica_sets_from_assignment, replication_degree

from conftest import random_edges


def _partitioned(edges, n, k=4, seed=0):
    res = hdrf_partition(edges, n, k, seed=seed)
    return build_partitioned_graph(edges, res.assign, n, k)


def _pagerank_oracle(edges, n, iters, damping=0.85):
    deg = np.zeros(n)
    np.add.at(deg, edges[:, 0], 1)
    np.add.at(deg, edges[:, 1], 1)
    x = np.full(n, 1.0 / n)
    for _ in range(iters):
        acc = np.zeros(n)
        np.add.at(acc, edges[:, 1], x[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
        np.add.at(acc, edges[:, 0], x[edges[:, 1]] / np.maximum(deg[edges[:, 1]], 1))
        x = (1 - damping) / n + damping * acc
    return x


def _wcc_oracle(edges, n):
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for u, v in edges:
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[ru] = rv
    return {find(v) for v in np.unique(edges)}


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), k=st.sampled_from([2, 4, 8]))
def test_pagerank_matches_oracle(seed, k):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, 80, 300)
    if len(edges) == 0:
        return
    g = _partitioned(edges, 80, k, seed)
    pr, _ = pagerank(g, iters=8)
    expect = _pagerank_oracle(edges, 80, 8)
    np.testing.assert_allclose(pr, expect, rtol=1e-4, atol=1e-7)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_wcc_matches_union_find(seed):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, 120, 100)
    if len(edges) == 0:
        return
    g = _partitioned(edges, 120, 4, seed)
    cc, _ = label_propagation(g, max_iters=128)
    present = np.unique(edges)
    assert len(np.unique(cc[present])) == len(_wcc_oracle(edges, 120))
    # Endpoints of every edge share a component label.
    assert (cc[edges[:, 0]] == cc[edges[:, 1]]).all()


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_coloring_is_proper(seed):
    rng = np.random.default_rng(seed)
    edges = random_edges(rng, 60, 200)
    if len(edges) == 0:
        return
    g = _partitioned(edges, 60, 4, seed)
    col, info = coloring(g, max_colors=64)
    e = edges[edges[:, 0] != edges[:, 1]]
    assert (col[e[:, 0]] != col[e[:, 1]]).all()


def test_triangles_exact(tiny_graph):
    edges, n = tiny_graph
    g = _partitioned(edges, n, 4)
    got, _ = triangle_count(g, sketch_bits=-(-n // 128) * 128)
    adj = [set() for _ in range(n)]
    for u, v in edges:
        if u != v:
            adj[u].add(v)
            adj[v].add(u)
    expect = sum(len(adj[u] & adj[v]) for u, v in edges if u != v) // 3
    assert got == expect


def test_partition_quality_drives_modeled_latency(tiny_graph):
    """The engine cost model must preserve the paper's causal chain:
    lower replication degree ⇒ lower sync traffic ⇒ lower processing
    latency."""
    edges, n = tiny_graph
    k = 16
    g_good = build_partitioned_graph(edges, hdrf_partition(edges, n, k).assign, n, k)
    g_bad = build_partitioned_graph(edges, hash_partition(edges, n, k).assign, n, k)
    assert g_good.replication_degree < g_bad.replication_degree
    m_good = process_latency(g_good, 100, 1, PAPER_CLUSTER)
    m_bad = process_latency(g_bad, 100, 1, PAPER_CLUSTER)
    assert m_good["t_total_s"] < m_bad["t_total_s"]
    assert m_good["sync_bytes_per_step"] < m_bad["sync_bytes_per_step"]


def test_partition_latency_overlap_billing():
    """`partition_latency` prefers the measured refill stall over the
    modeled h2d transfer when refills actually ran, and bills
    max(compute, io, h2d) instead of the sum once the prefetch pipeline
    is active."""
    from repro.engine.latency_model import (
        EDGE_IO_COST_S,
        H2D_BW_BPS,
        SCORE_COST_S,
        partition_latency,
    )

    m, k = 10_000, 8
    base = dict(score_rows=m, stream_reads=1, h2d_bytes=m * 8)
    compute = m * k * SCORE_COST_S
    io = m * EDGE_IO_COST_S
    # No refills ran (resident upload): modeled transfer, additive model.
    modeled = m * 8 / H2D_BW_BPS
    lat = partition_latency(dict(base, h2d_wait_s=0.0, refill_spans=0), m, k)
    assert lat == pytest.approx(compute + io + modeled)
    # Ring refills ran: the measured stall replaces the modeled transfer.
    lat = partition_latency(
        dict(base, h2d_wait_s=0.5, refill_spans=7, prefetch_depth=0), m, k
    )
    assert lat == pytest.approx(compute + io + 0.5)
    # Pipeline active: overlap-aware max() — the dominant term wins alone.
    lat = partition_latency(
        dict(base, h2d_wait_s=0.5, refill_spans=7, prefetch_depth=2), m, k
    )
    assert lat == pytest.approx(max(compute, io, 0.5))


def test_replication_degree_bounds(tiny_graph):
    edges, n = tiny_graph
    k = 8
    res = hdrf_partition(edges, n, k)
    rep = replica_sets_from_assignment(edges, res.assign, n, k)
    rd = replication_degree(rep)
    assert 1.0 <= rd <= k
