"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import sys

# Offline fallback: when `hypothesis` is not installed (the no-network CI
# container), serve the vendored seeded-sampling shim under its name so the
# property-test modules collect and run unchanged.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _propcheck

    sys.modules["hypothesis"] = _propcheck
    sys.modules["hypothesis.strategies"] = _propcheck.strategies

import numpy as np
import pytest

from repro.graph import make_graph


@pytest.fixture(autouse=True)
def _hermetic_kernel_autotune(tmp_path, monkeypatch):
    """Point the kernel-tier autotune cache at a per-test path and drop the
    in-process memo, so a developer machine's accumulated table (or another
    test's recordings) can never leak measured walls into assertions — e.g.
    `partition_latency` expectations computed from SCORE_COST_S."""
    from repro.kernels import ops

    monkeypatch.setenv(ops.AUTOTUNE_CACHE_ENV,
                       str(tmp_path / "kernel_tiers.json"))
    ops.clear_tier_cache()
    yield
    ops.clear_tier_cache()


@pytest.fixture(scope="session")
def tiny_graph():
    edges, n = make_graph("tiny_clustered", seed=1)
    return edges, n


@pytest.fixture(scope="session")
def tiny_social():
    edges, n = make_graph("tiny_social", seed=2)
    return edges, n


def random_edges(rng: np.random.Generator, n: int, m: int) -> np.ndarray:
    u = rng.integers(0, n, m)
    v = rng.integers(0, n, m)
    keep = u != v
    return np.stack([u[keep], v[keep]], axis=1).astype(np.int32)
