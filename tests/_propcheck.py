"""Vendored no-network fallback for `hypothesis`.

The offline CI container has no `hypothesis` package, but the property-test
modules are written against its API. This shim implements the small subset
they use — `given` / `settings` / `strategies` (integers, sampled_from,
booleans, floats, just) plus `assume` — backed by seeded random sampling, so
the same invariants run (deterministically) with or without the real
library. `tests/conftest.py` installs it into `sys.modules["hypothesis"]`
only when the real package is missing.

Semantics: `@given(name=strategy, ...)` draws `max_examples` (default 20,
settable via `@settings(max_examples=N)`) independent examples per test from
an RNG seeded by the test's qualified name, and runs the test body once per
example. There is no shrinking — on failure the pytest error message carries
the drawn arguments instead.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__all__ = ["given", "settings", "strategies", "assume", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 20


class _Unsatisfied(Exception):
    """Raised by assume(False): skip this example, draw another."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Strategy:
    def draw(self, rng: random.Random):  # pragma: no cover - interface
        raise NotImplementedError

    def map(self, fn):
        outer = self

        class _Mapped(_Strategy):
            def draw(self, rng):
                return fn(outer.draw(rng))

        return _Mapped()


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = -(2**31) if min_value is None else int(min_value)
        self.hi = 2**31 - 1 if max_value is None else int(max_value)

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from requires a non-empty collection")

    def draw(self, rng):
        return rng.choice(self.elements)


class _Booleans(_Strategy):
    def draw(self, rng):
        return rng.random() < 0.5


class _Floats(_Strategy):
    def __init__(self, min_value=0.0, max_value=1.0, **_):
        self.lo = float(min_value)
        self.hi = float(max_value)

    def draw(self, rng):
        return rng.uniform(self.lo, self.hi)


class _Just(_Strategy):
    def __init__(self, value):
        self.value = value

    def draw(self, rng):
        return self.value


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=10, **_):
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def draw(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.draw(rng) for _ in range(n)]


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _Integers
strategies.sampled_from = _SampledFrom
strategies.booleans = _Booleans
strategies.floats = _Floats
strategies.just = _Just
strategies.lists = _Lists


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    """Decorator: records max_examples on the test (deadline etc. ignored)."""

    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn

    return deco


# Accepted-and-ignored attribute so `suppress_health_check=[...]` parses.
HealthCheck = types.SimpleNamespace(
    too_slow="too_slow", data_too_large="data_too_large", filter_too_much="filter_too_much"
)


def given(*args, **strategy_kwargs):
    """Decorator: run the test once per drawn example (kwargs style only)."""
    if args:
        raise TypeError("propcheck given() supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*call_args, **call_kwargs):
            max_examples = getattr(
                wrapper, "_propcheck_max_examples", _DEFAULT_MAX_EXAMPLES
            )
            base = zlib.crc32(fn.__qualname__.encode())
            drawn = None
            attempts = 0
            ran = 0
            while ran < max_examples and attempts < max_examples * 50:
                rng = random.Random(base * 1_000_003 + attempts)
                drawn = {k: s.draw(rng) for k, s in strategy_kwargs.items()}
                attempts += 1
                try:
                    fn(*call_args, **call_kwargs, **drawn)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"propcheck example #{ran} failed with drawn args "
                        f"{drawn!r}: {e}"
                    ) from e
                ran += 1
            if ran < max_examples:
                # Mirror hypothesis' filter_too_much health check: never let
                # an over-restrictive assume() pass a test vacuously.
                raise AssertionError(
                    f"propcheck: only {ran}/{max_examples} examples satisfied "
                    f"assume() after {attempts} attempts"
                )

        # Hide the drawn parameters from pytest's fixture resolution while
        # keeping any real fixtures the test also takes.
        sig = inspect.signature(fn)
        params = [p for p in sig.parameters.values() if p.name not in strategy_kwargs]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return deco
