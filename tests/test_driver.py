"""Unified streaming-scan driver (`repro.core.driver`): ring-buffer
invariants, bit-parity between the device-resident ring (file) path and the
resident full-upload path, the host→device traffic accounting, and the
double-buffered refill pipeline (read-ahead worker determinism/teardown)."""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AdwiseConfig,
    partition_file,
    run_partitioner,
    spotlight_partition,
)
from repro.core.adwise import partition_stream
from repro.core.driver import (
    FileSource,
    ResidentSource,
    ScanDriver,
    resolve_backend,
    resolve_prefetch,
)
from repro.graph import rmat
from repro.graph.io import EdgeFileReader, write_edge_file

K = 8


@pytest.fixture(scope="module")
def rmat_file(tmp_path_factory):
    edges, n = rmat(8, 1100, seed=21)
    td = tmp_path_factory.mktemp("driver")
    path = str(td / "rmat.adw")
    write_edge_file(path, edges, n)
    return path, edges, n


# ----------------------------------------------------------------------------
# FileSource sizing / refill invariants
# ----------------------------------------------------------------------------


def test_file_source_sizing(rmat_file):
    path, edges, n = rmat_file
    for chunk, wmax, b in [(64, 8, 1), (400, 8, 2), (100, 16, 4), (7, 4, 1)]:
        cfg = AdwiseConfig(k=K, window_max=wmax, assign_batch=b)
        with EdgeFileReader(path) as r:
            src = FileSource([r], chunk_edges=chunk, cfg=cfg)
            f = wmax + src.scan_steps * b
            assert src.B % src.Rq == 0
            # Quantized refills always leave >= F consumable rows ahead.
            assert src.B >= f + src.Rq - 1
            assert src.Rq & (src.Rq - 1) == 0  # power of two
            # Single reads never exceed the caller's chunk bound.
            assert src.max_span <= max(chunk, wmax + b)
            assert src.max_span % src.Rq == 0 or src.max_span == src.Rq


def test_file_source_refill_overrun_guard(rmat_file):
    """A cursor past the uploaded high-water mark is a bug, not a refill."""
    path, _, n = rmat_file
    cfg = AdwiseConfig(k=K, window_max=8)
    with EdgeFileReader(path) as r:
        with FileSource([r], chunk_edges=100, cfg=cfg) as src:
            buf = src.alloc()
            buf = src.refill(buf, np.zeros(1, np.int64))
            with pytest.raises(AssertionError, match="overran"):
                src.refill(buf, np.array([int(src.hi[0]) + 1], np.int64))


def test_driver_direct_ring_run(rmat_file):
    """Drive ScanDriver over a FileSource by hand: parity with the resident
    path, cursors land exactly on the uploaded high-water mark, and every
    stream row ships to the device exactly once."""
    path, edges, n = rmat_file
    m = len(edges)
    cfg = AdwiseConfig(k=K, window_max=8)
    ref = partition_stream(edges, n, cfg)
    assign = np.full((m,), -1, np.int32)

    def on_assign(i, idx, p):
        assign[idx] = p

    with EdgeFileReader(path) as r:
        src = FileSource([r], chunk_edges=150, cfg=cfg)
        drv = ScanDriver(src, cfg, n)
        res = drv.run(on_assign=on_assign)
        assert (src.hi == m).all()  # no over- or under-upload
    assert (assign == ref.assign).all()
    assert int(res.assigned[0]) == m
    assert res.h2d_rows == m  # each row shipped exactly once
    assert res.h2d_bytes == m * 8  # no prev-pass buffer on a cold pass
    assert res.buffer_rows == src.B


# ----------------------------------------------------------------------------
# Property: ring path == full-upload path over random geometry
# ----------------------------------------------------------------------------


@settings(max_examples=6)
@given(
    chunk=st.integers(min_value=48, max_value=500),
    wmax=st.sampled_from([4, 8]),
    b=st.sampled_from([1, 2]),
    z=st.sampled_from([1, 2, 4]),
)
def test_ring_parity_property(rmat_file, tmp_path_factory, chunk, wmax, b, z):
    """For random (chunk_edges, window_max, assign_batch, z): the ring-buffer
    file path assigns bit-identically to the in-memory path, never overruns
    the refill cursor (asserted inside FileSource), and ships each stream
    row once."""
    path, edges, n = rmat_file
    m = len(edges)
    cfg = dict(window_max=wmax, assign_batch=b)
    if z == 1:
        ref = run_partitioner("adwise", edges, n, K, seed=0, **cfg)
    else:
        ref = spotlight_partition(
            edges, n, K, z=z, spread=max(1, K // z), strategy="adwise",
            cfg=AdwiseConfig(k=K, seed=0, **cfg),
        )
    td = tmp_path_factory.mktemp("ringprop")
    with EdgeFileReader(path) as r:
        res = partition_file(
            r, "adwise", K, z=z, spread=max(1, K // z) if z > 1 else None,
            seed=0, chunk_edges=chunk, spill_dir=str(td), **cfg,
        )
    assert (np.asarray(res.assign) == ref.assign).all(), (
        f"ring diverged at chunk={chunk} wmax={wmax} b={b} z={z}"
    )
    assert res.stats["h2d_rows"] == m, "each row must ship exactly once"
    assert res.stats["h2d_bytes"] == m * 8
    if res.stats["scan_calls"] >= 2:
        # The point of the ring: per-call traffic is the refill, not the
        # full buffer re-upload (z * B rows per call).
        full_upload = res.stats["scan_calls"] * z * res.stats["buffer_rows"]
        assert res.stats["h2d_rows"] < full_upload


def test_restream_ring_h2d_accounting(rmat_file, tmp_path):
    """Re-streaming from disk: pass 1 ships (u, v) rows only; pass 2 also
    ships the prior pass's placements (4 more bytes per row) for buffered
    revocation — and still matches the in-memory restream bit for bit.

    With chunk_edges < m the ring wraps, so pass 2 must re-ship the uv rows
    (the cross-pass resume only adopts never-wrapped rings)."""
    path, edges, n = rmat_file
    m = len(edges)
    cfg = dict(window_max=8, passes=2)
    ref = run_partitioner("adwise-restream", edges, n, K, seed=0, **cfg)
    with EdgeFileReader(path) as r:
        res = partition_file(r, "adwise-restream", K, seed=0, chunk_edges=200,
                             spill_dir=str(tmp_path), **cfg)
    assert (np.asarray(res.assign) == ref.assign).all()
    assert res.stats["h2d_rows"] == 2 * m
    assert res.stats["h2d_bytes"] == m * 8 + m * 12
    # In-memory restream reuses the uploaded device stream across passes
    # (StreamResidency): one uv upload total; every resident pass still
    # ships its (m,) prev table (pass 1's is the all -1 cold table).
    assert ref.stats["h2d_rows"] == m
    assert ref.stats["h2d_bytes"] == m * 8 + 2 * m * 4


def test_restream_ring_cross_pass_resume(rmat_file, tmp_path):
    """chunk_edges >= m keeps the whole stream ring-resident, so pass 2
    adopts pass 1's donated ring (RingHandle) and ships ONLY the 4 B/row
    prev table: h2d drops from 8m + 12m to 8m + 4m — bit-identically."""
    path, edges, n = rmat_file
    m = len(edges)
    cfg = dict(window_max=8, passes=2)
    ref = run_partitioner("adwise-restream", edges, n, K, seed=0, **cfg)
    with EdgeFileReader(path) as r:
        res = partition_file(r, "adwise-restream", K, seed=0,
                             chunk_edges=2048, spill_dir=str(tmp_path), **cfg)
    assert (np.asarray(res.assign) == ref.assign).all()
    assert res.stats["h2d_rows"] == m  # uv shipped once, pass 2 prev-only
    assert res.stats["h2d_bytes"] == m * 8 + m * 4
    assert (res.stats["spans_prestaged"] + res.stats["spans_missed"]
            == res.stats["refill_spans"])


# ----------------------------------------------------------------------------
# Double-buffered refill pipeline (read-ahead worker)
# ----------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(
    chunk=st.integers(min_value=48, max_value=500),
    wmax=st.sampled_from([4, 8]),
    b=st.sampled_from([1, 2]),
    z=st.sampled_from([1, 2]),
    depth=st.sampled_from([1, 3]),
)
def test_prefetch_determinism_property(
    rmat_file, tmp_path_factory, chunk, wmax, b, z, depth
):
    """The refill pipeline is a pure latency optimization: for random
    (chunk_edges, window_max, assign_batch, z, prefetch_depth) and jittered
    worker read timing, the pipelined run assigns bit-identically to the
    synchronous (prefetch=0) run and to the in-memory path, and every refill
    span is accounted exactly once (prestaged XOR missed)."""
    path, edges, n = rmat_file
    m = len(edges)
    cfg = dict(window_max=wmax, assign_batch=b)
    if z == 1:
        ref = run_partitioner("adwise", edges, n, K, seed=0, **cfg)
    else:
        ref = spotlight_partition(
            edges, n, K, z=z, spread=max(1, K // z), strategy="adwise",
            cfg=AdwiseConfig(k=K, seed=0, **cfg),
        )
    td = tmp_path_factory.mktemp("pfprop")
    outs = {}
    from repro.graph.io.format import EdgeFileReader as _R
    from repro.graph.io.format import EdgeFileSubReader as _SR
    for pf in (0, depth):
        jitter = {}
        if pf:  # delays land inside the read-ahead worker thread
            jitter = {
                _R: ("read", _R.read), _SR: ("read", _SR.read),
            }
            for klass, (name, orig) in jitter.items():
                def slow(self, start, count, _orig=orig):
                    time.sleep(((start // 64) % 3) * 5e-4)
                    return _orig(self, start, count)
                setattr(klass, name, slow)
        try:
            with EdgeFileReader(path) as r:
                res = partition_file(
                    r, "adwise", K, z=z,
                    spread=max(1, K // z) if z > 1 else None, seed=0,
                    chunk_edges=chunk, spill_dir=str(td), prefetch=pf, **cfg,
                )
        finally:
            for klass, (name, orig) in jitter.items():
                setattr(klass, name, orig)
        outs[pf] = res
        s = res.stats
        assert s["prefetch_depth"] == pf
        assert s["spans_prestaged"] + s["spans_missed"] == s["refill_spans"]
        if pf == 0:
            assert s["spans_prestaged"] == 0  # sync path never prestages
        assert s["h2d_rows"] == m  # pipeline never re-ships a row
        assert (np.asarray(res.assign) == ref.assign).all(), (
            f"prefetch={pf} diverged at chunk={chunk} wmax={wmax} b={b} z={z}"
        )
    assert (np.asarray(outs[0].assign) == np.asarray(outs[depth].assign)).all()


def test_prefetch_worker_prestages(rmat_file):
    """The read-ahead worker stages spans before the consumer asks: once it
    has provably read past the next refill target, that refill is a
    pipeline hit (spans_prestaged), not a miss."""
    path, _, n = rmat_file
    cfg = AdwiseConfig(k=K, window_max=8)
    with EdgeFileReader(path) as r:
        with FileSource([r], chunk_edges=150, cfg=cfg, prefetch=2) as src:
            buf = src.alloc()
            buf = src.refill(buf, np.zeros(1, np.int64))
            hi0 = int(src.hi[0])
            assert src._worker is not None  # pipeline actually engaged
            # Wait until the worker has staged at least one block past hi
            # (it may stage up to depth = 2 * max_span rows ahead).
            target = min(hi0 + src.Rq, int(src.m_per[0]))
            deadline = time.monotonic() + 10.0
            while int(src._worker._next[0]) < target:
                assert time.monotonic() < deadline, "worker never got ahead"
                time.sleep(0.005)
            buf = src.refill(buf, np.array([hi0], np.int64))
            assert int(src.hi[0]) > hi0
            assert src.spans_prestaged >= 1, "staged refill counted as miss"
            assert (src.spans_prestaged + src.spans_missed
                    == src.refill_spans)


def test_prefetch_worker_teardown_on_error(rmat_file):
    """A reader failure inside the worker thread surfaces as the consumer's
    exception, and FileSource teardown joins the thread — no leak."""
    path, _, n = rmat_file

    class _BoomReader:
        def __init__(self, inner):
            self.num_edges = inner.num_edges

        def read(self, start, count):
            raise IOError("disk pulled")

    cfg = AdwiseConfig(k=K, window_max=8)
    before = {t for t in threading.enumerate() if t.name == "adwise-readahead"}
    with EdgeFileReader(path) as r:
        with pytest.raises(RuntimeError, match="read-ahead worker failed"):
            with FileSource([_BoomReader(r)], chunk_edges=100, cfg=cfg,
                            prefetch=2) as src:
                src.refill(src.alloc(), np.zeros(1, np.int64))
    leaked = {
        t for t in threading.enumerate() if t.name == "adwise-readahead"
    } - before
    assert not leaked, f"read-ahead thread leaked: {leaked}"


def test_resolve_prefetch_env(monkeypatch):
    monkeypatch.delenv("ADWISE_PREFETCH", raising=False)
    assert resolve_prefetch(None) == 2  # pipeline on by default
    assert resolve_prefetch(0) == 0
    assert resolve_prefetch(5) == 5
    monkeypatch.setenv("ADWISE_PREFETCH", "0")
    assert resolve_prefetch(None) == 0
    monkeypatch.setenv("ADWISE_PREFETCH", "3")
    assert resolve_prefetch(None) == 3
    assert resolve_prefetch(1) == 1  # explicit argument beats the env


# ----------------------------------------------------------------------------
# Resident-source driving (the partition_stream / batched thin callers)
# ----------------------------------------------------------------------------


def test_partition_stream_reports_h2d(rmat_file):
    path, edges, n = rmat_file
    m = len(edges)
    res = partition_stream(edges, n, AdwiseConfig(k=K, window_max=8))
    # One resident upload: the (m, 2) stream plus the (m,) prev buffer.
    assert res.stats["h2d_rows"] == m
    assert res.stats["h2d_bytes"] == m * 8 + m * 4
    assert res.stats["scan_calls"] >= 1
    assert res.stats["unassigned"] == 0


def test_resident_source_validates_shapes():
    streams = np.zeros((2, 10, 2), np.int32)
    src = ResidentSource(streams, np.array([10, 7]))
    assert src.z == 2 and src.per == 10 and src.upload_rows == 20
    with pytest.raises(AssertionError):
        ResidentSource(streams, np.array([10, 11]))  # m_per > per


def test_resolve_backend():
    assert resolve_backend("vmap", 4) == ("vmap", 0)
    # Single-device hosts degrade shard_map to vmap.
    import jax

    if jax.device_count() == 1:
        assert resolve_backend("auto", 4) == ("vmap", 0)
        assert resolve_backend("shard_map", 4) == ("vmap", 0)
    with pytest.raises(ValueError, match="backend"):
        resolve_backend("loop", 2)


def test_driver_rejects_file_mode_without_sink(rmat_file):
    path, _, n = rmat_file
    cfg = AdwiseConfig(k=K, window_max=8)
    with EdgeFileReader(path) as r:
        src = FileSource([r], chunk_edges=100, cfg=cfg)
        drv = ScanDriver(src, cfg, n)
        with pytest.raises(AssertionError, match="on_assign"):
            drv.run()
