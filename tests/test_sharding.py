"""Sharding rules: spec validity for every arch × mesh; divisibility guards."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.data import make_batch_spec
from repro.launch import sharding as shg
from repro.models import lm

ALL_ARCHS = [
    "rwkv6-7b", "llama3.2-3b", "phi3-mini-3.8b", "qwen1.5-110b",
    "qwen1.5-0.5b", "zamba2-7b", "whisper-tiny", "granite-moe-1b-a400m",
    "grok-1-314b", "internvl2-26b",
]


class FakeMesh:
    """Shape-only stand-in so spec derivation needs no real devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_total(mesh, entry):
    axes = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([mesh.shape[a] for a in axes]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["1pod", "2pod"])
def test_param_specs_no_overshard(arch, mesh):
    """No dim is sharded across more shards than its size; ranks match."""
    cfg = get_config(arch)
    tp = 16
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(lambda k: lm.init_params(cfg, k, tp=tp), key)
    specs = shg.param_specs(cfg, mesh, tp, params_shape)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if entry is None:
                continue
            assert dim >= _axis_total(mesh, entry), (path, spec, leaf.shape)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), params_shape, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.parametrize("arch", ["llama3.2-3b", "grok-1-314b", "rwkv6-7b"])
def test_big_weights_are_sharded(arch):
    """Multi-GB tensors must not be replicated at tp=16."""
    cfg = get_config(arch)
    tp = 16
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(lambda k: lm.init_params(cfg, k, tp=tp), key)
    specs = shg.param_specs(cfg, MESH2, tp, params_shape)
    flat_p = jax.tree_util.tree_flatten_with_path(params_shape)[0]
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_p, flat_s):
        nbytes = leaf.size * leaf.dtype.itemsize
        if nbytes > 512e6:  # anything >0.5 GB must shard
            assert any(ax is not None for ax in spec), (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_cache_specs_cover_cache(arch):
    cfg = get_config(arch)
    tp = 16
    cache_shape = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024, tp=tp))
    specs = shg.cache_specs(cfg, MESH1, tp, cache_shape)
    flat_c = jax.tree.leaves(cache_shape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_c) == len(flat_s)
    for leaf, spec in zip(flat_c, flat_s):
        assert len(spec) <= len(leaf.shape)


def test_batch_specs_respect_divisibility():
    cfg = get_config("rwkv6-7b")
    tok = {"tokens": jax.ShapeDtypeStruct((1, 42), jnp.int32)}  # batch 1
    specs = shg.batch_specs(cfg, MESH1, tok)
    assert specs["tokens"][0] is None  # 1 % 16 != 0 -> replicated
    tok = {"tokens": jax.ShapeDtypeStruct((256, 42), jnp.int32)}
    specs = shg.batch_specs(cfg, MESH1, tok)
    assert specs["tokens"][0] == "data"


def test_head_policy_table():
    """Attention TP policies chosen per arch at tp=16 (documented table)."""
    expect = {
        "llama3.2-3b": "pad",        # 24 Q heads -> 32
        "phi3-mini-3.8b": "shard",   # 32/32
        "qwen1.5-110b": "shard_q",   # 64 Q, 8 KV replicated
        "whisper-tiny": "replicate",  # 6 heads, padding too wasteful
        "grok-1-314b": "shard_q",
        "qwen1.5-0.5b": "shard",
    }
    for arch, policy in expect.items():
        cfg = get_config(arch)
        assert cfg.padded_heads(16)[2] == policy, arch


def test_jit_with_specs_runs_on_local_mesh():
    """End-to-end: reduced arch jitted with derived shardings on 1 device."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0), tp=1)
    specs = shg.param_specs(cfg, mesh, 1, params)
    shard = shg.to_shardings(mesh, specs)
    params = jax.device_put(params, shard)
    batch = {"tokens": jnp.zeros((2, 17), jnp.int32)}
    with mesh:
        loss, _ = jax.jit(lambda p, b: lm.loss_fn(p, cfg, b))(params, batch)
    assert np.isfinite(float(loss))
