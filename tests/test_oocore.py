"""Out-of-core partitioning driver: registry-wide bit-parity with the
in-memory path (z=1 and z>1 spotlight), bounded resident edge memory, and
the 2PS clustering `lax.scan` port against its numpy oracle."""
import os
import weakref

import numpy as np
import pytest

from repro.core import (
    AdwiseConfig,
    partition_file,
    run_partitioner,
    spotlight_partition,
)
from repro.core.driver import resolve_prefetch
from repro.core.restream import (
    VertexClusteringState,
    _degrees,
    streaming_vertex_clustering,
    streaming_vertex_clustering_np,
)
from repro.engine import partition_latency
from repro.graph import rmat
from repro.graph.io import EdgeFileReader, write_edge_file

from conftest import random_edges

K = 8
WMAX = 8  # one shared window_max so scan compilations are reused across tests


def _write(tmp_path, edges, n, name="g.adw"):
    p = str(tmp_path / name)
    write_edge_file(p, edges, n)
    return p


@pytest.fixture(scope="module")
def rmat_file(tmp_path_factory):
    """One moderately sized R-MAT graph shared by the parity tests."""
    edges, n = rmat(9, 2500, seed=13)
    td = tmp_path_factory.mktemp("oocore")
    path = str(td / "rmat.adw")
    write_edge_file(path, edges, n)
    return path, edges, n


# ----------------------------------------------------------------------------
# Registry-wide parity: file-driven == in-memory, z == 1
# ----------------------------------------------------------------------------

_Z1_CASES = [
    ("hash", {}),
    ("grid", {}),
    ("dbh", {}),
    ("hdrf", {}),
    ("hdrf", dict(lam=1.5)),
    ("greedy", {}),
    ("adwise", dict(window_max=WMAX)),
    ("2ps", dict(window_max=WMAX)),
    ("2ps-l", {}),
    ("2ps-l", dict(lam=1.5, cap_slack=1.3)),
    ("adwise-restream", dict(window_max=WMAX, passes=2)),
]


@pytest.mark.parametrize("strategy,cfg", _Z1_CASES,
                         ids=[f"{s}-{i}" for i, (s, _) in enumerate(_Z1_CASES)])
def test_partition_file_parity_z1(rmat_file, tmp_path, strategy, cfg):
    path, edges, n = rmat_file
    ref = run_partitioner(strategy, edges, n, K, seed=0, **cfg)
    with EdgeFileReader(path) as r:
        res = partition_file(r, strategy, K, seed=0, chunk_edges=400,
                             spill_dir=str(tmp_path), **cfg)
    assert (np.asarray(res.assign) == ref.assign).all(), (
        f"{strategy}: file-driven assignment diverged from in-memory"
    )
    assert res.stats["unassigned"] == 0
    assert res.stats["rows_read"] >= len(edges)  # at least one full pass
    assert res.stats["io_wall_s"] >= 0.0


def test_partition_file_parity_random_rmat_property(tmp_path):
    """Random R-MAT streams (varying skew/seed): the cheap strategies stay
    bit-identical through the file path — the registry-wide property."""
    for seed in range(4):
        rng = np.random.default_rng(seed)
        edges, n = rmat(8, int(rng.integers(200, 900)),
                        a=float(rng.uniform(0.3, 0.6)), seed=seed)
        if len(edges) == 0:
            continue
        path = _write(tmp_path, edges, n, f"p{seed}.adw")
        chunk = int(rng.integers(37, 300))
        for strategy in ("hash", "grid", "dbh", "hdrf", "greedy"):
            ref = run_partitioner(strategy, edges, n, K, seed=seed)
            with EdgeFileReader(path) as r:
                res = partition_file(r, strategy, K, seed=seed,
                                     chunk_edges=chunk,
                                     spill_dir=str(tmp_path / f"s{seed}{strategy}"))
            assert (np.asarray(res.assign) == ref.assign).all(), (
                strategy, seed, chunk)


def test_partition_file_chunk_size_invariance(rmat_file, tmp_path):
    """The chunk bound never changes the ADWISE scan's output."""
    path, edges, n = rmat_file
    cfg = dict(window_max=WMAX)
    outs = []
    for chunk in (400, 997):
        with EdgeFileReader(path) as r:
            res = partition_file(r, "adwise", K, seed=0, chunk_edges=chunk,
                                 spill_dir=str(tmp_path / f"c{chunk}"), **cfg)
        outs.append(np.asarray(res.assign).copy())
    assert (outs[0] == outs[1]).all()


def test_hdrf_tie_noise_invariant_under_chunk_geometry(rmat_file, tmp_path):
    """HDRF's tie noise is a counter-based draw keyed on the GLOBAL stream
    row id (edge index), not on chunk-local position or any carried RNG
    state — so permuting the chunk geometry (which reshuffles how rows land
    in scan calls and ring refills) must reproduce identical assignments,
    all equal to the in-memory scan and the numpy oracle."""
    path, edges, n = rmat_file
    ref = run_partitioner("hdrf", edges, n, K, seed=3)
    outs = []
    for chunk in (64, 211, 400, 997, len(edges) + 7):
        with EdgeFileReader(path) as r:
            res = partition_file(r, "hdrf", K, seed=3, chunk_edges=chunk,
                                 spill_dir=str(tmp_path / f"h{chunk}"))
        outs.append(np.asarray(res.assign).copy())
        assert (outs[-1] == ref.assign).all(), chunk
    for a in outs[1:]:
        assert (a == outs[0]).all()


# ----------------------------------------------------------------------------
# z > 1 spotlight parity (the acceptance configuration)
# ----------------------------------------------------------------------------

_SPOT_CASES = [
    ("hash", {}, None),
    ("dbh", {}, None),
    ("hdrf", {}, None),
    ("greedy", {}, None),
    ("2ps", dict(window_max=WMAX), dict(window_max=WMAX)),
    ("2ps-l", {}, None),
    ("adwise", dict(window_max=WMAX), None),
    ("adwise-restream", dict(window_max=WMAX, passes=2),
     dict(window_max=WMAX, passes=2)),
]


@pytest.mark.parametrize("strategy,cfg,scfg", _SPOT_CASES,
                         ids=[s for s, _, _ in _SPOT_CASES])
def test_partition_file_parity_spotlight(rmat_file, tmp_path, strategy, cfg, scfg):
    """z=4, spread=2: file-driven spotlight == in-memory spotlight for every
    registry strategy (batched for the adwise family — per-instance readers
    over the split_bounds byte ranges — masked loop for the baselines)."""
    path, edges, n = rmat_file
    z, spread = 4, 2
    if strategy == "adwise":
        ref = spotlight_partition(edges, n, K, z=z, spread=spread,
                                  strategy="adwise",
                                  cfg=AdwiseConfig(k=K, window_max=WMAX), seed=0)
    else:
        ref = spotlight_partition(edges, n, K, z=z, spread=spread,
                                  strategy=strategy, seed=0, strategy_cfg=scfg)
    with EdgeFileReader(path) as r:
        res = partition_file(r, strategy, K, z=z, spread=spread, seed=0,
                             chunk_edges=400, spill_dir=str(tmp_path), **cfg)
    assert (np.asarray(res.assign) == ref.assign).all(), (
        f"{strategy}: file-driven z={z} spotlight diverged from in-memory"
    )
    assert res.stats["z"] == z


def test_partition_file_on_sub_reader(rmat_file, tmp_path):
    """partition_file accepts a row-range sub-reader, including z>1 (the
    sub-reader re-splits its own range and forwards IO accounting)."""
    path, edges, n = rmat_file
    half = len(edges) // 2
    ref = spotlight_partition(edges[:half], n, K, z=2, spread=4,
                              strategy="hdrf", seed=0)
    with EdgeFileReader(path) as r:
        sub = r.sub(0, half)
        res = partition_file(sub, "hdrf", K, z=2, spread=4, seed=0,
                             chunk_edges=300, spill_dir=str(tmp_path))
        assert (np.asarray(res.assign) == ref.assign).all()
        assert res.stats["rows_read"] == half  # accounting flows to the root


def test_partition_file_spotlight_rejects_grid(rmat_file, tmp_path):
    path, _, _ = rmat_file
    with EdgeFileReader(path) as r:
        with pytest.raises(ValueError, match="spotlight"):
            partition_file(r, "grid", K, z=4, spread=2,
                           spill_dir=str(tmp_path))


# ----------------------------------------------------------------------------
# Bounded resident edge memory (counting reader)
# ----------------------------------------------------------------------------


class CountingReader:
    """Reader proxy that counts the edge rows of every array it has handed
    out that is still alive (weakref finalizers; CPython refcounting frees
    drained chunks promptly). ``peak`` is the high-water mark."""

    def __init__(self, inner, counter=None):
        self._inner = inner
        self._c = counter if counter is not None else {"live": 0, "peak": 0, "max_req": 0}
        self.num_edges = inner.num_edges
        self.num_vertices = inner.num_vertices
        self.path = getattr(inner, "path", None)

    # shared-counter stats
    @property
    def peak(self):
        return self._c["peak"]

    @property
    def max_request(self):
        return self._c["max_req"]

    @property
    def rows_read(self):
        root = self._inner
        while hasattr(root, "_parent"):
            root = root._parent
        return getattr(root, "rows_read", 0)

    @property
    def read_seconds(self):
        root = self._inner
        while hasattr(root, "_parent"):
            root = root._parent
        return getattr(root, "read_seconds", 0.0)

    def read(self, start, count):
        arr = self._inner.read(start, count)
        c = self._c
        rows = len(arr)
        c["live"] += rows
        c["peak"] = max(c["peak"], c["live"])
        c["max_req"] = max(c["max_req"], rows)
        weakref.finalize(arr, CountingReader._dec, c, rows)
        return arr

    @staticmethod
    def _dec(c, rows):
        c["live"] -= rows

    def chunks(self, chunk_edges):
        for start in range(0, self.num_edges, chunk_edges):
            yield self.read(start, chunk_edges)

    def read_all(self):
        return self.read(0, self.num_edges)

    def sub(self, start, stop):
        return CountingReader(self._inner.sub(start, stop), self._c)

    def split(self, z):
        return [CountingReader(s, self._c) for s in self._inner.split(z)]


@pytest.mark.parametrize("strategy,cfg,z", [
    ("adwise", dict(window_max=WMAX), 1),
    ("adwise-restream", dict(window_max=WMAX, passes=2), 1),
    ("hdrf", {}, 1),
    ("2ps", dict(window_max=WMAX), 1),
    ("adwise", dict(window_max=WMAX), 4),
])
def test_partition_file_memory_bounded(tmp_path, strategy, cfg, z):
    """Peak live edge rows handed out by the reader stay O(chunk) — far
    below m — while the output still matches the in-memory path.

    The graph grows with z so the staging bound (which is per-instance)
    stays meaningfully below m for the spotlight case too."""
    edges, n = rmat(9 if z == 1 else 11, 2500 * z, seed=13)
    m = len(edges)
    path = _write(tmp_path, edges, n)
    chunk = 400
    with EdgeFileReader(path) as inner:
        r = CountingReader(inner)
        res = partition_file(r, strategy, K, z=z,
                             spread=2 if z > 1 else None, seed=0,
                             chunk_edges=chunk, spill_dir=str(tmp_path / "sp"),
                             **cfg)
    # Buffer refills copy the chunk out and drop it; at most a couple of
    # read results are alive at once per instance — plus, with the refill
    # pipeline on, up to `prefetch` read-ahead spans staged per instance.
    pf = resolve_prefetch(None)
    bound = (3 + pf) * max(chunk, WMAX + 1) * max(z, 1)
    assert r.max_request <= max(chunk, WMAX + 1), (
        f"a single read pulled {r.max_request} rows (> chunk bound)"
    )
    assert r.peak <= bound, f"peak live rows {r.peak} > bound {bound}"
    assert r.peak < m / 2, "memory bound is not meaningfully below m"
    assert res.stats["peak_resident_edges"] < 4 * chunk * max(z, 1) + 1
    # And bounded-memory execution still matches the resident-array path.
    if z == 1:
        ref = run_partitioner(strategy, edges, n, K, seed=0, **cfg)
        assert (np.asarray(res.assign) == ref.assign).all()


# ----------------------------------------------------------------------------
# IO accounting drives the latency model
# ----------------------------------------------------------------------------

def test_stream_reads_billed_per_strategy(rmat_file, tmp_path):
    path, edges, n = rmat_file
    m = len(edges)
    expected = {"hash": 1, "dbh": 2, "2ps": 3}
    for strategy, reads in expected.items():
        cfg = dict(window_max=WMAX) if strategy == "2ps" else {}
        with EdgeFileReader(path) as r:
            res = partition_file(r, strategy, K, seed=0, chunk_edges=500,
                                 spill_dir=str(tmp_path / strategy), **cfg)
        assert res.stats["stream_reads"] == reads, strategy
        assert res.stats["stream_reads_measured"] == reads, strategy
        assert res.stats["rows_read"] == reads * m, strategy
        # partition_latency bills the measured read count.
        lat = partition_latency(res.stats, m, K)
        base = partition_latency(dict(res.stats, stream_reads=1), m, K)
        assert lat >= base


def test_restream_file_stats(rmat_file, tmp_path):
    path, edges, n = rmat_file
    with EdgeFileReader(path) as r:
        res = partition_file(r, "adwise-restream", K, seed=0, chunk_edges=500,
                             spill_dir=str(tmp_path), window_max=WMAX,
                             passes=3, keep_best=True)
    s = res.stats
    assert s["passes_run"] == 3 and s["stream_reads"] == 3
    assert len(s["pass_rd"]) == 3
    # Intermediate pass spills were deleted; only the final spill remains.
    spill_files = [f for f in os.listdir(str(tmp_path)) if f.endswith(".i32")]
    assert spill_files == ["assign.i32"], spill_files
    # keep_best: final quality equals the best pass's.
    ref = run_partitioner("adwise-restream", edges, n, K, seed=0,
                          window_max=WMAX, passes=3, keep_best=True)
    assert (np.asarray(res.assign) == ref.assign).all()
    assert s["pass_rd"] == ref.stats["pass_rd"]
    assert s["best_pass"] == ref.stats["best_pass"]


def test_partition_file_empty_and_errors(tmp_path):
    p = _write(tmp_path, np.zeros((0, 2), np.int32), 5, "empty.adw")
    with EdgeFileReader(p) as r:
        res = partition_file(r, "adwise", K, spill_dir=str(tmp_path))
    assert res.assign.shape == (0,)

    edges, n = rmat(8, 200, seed=0)
    p = _write(tmp_path, edges, n, "e.adw")
    with EdgeFileReader(p) as r:
        with pytest.raises(KeyError, match="out-of-core"):
            partition_file(r, "nope", K, spill_dir=str(tmp_path))
        with pytest.raises(TypeError, match="unknown config"):
            partition_file(r, "adwise", K, bogus=1, spill_dir=str(tmp_path))
        with pytest.raises(TypeError, match="unknown config"):
            partition_file(r, "hdrf", K, bogus=1, spill_dir=str(tmp_path))


# ----------------------------------------------------------------------------
# 2PS clustering: lax.scan port == numpy oracle
# ----------------------------------------------------------------------------

def test_clustering_scan_matches_numpy_oracle_adversarial():
    """Self-loops, duplicate edges, hubs, random streams: identical cluster
    ids AND identical volumes at every k."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(5, 90))
        m = int(rng.integers(1, 400))
        u = rng.integers(0, n, m)
        v = np.where(rng.random(m) < 0.15, u, rng.integers(0, n, m))  # loops
        edges = np.stack([u, v], 1).astype(np.int32)
        for k in (2, 5):
            cl_np, vols_np = streaming_vertex_clustering_np(edges, n, k)
            cl_sc, vols_sc = streaming_vertex_clustering(edges, n, k)
            assert (cl_np == cl_sc).all(), (seed, k)
            assert len(vols_np) == len(vols_sc)
            assert (vols_np == vols_sc).all(), (seed, k)


def test_clustering_scan_chunking_invariance():
    rng = np.random.default_rng(2)
    edges = random_edges(rng, 60, 300)
    n, k, m = 60, 4, len(edges)
    one_cl, one_vols = streaming_vertex_clustering(edges, n, k)
    st = VertexClusteringState(n, k, m, _degrees(edges, n), chunk_edges=71)
    for s in range(0, m, 71):
        st.update(edges[s : s + 71])
    cl, vols = st.finalize()
    assert (cl == one_cl).all() and (vols == one_vols).all()


def test_2ps_registry_uses_scan_port(tiny_graph):
    """The '2ps' registry entry now runs the scan clustering; its phase-1
    result equals the oracle, so quality claims carry over unchanged."""
    edges, n = tiny_graph
    edges = edges[:1500]
    cl_np, vols_np = streaming_vertex_clustering_np(edges, n, K)
    cl_sc, vols_sc = streaming_vertex_clustering(edges, n, K)
    assert (cl_np == cl_sc).all() and (vols_np == vols_sc).all()
