"""Tracing + metrics layer (`repro.obs`): null-tracer overhead contract,
registry-wide bit-parity with tracing off AND on, span-tree well-formedness
over random driver geometries, category/counter reconciliation, Chrome
trace-event export schema, the SC003 tracer-in-closure rule, and the
bench_compare regression gate."""
import json
import sys
import textwrap
import tracemalloc
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import available_strategies, partition_file, run_partitioner
from repro.core.adwise import partition_stream
from repro.core.restream import restream_partition
from repro.core.types import AdwiseConfig
from repro.graph import rmat
from repro.graph.io import EdgeFileReader, write_edge_file
from repro.obs import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    chrome_trace,
    resolve_tracer,
    validate_chrome_trace,
)

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:  # for tools.* imports under `python -m pytest`
    sys.path.insert(0, str(REPO_ROOT))

K = 8


@pytest.fixture(scope="module")
def rmat_file(tmp_path_factory):
    edges, n = rmat(8, 1200, seed=5)
    td = tmp_path_factory.mktemp("obs")
    path = str(td / "g.adw")
    write_edge_file(path, edges, n)
    return path, edges, n


# ----------------------------------------------------------------------------
# null tracer: the disabled path is free
# ----------------------------------------------------------------------------


def test_null_tracer_singleton_and_noop():
    assert resolve_tracer(None) is NULL_TRACER
    tr = Tracer()
    assert resolve_tracer(tr) is tr
    assert NULL_TRACER.enabled is False and tr.enabled is True
    # the coarse path hands out ONE shared no-op span object
    s1 = NULL_TRACER.span("a", cat="scan", x=1)
    s2 = NULL_TRACER.span("b")
    assert s1 is s2
    with s1 as s:
        s.set(rows=3)
    NULL_TRACER.add_span("x", "scan", 0.0, 1.0)
    NULL_TRACER.instant("i")
    NULL_TRACER.gauge("g", 2.0)
    summ = NULL_TRACER.summary()
    assert summ.events == 0 and summ.categories == {}
    with pytest.raises(RuntimeError):
        NULL_TRACER.export("/tmp/never.json")
    # NullTracer instances carry no per-instance state at all
    assert NullTracer.__slots__ == ()


def test_null_tracer_hot_path_allocates_nothing():
    tr = resolve_tracer(None)
    # warm up (interned args, bytecode caches)
    for _ in range(100):
        tr.add_span("s", "scan", 0.0, 1.0)
        with tr.span("s"):
            pass
    tracemalloc.start()
    before, _ = tracemalloc.get_traced_memory()
    for _ in range(5000):
        tr.add_span("s", "scan", 0.0, 1.0)
        with tr.span("s"):
            pass
    after, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # 10k no-op calls must not retain memory and barely touch the peak:
    # anything growing per-call (a span object, a record, an attrs dict
    # that survives) would show up as hundreds of KB here.
    assert after - before < 16_384, (before, after)
    assert peak - before < 65_536, (before, peak)


# ----------------------------------------------------------------------------
# registry-wide parity: tracing off AND on is bit-identical
# ----------------------------------------------------------------------------


def test_registry_parity_traced_vs_untraced(rmat_file):
    path, edges, n = rmat_file
    for strategy in available_strategies():
        if strategy == "oracle":
            continue  # no file-driven route (launcher refuses it too)
        cfg = {"passes": 2} if strategy in ("adwise-restream",) else {}
        runs = {}
        for label, trace in (("off", None), ("on", Tracer())):
            with EdgeFileReader(path) as r:
                res = partition_file(
                    r, strategy, K, seed=0, chunk_edges=256,
                    spill_dir=None, trace=trace, **cfg,
                )
            runs[label] = np.asarray(res.assign)
            if trace is not None:
                assert res.stats.get("trace_summary"), strategy
        assert (runs["off"] == runs["on"]).all(), (
            f"{strategy}: tracing changed the assignment"
        )


# ----------------------------------------------------------------------------
# span-tree well-formedness + counter reconciliation (property test)
# ----------------------------------------------------------------------------


def _check_well_formed(tr, stats):
    spans = list(tr.spans)
    assert spans, "traced run recorded no spans"
    eps = 1e-9
    by_track = {}
    for s in spans:
        assert s.t1 >= s.t0 - eps, (s.name, s.t0, s.t1)
        by_track.setdefault(s.track, []).append(s)
    # Nesting by timestamp containment per track: any two overlapping spans
    # on one track must nest (one contains the other) — that is the layout
    # Perfetto renders, and interleaved half-overlaps would mean a span
    # leaked across a phase boundary.
    for track, ss in by_track.items():
        ss = sorted(ss, key=lambda s: (s.t0, -s.t1))
        for i, a in enumerate(ss):
            for b in ss[i + 1:]:
                if b.t0 >= a.t1 - eps:
                    break  # sorted: no later span can overlap `a` either
                assert b.t1 <= a.t1 + eps, (
                    f"half-overlap on track {track}: "
                    f"{a.name}[{a.t0:.6f},{a.t1:.6f}] vs "
                    f"{b.name}[{b.t0:.6f},{b.t1:.6f}]"
                )
    # Worker-track spans come from the worker thread and vice versa.
    for s in spans:
        if s.cat == "stage":
            assert s.thread.startswith("adwise-readahead"), s
        if s.cat in ("scan", "refill"):
            assert not s.thread.startswith("adwise-readahead"), s
    # Category totals reconcile with the scalar counters: the hot spans
    # reuse the exact perf_counter floats behind the stats fields.
    cats = tr.summary().categories
    scan_calls = int(stats.get("scan_calls", 0))
    if scan_calls:
        assert cats["scan"]["count"] == scan_calls, (
            cats["scan"], scan_calls)
    h2d_wait = float(stats.get("h2d_wait_s", 0.0))
    refill_wall = cats.get("refill", {}).get("wall_s", 0.0)
    assert abs(refill_wall - h2d_wait) < 1e-6, (refill_wall, h2d_wait)
    prestage = float(stats.get("prestage_wall_s", 0.0))
    stage_wall = cats.get("stage", {}).get("wall_s", 0.0)
    assert abs(stage_wall - prestage) < 1e-6, (stage_wall, prestage)
    # Every byte read off disk is inside a stage (worker) or fetch
    # (blocking-refill) span; io_wall_s can only be smaller plus noise.
    io_wall = float(stats.get("io_wall_s", 0.0))
    covered = stage_wall + cats.get("fetch", {}).get("wall_s", 0.0)
    assert io_wall <= covered + 0.25, (io_wall, covered)


@settings(max_examples=6, deadline=None)
@given(
    chunk=st.integers(48, 700),
    wmax=st.sampled_from([4, 8, 16]),
    prefetch=st.sampled_from([0, 1, 2]),
    strategy=st.sampled_from(["hdrf", "adwise"]),
)
def test_span_tree_well_formed_random_geometry(
    rmat_file, chunk, wmax, prefetch, strategy
):
    path, edges, n = rmat_file
    tr = Tracer()
    cfg = {"window_max": wmax} if strategy == "adwise" else {}
    with EdgeFileReader(path) as r:
        res = partition_file(
            r, strategy, K, seed=0, chunk_edges=chunk, prefetch=prefetch,
            spill_dir=None, trace=tr, **cfg,
        )
    _check_well_formed(tr, res.stats)
    ref = run_partitioner(strategy, edges, n, K, seed=0, **cfg)
    assert (np.asarray(res.assign) == ref.assign).all()


# ----------------------------------------------------------------------------
# restream lanes + entry-point summaries
# ----------------------------------------------------------------------------


def test_restream_pass_lanes(rmat_file):
    path, edges, n = rmat_file
    tr = Tracer()
    with EdgeFileReader(path) as r:
        res = partition_file(
            r, "adwise-restream", K, seed=0, chunk_edges=512,
            passes=3, window_max=8, spill_dir=None, trace=tr,
        )
    passes_run = int(res.stats["passes_run"])
    summ = tr.summary()
    assert summ.categories["pass"]["count"] == passes_run
    lanes = {t for t in summ.tracks if t.startswith("restream-pass-")}
    assert lanes == {f"restream-pass-{j}" for j in range(1, passes_run + 1)}
    pass_spans = sorted(
        (s for s in tr.spans if s.cat == "pass"), key=lambda s: s.t0
    )
    # per-pass quality deltas ride on the span attrs
    assert "rd" in pass_spans[0].attrs
    for s in pass_spans[1:]:
        assert "rd_delta" in s.attrs
    tsum = res.stats["trace_summary"]
    assert tsum["events"] == summ.events


def test_partition_stream_and_restream_summary(rmat_file):
    _, edges, n = rmat_file
    tr = Tracer()
    res = partition_stream(
        edges, n, AdwiseConfig(k=K, window_max=8), n_chunks=4, trace=tr
    )
    assert res.stats["trace_summary"]["categories"]["scan"]["count"] == (
        res.stats["scan_calls"]
    )
    tr2 = Tracer()
    res2 = restream_partition(
        edges, n, K, passes=2, window_max=8, trace=tr2
    )
    assert tr2.summary().categories["pass"]["count"] == (
        res2.stats["passes_run"]
    )
    assert res2.stats["trace_summary"]["events"] == tr2.summary().events


def test_engine_superstep_spans():
    from repro.engine import build_partitioned_graph, pagerank

    edges, n = rmat(7, 300, seed=3)
    assign = run_partitioner("hash", edges, n, 4, seed=0).assign
    g = build_partitioned_graph(edges, assign, n, 4)
    tr = Tracer()
    _, info = pagerank(g, iters=3, trace=tr)
    cats = tr.summary().categories
    assert cats["engine"]["count"] == 3
    # Every superstep span carries the device slab placement (balanced to
    # within one real slab by make_superstep) for Perfetto visibility.
    steps = [s for s in tr.spans if s.name == "superstep"]
    assert len(steps) == 3
    for s in steps:
        occ = s.attrs["slab_occupancy"]
        assert len(occ) == s.attrs["n_shards"]
        assert sum(occ) == 4  # k real slabs, none lost to padding
        assert max(occ) - min(occ) <= 1
    _, info2 = pagerank(g, iters=3)
    assert info2["supersteps"] == info["supersteps"]


# ----------------------------------------------------------------------------
# exporter: Chrome trace-event schema
# ----------------------------------------------------------------------------


def test_export_schema_and_validation(tmp_path):
    tr = Tracer()
    with tr.span("outer", cat="phase", k=8):
        with tr.span("inner", cat="scan", rows=np.int64(7)):
            pass
    tr.add_span("staged", "stage", tr.t0, tr.t0 + 0.001,
                track="adwise-readahead", attrs={"rows": np.float32(2.5)})
    tr.instant("ring-adopt", "refill", z=2)
    tr.gauge("depth", 3, track="adwise-readahead")
    path = tmp_path / "trace.json"
    n = tr.export(str(path))
    doc = json.loads(path.read_text())
    assert validate_chrome_trace(doc) == []
    events = doc["traceEvents"]
    assert n == len(events)
    x = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in x} == {"outer", "inner", "staged"}
    # np scalars were unwrapped to plain JSON numbers
    inner = next(e for e in x if e["name"] == "inner")
    assert inner["args"]["rows"] == 7
    tracks = {e["args"]["name"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"main", "adwise-readahead"} <= tracks
    # ts must be globally monotonic (the validator enforces it; double-
    # check the sort here so a validator regression can't hide it)
    ts = [e["ts"] for e in events if e["ph"] != "M"]
    assert ts == sorted(ts)


def test_validator_catches_malformed():
    ok = chrome_trace(_traced_once())
    assert validate_chrome_trace(ok) == []
    assert validate_chrome_trace({"traceEvents": "nope"})
    bad_ph = {"traceEvents": [dict(ok["traceEvents"][0], ph="Z")]}
    assert validate_chrome_trace(bad_ph)
    no_x = {"traceEvents": [e for e in ok["traceEvents"] if e["ph"] != "X"]}
    assert validate_chrome_trace(no_x)


def _traced_once():
    tr = Tracer()
    with tr.span("s", cat="scan"):
        pass
    return tr


# ----------------------------------------------------------------------------
# SC003: tracer calls inside jit-traced step closures
# ----------------------------------------------------------------------------


def test_sc003_flags_tracer_in_step_closure():
    from tools.staticcheck import check_source

    found = check_source(textwrap.dedent("""
        def make_step(core, trace):
            def step(carry, row):
                with trace.span("step", cat="scan"):
                    carry = carry + row
                return carry, carry
            return step
    """), "src/repro/core/virtual.py")
    assert {f.rule for f in found if not f.suppressed} == {"SC003"}
    assert any("tracer" in f.message for f in found)


def test_sc003_allows_tracer_in_stepping_loop():
    from tools.staticcheck import check_source

    # The stepping loop runs on the host: tracing there is the DESIGN.
    found = check_source(textwrap.dedent("""
        import time

        class ScanDriver:
            def _run_ring(self, run_chunk, src):
                carry = self.carry
                calls = 0
                while calls < 8:
                    t_call = time.perf_counter()
                    carry, out = run_chunk(carry)
                    self.trace.add_span("scan-call", "scan", t_call,
                                        time.perf_counter())
                    calls += 1
                return carry
    """), "src/repro/core/virtual.py")
    assert {f.rule for f in found if not f.suppressed} == set()


# ----------------------------------------------------------------------------
# bench_compare: the regression gate
# ----------------------------------------------------------------------------


def _bench_doc(wall, mode="smoke", compiles=None):
    return {
        "mode": mode,
        "summary": {
            "partition_file_wall_s": wall,
            "partition_file_sync_wall_s": wall * 1.5,
            "h2d_wait_s": wall / 10,
            "prestage_wall_s": wall / 5,
            "overlap_efficiency": 0.5,
        },
        "jit_scan_compiles": compiles or {"run_scan_ring": 3},
    }


def test_bench_compare_gate(tmp_path, capsys):
    from tools.bench_compare import main as compare_main

    d = tmp_path / "bench_logs"
    d.mkdir()
    # 0 or 1 summaries: nothing to compare, exit 0
    assert compare_main([str(d)]) == 0
    (d / "BENCH_0.json").write_text(json.dumps(_bench_doc(1.0)))
    assert compare_main([str(d)]) == 0
    # within threshold: +10% passes at the default 25%
    (d / "BENCH_1.json").write_text(json.dumps(_bench_doc(1.1)))
    assert compare_main([str(d)]) == 0
    # over threshold on the two NEWEST files (1 -> 2), not the oldest pair
    (d / "BENCH_2.json").write_text(json.dumps(_bench_doc(2.0)))
    assert compare_main([str(d)]) == 1
    out = capsys.readouterr()
    assert "REGRESSION" in out.out and "partition_file_wall_s" in out.err
    # tighter threshold flips the earlier pair too
    (d / "BENCH_3.json").write_text(json.dumps(_bench_doc(2.1)))
    assert compare_main([str(d), "--threshold", "0.01"]) == 1
    # improvement never fails
    (d / "BENCH_4.json").write_text(json.dumps(_bench_doc(0.5)))
    assert compare_main([str(d)]) == 0
    # mode mismatch: report, never gate
    (d / "BENCH_5.json").write_text(json.dumps(_bench_doc(9.0, mode="full")))
    assert compare_main([str(d)]) == 0
