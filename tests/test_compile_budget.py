"""Dynamic complement to tools/staticcheck: the jit compile-cache budget.

The pow2-``Rq`` refill quantization (PR 5) exists to keep the driver's
kernel-shape set *bounded*: scan kernels compile once per distinct
(core static config, n_steps, carry/stream shapes) and ``_ring_write``
once per quantized span shape — never once per chunk, never once per
stream length. A stray unquantized shape or a traced value leaking into a
static argument reintroduces unbounded recompilation (the latency
pathology the static rules SC001–SC003 guard the source side of). This
module enforces the bound dynamically: random ``(chunk_edges, window_max,
assign_batch, z)`` geometries sweep through :class:`ScanDriver` over both
sources, and the live jit cache sizes (``scan_compile_counts``) must stay
within the analytic budget. benchmarks/run.py emits the same counters
into ``BENCH_<n>.json`` so retrace regressions also show in the perf
trajectory.
"""
import numpy as np
import pytest

from repro.core.baselines import GreedyCore, HdrfCore
from repro.core.driver import (
    AdwiseCore,
    FileSource,
    ResidentSource,
    ScanDriver,
    scan_compile_counts,
)
from repro.core.types import AdwiseConfig

N_GEOMETRIES = 22  # acceptance floor is 20, on BOTH sources


class ArrayReader:
    """Minimal FileSource reader over an in-memory edge array (the ring
    path only needs ``num_edges`` + ``read``; no disk round-trip here —
    this test measures compiles, not I/O)."""

    def __init__(self, edges: np.ndarray):
        self.edges = np.ascontiguousarray(edges, np.int32)

    @property
    def num_edges(self) -> int:
        return len(self.edges)

    def read(self, start: int, count: int) -> np.ndarray:
        return self.edges[start : start + count]


def _make_core(kind: str, rng, V: int, k: int):
    if kind == "adwise":
        w = int(rng.choice([4, 8, 16]))
        b = int(rng.integers(1, 5))
        return AdwiseCore(
            cfg=AdwiseConfig(k=k, window_max=w, assign_batch=b),
            num_vertices=V,
        )
    if kind == "hdrf":
        # seed is compare=False: it must NOT enter the jit cache key.
        return HdrfCore(num_vertices=V, k=k, seed=int(rng.integers(0, 99)))
    return GreedyCore(num_vertices=V, k=k)


def _geometries(rng, n: int):
    kinds = ["adwise"] * 8 + ["hdrf"] * 8 + ["greedy"] * 4
    while len(kinds) < n:
        kinds.append("hdrf")
    out = []
    for kind in kinds[:n]:
        V = int(rng.integers(16, 48))
        k = int(rng.choice([3, 4, 8]))
        z = int(rng.integers(1, 4))
        chunk = int(rng.integers(16, 400))
        ms = [int(rng.integers(40, 260)) for _ in range(z)]
        core = _make_core(kind, rng, V, k)
        out.append(dict(core=core, V=V, z=z, chunk=chunk, ms=ms,
                        n_chunks=int(rng.integers(1, 7))))
    return out


def _edges(rng, V: int, m: int) -> np.ndarray:
    return rng.integers(0, V, size=(m, 2)).astype(np.int32)


def _run_resident(geo, rng):
    z, ms = geo["z"], geo["ms"]
    per = max(ms)
    streams = np.zeros((z, per, 2), np.int32)
    for i, m in enumerate(ms):
        streams[i, :m] = _edges(rng, geo["V"], m)
    src = ResidentSource(streams, np.array(ms, np.int64))
    drv = ScanDriver(src, geo["core"])
    res = drv.run(n_chunks=geo["n_chunks"])
    assert (res.assigned == np.array(ms)).all()
    return per


def _run_ring(geo, rng, ms=None):
    ms = ms if ms is not None else geo["ms"]
    readers = [ArrayReader(_edges(rng, geo["V"], m)) for m in ms]
    src = FileSource(readers, chunk_edges=geo["chunk"], core=geo["core"])
    drv = ScanDriver(src, geo["core"])
    got = [0] * len(ms)

    def on_assign(i, idx, p):
        got[i] += len(idx)

    drv.run(on_assign=on_assign)
    assert got == list(ms)
    return src


def _resident_key(geo, per):
    """The driver's static signature for `_run_scan_resident`, replicated:
    one compile per distinct (core, chunk_steps, z, per)."""
    core = geo["core"]
    b = core.rows_per_step
    m_max = max(geo["ms"])
    steps_total = -(-m_max // b) + -(-core.window_rows // b) + 2
    nc = max(1, min(geo["n_chunks"], steps_total))
    chunk_steps = -(-steps_total // nc)
    return (core, chunk_steps, geo["z"], per)


def test_compile_budget_random_geometries():
    """≥20 random geometries over BOTH sources: scan-kernel compiles stay
    ≤ the number of distinct static signatures (each geometry adds at most
    one program per source), and ring-write compiles stay within the
    quantized-span budget ``max_span/Rq + z`` per run."""
    rng = np.random.default_rng(20260809)
    geos = _geometries(rng, N_GEOMETRIES)

    resident_keys, ring_keys = set(), set()
    base = scan_compile_counts()
    for geo in geos:
        pre = scan_compile_counts()
        per = _run_resident(geo, rng)
        src = _run_ring(geo, rng)
        post = scan_compile_counts()

        resident_keys.add(_resident_key(geo, per))
        ring_keys.add((geo["core"], src.scan_steps, geo["z"], src.B))

        # Per-geometry: at most ONE new program per scan kernel — n_steps
        # and every shape are fixed by the geometry, so chunked stepping
        # and the drain reuse the same trace.
        assert post["run_scan_resident"] - pre["run_scan_resident"] <= 1, geo
        assert post["run_scan_ring"] - pre["run_scan_ring"] <= 1, geo
        # Ring refills: only Rq-multiples up to max_span, plus at most one
        # ragged final-tail span per instance (the unquantized remainder
        # at target == m_i).
        span_budget = src.max_span // src.Rq + geo["z"] + 1
        assert post["ring_write"] - pre["ring_write"] <= span_budget, (
            geo, src.Rq, src.max_span, pre, post,
        )

    end = scan_compile_counts()
    assert end["run_scan_resident"] - base["run_scan_resident"] <= len(
        resident_keys
    )
    assert end["run_scan_ring"] - base["run_scan_ring"] <= len(ring_keys)


def test_ring_same_geometry_new_stream_zero_recompiles():
    """The headline pow2-Rq promise: a second run with the SAME
    (chunk_edges, window_max, assign_batch, z) geometry but a *different*
    stream (different m, different edges) adds ZERO scan-kernel compiles —
    m_real rides as a traced input, never a static — and at most z new
    ragged-tail spans in the update kernel."""
    rng = np.random.default_rng(42)
    geos = _geometries(rng, 6)
    for geo in geos[:4]:
        _run_ring(geo, rng)  # warm: compiles this geometry's programs
        pre = scan_compile_counts()
        new_ms = [int(rng.integers(40, 300)) for _ in range(geo["z"])]
        src = _run_ring(geo, rng, ms=new_ms)
        post = scan_compile_counts()
        assert post["run_scan_ring"] == pre["run_scan_ring"], (
            "scan kernel recompiled on a same-geometry re-run: the stream "
            "length leaked into a static argument",
            geo, new_ms,
        )
        # Quantized spans are cached from the first run up to whatever it
        # used; the only genuinely new shapes are ragged tails (<= 1 per
        # instance) and at most a couple of not-yet-seen Rq multiples.
        assert post["ring_write"] - pre["ring_write"] <= geo["z"] + 2, (
            geo, new_ms, pre, post,
        )


def test_hdrf_seed_not_in_cache_key():
    """HdrfCore.seed is field(compare=False): spotlight's per-instance
    seeds must share one trace, so two equal-geometry cores differing only
    in seed may not add a second program."""
    rng = np.random.default_rng(3)
    geo = dict(
        core=HdrfCore(num_vertices=30, k=4, seed=1),
        V=30, z=2, chunk=64, ms=[90, 120], n_chunks=3,
    )
    _run_ring(geo, rng)
    pre = scan_compile_counts()
    geo2 = dict(geo, core=HdrfCore(num_vertices=30, k=4, seed=77))
    _run_ring(geo2, rng)
    post = scan_compile_counts()
    assert post["run_scan_ring"] == pre["run_scan_ring"]


def test_batched_length_buckets_bound_scan_programs():
    """Ragged z-instance batching compiles at most
    ``ceil(log2(max_m / min_m)) + 1`` resident scan programs: instances are
    pow2-length-bucketed (`partition_stream_batched`), so skewed lengths
    share ≤ one program per occupied pow2 class instead of padding every
    instance to the global max."""
    import math

    from repro.core.adwise import _ceil_pow2, partition_stream_batched

    rng = np.random.default_rng(11)
    ms = [40, 70, 130, 300, 520, 1000]  # 5 pow2 classes, 6 instances
    z, per, V, k = len(ms), max(ms), 40, 8
    streams = np.zeros((z, per, 2), np.int32)
    valid = np.zeros((z, per), bool)
    for i, m in enumerate(ms):
        streams[i, :m] = _edges(rng, V, m)
        valid[i, :m] = True
    pre = scan_compile_counts()["run_scan_resident"]
    res = partition_stream_batched(
        streams, valid, V, None, core=HdrfCore(num_vertices=V, k=k, seed=0)
    )
    post = scan_compile_counts()["run_scan_resident"]
    bound = math.ceil(math.log2(max(ms) / min(ms))) + 1
    n_buckets = len({_ceil_pow2(m) for m in ms})
    assert n_buckets <= bound
    assert post - pre <= bound, (post - pre, bound)
    assert post - pre <= n_buckets, (post - pre, n_buckets)
    for i, m in enumerate(ms):
        assert len(res[i].assign) == m
        assert res[i].stats["n_buckets"] == n_buckets
        assert res[i].stats["bucket_rows"] == min(_ceil_pow2(m), per)


def test_counts_are_live_gauges():
    counts = scan_compile_counts()
    assert set(counts) == {"run_scan_resident", "run_scan_ring", "ring_write"}
    assert all(isinstance(v, int) and v >= 0 for v in counts.values())
