"""Batched (vmapped / shard_mapped) spotlight path.

Covers the device-parallel refactor: z instance scans as one program
(`partition_stream_batched`), the spotlight rewrite on top of it, the
restream × spotlight composition (per-instance WarmState batches), and —
in a subprocess with 4 fake CPU devices — the padded `parts` engine mesh
plus the shard_map instance axis.

The property tests run under the vendored `tests/_propcheck.py` shim when
`hypothesis` is absent, as in test_restream.py.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdwiseConfig,
    partition_stream,
    partition_stream_batched,
    restream_partition_batched,
    run_partitioner,
    spotlight_partition,
    spread_mask,
    warm_from_assignment,
)
from repro.graph import replica_sets_from_assignment, replication_degree
from repro.graph.stream import EdgeStream

N, M = 24, 60  # same adversarial-stream shapes as test_restream.py


def _adversarial_stream(kind: str, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if kind == "random":
        uv = rng.integers(0, N, (M, 2))
    elif kind == "self_loops":
        u = rng.integers(0, N, M)
        v = np.where(rng.random(M) < 0.5, u, rng.integers(0, N, M))
        uv = np.stack([u, v], axis=1)
    elif kind == "duplicates":
        base = rng.integers(0, N, (4, 2))
        uv = base[rng.integers(0, 4, M)]
    elif kind == "star":
        center = int(rng.integers(0, N))
        leaves = rng.integers(0, N, M)
        uv = np.stack([np.full(M, center), leaves], axis=1)
    else:  # pragma: no cover
        raise ValueError(kind)
    return uv.astype(np.int32)


def _random_edges(seed, n=50, m=300):
    rng = np.random.default_rng(seed)
    return np.stack(
        [rng.integers(0, n, m), rng.integers(0, n, m)], axis=1
    ).astype(np.int32)


# ----------------------------------------------------------------------------
# z=1 parity: the batched program is the same trace, vmapped
# ----------------------------------------------------------------------------

def test_batched_z1_bit_identical_to_partition_stream():
    edges = _random_edges(0)
    n, k = 50, 8
    cfg = AdwiseConfig(k=k, window_max=16, window_init=4)
    ref = partition_stream(edges, n, cfg)
    streams, valid = EdgeStream(edges, n).split_padded(1)
    got = partition_stream_batched(streams, valid, n, cfg)
    assert len(got) == 1
    np.testing.assert_array_equal(ref.assign, got[0].assign)
    # Same work was done, not just the same answer.
    assert ref.stats["score_rows"] == got[0].stats["score_rows"]
    assert ref.stats["final_w"] == got[0].stats["final_w"]
    np.testing.assert_array_equal(ref.stats["w_trace"], got[0].stats["w_trace"])


def test_batched_z1_warm_pass_bit_identical():
    """Warm-started (re-streaming) passes go through the same batched trace."""
    edges = _random_edges(3)
    n, k = 50, 6
    cfg = AdwiseConfig(k=k, window_max=16, window_init=4)
    base = partition_stream(edges, n, cfg)
    warm = warm_from_assignment(edges, base.assign, n, k)
    ref = partition_stream(edges, n, cfg, warm=warm)
    streams, valid = EdgeStream(edges, n).split_padded(1)
    got = partition_stream_batched(streams, valid, n, cfg, warm=[warm])
    np.testing.assert_array_equal(ref.assign, got[0].assign)


def test_batched_z1_allowed_mask_bit_identical():
    edges = _random_edges(7)
    n, k = 50, 8
    cfg = AdwiseConfig(k=k, window_max=16, window_init=4)
    allowed = spread_mask(k, 2, 0, 4)
    ref = partition_stream(edges, n, cfg, allowed=allowed)
    streams, valid = EdgeStream(edges, n).split_padded(1)
    got = partition_stream_batched(
        streams, valid, n, cfg, allowed=allowed[None, :]
    )
    np.testing.assert_array_equal(ref.assign, got[0].assign)


@pytest.mark.parametrize("m", [400, 250])  # z | m and z ∤ m
def test_spotlight_batched_matches_loop(m):
    """Both backends split the stream at the same instance boundaries
    (EdgeStream.split_bounds) — including ragged tails when z does not
    divide m — so the batched program must reproduce the sequential
    instances bit-for-bit."""
    edges = _random_edges(1, m=m)
    n, k, z = 50, 8, 4
    cfg = AdwiseConfig(k=k, window_max=16, window_init=4)
    loop = spotlight_partition(edges, n, k, z=z, spread=2, cfg=cfg,
                               backend="loop")
    batched = spotlight_partition(edges, n, k, z=z, spread=2, cfg=cfg,
                                  backend="batched")
    np.testing.assert_array_equal(loop.assign, batched.assign)
    assert batched.stats["backend"] in ("vmap", "shard_map")
    assert loop.stats["backend"] == "loop"


def test_length_bucketed_batch_bit_identical_to_per_instance():
    """Skewed per-instance lengths split the batch into several pow2 length
    buckets; every instance must still reproduce its stand-alone scan
    bit-for-bit — for ADWISE (stateless across instances) and for HDRF,
    whose tie-break seeds derive from the *global* instance id and would
    drift if bucketing's permutation leaked into `seed_instances`."""
    from repro.core.adwise import _ceil_pow2
    from repro.core.baselines import HdrfCore

    rng = np.random.default_rng(9)
    ms = [30, 70, 150, 290]
    z, per, n, k = len(ms), max(ms), 50, 8
    streams = np.zeros((z, per, 2), np.int32)
    valid = np.zeros((z, per), bool)
    for i, m in enumerate(ms):
        streams[i, :m] = np.stack(
            [rng.integers(0, n, m), rng.integers(0, n, m)], axis=1
        )
        valid[i, :m] = True
    assert len({_ceil_pow2(m) for m in ms}) == 4  # genuinely multi-bucket

    cfg = AdwiseConfig(k=k, window_max=8, window_init=2)
    got = partition_stream_batched(streams, valid, n, cfg)
    assert got[0].stats["n_buckets"] == 4
    for i, m in enumerate(ms):
        ref = partition_stream(streams[i, :m], n, cfg)
        np.testing.assert_array_equal(ref.assign, got[i].assign)

    # HDRF: the batch seeds instance i with seed + i (its global id), so
    # the stand-alone reference for instance i is a z=1 batch seeded seed+i.
    seed = 5
    got_h = partition_stream_batched(
        streams, valid, n, None, core=HdrfCore(num_vertices=n, k=k, seed=seed)
    )
    for i, m in enumerate(ms):
        ref_h = partition_stream_batched(
            streams[i : i + 1, :m], valid[i : i + 1, :m], n, None,
            core=HdrfCore(num_vertices=n, k=k, seed=seed + i),
        )
        np.testing.assert_array_equal(ref_h[0].assign, got_h[i].assign)


# ----------------------------------------------------------------------------
# Spread-mask property on adversarial streams
# ----------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(["random", "self_loops", "duplicates", "star"]),
    z=st.sampled_from([2, 4]),
    spread=st.sampled_from([2, 4]),
)
def test_batched_spotlight_respects_spread_property(seed, kind, z, spread):
    """Every batched instance stays inside its spread block — adversarial
    streams (stars stall the top-b pick, duplicates/self-loops stress the
    window) included, and every edge is assigned."""
    edges = _adversarial_stream(kind, seed)
    k = 8
    cfg = AdwiseConfig(k=k, window_max=8, window_init=2)
    res = spotlight_partition(edges, N, k, z=z, spread=spread, cfg=cfg,
                              backend="batched")
    assert (res.assign >= 0).all() and (res.assign < k).all()
    per = -(-len(edges) // z)
    for i in range(z):
        allowed = set(np.flatnonzero(spread_mask(k, z, i, spread)))
        seg = res.assign[i * per : min((i + 1) * per, len(edges))]
        assert set(np.unique(seg)) <= allowed, (kind, i)


def test_batched_more_instances_than_edges():
    """z > m leaves some instances with empty streams — still valid."""
    edges = np.array([[0, 1], [1, 2]], np.int32)
    res = spotlight_partition(edges, 4, 8, z=4, spread=2,
                              cfg=AdwiseConfig(k=8, window_max=4),
                              backend="batched")
    assert res.assign.shape == (2,)
    assert (res.assign >= 0).all()


# ----------------------------------------------------------------------------
# restream × spotlight composition (per-instance WarmState batches)
# ----------------------------------------------------------------------------

def test_restream_batched_composes_with_spread(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:400]
    k, z, spread = 8, 2, 4
    res = spotlight_partition(
        edges, n, k, z=z, spread=spread, strategy="adwise-restream",
        strategy_cfg=dict(passes=2, window_max=8, window_init=2),
        backend="batched",
    )
    assert (res.assign >= 0).all() and (res.assign < k).all()
    assert res.stats["passes_run"] == 2
    assert res.stats["stream_reads"] == 2
    per = -(-len(edges) // z)
    for i in range(z):
        allowed = set(np.flatnonzero(spread_mask(k, z, i, spread)))
        seg = res.assign[i * per : min((i + 1) * per, len(edges))]
        assert set(np.unique(seg)) <= allowed


def test_restream_batched_quality_monotone_per_instance(tiny_graph):
    """keep_best holds per instance: 2-pass batched restream is no worse
    than the 1-pass batched run on every instance's sub-stream."""
    edges, n = tiny_graph
    k, z = 8, 2
    streams, valid = EdgeStream(edges, n).split_padded(z)
    allowed = np.stack([spread_mask(k, z, i, 4) for i in range(z)])
    cfg = dict(window_max=8, window_init=2)
    one = restream_partition_batched(
        streams, valid, n, k, allowed=allowed, passes=1, **cfg)
    two = restream_partition_batched(
        streams, valid, n, k, allowed=allowed, passes=2, **cfg)
    for i in range(z):
        m_i = int(valid[i].sum())
        sub = streams[i, :m_i]
        rd1 = replication_degree(
            replica_sets_from_assignment(sub, one[i].assign, n, k))
        rd2 = replication_degree(
            replica_sets_from_assignment(sub, two[i].assign, n, k))
        assert rd2 <= rd1 + 1e-9
        assert two[i].stats["passes_run"] == 2


def test_restream_batched_eps_early_stop(tiny_graph):
    edges, n = tiny_graph
    streams, valid = EdgeStream(edges[:300], n).split_padded(2)
    res = restream_partition_batched(
        streams, valid, n, 8, passes=5, eps=10.0,
        window_max=8, window_init=2,
    )
    # A pass never improves RD by >= 10, so exactly one extra pass runs.
    assert res[0].stats["passes_run"] == 2
    assert res[0].stats["passes"] == 5
    assert res[0].stats["stream_reads"] == 2


# ----------------------------------------------------------------------------
# Backend validation
# ----------------------------------------------------------------------------

def test_batched_backend_rejects_custom_partitioner(tiny_graph):
    """Every *registry* strategy batches now; only a custom partitioner
    callable still needs the loop escape hatch."""
    edges, n = tiny_graph

    def custom(sub_edges, nv, k, seed=0, allowed=None):
        from repro.core.registry import run_partitioner
        return run_partitioner("hash", sub_edges, nv, k, seed=seed,
                               allowed=allowed)

    with pytest.raises(ValueError, match="loop"):
        spotlight_partition(edges, n, 8, z=2, spread=4, partitioner=custom,
                            backend="batched")


def test_unknown_backend_rejected(tiny_graph):
    edges, n = tiny_graph
    with pytest.raises(ValueError, match="backend"):
        spotlight_partition(edges, n, 8, z=2, spread=4, backend="tpu")


def test_baselines_auto_select_batched(tiny_graph):
    """auto resolves to the batched backend for every registry strategy —
    the baselines included — and matches the loop backend bit-for-bit."""
    edges, n = tiny_graph
    res = spotlight_partition(edges, n, 16, z=4, spread=4, strategy="dbh")
    assert res.stats["backend"] != "loop"
    assert (res.assign >= 0).all()
    loop = spotlight_partition(edges, n, 16, z=4, spread=4, strategy="dbh",
                               backend="loop")
    assert (res.assign == loop.assign).all()


# ----------------------------------------------------------------------------
# Multi-device: 4 fake CPU devices in a subprocess
# ----------------------------------------------------------------------------

@pytest.mark.slow
def test_multi_device_padding_and_instance_sharding():
    """On a forced 4-device CPU host: `engine_mesh` keeps all devices for a
    k (=6) not divisible by the device count (the parts axis pads 6 -> 8
    inside make_superstep), the engine still computes correct PageRank, and
    the batched partitioner's shard_map backend equals vmap exactly."""
    prog = textwrap.dedent("""
        import numpy as np, jax
        assert jax.device_count() == 4, jax.device_count()
        from repro.core import AdwiseConfig, run_partitioner, spotlight_partition
        from repro.core.adwise import partition_stream_batched
        from repro.engine import build_partitioned_graph, pagerank
        from repro.engine.gas import engine_mesh
        from repro.graph.stream import EdgeStream

        # Padding: k=6 on 4 devices keeps all 4 (6 pads to 8); k=2 caps at 2.
        assert engine_mesh(k=6).devices.size == 4
        assert engine_mesh(k=2).devices.size == 2

        rng = np.random.default_rng(0)
        u, v = rng.integers(0, 40, 300), rng.integers(0, 40, 300)
        keep = u != v
        edges = np.stack([u[keep], v[keep]], 1).astype(np.int32)
        n, k = 40, 6
        res = run_partitioner("hdrf", edges, n, k)
        g = build_partitioned_graph(edges, res.assign, n, k)
        pr, _ = pagerank(g, iters=5)
        deg = np.zeros(n)
        np.add.at(deg, edges[:, 0], 1); np.add.at(deg, edges[:, 1], 1)
        x = np.full(n, 1.0 / n)
        for _ in range(5):
            acc = np.zeros(n)
            np.add.at(acc, edges[:, 1], x[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
            np.add.at(acc, edges[:, 0], x[edges[:, 1]] / np.maximum(deg[edges[:, 1]], 1))
            x = 0.15 / n + 0.85 * acc
        np.testing.assert_allclose(pr, x, rtol=1e-4, atol=1e-7)

        # Slab-balanced placement: k=6 on 4 devices pads to 8 slabs, and
        # the pads are spread so real-slab counts differ by at most 1
        # (naive tail-padding would give (2, 2, 2, 0)). The PageRank check
        # above already proves the permuted layout computes identically.
        from repro.engine.gas import make_superstep
        step = make_superstep(
            g, lambda xu, xv, du, dv: (xu, xv), lambda s, a, d: s,
            engine_mesh(k=6),
        )
        assert step.slab_occupancy == (2, 2, 1, 1), step.slab_occupancy
        assert max(step.slab_occupancy) - min(step.slab_occupancy) <= 1

        # Instance axis on devices: shard_map backend == vmap backend.
        cfg = AdwiseConfig(k=6, window_max=8, window_init=2)
        streams, valid = EdgeStream(edges, n).split_padded(4)
        sm = partition_stream_batched(streams, valid, n, cfg, backend="shard_map")
        vm = partition_stream_batched(streams, valid, n, cfg, backend="vmap")
        assert sm[0].stats["n_shards"] == 4
        for a, b in zip(sm, vm):
            np.testing.assert_array_equal(a.assign, b.assign)

        # spotlight auto picks shard_map on a multi-device host.
        res = spotlight_partition(edges, n, 6, z=4, spread=2, cfg=cfg)
        assert res.stats["backend"] == "shard_map", res.stats["backend"]
        assert (res.assign >= 0).all()
        print("MULTIDEV_BATCHED_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.abspath("src"), env.get("PYTHONPATH")] if p
    )
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "MULTIDEV_BATCHED_OK" in out.stdout


# ----------------------------------------------------------------------------
# build_partitioned_graph unassigned guard (satellite)
# ----------------------------------------------------------------------------

def test_build_partitioned_graph_rejects_unassigned():
    from repro.engine import build_partitioned_graph

    edges = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    assign = np.array([0, -1, 1], np.int32)
    with pytest.raises(ValueError, match="unassigned|outside"):
        build_partitioned_graph(edges, assign, 4, 2)
    with pytest.raises(ValueError, match="outside"):
        build_partitioned_graph(edges, np.array([0, 2, 1], np.int32), 4, 2)
