"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import compat
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.segment_sum import csr_block_layout, segment_sum_xla, EB, SB

# The pallas-vs-ref comparisons below are meaningless if resolve_impl would
# degrade the explicit 'pallas' request to 'ref' (the two sides would be the
# same code) — skip rather than pass vacuously on such installs.
requires_pallas = pytest.mark.skipif(
    not compat.has_pallas(), reason="jax.experimental.pallas unavailable")
requires_pallas_tpu = pytest.mark.skipif(
    not compat.has_pallas(require_tpu_support=True),
    reason="jax.experimental.pallas.tpu unavailable")
requires_prefetch_grid = pytest.mark.skipif(
    not (compat.has_pallas(require_tpu_support=True) and compat.HAS_PREFETCH_GRID),
    reason="pltpu.PrefetchScalarGridSpec unavailable")


# ----------------------------------------------------------------------------
# window_score
# ----------------------------------------------------------------------------

@requires_pallas
@pytest.mark.parametrize("w,k,use_cs", [
    (1, 2, True), (7, 3, True), (128, 32, True), (200, 20, True),
    (130, 64, False), (64, 5, False),
])
def test_window_score_shapes(w, k, use_cs):
    rng = np.random.default_rng(w * 31 + k)
    v = 200
    uv = rng.integers(0, v, (w, 2)).astype(np.int32)
    valid = rng.random(w) < 0.85
    repu = rng.random((w, k)) < 0.2
    repv = rng.random((w, k)) < 0.2
    degu = rng.integers(1, 40, w).astype(np.int32)
    degv = rng.integers(1, 40, w).astype(np.int32)
    bal = rng.random(k).astype(np.float32)
    allowed = rng.random(k) < 0.9
    args = (uv, valid, repu, repv, degu, degv, bal, allowed,
            jnp.float32(1.3), jnp.int32(40))
    a = ops.window_score(*args, use_cs=use_cs, impl="pallas")
    b = ops.window_score(*args, use_cs=use_cs, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


@requires_pallas
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), w=st.integers(1, 80), k=st.integers(1, 40))
def test_window_score_property(seed, w, k):
    rng = np.random.default_rng(seed)
    uv = rng.integers(0, 50, (w, 2)).astype(np.int32)
    valid = rng.random(w) < 0.7
    repu = rng.random((w, k)) < 0.3
    repv = rng.random((w, k)) < 0.3
    degu = rng.integers(1, 10, w).astype(np.int32)
    degv = rng.integers(1, 10, w).astype(np.int32)
    bal = rng.random(k).astype(np.float32)
    allowed = np.ones(k, bool)
    args = (uv, valid, repu, repv, degu, degv, bal, allowed,
            jnp.float32(0.7), jnp.int32(10))
    a = np.asarray(ops.window_score(*args, impl="pallas"))
    b = np.asarray(ops.window_score(*args, impl="ref"))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    # Masking invariant: invalid rows / disallowed cols are -inf-ish.
    assert (a[~valid] < -1e29).all()


# ----------------------------------------------------------------------------
# segment_sum
# ----------------------------------------------------------------------------

@requires_prefetch_grid
@pytest.mark.parametrize("e,d,s,dtype", [
    (10, 8, 5, np.float32), (1000, 64, 300, np.float32),
    (3000, 32, 700, np.float32), (513, 128, 129, np.float32),
    (2048, 16, 256, np.float16),
])
def test_segment_sum_shapes(e, d, s, dtype):
    rng = np.random.default_rng(e + d)
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(dtype)
    a = ops.segment_sum_sorted(jnp.asarray(data), seg, s, impl="pallas")
    # Oracle in fp32: the kernel accumulates in fp32 regardless of input dtype
    # (MXU-style mixed precision), so compare against the fp32 reference.
    b = ops.segment_sum_sorted(jnp.asarray(data, jnp.float32), seg, s, impl="ref")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b, np.float32),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("e,d,s", [
    (10, 8, 5), (1000, 64, 300), (513, 16, 129), (3000, 32, 700),
])
def test_segment_sum_xla_fast_path_parity(e, d, s):
    """The no-PrefetchScalarGridSpec fast path (jax.ops.segment_sum over the
    blocked CSR layout) must agree with the plain sorted-segment reference.
    Runs on every install — it needs no pallas at all."""
    rng = np.random.default_rng(e * 13 + d)
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(np.float32)
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(seg, s, d)
    gather = np.where(perm[:, None] >= 0, data[np.maximum(perm, 0)], 0.0)
    a = segment_sum_xla(
        jnp.asarray(gather, jnp.float32), jnp.asarray(loc),
        jnp.asarray(chunk_ptr), s,
    )
    b = kref.segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_segment_sum_pallas_falls_back_without_prefetch_grid(monkeypatch):
    """When pallas-TPU lacks PrefetchScalarGridSpec, the blocked kernel entry
    point must route to the XLA fast path instead of raising."""
    from repro.kernels import segment_sum as ss

    monkeypatch.setattr(ss, "pltpu", None)
    rng = np.random.default_rng(7)
    e, d, s = 400, 8, 100
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(np.float32)
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(seg, s, d)
    gather = np.where(perm[:, None] >= 0, data[np.maximum(perm, 0)], 0.0)
    with pytest.warns(RuntimeWarning, match="NOT pallas timings"):
        out = ss.segment_sum_pallas(
            jnp.asarray(gather, jnp.float32), jnp.asarray(loc),
            jnp.asarray(chunk_ptr), jnp.asarray(nchunks), s,
        )
    ref = kref.segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_csr_block_layout_invariants():
    rng = np.random.default_rng(0)
    e, s = 5000, 1000
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(seg, s, 4)
    live = perm >= 0
    assert live.sum() == e
    assert sorted(perm[live]) == list(range(e))  # a permutation of all edges
    assert (loc[live] >= 0).all() and (loc[live] < SB).all()
    assert e_pad % EB == 0
    # Each block's chunks hold exactly its edges.
    for b in range(len(chunk_ptr)):
        lo, hi = chunk_ptr[b] * EB, (chunk_ptr[b] + nchunks[b]) * EB
        rows = perm[lo:hi]
        segs = seg[rows[rows >= 0]]
        if len(segs):
            assert (segs // SB == b).all()


# ----------------------------------------------------------------------------
# flash_attention
# ----------------------------------------------------------------------------

@requires_pallas_tpu
@pytest.mark.parametrize("b,hq,hkv,tq,tk,dh,dtype", [
    (1, 1, 1, 8, 8, 32, np.float32),
    (2, 4, 2, 130, 130, 64, np.float32),
    (1, 8, 1, 256, 256, 128, np.float32),   # MQA
    (2, 4, 4, 64, 64, 64, np.float16),
    (1, 4, 2, 1, 513, 64, np.float32),      # decode append
    (1, 2, 2, 100, 356, 32, np.float32),    # chunked continuation
])
def test_flash_attention_shapes(b, hq, hkv, tq, tk, dh, dtype):
    rng = np.random.default_rng(b * 7 + tq)
    q = rng.normal(size=(b, hq, tq, dh)).astype(dtype)
    k = rng.normal(size=(b, hkv, tk, dh)).astype(dtype)
    v = rng.normal(size=(b, hkv, tk, dh)).astype(dtype)
    a = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            impl="pallas")
    b_ = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             impl="ref")
    tol = 5e-3 if dtype == np.float16 else 2e-3
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b_, np.float32), rtol=tol, atol=tol)


def test_flash_attention_ref_is_softmax_attention():
    """The oracle itself vs a literal softmax implementation."""
    rng = np.random.default_rng(3)
    b, h, t, dh = 1, 2, 16, 8
    q = rng.normal(size=(b, h, t, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, t, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, t, dh)).astype(np.float32)
    out = np.asarray(kref.flash_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True))
    for bb in range(b):
        for hh in range(h):
            logits = q[bb, hh] @ k[bb, hh].T / np.sqrt(dh)
            mask = np.tril(np.ones((t, t), bool))
            logits = np.where(mask, logits, -1e30)
            p = np.exp(logits - logits.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            np.testing.assert_allclose(out[bb, hh], p @ v[bb, hh], rtol=1e-4,
                                       atol=1e-5)
