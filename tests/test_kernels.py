"""Pallas kernels vs pure-jnp oracles across the kernel-tier ladder.

The dispatch layer (`kernels/ops.py`) resolves every op to a tier that can
genuinely run (`xla` / lowered pallas); `interpret` is an explicit debug
request. Parity is asserted tier-by-tier: every tier the install can run —
plus interpret where pallas exists at all — must agree with the `xla`
reference within documented fp tolerance, and the pure-mask paths (invalid
rows / disallowed columns) must be bit-identical NEG_INF everywhere.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import compat
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.segment_sum import csr_block_layout, segment_sum_xla, EB, SB

# Tier-vs-ref comparisons are meaningless if the tier silently degrades to
# the same code as the reference — skip rather than pass vacuously.
requires_pallas = pytest.mark.skipif(
    not compat.has_pallas(), reason="jax.experimental.pallas unavailable")
requires_pallas_tpu = pytest.mark.skipif(
    not compat.has_pallas(require_tpu_support=True),
    reason="jax.experimental.pallas.tpu unavailable")
requires_prefetch_grid = pytest.mark.skipif(
    not (compat.has_pallas(require_tpu_support=True) and compat.HAS_PREFETCH_GRID),
    reason="pltpu.PrefetchScalarGridSpec unavailable")


def _tiers_under_test(op: str) -> list:
    """Every runnable tier, plus explicit interpret where pallas exists."""
    tiers = list(ops.available_tiers(op))
    if compat.has_pallas(op in ("segment_sum", "flash_attention")):
        if op != "segment_sum" or compat.HAS_PREFETCH_GRID:
            tiers.append(ops.INTERPRET_TIER)
    return tiers


# ----------------------------------------------------------------------------
# window_score
# ----------------------------------------------------------------------------

@requires_pallas
@pytest.mark.parametrize("w,k,use_cs", [
    (1, 2, True), (7, 3, True), (128, 32, True), (200, 20, True),
    (130, 64, False), (64, 5, False),
])
def test_window_score_shapes(w, k, use_cs):
    rng = np.random.default_rng(w * 31 + k)
    v = 200
    uv = rng.integers(0, v, (w, 2)).astype(np.int32)
    valid = rng.random(w) < 0.85
    repu = rng.random((w, k)) < 0.2
    repv = rng.random((w, k)) < 0.2
    degu = rng.integers(1, 40, w).astype(np.int32)
    degv = rng.integers(1, 40, w).astype(np.int32)
    bal = rng.random(k).astype(np.float32)
    allowed = rng.random(k) < 0.9
    args = (uv, valid, repu, repv, degu, degv, bal, allowed,
            jnp.float32(1.3), jnp.int32(40))
    b = ops.window_score(*args, use_cs=use_cs, tier="xla")
    for tier in _tiers_under_test("window_score"):
        a = ops.window_score(*args, use_cs=use_cs, tier=tier)
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
            err_msg=f"tier={tier}")
        # Masked (invalid-row / disallowed-col) entries are produced by the
        # same jnp.where(..., NEG_INF) on every tier: bit-identical.
        mask = (~valid)[:, None] | (~allowed)[None, :]
        np.testing.assert_array_equal(
            np.asarray(a)[mask], np.asarray(b)[mask], err_msg=f"tier={tier}")


@requires_pallas
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), w=st.integers(1, 80), k=st.integers(1, 40))
def test_window_score_property(seed, w, k):
    rng = np.random.default_rng(seed)
    uv = rng.integers(0, 50, (w, 2)).astype(np.int32)
    valid = rng.random(w) < 0.7
    repu = rng.random((w, k)) < 0.3
    repv = rng.random((w, k)) < 0.3
    degu = rng.integers(1, 10, w).astype(np.int32)
    degv = rng.integers(1, 10, w).astype(np.int32)
    bal = rng.random(k).astype(np.float32)
    allowed = np.ones(k, bool)
    args = (uv, valid, repu, repv, degu, degv, bal, allowed,
            jnp.float32(0.7), jnp.int32(10))
    a = np.asarray(ops.window_score(*args, tier=ops.INTERPRET_TIER))
    b = np.asarray(ops.window_score(*args, tier="xla"))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    # Masking invariant: invalid rows / disallowed cols are -inf-ish.
    assert (a[~valid] < -1e29).all()


# ----------------------------------------------------------------------------
# segment_sum
# ----------------------------------------------------------------------------

@requires_prefetch_grid
@pytest.mark.parametrize("e,d,s,dtype", [
    (10, 8, 5, np.float32), (1000, 64, 300, np.float32),
    (3000, 32, 700, np.float32), (513, 128, 129, np.float32),
    (2048, 16, 256, np.float16),
])
def test_segment_sum_shapes(e, d, s, dtype):
    rng = np.random.default_rng(e + d)
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(dtype)
    # Oracle in fp32: the kernel accumulates in fp32 regardless of input dtype
    # (MXU-style mixed precision), so compare against the fp32 reference.
    b = ops.segment_sum_sorted(jnp.asarray(data, jnp.float32), seg, s, tier="xla")
    for tier in _tiers_under_test("segment_sum"):
        a = ops.segment_sum_sorted(jnp.asarray(data), seg, s, tier=tier)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3, err_msg=f"tier={tier}")


@pytest.mark.parametrize("e,d,s", [
    (10, 8, 5), (1000, 64, 300), (513, 16, 129), (3000, 32, 700),
])
def test_segment_sum_xla_fast_path_parity(e, d, s):
    """The no-PrefetchScalarGridSpec fast path (jax.ops.segment_sum over the
    blocked CSR layout) must agree with the plain sorted-segment reference.
    Runs on every install — it needs no pallas at all."""
    rng = np.random.default_rng(e * 13 + d)
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(np.float32)
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(seg, s, d)
    gather = np.where(perm[:, None] >= 0, data[np.maximum(perm, 0)], 0.0)
    a = segment_sum_xla(
        jnp.asarray(gather, jnp.float32), jnp.asarray(loc),
        jnp.asarray(chunk_ptr), s,
    )
    b = kref.segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_segment_sum_pallas_falls_back_without_prefetch_grid(monkeypatch):
    """When pallas-TPU lacks PrefetchScalarGridSpec, the blocked kernel entry
    point must route to the XLA fast path instead of raising."""
    from repro.kernels import segment_sum as ss

    monkeypatch.setattr(ss, "pltpu", None)
    rng = np.random.default_rng(7)
    e, d, s = 400, 8, 100
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    data = rng.normal(size=(e, d)).astype(np.float32)
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(seg, s, d)
    gather = np.where(perm[:, None] >= 0, data[np.maximum(perm, 0)], 0.0)
    with pytest.warns(RuntimeWarning, match="NOT pallas timings"):
        out = ss.segment_sum_pallas(
            jnp.asarray(gather, jnp.float32), jnp.asarray(loc),
            jnp.asarray(chunk_ptr), jnp.asarray(nchunks), s,
        )
    ref = kref.segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_csr_block_layout_rejects_unsorted_ids():
    with pytest.raises(ValueError, match=r"sorted ascending.*seg_ids\[1\]=5"):
        csr_block_layout(np.array([1, 5, 3], np.int32), 10, 4)


def test_csr_block_layout_rejects_out_of_range_ids():
    with pytest.raises(ValueError, match=r"\[0, 10\).*seg_ids\[2\]=10"):
        csr_block_layout(np.array([0, 4, 10], np.int32), 10, 4)
    with pytest.raises(ValueError, match=r"seg_ids\[0\]=-1"):
        csr_block_layout(np.array([-1, 0, 3], np.int32), 10, 4)
    with pytest.raises(ValueError, match="num_segments"):
        csr_block_layout(np.array([], np.int32), 0, 4)


def test_csr_block_layout_degenerate_empty_and_single_segment():
    # m=0: an all-padding layout that the XLA fast path reduces to zeros.
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(
        np.array([], np.int32), 300, 4)
    assert (perm == -1).all() and e_pad % EB == 0 and e_pad > 0
    out = segment_sum_xla(
        jnp.zeros((e_pad, 4), jnp.float32), jnp.asarray(loc),
        jnp.asarray(chunk_ptr), 300)
    assert out.shape == (300, 4) and not np.asarray(out).any()
    # Single segment: every edge lands in block 0 / local id 0.
    e = 700
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(
        np.zeros(e, np.int32), 1, 4)
    live = perm >= 0
    assert live.sum() == e and (loc[live] == 0).all()
    data = np.arange(e, dtype=np.float32)[:, None].repeat(4, 1)
    gather = np.where(perm[:, None] >= 0, data[np.maximum(perm, 0)], 0.0)
    out = segment_sum_xla(
        jnp.asarray(gather, jnp.float32), jnp.asarray(loc),
        jnp.asarray(chunk_ptr), 1)
    np.testing.assert_allclose(np.asarray(out)[0], data.sum(0), rtol=1e-6)


def test_segment_sum_sorted_empty_stream():
    out = ops.segment_sum_sorted(
        jnp.zeros((0, 4), jnp.float32), np.array([], np.int32), 7)
    assert out.shape == (7, 4) and not np.asarray(out).any()


def test_csr_block_layout_invariants():
    rng = np.random.default_rng(0)
    e, s = 5000, 1000
    seg = np.sort(rng.integers(0, s, e)).astype(np.int32)
    perm, loc, chunk_ptr, nchunks, e_pad = csr_block_layout(seg, s, 4)
    live = perm >= 0
    assert live.sum() == e
    assert sorted(perm[live]) == list(range(e))  # a permutation of all edges
    assert (loc[live] >= 0).all() and (loc[live] < SB).all()
    assert e_pad % EB == 0
    # Each block's chunks hold exactly its edges.
    for b in range(len(chunk_ptr)):
        lo, hi = chunk_ptr[b] * EB, (chunk_ptr[b] + nchunks[b]) * EB
        rows = perm[lo:hi]
        segs = seg[rows[rows >= 0]]
        if len(segs):
            assert (segs // SB == b).all()


# ----------------------------------------------------------------------------
# flash_attention
# ----------------------------------------------------------------------------

@requires_pallas_tpu
@pytest.mark.parametrize("b,hq,hkv,tq,tk,dh,dtype", [
    (1, 1, 1, 8, 8, 32, np.float32),
    (2, 4, 2, 130, 130, 64, np.float32),
    (1, 8, 1, 256, 256, 128, np.float32),   # MQA
    (2, 4, 4, 64, 64, 64, np.float16),
    (1, 4, 2, 1, 513, 64, np.float32),      # decode append
    (1, 2, 2, 100, 356, 32, np.float32),    # chunked continuation
])
def test_flash_attention_shapes(b, hq, hkv, tq, tk, dh, dtype):
    rng = np.random.default_rng(b * 7 + tq)
    q = rng.normal(size=(b, hq, tq, dh)).astype(dtype)
    k = rng.normal(size=(b, hkv, tk, dh)).astype(dtype)
    v = rng.normal(size=(b, hkv, tk, dh)).astype(dtype)
    b_ = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                             tier="xla")
    tol = 5e-3 if dtype == np.float16 else 2e-3
    for tier in _tiers_under_test("flash_attention"):
        a = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                                tier=tier)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32), rtol=tol,
                                   atol=tol, err_msg=f"tier={tier}")


# ----------------------------------------------------------------------------
# tier resolver / autotune table
# ----------------------------------------------------------------------------

def test_available_tiers_never_interpret_and_end_on_xla():
    for op in ("window_score", "segment_sum", "flash_attention"):
        tiers = ops.available_tiers(op)
        assert tiers[-1] == "xla"
        assert ops.INTERPRET_TIER not in tiers
        if jax.default_backend() != "tpu":
            assert "pallas-tpu" not in tiers
    with pytest.raises(ValueError, match="unknown op"):
        ops.available_tiers("nope")


def test_resolve_tier_default_is_never_interpret(monkeypatch):
    monkeypatch.delenv(ops.KERNEL_TIER_ENV, raising=False)
    for op in ("window_score", "segment_sum", "flash_attention"):
        assert ops.resolve_tier(op) in ops.available_tiers(op)


def test_resolve_tier_env_override(monkeypatch):
    monkeypatch.setenv(ops.KERNEL_TIER_ENV, "xla")
    assert ops.resolve_tier("window_score") == "xla"
    monkeypatch.setenv(ops.KERNEL_TIER_ENV, "bogus-tier")
    with pytest.raises(ValueError, match="unknown kernel tier"):
        ops.resolve_tier("window_score")
    # Explicit tier= beats a contradictory env var.
    monkeypatch.setenv(ops.KERNEL_TIER_ENV, "xla")
    assert ops.resolve_tier("window_score", "xla") == "xla"


@requires_pallas
def test_resolve_tier_interpret_is_explicit_debug_only(monkeypatch):
    monkeypatch.delenv(ops.KERNEL_TIER_ENV, raising=False)
    assert ops.resolve_tier("window_score") != ops.INTERPRET_TIER
    assert ops.resolve_tier("window_score", "interpret") == ops.INTERPRET_TIER
    monkeypatch.setenv(ops.KERNEL_TIER_ENV, "interpret")
    assert ops.resolve_tier("window_score") == ops.INTERPRET_TIER


def test_resolve_tier_unavailable_request_downgrades_loudly(monkeypatch):
    avail = ops.available_tiers("window_score")
    if "pallas-tpu" in avail:
        pytest.skip("pallas-tpu available: nothing to downgrade")
    ops.clear_tier_cache()
    with pytest.warns(RuntimeWarning, match="NOT pallas-tpu timings"):
        got = ops.resolve_tier("window_score", "pallas-tpu")
    assert got == avail[0]


def test_autotune_microbench_caches_on_disk(monkeypatch, tmp_path):
    """Two candidate tiers -> one timed shoot-out, verdict cached in the
    on-disk table and the in-process memo (candidates never re-run)."""
    import time as _time

    cache = tmp_path / "kernel_tiers.json"
    monkeypatch.setenv(ops.AUTOTUNE_CACHE_ENV, str(cache))
    monkeypatch.delenv(ops.KERNEL_TIER_ENV, raising=False)
    monkeypatch.setattr(
        ops, "available_tiers", lambda op: ("pallas-cpu", "xla"))
    ops.clear_tier_cache()
    calls = {"pallas-cpu": 0, "xla": 0}

    def slow():
        calls["pallas-cpu"] += 1
        _time.sleep(0.02)
        return jnp.zeros(())

    def fast():
        calls["xla"] += 1
        return jnp.zeros(())

    cands = {"pallas-cpu": slow, "xla": fast}
    assert ops.resolve_tier("window_score", bucket="64x64",
                            candidates=cands) == "xla"
    assert calls["pallas-cpu"] > 0 and calls["xla"] > 0
    doc = json.loads(cache.read_text())
    [(key, entry)] = list(doc["entries"].items())
    assert key.startswith("window_score|64x64|") and entry["tier"] == "xla"
    assert set(entry["walls_s"]) == {"pallas-cpu", "xla"}
    # Second resolve: memoised, no re-benchmark.
    before = dict(calls)
    assert ops.resolve_tier("window_score", bucket="64x64",
                            candidates=cands) == "xla"
    assert calls == before
    # Fresh process simulation: memo cleared, disk table answers.
    ops.clear_tier_cache()
    assert ops.resolve_tier("window_score", bucket="64x64",
                            candidates=cands) == "xla"
    assert calls == before
    ops.clear_tier_cache()


def test_measured_score_cost_feeds_latency_model(monkeypatch, tmp_path):
    from repro.engine import latency_model

    monkeypatch.setenv(ops.AUTOTUNE_CACHE_ENV, str(tmp_path / "kt.json"))
    ops.clear_tier_cache()
    assert ops.measured_score_cost_s() is None
    # Record a wall for a 512x128 window_score bucket: 6.5536 ms / (512*128)
    # scores = 1e-7 s per score.
    ops.autotune_record(
        "window_score", "512x128", {"xla": lambda: jnp.zeros(())})
    memo_key = ("window_score", "512x128", jax.default_backend())
    ops._TIER_MEMO[memo_key]["walls_s"]["xla"] = 6.5536e-3
    cost = ops.measured_score_cost_s()
    assert cost == pytest.approx(1e-7)
    stats = dict(score_rows=1000, h2d_bytes=0)
    lat = latency_model.partition_latency(stats, m=1000, k=4)
    expect = 1000 * 4 * cost + 1000 * latency_model.EDGE_IO_COST_S
    assert lat == pytest.approx(expect)
    # The calibrated constant still rules when nothing was measured.
    ops.clear_tier_cache()
    monkeypatch.setenv(ops.AUTOTUNE_CACHE_ENV, str(tmp_path / "empty.json"))
    lat = latency_model.partition_latency(stats, m=1000, k=4)
    expect = 1000 * 4 * latency_model.SCORE_COST_S \
        + 1000 * latency_model.EDGE_IO_COST_S
    assert lat == pytest.approx(expect)
    ops.clear_tier_cache()


def test_flash_attention_ref_is_softmax_attention():
    """The oracle itself vs a literal softmax implementation."""
    rng = np.random.default_rng(3)
    b, h, t, dh = 1, 2, 16, 8
    q = rng.normal(size=(b, h, t, dh)).astype(np.float32)
    k = rng.normal(size=(b, h, t, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, t, dh)).astype(np.float32)
    out = np.asarray(kref.flash_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True))
    for bb in range(b):
        for hh in range(h):
            logits = q[bb, hh] @ k[bb, hh].T / np.sqrt(dh)
            mask = np.tril(np.ones((t, t), bool))
            logits = np.where(mask, logits, -1e30)
            p = np.exp(logits - logits.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            np.testing.assert_allclose(out[bb, hh], p @ v[bb, hh], rtol=1e-4,
                                       atol=1e-5)
