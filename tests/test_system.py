"""End-to-end system behaviour: the paper's pipeline and the LM framework.

Everything here is marked `slow` (full CLI runs, multi-minute together);
the quick profile is `pytest -m "not slow"`.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro.core import AdwiseConfig, hdrf_partition, partition_stream
from repro.engine import (
    PAPER_CLUSTER,
    build_partitioned_graph,
    pagerank,
    process_latency,
)
from repro.graph import make_graph, replica_sets_from_assignment, replication_degree


def _total_latency(edges, n, k, res, iters=300):
    g = build_partitioned_graph(edges, res.assign, n, k)
    model = process_latency(g, iters, 1, PAPER_CLUSTER)
    return res.stats["wall_time_s"], model["t_total_s"], g.replication_degree


def test_partition_process_pipeline_end_to_end(tiny_graph):
    """The paper's main claim in miniature: investing partitioning latency
    (ADWISE window) buys lower replication degree and thus lower modeled
    processing latency than single-edge streaming."""
    edges, n = tiny_graph
    k = 8
    res_adwise = partition_stream(edges, n, AdwiseConfig(k=k, window_max=64))
    res_hdrf = hdrf_partition(edges, n, k)
    _, proc_a, rd_a = _total_latency(edges, n, k, res_adwise)
    _, proc_h, rd_h = _total_latency(edges, n, k, res_hdrf)
    assert rd_a < rd_h
    assert proc_a < proc_h


def test_pagerank_correct_after_adwise_partitioning(tiny_graph):
    """PageRank on an ADWISE-partitioned graph equals the dense oracle —
    partitioning must never change workload results."""
    edges, n = tiny_graph
    edges = edges[:2000]
    k = 4
    res = partition_stream(edges, n, AdwiseConfig(k=k, window_max=32))
    g = build_partitioned_graph(edges, res.assign, n, k)
    pr, _ = pagerank(g, iters=5)
    deg = np.zeros(n)
    np.add.at(deg, edges[:, 0], 1)
    np.add.at(deg, edges[:, 1], 1)
    x = np.full(n, 1.0 / n)
    for _ in range(5):
        acc = np.zeros(n)
        np.add.at(acc, edges[:, 1], x[edges[:, 0]] / np.maximum(deg[edges[:, 0]], 1))
        np.add.at(acc, edges[:, 0], x[edges[:, 1]] / np.maximum(deg[edges[:, 1]], 1))
        x = 0.15 / n + 0.85 * acc
    np.testing.assert_allclose(pr, x, rtol=1e-4, atol=1e-7)


def test_train_cli_loss_decreases(tmp_path):
    from repro.launch.train import main

    losses = main([
        "--arch", "qwen1.5-0.5b", "--reduced", "--steps", "25",
        "--batch", "8", "--seq", "32", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path / "ck"),
    ])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_cli_resume_continues(tmp_path):
    from repro.launch.train import main

    main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "10",
          "--batch", "4", "--seq", "16", "--ckpt-dir", str(tmp_path / "ck"),
          "--ckpt-every", "5"])
    losses = main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "5",
                   "--batch", "4", "--seq", "16",
                   "--ckpt-dir", str(tmp_path / "ck"), "--resume"])
    assert len(losses) == 5


def test_train_cli_grad_compression_works(tmp_path):
    from repro.launch.train import main

    losses = main(["--arch", "qwen1.5-0.5b", "--reduced", "--steps", "15",
                   "--batch", "8", "--seq", "32", "--lr", "1e-2",
                   "--grad-compress", "0.1"])
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_serve_cli_generates():
    from repro.launch.serve import main

    gen = main(["--arch", "qwen1.5-0.5b", "--reduced", "--batch", "2",
                "--prompt-len", "8", "--gen", "6"])
    assert gen.shape == (2, 6)
    assert (gen >= 0).all()


def test_partition_cli_reports_total_latency(tmp_path, capsys):
    from repro.launch.partition import main

    out = main(["--graph", "tiny_clustered", "--strategy", "adwise",
                "--k", "8", "--workload", "pagerank", "--iters", "50",
                "--window-max", "32",
                "--json", str(tmp_path / "out.json")])
    assert out["replication_degree"] > 1.0
    assert out["total_latency_s"] > 0
    assert (tmp_path / "out.json").exists()


def test_spotlight_cli_parallel_loading():
    from repro.launch.partition import main

    out = main(["--graph", "tiny_clustered", "--strategy", "hdrf",
                "--k", "16", "--parallel", "4", "--spread", "4",
                "--workload", "none"])
    assert out["replication_degree"] > 0
