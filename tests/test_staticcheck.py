"""The trace-contract checker checked: per-rule fixtures (flagged + clean),
suppression parsing, baseline diffing, the CLI exit-code contract, and the
whole-repo gate (zero unsuppressed findings over src/ + tools/)."""
import json
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT) not in sys.path:  # `python -m pytest` adds cwd; be robust
    sys.path.insert(0, str(REPO_ROOT))

from tools.staticcheck import check_paths, check_source, run_selftest  # noqa: E402
from tools.staticcheck.__main__ import main as cli_main  # noqa: E402
from tools.staticcheck.engine import (  # noqa: E402
    load_baseline,
    new_findings,
    parse_suppressions,
    write_baseline,
)

FIXTURES = REPO_ROOT / "tools" / "staticcheck" / "fixtures"
CORE_PATH = "src/repro/core/virtual.py"  # activates path-filtered rules


def rules_of(findings, suppressed=False):
    return {f.rule for f in findings if f.suppressed == suppressed}


def check(snippet: str, path: str = CORE_PATH):
    return check_source(textwrap.dedent(snippet), path)


# ----------------------------------------------------------------------------
# per-rule: fixtures flag, clean variants stay silent
# ----------------------------------------------------------------------------


@pytest.mark.parametrize(
    "fixture,rule",
    [
        ("sc001_unfrozen_core.py", "SC001"),
        ("sc002_traced_branch.py", "SC002"),
        ("sc003_host_sync.py", "SC003"),
        ("sc004_legacy_rng.py", "SC004"),
        ("sc005_donated_read.py", "SC005"),
        ("sc006_interpret_literal.py", "SC006"),
    ],
)
def test_fixture_flags_only_its_rule(fixture, rule):
    found = check_source(
        (FIXTURES / fixture).read_text(), f"src/repro/core/{fixture}"
    )
    assert rules_of(found) == {rule}
    # every finding carries the rule's severity + a non-empty fix-it hint
    for f in found:
        assert f.severity == "error"
        assert f.hint


def test_clean_fixture_is_clean():
    found = check_source(
        (FIXTURES / "clean_core.py").read_text(), "src/repro/core/clean.py"
    )
    assert found == []


def test_selftest_passes():
    ok, lines = run_selftest()
    assert ok, "\n".join(lines)


def test_selftest_catches_a_broken_rule(tmp_path):
    """A fixture whose declared rule never fires must fail the self-test
    (the 'silently-broken checker' CI guard)."""
    f = tmp_path / "sc001_bogus.py"
    f.write_text("# staticcheck-fixture-expect: SC001\nx = 1\n")
    ok, lines = run_selftest(str(tmp_path))
    assert not ok
    assert any("sc001_bogus" in ln for ln in lines)


# ----------------------------------------------------------------------------
# targeted rule behavior beyond the fixtures
# ----------------------------------------------------------------------------


def test_sc001_frozen_stepcore_subclass_ok():
    found = check(
        """
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class FineCore(StepCore):
            k: int = 2
            gamma: float = 1.0
        """
    )
    assert rules_of(found) == set()


def test_sc002_branches_on_closure_constants_ok():
    """Python branching on *static* closure values (cfg flags, batch size)
    is the jit-specialization idiom and must not be flagged."""
    found = check(
        """
        def make_step(stream, lazy, b):
            def step(carry, _):
                if lazy:
                    carry = carry + 1
                if b == 1:
                    carry = carry + 2
                return carry, None
            return step
        """
    )
    assert rules_of(found) == set()


def test_sc003_materialize_after_loop_ok():
    found = check(
        """
        import numpy as np

        class ScanDriver:
            def _run_resident(self, run_chunk, n):
                carry = self.carry
                outs = []
                for _ in range(n):
                    carry, out = run_chunk(carry)
                    outs.append(out)
                return [np.asarray(o) for o in outs]
        """
    )
    assert rules_of(found) == set()


def test_sc004_only_applies_under_core():
    legacy = "import numpy as np\nnoise = np.random.rand(3)\n"
    assert rules_of(check_source(legacy, CORE_PATH)) == {"SC004"}
    assert rules_of(check_source(legacy, "src/repro/graph/other.py")) == set()
    seeded = "import numpy as np\nr = np.random.default_rng(0)\n"
    assert rules_of(check_source(seeded, CORE_PATH)) == set()


def test_sc005_rebind_is_clean_tuple_arg_tracked():
    found = check(
        """
        from functools import partial
        import jax

        @partial(jax.jit, donate_argnums=(0,))
        def run(cb, xs):
            return cb, xs

        def ok(carry, buf, xs):
            (carry, buf), out = run((carry, buf), xs)
            return carry, buf, out

        def bad(carry, buf, xs):
            (c2, b2), out = run((carry, buf), xs)
            return buf, out  # buf was inside the donated tuple
        """
    )
    sc5 = [f for f in found if f.rule == "SC005"]
    assert len(sc5) == 1
    assert "`buf`" in sc5[0].message


def test_sc006_flags_literal_but_exempts_kernel_modules():
    snippet = (
        "def f(kernel, x):\n"
        "    return pallas_call(kernel, interpret=True)(x)\n"
    )
    assert rules_of(check_source(snippet, CORE_PATH)) == {"SC006"}
    # The kernel modules own `interpret` as their debug parameter.
    for mod in ("window_score", "segment_sum", "flash_attention"):
        assert check_source(snippet, f"src/repro/kernels/{mod}.py") == []
    # Forwarding a variable (the dispatcher's decision) is always fine.
    fwd = (
        "def f(kernel, x, interpret):\n"
        "    return pallas_call(kernel, interpret=interpret)(x)\n"
    )
    assert rules_of(check_source(fwd, CORE_PATH)) == set()


# ----------------------------------------------------------------------------
# suppressions
# ----------------------------------------------------------------------------

LEGACY_LINE = "import numpy as np\n"


def test_suppression_same_line_with_reason():
    found = check_source(
        LEGACY_LINE
        + "x = np.random.rand(3)  # staticcheck: disable=SC004 parity oracle\n",
        CORE_PATH,
    )
    assert rules_of(found) == set()
    assert rules_of(found, suppressed=True) == {"SC004"}
    (f,) = found
    assert f.suppress_reason == "parity oracle"


def test_suppression_comment_line_above():
    found = check_source(
        LEGACY_LINE
        + "# staticcheck: disable=SC004 oracle noise, not core RNG\n"
        + "x = np.random.rand(3)\n",
        CORE_PATH,
    )
    assert rules_of(found) == set()
    assert rules_of(found, suppressed=True) == {"SC004"}


def test_suppression_without_reason_does_not_suppress():
    found = check_source(
        LEGACY_LINE + "x = np.random.rand(3)  # staticcheck: disable=SC004\n",
        CORE_PATH,
    )
    # the finding survives AND the reasonless suppression is itself flagged
    assert rules_of(found) == {"SC004", "SC000"}


def test_suppression_wrong_rule_does_not_suppress():
    found = check_source(
        LEGACY_LINE
        + "x = np.random.rand(3)  # staticcheck: disable=SC003 wrong rule\n",
        CORE_PATH,
    )
    assert rules_of(found) == {"SC004"}


def test_suppression_multiple_rules():
    lines, bad = parse_suppressions(
        ["y = f(x)  # staticcheck: disable=SC003,SC005 shared sync point"]
    )
    assert bad == []
    assert lines[1] == {
        "SC003": "shared sync point",
        "SC005": "shared sync point",
    }


# ----------------------------------------------------------------------------
# baseline diffing
# ----------------------------------------------------------------------------


def test_baseline_roundtrip(tmp_path):
    src = LEGACY_LINE + "x = np.random.rand(3)\n"
    found = check_source(src, CORE_PATH)
    assert rules_of(found) == {"SC004"}

    bl = tmp_path / "baseline.json"
    write_baseline(str(bl), found)
    assert new_findings(found, load_baseline(str(bl))) == []

    # a NEW finding (different source line) is not masked by the baseline
    grown = src + "y = np.random.randn(4)\n"
    found2 = check_source(grown, CORE_PATH)
    fresh = new_findings(found2, load_baseline(str(bl)))
    assert len(fresh) == 1 and "randn" not in json.dumps(
        [f.fingerprint for f in found]
    )


def test_fingerprint_stable_across_line_drift():
    src = LEGACY_LINE + "x = np.random.rand(3)\n"
    moved = "import os\n" + LEGACY_LINE + "\n\nx = np.random.rand(3)\n"
    fp1 = {f.fingerprint for f in check_source(src, CORE_PATH)}
    fp2 = {f.fingerprint for f in check_source(moved, CORE_PATH)}
    assert fp1 == fp2


# ----------------------------------------------------------------------------
# CLI + whole-repo gate
# ----------------------------------------------------------------------------


def test_cli_exit_codes(tmp_path):
    dirty = tmp_path / "core"
    dirty.mkdir()
    (dirty / "m.py").write_text("x = 0\n")
    assert cli_main([str(dirty)]) == 0
    (dirty / "bad.py").write_text(
        "from functools import partial\nimport jax\n\n"
        "@partial(jax.jit, donate_argnums=(0,))\n"
        "def f(c):\n    return c\n\n"
        "def g(c):\n    d = f(c)\n    return c\n"
    )
    assert cli_main([str(dirty)]) == 1
    assert cli_main(["--selftest"]) == 0


def test_repo_has_zero_unsuppressed_findings():
    """The CI gate, as a test: src/ and tools/ are clean (fixtures are
    excluded by the engine; intentional syncs carry justified inline
    suppressions, not baseline entries — the shipped baseline is empty)."""
    findings = check_paths([str(REPO_ROOT / "src"), str(REPO_ROOT / "tools")])
    fresh = [f for f in findings if not f.suppressed]
    assert fresh == [], "\n".join(f.render() for f in fresh)
    # every suppression in the tree carries a justification
    assert all(f.suppress_reason for f in findings if f.suppressed)
    shipped = load_baseline(
        str(REPO_ROOT / "tools" / "staticcheck" / "baseline.json")
    )
    assert shipped == set()
