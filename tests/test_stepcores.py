"""Registry-wide step-core parity: every partitioner is one scan driver.

Every registry strategy now runs as a device-resident, warm-startable
`lax.scan` step-core through the one :class:`repro.core.driver.ScanDriver`
(hash/dbh stay stateless vectorized assignments). The acceptance
properties, exercised on adversarial streams — self-loops, duplicate
edges, hub stars, the empty stream, and m < z (some spotlight instances
receive no edges):

* spotlight z>1: the batched backend (one vmapped program for all
  instances) == the loop backend (sequential per-instance registry calls)
  bit-for-bit, for EVERY registry strategy ('grid' excluded by design);
* file-driven == in-memory at z=1 and z=4 — the FileSource ring buffer and
  the ResidentSource feed the very same step trace;
* the scan cores are bit-identical to their per-edge numpy oracles
  (hdrf / greedy / 2ps-l keep their loops as parity references).
"""
import numpy as np
import pytest

from repro.core.oocore import partition_file
from repro.core.registry import run_partitioner
from repro.core.spotlight import spotlight_partition
from repro.core.types import AdwiseConfig
from repro.graph.io.format import EdgeFileReader, write_edge_file

N = 16
K = 8
Z, SPREAD = 4, 2
_SMALL = dict(window_max=8, window_init=2)

# (strategy, registry/partition_file cfg) — every registry strategy except
# 'grid' (rejected: a fixed vertex->partition hash cannot honor a spread
# mask; see repro.core.spotlight).
STRATEGIES = [
    ("hash", {}),
    ("dbh", {}),
    ("hdrf", {}),
    ("hdrf", dict(lam=1.5)),
    ("greedy", {}),
    ("adwise", dict(_SMALL)),
    ("adwise-restream", dict(_SMALL, passes=2)),
    ("2ps", dict(_SMALL)),
    ("2ps-l", {}),
    ("2ps-l", dict(lam=1.5, cap_slack=1.3)),
]
_IDS = [f"{s}-{i}" for i, (s, _) in enumerate(STRATEGIES)]


def _adversarial_streams():
    rng = np.random.default_rng(0)
    base = rng.integers(0, N, size=(48, 2)).astype(np.int32)
    mixed = base.copy()
    mixed[::3, 1] = mixed[::3, 0]  # self-loops
    mixed[24:36] = mixed[:12]      # duplicate edges
    star = np.stack(
        [np.zeros(40, np.int32),
         rng.integers(0, N, size=40).astype(np.int32)], axis=1,
    )  # one hub touches every edge
    empty = np.zeros((0, 2), np.int32)
    tiny = base[:3]  # m < z: split leaves instances without edges
    return dict(mixed=mixed, star=star, empty=empty, tiny=tiny)


STREAMS = _adversarial_streams()


def _spot(edges, strategy, cfg, backend):
    if strategy == "adwise":
        return spotlight_partition(
            edges, N, K, z=Z, spread=SPREAD, seed=1, strategy="adwise",
            cfg=AdwiseConfig(k=K, **cfg), backend=backend,
        )
    return spotlight_partition(
        edges, N, K, z=Z, spread=SPREAD, seed=1, strategy=strategy,
        strategy_cfg=cfg or None, backend=backend,
    )


@pytest.mark.parametrize("strategy,cfg", STRATEGIES, ids=_IDS)
def test_spotlight_batched_equals_loop_adversarial(strategy, cfg):
    for name, edges in STREAMS.items():
        batched = _spot(edges, strategy, cfg, "batched")
        loop = _spot(edges, strategy, cfg, "loop")
        assert np.array_equal(batched.assign, loop.assign), (strategy, name)
        assert batched.stats["backend"] != "loop"


@pytest.mark.parametrize("strategy,cfg", STRATEGIES, ids=_IDS)
def test_file_equals_memory_z1_and_z4(strategy, cfg, tmp_path):
    for name, edges in STREAMS.items():
        path = str(tmp_path / f"{name}.adw")
        write_edge_file(path, edges, N)
        ref1 = run_partitioner(strategy, edges, N, K, seed=1, **cfg)
        with EdgeFileReader(path) as r:
            res1 = partition_file(
                r, strategy, K, seed=1, chunk_edges=29,
                spill_dir=str(tmp_path / f"{name}-z1"), **cfg,
            )
        assert np.array_equal(np.asarray(res1.assign), ref1.assign), (
            strategy, name, "z=1")
        ref4 = _spot(edges, strategy, cfg, "auto")
        with EdgeFileReader(path) as r:
            res4 = partition_file(
                r, strategy, K, z=Z, spread=SPREAD, seed=1, chunk_edges=29,
                spill_dir=str(tmp_path / f"{name}-z4"), **cfg,
            )
        assert np.array_equal(np.asarray(res4.assign), ref4.assign), (
            strategy, name, "z=4")


_ORACLE_BACKED = [
    ("hdrf", {}),
    ("hdrf", dict(lam=1.5)),
    ("greedy", {}),
    ("2ps-l", {}),
    ("2ps-l", dict(lam=1.5, cap_slack=1.3)),
]


@pytest.mark.parametrize(
    "strategy,cfg", _ORACLE_BACKED,
    ids=[f"{s}-{i}" for i, (s, _) in enumerate(_ORACLE_BACKED)],
)
def test_scan_core_equals_numpy_oracle(strategy, cfg):
    streams = dict(STREAMS)
    for seed in range(3):
        rng = np.random.default_rng(100 + seed)
        streams[f"rand{seed}"] = rng.integers(
            0, N, size=(int(rng.integers(5, 120)), 2)).astype(np.int32)
    for name, edges in streams.items():
        scan = run_partitioner(strategy, edges, N, K, seed=2, scan=True, **cfg)
        oracle = run_partitioner(
            strategy, edges, N, K, seed=2, scan=False, **cfg)
        assert np.array_equal(scan.assign, oracle.assign), (strategy, name)
