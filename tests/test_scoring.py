"""Scoring function semantics (Eq. 3-7) — vectorized vs pen-and-paper."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import scoring


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 12))
def test_balance_score_eq3(seed, k):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, 100, k)
    allowed = np.ones(k, bool)
    b = np.asarray(scoring.balance_score(jnp.asarray(sizes), jnp.asarray(allowed), 0.01))
    mx, mn = sizes.max(), sizes.min()
    expect = (mx - sizes) / (mx - mn + 0.01)
    np.testing.assert_allclose(b, expect, rtol=1e-5)
    # Emptiest partition gets the max score; fullest gets ~0.
    assert b[sizes.argmin()] == b.max()
    assert b[sizes.argmax()] == b.min()


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_replication_score_eq5(seed):
    rng = np.random.default_rng(seed)
    w, k = 6, 4
    rep_u = rng.random((w, k)) < 0.4
    rep_v = rng.random((w, k)) < 0.4
    deg_u = rng.integers(1, 20, w)
    deg_v = rng.integers(1, 20, w)
    max_deg = max(int(deg_u.max()), int(deg_v.max()))
    r = np.asarray(scoring.replication_score(
        jnp.asarray(rep_u), jnp.asarray(rep_v),
        jnp.asarray(deg_u), jnp.asarray(deg_v), jnp.int32(max_deg)))
    for i in range(w):
        psi_u = deg_u[i] / (2 * max_deg)
        psi_v = deg_v[i] / (2 * max_deg)
        for p in range(k):
            expect = rep_u[i, p] * (2 - psi_u) + rep_v[i, p] * (2 - psi_v)
            assert abs(r[i, p] - expect) < 1e-5


def test_replication_prefers_high_degree_replication():
    """Eq. 5 intuition (Fig. 5): replicating the HIGH-degree vertex scores
    higher ⇒ the partitioner cuts through hubs."""
    rep = jnp.asarray([[True]])
    lo = scoring.replication_score(rep, jnp.asarray([[False]]),
                                   jnp.asarray([2]), jnp.asarray([2]), jnp.int32(10))
    hi = scoring.replication_score(rep, jnp.asarray([[False]]),
                                   jnp.asarray([10]), jnp.asarray([2]), jnp.int32(10))
    # Low-degree u already on p ⇒ HIGHER score than high-degree u on p:
    # assigning here keeps low-degree vertices local, replicates hubs.
    assert float(lo[0, 0]) > float(hi[0, 0])


def test_clustering_score_eq6_example():
    """Figure 6 of the paper: u has 3 window-neighbours replicated on p1, one
    on p2 ⇒ CS(e, p1) > CS(e, p2)."""
    # window: edge 0 = (u=0, v=1); edges 1-3 connect u to 2,3,4; edge 4: u-5.
    win_uv = jnp.asarray([[0, 1], [0, 2], [0, 3], [0, 4], [0, 5]])
    win_valid = jnp.ones(5, bool)
    k = 2
    # Neighbour replica rows: rep_v[j] = replicas of v_j (2,3,4 on p0; 5 on p1).
    rep_v = jnp.asarray([[0, 0], [1, 0], [1, 0], [1, 0], [0, 1]], jnp.float32)
    rep_u = jnp.zeros((5, k), jnp.float32)
    num, den = scoring.clustering_terms(win_uv, win_valid, rep_u, rep_v)
    cs = np.asarray(num / np.maximum(np.asarray(den)[:, None], 1.0))
    assert cs[0, 0] > cs[0, 1]
    assert abs(cs[0, 0] - 3 / 4) < 1e-6  # 3 of 4 window-neighbours on p0


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_lambda_update_eq4(seed):
    rng = np.random.default_rng(seed)
    k = 8
    sizes = rng.integers(0, 1000, k)
    assigned = int(rng.integers(1, 2000))
    m = 2000
    lam0 = 1.0
    lam1 = float(scoring.lambda_update(
        jnp.float32(lam0), jnp.asarray(sizes), jnp.ones(k, bool),
        jnp.int32(assigned), jnp.int32(m), 0.4, 5.0))
    mx, mn = sizes.max(), sizes.min()
    iota = (mx - mn) / mx if mx > 0 else 0.0
    tol = max(0.0, 1.0 - assigned / m)
    expect = np.clip(lam0 + (iota - tol), 0.4, 5.0)
    assert abs(lam1 - expect) < 1e-5
    assert 0.4 <= lam1 <= 5.0


def test_lambda_dynamics_monotone():
    """Early stream + balanced ⇒ λ decreases; late stream + imbalanced ⇒ λ
    increases (the paper's two requirements in §III-C)."""
    k = 4
    balanced = jnp.asarray([100, 100, 100, 100])
    imbalanced = jnp.asarray([400, 10, 10, 10])
    allowed = jnp.ones(k, bool)
    early_bal = float(scoring.lambda_update(
        jnp.float32(1.0), balanced, allowed, jnp.int32(10), jnp.int32(1000), 0.4, 5.0))
    late_imb = float(scoring.lambda_update(
        jnp.float32(1.0), imbalanced, allowed, jnp.int32(990), jnp.int32(1000), 0.4, 5.0))
    assert early_bal < 1.0
    assert late_imb > 1.0
