"""Per-arch smoke tests (reduced configs) + model-math oracles.

Every assigned architecture instantiates its reduced-config family variant,
runs one forward/train step on CPU, asserts output shapes + finite values,
and checks prefill/decode consistency against the train forward.
"""
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.models.layers import moe_ffn, init_moe
from repro.models.ssm import _rwkv6_chunk_scan, _ssd_chunk_scan

ALL_ARCHS = [
    "rwkv6-7b", "llama3.2-3b", "phi3-mini-3.8b", "qwen1.5-110b",
    "qwen1.5-0.5b", "zamba2-7b", "whisper-tiny", "granite-moe-1b-a400m",
    "grok-1-314b", "internvl2-26b",
]


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)))}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s // 2, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.vlm_patches, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(zlib.crc32(arch.encode()))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, metrics = lm.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g, np.float32)).all()
    # Logit shapes.
    inp = dict(batch)
    inp["tokens"] = batch["tokens"][:, :-1]
    logits, _ = lm.forward_train(params, cfg, inp, remat=False)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_prefill_decode_consistency(arch):
    """prefill(S-1) + decode(1) logits == train-forward logits."""
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(1 + zlib.crc32(arch.encode()))
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 12
    batch = _batch(cfg, rng, b, s)
    inp = dict(batch)
    inp["tokens"] = batch["tokens"][:, :-1]
    ref_logits, _ = lm.forward_train(params, cfg, inp, remat=False)

    cache = lm.init_cache(cfg, b, s + 4)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kw["patches"] = batch["patches"]
    lp, cache = lm.forward_cached(
        params, cfg, cache, batch["tokens"][:, : s - 1], jnp.int32(0), **kw)
    np.testing.assert_allclose(
        np.asarray(lp, np.float32), np.asarray(ref_logits[:, : s - 1], np.float32),
        rtol=2e-3, atol=2e-3)
    pos = (cfg.vlm_patches if cfg.family == "vlm" else 0) + s - 1
    ld, _ = lm.forward_cached(
        params, cfg, cache, batch["tokens"][:, s - 1 : s], jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32), np.asarray(ref_logits[:, s - 1], np.float32),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "rwkv6-7b", "zamba2-7b",
                                  "granite-moe-1b-a400m", "whisper-tiny"])
def test_unroll_equals_scan(arch):
    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(7)
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, rng)
    l_scan, _ = lm.loss_fn(params, cfg, batch, unroll=False)
    l_unroll, _ = lm.loss_fn(params, cfg, batch, unroll=True)
    assert abs(float(l_scan) - float(l_unroll)) < 1e-4


def test_head_padding_preserves_function():
    """tp-padded attention heads (llama 24→32) must not change outputs."""
    cfg = get_config("llama3.2-3b").reduced()
    # reduced has 4 heads; tp=8 pads to 8 (policy 'pad' since 4 % 8 != 0).
    rng = np.random.default_rng(9)
    batch = _batch(cfg, rng)
    p1 = lm.init_params(cfg, jax.random.PRNGKey(3), tp=1)
    l1, _ = lm.loss_fn(p1, cfg, batch, tp=1)
    assert np.isfinite(float(l1))
    dims8 = lm.model_dims(cfg, tp=8)
    assert dims8.policy in ("pad", "replicate", "shard", "shard_q")
    p8 = lm.init_params(cfg, jax.random.PRNGKey(3), tp=8)
    l8, _ = lm.loss_fn(p8, cfg, batch, tp=8)
    assert np.isfinite(float(l8))


def test_moe_dispatch_matches_dense_oracle():
    """Sort-based capacity MoE == explicit per-token loop (ample capacity)."""
    rng = np.random.default_rng(11)
    d, ff, e, k, t = 16, 32, 4, 2, 24
    params = init_moe(jax.random.PRNGKey(4), d, ff, e, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, t, d)).astype(np.float32))
    out, aux, counts = moe_ffn(params, x, n_experts=e, top_k=k,
                               capacity_factor=8.0)
    # Oracle: per-token dense computation of the same top-k mixture.
    logits = np.asarray(x[0] @ params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    expect = np.zeros((t, d), np.float32)
    for i in range(t):
        top = np.argsort(-probs[i])[:k]
        w = probs[i][top] / probs[i][top].sum()
        for wj, ej in zip(w, top):
            h = np.asarray(x[0, i] @ params["w_gate"][ej])
            h = h / (1 + np.exp(-h)) * np.asarray(x[0, i] @ params["w_up"][ej])
            expect[i] += wj * np.asarray(h @ params["w_down"][ej])
    np.testing.assert_allclose(np.asarray(out[0]), expect, rtol=2e-4, atol=2e-4)
    assert counts.sum() == t * k


def test_moe_capacity_drops_overflow():
    rng = np.random.default_rng(13)
    d, ff, e, k, t = 8, 16, 4, 1, 64
    params = init_moe(jax.random.PRNGKey(5), d, ff, e, jnp.float32)
    # Force all tokens to expert 0: positive inputs x a large positive col.
    params["router"] = params["router"].at[:, 0].set(100.0)
    x = jnp.asarray(np.abs(rng.normal(size=(1, t, d))).astype(np.float32))
    out, aux, counts = moe_ffn(params, x, n_experts=e, top_k=k,
                               capacity_factor=0.5)
    cap = max(8, -(-int(0.5 * t * k / e) // 8) * 8)
    # Overflowing tokens produce zero output rows (dropped), not garbage.
    assert np.isfinite(np.asarray(out)).all()
    zero_rows = (np.abs(np.asarray(out[0])).max(axis=1) < 1e-9).sum()
    assert zero_rows >= t - cap


def test_rwkv6_chunk_equals_naive_recurrence():
    rng = np.random.default_rng(17)
    b, t, h, n = 2, 21, 2, 4
    r, k, v = (rng.normal(size=(b, t, h, n)).astype(np.float32) * 0.5
               for _ in range(3))
    w = rng.uniform(0.7, 0.999, size=(b, t, h, n)).astype(np.float32)
    u = rng.normal(size=(h, n)).astype(np.float32) * 0.3
    s0 = np.zeros((b, h, n, n), np.float32)
    o, sf = _rwkv6_chunk_scan(jnp.asarray(r), jnp.asarray(k), jnp.asarray(v),
                              jnp.log(jnp.asarray(w)), jnp.asarray(u),
                              jnp.asarray(s0), chunk=8)
    s = s0.copy()
    for ti in range(t):
        for bi in range(b):
            for hi in range(h):
                rt, kt, vt, wt = r[bi, ti, hi], k[bi, ti, hi], v[bi, ti, hi], w[bi, ti, hi]
                expect = s[bi, hi].T @ rt + (rt * u[hi] * kt).sum() * vt
                np.testing.assert_allclose(np.asarray(o[bi, ti, hi]), expect,
                                           rtol=1e-4, atol=1e-5)
                s[bi, hi] = wt[:, None] * s[bi, hi] + np.outer(kt, vt)
    np.testing.assert_allclose(np.asarray(sf), s, rtol=1e-4, atol=1e-5)


def test_ssd_chunk_equals_naive_recurrence():
    rng = np.random.default_rng(19)
    b, t, h, n, p = 1, 19, 2, 4, 6
    xh = rng.normal(size=(b, t, h, p)).astype(np.float32) * 0.5
    bc = rng.normal(size=(b, t, n)).astype(np.float32) * 0.5
    cc = rng.normal(size=(b, t, n)).astype(np.float32) * 0.5
    a = rng.uniform(0.6, 0.999, size=(b, t, h)).astype(np.float32)
    s0 = np.zeros((b, h, n, p), np.float32)
    y, sf = _ssd_chunk_scan(jnp.asarray(xh), jnp.asarray(bc), jnp.asarray(cc),
                            jnp.log(jnp.asarray(a)), jnp.asarray(s0), chunk=4)
    s = s0.copy()
    for ti in range(t):
        for bi in range(b):
            for hi in range(h):
                s[bi, hi] = a[bi, ti, hi] * s[bi, hi] + np.outer(bc[bi, ti], xh[bi, ti, hi])
                np.testing.assert_allclose(np.asarray(y[bi, ti, hi]),
                                           s[bi, hi].T @ cc[bi, ti],
                                           rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sf), s, rtol=1e-4, atol=1e-5)


def test_param_count_formula_close():
    """ArchConfig.param_count() tracks the real init within 10% (reduced)."""
    for arch in ["llama3.2-3b", "qwen1.5-0.5b", "granite-moe-1b-a400m",
                 "rwkv6-7b"]:
        cfg = get_config(arch).reduced()
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        real = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(est - real) / real < 0.15, (arch, est, real)
