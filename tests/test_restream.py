"""Multi-pass re-streaming (restream.py) + registry-wide assignment invariants.

The property tests run under the vendored `tests/_propcheck.py` shim when
`hypothesis` is absent (seeded sampling, no shrinking) — same invariants
either way. Streams are adversarial by construction: self-loops, duplicate
edges, star graphs (which stall the vertex-disjoint top-b pick), empty
streams and streams shorter than the assign batch.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AdwiseConfig,
    available_strategies,
    partition_stream,
    restream_partition,
    run_partitioner,
    warm_from_assignment,
)
from repro.core.adwise import Carry
from repro.core.restream import streaming_vertex_clustering
from repro.graph import (
    partition_balance,
    replica_sets_from_assignment,
    replication_degree,
)

N, M = 24, 60  # fixed shapes so the scan compiles once per (k, warm) pair


def _rd(edges, assign, n, k):
    return replication_degree(replica_sets_from_assignment(edges, assign, n, k))


def _adversarial_stream(kind: str, seed: int) -> np.ndarray:
    """(M, 2) int32 stream over N vertices; every kind is a worst case."""
    rng = np.random.default_rng(seed)
    if kind == "random":
        uv = rng.integers(0, N, (M, 2))
    elif kind == "self_loops":
        u = rng.integers(0, N, M)
        v = np.where(rng.random(M) < 0.5, u, rng.integers(0, N, M))
        uv = np.stack([u, v], axis=1)
    elif kind == "duplicates":
        base = rng.integers(0, N, (4, 2))
        uv = base[rng.integers(0, 4, M)]
    elif kind == "star":
        center = int(rng.integers(0, N))
        leaves = rng.integers(0, N, M)
        uv = np.stack([np.full(M, center), leaves], axis=1)
    else:  # pragma: no cover
        raise ValueError(kind)
    return uv.astype(np.int32)


# Shared strategy cfg: small windows so every adwise-family strategy reuses
# one compiled scan per (k, warm) combination.
def _cfg_for(name: str) -> dict:
    if name in ("adwise", "adwise-restream", "2ps"):
        cfg = dict(window_max=8, window_init=2)
        if name == "adwise-restream":
            cfg["passes"] = 2
        return cfg
    return {}


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    kind=st.sampled_from(["random", "self_loops", "duplicates", "star"]),
    k=st.sampled_from([2, 5]),
)
def test_registry_wide_no_unassigned(seed, kind, k):
    """Every registered strategy returns assign in [0, k) — never -1."""
    edges = _adversarial_stream(kind, seed)
    for name in available_strategies():
        res = run_partitioner(name, edges, N, k, seed=seed, **_cfg_for(name))
        assert res.assign.shape == (M,), name
        assert res.assign.dtype == np.int32, name
        assert (res.assign >= 0).all() and (res.assign < k).all(), (
            f"{name} on {kind}: assign outside [0, {k})"
        )


@pytest.mark.parametrize("name", [
    "adwise", "adwise-restream", "2ps", "hdrf", "dbh", "greedy", "hash", "grid",
])
def test_registry_empty_stream(name):
    edges = np.zeros((0, 2), np.int32)
    res = run_partitioner(name, edges, 10, 4, **_cfg_for(name))
    assert res.assign.shape == (0,)


def test_registry_stream_shorter_than_assign_batch():
    edges = np.array([[0, 1], [2, 3]], np.int32)
    for name in ("adwise", "adwise-restream"):
        cfg = dict(_cfg_for(name), assign_batch=4)
        res = run_partitioner(name, edges, 5, 3, **cfg)
        assert (res.assign >= 0).all() and (res.assign < 3).all()


def test_star_graph_batched_drain_assigns_everything():
    """Regression: the static steps_total heuristic under-provisioned scan
    steps when the vertex-disjoint top-b pick stalls (star + assign_batch>1);
    edges were silently left at -1. The bounded drain loop must finish."""
    m = 100
    edges = np.stack(
        [np.zeros(m, np.int32), np.arange(1, m + 1, dtype=np.int32)], axis=1
    )
    for b in (2, 8):
        cfg = AdwiseConfig(k=4, window_max=16, assign_batch=b)
        res = partition_stream(edges, m + 1, cfg)
        assert res.stats["unassigned"] == 0
        assert (res.assign >= 0).all()


# ----------------------------------------------------------------------------
# Re-streaming semantics
# ----------------------------------------------------------------------------

def test_warm_start_carry_fields():
    cfg = AdwiseConfig(k=3, window_max=4)
    v = 6
    replicas = np.zeros((v, 3), bool)
    replicas[1, 2] = True
    deg = np.arange(v)
    sizes = np.array([5, 1, 2])
    carry = Carry.warm_start(cfg, v, 0.0, replicas=replicas, deg=deg, sizes=sizes)
    assert carry.replicas.shape == (v + 1, 3)  # scatter-dump row appended
    assert bool(carry.replicas[1, 2]) and not bool(carry.replicas[v].any())
    assert carry.deg[:v].tolist() == deg.tolist()
    assert int(carry.max_deg) == v - 1
    assert carry.sizes.tolist() == sizes.tolist()
    assert float(carry.lam) == cfg.lam_init  # λ re-anneals each pass
    assert int(carry.assigned) == 0


def test_warm_from_assignment_round_trip(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:400]
    k = 4
    res = run_partitioner("hdrf", edges, n, k)
    warm = warm_from_assignment(edges, res.assign, n, k)
    assert warm.replicas.shape == (n, k)
    assert warm.sizes.sum() == len(edges)
    assert (warm.deg >= 0).all() and warm.deg.sum() == 2 * len(edges)
    assert warm.prev_assign is not None
    # A warm pass over the same stream stays valid and balanced.
    res2 = partition_stream(edges, n, AdwiseConfig(k=k, window_max=16), warm=warm)
    assert (res2.assign >= 0).all() and (res2.assign < k).all()
    assert partition_balance(res2.assign, k) < 0.5


def test_restream_pass2_not_worse_fixed_seeds(tiny_graph):
    """Pass-2 replication degree <= pass 1 on a fixed seed set (keep_best
    guarantees the *returned* assignment; pass_rd records the trajectory)."""
    edges, n = tiny_graph
    edges = edges[:1000]
    k = 8
    for seed in (0, 1, 2):
        res = restream_partition(
            edges, n, k, passes=2, seed=seed, window_max=32, window_init=8
        )
        pass_rd = res.stats["pass_rd"]
        assert len(pass_rd) == 2
        rd_final = _rd(edges, res.assign, n, k)
        assert rd_final <= pass_rd[0] + 1e-9
        assert rd_final == pytest.approx(min(pass_rd), abs=1e-9)


def test_restream_matches_single_pass_at_passes_one(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:600]
    k = 4
    cfg = dict(window_max=16, window_init=4)
    res1 = run_partitioner("adwise", edges, n, k, **cfg)
    resr = run_partitioner("adwise-restream", edges, n, k, passes=1, **cfg)
    np.testing.assert_array_equal(res1.assign, resr.assign)


def test_restream_base_strategy(tiny_graph):
    """Pass 1 may be any registered strategy; later passes are warm ADWISE."""
    edges, n = tiny_graph
    edges = edges[:600]
    k = 4
    res = restream_partition(
        edges, n, k, passes=2, base="hdrf", window_max=16, window_init=4
    )
    assert res.stats["base"] == "hdrf"
    assert (res.assign >= 0).all() and (res.assign < k).all()
    assert _rd(edges, res.assign, n, k) <= res.stats["pass_rd"][0] + 1e-9


def test_restream_stats_shape(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:600]
    res = restream_partition(edges, n, 4, passes=3, window_max=16, window_init=4)
    st_ = res.stats
    assert st_["passes"] == 3
    assert len(st_["pass_rd"]) == len(st_["pass_wall_s"]) == 3
    assert len(st_["pass_score_rows"]) == 3
    assert st_["score_rows"] == sum(st_["pass_score_rows"])
    assert 1 <= st_["best_pass"] <= 3
    assert st_["unassigned"] == 0


def test_restream_rejects_bad_cfg():
    edges = np.array([[0, 1]], np.int32)
    with pytest.raises(TypeError, match="unknown config"):
        run_partitioner("adwise-restream", edges, 2, 2, windw_max=8)
    with pytest.raises(ValueError, match="passes"):
        restream_partition(edges, 2, 2, passes=0)


# ----------------------------------------------------------------------------
# 2PS
# ----------------------------------------------------------------------------

def test_2ps_round_trip(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:800]
    k = 8
    res = run_partitioner("2ps", edges, n, k)
    assert (res.assign >= 0).all() and (res.assign < k).all()
    assert res.stats["name"] == "2ps"
    assert res.stats["n_clusters"] >= 1
    assert partition_balance(res.assign, k) < 0.5


def test_2ps_clustering_invariants(tiny_graph):
    edges, n = tiny_graph
    edges = edges[:800]
    k = 8
    cl, vols = streaming_vertex_clustering(edges, n, k)
    streamed = np.zeros(n, bool)
    streamed[edges.ravel()] = True
    assert (cl[streamed] >= 0).all()  # every streamed vertex is clustered
    assert (cl[~streamed] == -1).all()
    # Volumes are consistent with membership: vol[c] == sum deg over members.
    deg = np.zeros(n, np.int64)
    np.add.at(deg, edges[:, 0], 1)
    np.add.at(deg, edges[:, 1], 1)
    recomputed = np.zeros(len(vols))
    for v_id in np.flatnonzero(streamed):
        recomputed[cl[v_id]] += deg[v_id]
    np.testing.assert_allclose(recomputed, vols)


def test_2ps_cluster_affinity_lowers_replication(tiny_graph):
    """On a clustered graph, 2PS (phase-1 knowledge) beats single-edge
    streaming quality — the point of investing a clustering pass."""
    edges, n = tiny_graph
    k = 8
    rd_2ps = _rd(edges, run_partitioner("2ps", edges, n, k).assign, n, k)
    rd_hdrf = _rd(edges, run_partitioner("hdrf", edges, n, k).assign, n, k)
    assert rd_2ps < rd_hdrf


def test_spotlight_forwards_restream_cfg(tiny_graph):
    """Spotlight parallel loading composes with re-streaming strategies and
    forwards their cfg (regression: strategy_cfg used to be dropped)."""
    from repro.core import spotlight_partition, spread_mask

    edges, n = tiny_graph
    edges = edges[:400]
    k, z, spread = 8, 2, 4
    res = spotlight_partition(
        edges, n, k, z=z, spread=spread, strategy="adwise-restream",
        strategy_cfg=dict(passes=2, window_max=8, window_init=2),
    )
    assert (res.assign >= 0).all() and (res.assign < k).all()
    # Each instance stayed inside its spread block.
    from repro.graph.stream import EdgeStream
    bounds = EdgeStream.split_bounds(len(edges), z)
    for i in range(z):
        allowed = set(np.flatnonzero(spread_mask(k, z, i, spread)))
        assert set(np.unique(res.assign[bounds[i]:bounds[i + 1]])) <= allowed


def test_2ps_rejects_bad_cfg():
    edges = np.array([[0, 1]], np.int32)
    with pytest.raises(TypeError, match="unknown config"):
        run_partitioner("2ps", edges, 2, 2, cluster_slck=1.0)
