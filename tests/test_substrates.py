"""Data pipeline, optimizer, compression, checkpoint, runtime fault tolerance."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.moe_balance import adwise_router_bias, init_moe_balance, update_loads
from repro.data import SyntheticTokens
from repro.optim import (
    adamw_init,
    adamw_update,
    cosine_schedule,
    topk_compress_allreduce,
)
from repro.runtime import (
    FaultTolerantLoop,
    StepFailure,
    StragglerMonitor,
    plan_mesh,
    replan_after_failure,
)


# ----------------------------------------------------------------------------
# Data pipeline
# ----------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    cfg = get_config("qwen1.5-0.5b").reduced()
    shape = ShapeConfig("t", 32, 8, "train")
    a = SyntheticTokens(cfg, shape, seed=3).batch_at(7)
    b = SyntheticTokens(cfg, shape, seed=3).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(cfg, shape, seed=4).batch_at(7)
    assert (a["tokens"] != c["tokens"]).any()


def test_data_shards_disjoint_and_consistent():
    """Shard i of 4 must equal rows [i*b/4, (i+1)*b/4) of the global batch."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    shape = ShapeConfig("t", 16, 8, "train")
    full = SyntheticTokens(cfg, shape, seed=0, shard=(0, 1)).batch_at(3)["tokens"]
    parts = [
        SyntheticTokens(cfg, shape, seed=0, shard=(i, 4)).batch_at(3)["tokens"]
        for i in range(4)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), full)


def test_data_zipf_skew():
    cfg = get_config("qwen1.5-0.5b").reduced()
    shape = ShapeConfig("t", 256, 16, "train")
    toks = SyntheticTokens(cfg, shape, seed=0).batch_at(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < cfg.vocab


# ----------------------------------------------------------------------------
# Optimizer
# ----------------------------------------------------------------------------

def test_adamw_matches_reference_step():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))}
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)) * 0.01}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, new_st = adamw_update(g, st, p, jnp.float32(lr), clip_norm=1e9,
                                 weight_decay=wd)
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - b1), v / (1 - b2)
    expect = np.asarray(p["w"]) - lr * (mh / (np.sqrt(vh) + eps) + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_st["step"]) == 1


def test_adamw_clips_global_norm():
    p = {"w": jnp.zeros((10,), jnp.float32)}
    g = {"w": jnp.full((10,), 100.0)}
    st = adamw_init(p)
    new_p, _ = adamw_update(g, st, p, jnp.float32(1.0), clip_norm=1.0,
                            weight_decay=0.0)
    # With clipping the effective |g| per element is tiny; update ≈ lr·sign.
    assert np.abs(np.asarray(new_p["w"])).max() <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-4


def test_topk_compression_error_feedback_recovers_sum():
    """Over many steps, compressed updates + residual ≈ exact sum (EF-SGD)."""
    rng = np.random.default_rng(5)
    gsum = np.zeros(64, np.float32)
    csum = np.zeros(64, np.float32)
    residual = {"w": jnp.zeros(64, jnp.float32)}
    for _ in range(60):
        g = rng.normal(size=64).astype(np.float32)
        gsum += g
        out, residual = topk_compress_allreduce(
            {"w": jnp.asarray(g)}, residual, None, ratio=0.25)
        csum += np.asarray(out["w"])
    # Residual bound: |exact - compressed| == |residual| (telescoping).
    np.testing.assert_allclose(csum + np.asarray(residual["w"]), gsum, rtol=1e-4)


# ----------------------------------------------------------------------------
# Checkpoint manager
# ----------------------------------------------------------------------------

def test_checkpoint_roundtrip_bitexact(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=True)
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32)}}
    ckpt.save(10, tree, meta={"x": 1})
    ckpt.wait()
    restored, manifest = ckpt.restore(tree)
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(restored["a"]))
    np.testing.assert_array_equal(np.asarray(tree["b"]["c"]),
                                  np.asarray(restored["b"]["c"]))
    assert manifest["step"] == 10 and manifest["meta"]["x"] == 1


def test_checkpoint_keep_k_and_latest(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree)
    assert ckpt.all_steps() == [3, 4]
    assert ckpt.latest_step() == 4


def test_checkpoint_ignores_partial_writes(tmp_path):
    ckpt = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    tree = {"a": jnp.zeros(3)}
    ckpt.save(5, tree)
    # Simulate a crashed writer: a stale .tmp dir must be invisible.
    os.makedirs(tmp_path / "step_000000009.tmp-999", exist_ok=True)
    assert ckpt.latest_step() == 5


# ----------------------------------------------------------------------------
# Runtime: fault tolerance, elasticity, stragglers
# ----------------------------------------------------------------------------

def _mini_loop(tmp_path, failures):
    state = {"x": 0.0}
    saved = {}

    def step_fn(st, batch):
        return {"x": st["x"] + 1.0}, {"loss": 1.0 / (st["x"] + 1.0)}

    def save_fn(step, st):
        saved["ckpt"] = (step, dict(st))

    def restore_fn():
        step, st = saved["ckpt"]
        return dict(st), step

    fired = set()

    def failure_hook(step):
        if step in failures and step not in fired:
            fired.add(step)
            raise StepFailure(failures[step], f"injected at {step}")

    loop = FaultTolerantLoop(step_fn, save_fn, restore_fn, ckpt_every=2,
                             failure_hook=failure_hook)
    save_fn(0, state)
    return loop, loop.run(state, lambda s: None, 0, 10)


def test_fault_loop_transient_retry(tmp_path):
    loop, (state, hist) = _mini_loop(tmp_path, {3: "transient"})
    assert loop.stats.retries == 1
    assert loop.stats.restores == 0
    assert len(hist) == 10 and state["x"] == 10.0


def test_fault_loop_fatal_restores(tmp_path):
    loop, (state, hist) = _mini_loop(tmp_path, {5: "fatal"})
    assert loop.stats.restores == 1
    assert state["x"] == 10.0  # converged to the same end state post-restore


def test_fault_loop_nan_skips_batch(tmp_path):
    state = {"x": 0.0}
    saved = {}

    def step_fn(st, batch):
        loss = float("nan") if batch == 4 else 1.0
        return {"x": st["x"] + 1.0}, {"loss": loss}

    def save_fn(step, st):
        saved["ckpt"] = (step, dict(st))

    def restore_fn():
        return dict(saved["ckpt"][1]), saved["ckpt"][0]

    loop = FaultTolerantLoop(step_fn, save_fn, restore_fn, ckpt_every=2)
    save_fn(0, state)
    state, hist = loop.run(state, lambda s: s, 0, 10)
    assert loop.stats.skipped_data_steps == 1
    assert loop.stats.restores == 1


def test_elastic_plan_and_replan():
    plan = plan_mesh(512, model_parallel=16, pods=2)
    assert plan.shape == (2, 16, 16) and plan.chips == 512
    # Lose 3 chips -> lose 1 TP group; keep global batch via accumulation.
    new = replan_after_failure(plan, lost_chips=3, global_batch=256)
    assert new is not None
    assert new.chips < plan.chips
    assert new.model == 16
    assert 256 % (new.pod * new.data) == 0
    assert new.grad_accum * new.pod * new.data >= plan.pod * plan.data


def test_straggler_monitor_rebalances_and_evicts():
    mon = StragglerMonitor(hosts=4, microbatches_per_host=4, evict_after=3)
    times = np.array([1.0, 1.0, 1.0, 1.0])
    decision = None
    for step in range(20):
        t = times.copy() * mon.alloc / 4
        t[2] *= 2.5  # host 2 is persistently slow
        decision = mon.observe(t)
    assert decision.flagged_host == 2
    assert decision.evict
    assert mon.alloc[2] < 4 and mon.alloc.sum() == 16


# ----------------------------------------------------------------------------
# ADWISE ↔ MoE balance bridge (beyond-paper)
# ----------------------------------------------------------------------------

def test_adwise_router_bias_counteracts_imbalance():
    st = init_moe_balance(4)
    st = update_loads(st, jnp.asarray([100.0, 10.0, 10.0, 10.0]))
    bias, st = adwise_router_bias(st, progress=jnp.float32(0.9))
    b = np.asarray(bias)
    assert b[0] == b.min()  # overloaded expert is penalized
    assert b[1:].max() == b.max()
    # λ respects the paper's clip interval.
    assert 0.4 <= float(st.lam) <= 5.0
